//! Discrete-event engine.
//!
//! A minimal, deterministic event queue: events are `(time, payload)`
//! pairs; ties break by insertion order so runs are reproducible. The
//! engine is generic over the payload type — each subsystem defines its
//! own event enum and runs its own dispatch loop, which keeps borrows
//! local (no `dyn FnMut(&mut World)` contortions).
//!
//! Cancellation is supported through tombstones: `cancel(id)` marks the
//! event dead and `pop()` skips it.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use crate::sim::time::SimTime;

/// Handle for a scheduled event, usable with [`Engine::cancel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

#[derive(Debug)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

// BinaryHeap is a max-heap; order by Reverse((at, seq)) for earliest-first.
impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        Reverse((self.at, self.seq)).cmp(&Reverse((other.at, other.seq)))
    }
}

/// Deterministic discrete-event queue.
#[derive(Debug)]
pub struct Engine<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    cancelled: HashSet<u64>,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine {
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            cancelled: HashSet::new(),
            processed: 0,
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total number of events dispatched so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of pending (non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.heap.len() - self.cancelled.len()
    }

    /// Schedule `payload` at absolute time `at`. Scheduling in the past
    /// (before `now`) is clamped to `now` — this models "immediate"
    /// events without violating causality.
    pub fn schedule_at(&mut self, at: SimTime, payload: E) -> EventId {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, payload });
        EventId(seq)
    }

    /// Schedule `payload` after a delay relative to `now`.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) -> EventId {
        self.schedule_at(self.now + delay, payload)
    }

    /// Cancel a scheduled event. Returns true if it had not yet fired.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if id.0 >= self.seq {
            return false;
        }
        self.cancelled.insert(id.0)
    }

    /// Pop the next live event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        while let Some(ev) = self.heap.pop() {
            if self.cancelled.remove(&ev.seq) {
                continue;
            }
            debug_assert!(ev.at >= self.now, "event queue went backwards");
            self.now = ev.at;
            self.processed += 1;
            return Some((ev.at, ev.payload));
        }
        None
    }

    /// Time of the next live event without popping it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        // Drop leading tombstones so peek is O(k) amortised.
        while let Some(ev) = self.heap.peek() {
            if self.cancelled.contains(&ev.seq) {
                let seq = ev.seq;
                self.heap.pop();
                self.cancelled.remove(&seq);
            } else {
                return Some(ev.at);
            }
        }
        None
    }

    /// Run until the queue is empty or `until` is reached, dispatching
    /// each event to `f`. `f` may schedule further events.
    ///
    /// On return the clock sits at the later of the last dispatched
    /// event and `until` — except for the run-to-exhaustion idiom
    /// (`until == SimTime::MAX`), where it stays at the last event.
    pub fn run_until<F: FnMut(&mut Self, SimTime, E)>(&mut self, until: SimTime, mut f: F) {
        loop {
            match self.peek_time() {
                Some(t) if t <= until => {
                    let (at, ev) = self.pop().expect("peeked event vanished");
                    f(self, at, ev);
                }
                _ => break,
            }
        }
        if until != SimTime::MAX {
            self.now = self.now.max(until);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        A,
        B,
        Tick(u32),
    }

    #[test]
    fn fifo_order_within_same_time() {
        let mut e = Engine::new();
        e.schedule_at(SimTime::ns(10), Ev::A);
        e.schedule_at(SimTime::ns(10), Ev::B);
        assert_eq!(e.pop().unwrap().1, Ev::A);
        assert_eq!(e.pop().unwrap().1, Ev::B);
    }

    #[test]
    fn time_order() {
        let mut e = Engine::new();
        e.schedule_at(SimTime::ns(50), Ev::B);
        e.schedule_at(SimTime::ns(10), Ev::A);
        let (t1, x1) = e.pop().unwrap();
        let (t2, x2) = e.pop().unwrap();
        assert_eq!((t1, x1), (SimTime::ns(10), Ev::A));
        assert_eq!((t2, x2), (SimTime::ns(50), Ev::B));
        assert_eq!(e.now(), SimTime::ns(50));
    }

    #[test]
    fn cancel_skips() {
        let mut e = Engine::new();
        let id = e.schedule_at(SimTime::ns(10), Ev::A);
        e.schedule_at(SimTime::ns(20), Ev::B);
        assert!(e.cancel(id));
        assert!(!e.cancel(id), "double-cancel returns false");
        assert_eq!(e.pop().unwrap().1, Ev::B);
        assert!(e.pop().is_none());
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut e = Engine::new();
        e.schedule_at(SimTime::ns(100), Ev::A);
        e.pop();
        e.schedule_at(SimTime::ns(10), Ev::B); // in the past
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, SimTime::ns(100));
    }

    #[test]
    fn run_until_dispatches_and_respects_bound() {
        let mut e = Engine::new();
        for i in 0..10 {
            e.schedule_at(SimTime::ns(i * 10), Ev::Tick(i as u32));
        }
        let mut seen = vec![];
        e.run_until(SimTime::ns(45), |_, _, ev| {
            if let Ev::Tick(i) = ev {
                seen.push(i);
            }
        });
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        assert_eq!(e.now(), SimTime::ns(45));
        assert_eq!(e.pending(), 5);
    }

    #[test]
    fn cascading_events() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(SimTime::ns(0), 0);
        let mut count = 0;
        e.run_until(SimTime::us(1), |eng, _, depth| {
            count += 1;
            if depth < 5 {
                eng.schedule_in(SimTime::ns(7), depth + 1);
            }
        });
        assert_eq!(count, 6);
        assert_eq!(e.processed(), 6);
    }
}
