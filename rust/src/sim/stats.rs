//! Measurement primitives: latency histograms and throughput meters.
//!
//! The paper reports IOPS/bandwidth bars (Figure 6) and per-IO latencies
//! (Table 3). We use an HdrHistogram-style log-linear bucketing scheme
//! (3 significant decimal digits) so p50/p99/p999 are accurate across the
//! full 25 ns .. 25 ms range the simulation produces without storing
//! every sample.

use crate::sim::time::SimTime;

/// Log-linear latency histogram with ~0.1% relative error.
///
/// Buckets: values are grouped by (bucket = floor(log2(v / SUB)),
/// sub-bucket = linear within the bucket), with `SUB = 2048` sub-buckets
/// giving 3 significant digits.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

const SUB_BITS: u32 = 11; // 2048 sub-buckets per power of two
const SUB: u64 = 1 << SUB_BITS;
const BUCKETS: usize = 44; // covers up to ~2048 * 2^43 ns ≈ 208 days

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKETS * SUB as usize],
            total: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    #[inline]
    fn index_of(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS
        let bucket = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> (msb - SUB_BITS)) - SUB / 2 + SUB / 2; // top SUB_BITS+1 bits
        let sub = (sub & (SUB - 1)) as usize;
        (bucket * SUB as usize + sub).min(BUCKETS * SUB as usize - 1)
    }

    /// Lower edge of the bucket containing index `i` (inverse of index_of).
    fn value_of(i: usize) -> u64 {
        let bucket = i / SUB as usize;
        let sub = (i % SUB as usize) as u64;
        if bucket == 0 {
            sub
        } else {
            let shift = bucket as u32 - 1;
            (SUB + sub) << shift
        }
    }

    /// Record one latency sample.
    #[inline]
    pub fn record(&mut self, t: SimTime) {
        let v = t.as_ns();
        self.counts[Self::index_of(v)] += 1;
        self.total += 1;
        self.sum_ns += v as u128;
        self.min_ns = self.min_ns.min(v);
        self.max_ns = self.max_ns.max(v);
    }

    /// Record `n` identical samples (used by the batch data plane).
    #[inline]
    pub fn record_n(&mut self, t: SimTime, n: u64) {
        let v = t.as_ns();
        self.counts[Self::index_of(v)] += n;
        self.total += n;
        self.sum_ns += v as u128 * n as u128;
        self.min_ns = self.min_ns.min(v);
        self.max_ns = self.max_ns.max(v);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean latency.
    pub fn mean(&self) -> SimTime {
        if self.total == 0 {
            return SimTime::ZERO;
        }
        SimTime::ns((self.sum_ns / self.total as u128) as u64)
    }

    /// Minimum recorded sample (bucket-quantised).
    pub fn min(&self) -> SimTime {
        if self.total == 0 {
            SimTime::ZERO
        } else {
            SimTime::ns(self.min_ns)
        }
    }

    /// Maximum recorded sample (exact).
    pub fn max(&self) -> SimTime {
        SimTime::ns(self.max_ns)
    }

    /// Quantile in [0,1]; returns the lower edge of the containing bucket.
    pub fn quantile(&self, q: f64) -> SimTime {
        if self.total == 0 {
            return SimTime::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return SimTime::ns(Self::value_of(i));
            }
        }
        SimTime::ns(self.max_ns)
    }

    pub fn p50(&self) -> SimTime {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> SimTime {
        self.quantile(0.99)
    }
    pub fn p999(&self) -> SimTime {
        self.quantile(0.999)
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} p999={} max={}",
            self.total,
            self.mean(),
            self.p50(),
            self.p99(),
            self.p999(),
            self.max()
        )
    }
}

/// Throughput meter: completed operations + bytes over a simulated span.
#[derive(Debug, Clone, Copy, Default)]
pub struct Throughput {
    pub ops: u64,
    pub bytes: u64,
    pub span: SimTime,
}

impl Throughput {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `ops` completed operations moving `bytes` in total.
    pub fn record(&mut self, ops: u64, bytes: u64) {
        self.ops += ops;
        self.bytes += bytes;
    }

    /// Set the simulated wall-clock span the counters cover.
    pub fn set_span(&mut self, span: SimTime) {
        self.span = span;
    }

    /// IOs per second.
    pub fn iops(&self) -> f64 {
        if self.span == SimTime::ZERO {
            return 0.0;
        }
        self.ops as f64 / self.span.as_secs_f64()
    }

    /// Thousands of IOs per second (the unit Figure 6 uses).
    pub fn kiops(&self) -> f64 {
        self.iops() / 1e3
    }

    /// Bandwidth in GB/s (decimal, as SSD vendors quote).
    pub fn gbps(&self) -> f64 {
        if self.span == SimTime::ZERO {
            return 0.0;
        }
        self.bytes as f64 / self.span.as_secs_f64() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_exact_small_values() {
        let mut h = LatencyHistogram::new();
        for v in [25u64, 70, 190, 780, 880, 1190] {
            h.record(SimTime::ns(v));
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), SimTime::ns(25));
        assert_eq!(h.max(), SimTime::ns(1190));
        // values < 2048 land in exact buckets
        assert_eq!(h.quantile(0.01), SimTime::ns(25));
    }

    #[test]
    fn histogram_quantiles_within_relative_error() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100_000u64 {
            h.record(SimTime::ns(i * 10)); // 10ns .. 1ms uniform
        }
        let p50 = h.p50().as_ns() as f64;
        let p99 = h.p99().as_ns() as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.002, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.002, "p99={p99}");
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = LatencyHistogram::new();
        h.record_n(SimTime::us(10), 3);
        h.record_n(SimTime::us(40), 1);
        assert_eq!(h.mean(), SimTime::ns(17_500));
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(SimTime::ns(100));
        b.record(SimTime::ns(300));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean(), SimTime::ns(200));
    }

    #[test]
    fn throughput_units_match_paper() {
        // Table 3 Gen5: 2800 KIOPS 4K rand read; 14 GB/s 128K seq read.
        let mut t = Throughput::new();
        t.record(2_800_000, 2_800_000 * 4096);
        t.set_span(SimTime::secs(1));
        assert!((t.kiops() - 2800.0).abs() < 1e-6);
        let mut s = Throughput::new();
        s.record(106_812, 106_812 * 131_072); // ≈14 GB/s
        s.set_span(SimTime::secs(1));
        assert!((s.gbps() - 14.0).abs() < 0.01, "gbps={}", s.gbps());
    }

    #[test]
    fn index_value_roundtrip_monotone() {
        let mut last = 0usize;
        for v in [0u64, 1, 100, 2047, 2048, 4096, 10_000, 1 << 20, 1 << 33] {
            let i = LatencyHistogram::index_of(v);
            assert!(i >= last, "index must be monotone in value");
            let edge = LatencyHistogram::value_of(i);
            assert!(edge <= v, "edge {edge} must not exceed value {v}");
            // relative quantisation error bounded by one sub-bucket
            if v > 0 {
                assert!((v - edge) as f64 / v as f64 <= 1.0 / 1024.0);
            }
            last = i;
        }
    }
}
