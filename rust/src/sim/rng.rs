//! Deterministic pseudo-random number generation.
//!
//! crates.io is unreachable in this build environment, so instead of the
//! `rand` crate we implement PCG-XSL-RR 128/64 ("pcg64") directly — a
//! small, fast, statistically solid generator with a documented reference
//! implementation. Determinism matters more than crypto strength here:
//! every experiment row in EXPERIMENTS.md must be reproducible from its
//! seed.

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id; distinct streams
    /// are independent even with identical seeds (used to give each
    /// simulated device its own stream).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponentially distributed sample with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_diverge() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "independent streams should not collide");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_uniformish() {
        let mut rng = Pcg64::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exp(250.0)).sum::<f64>() / n as f64;
        assert!((mean - 250.0).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}
