//! Simulation time: nanosecond-resolution, 64-bit, saturating.
//!
//! All latencies in the paper are quoted in ns (CXL port 25 ns, switch
//! 70 ns, PCIe 780 ns) or µs (flash read 25 µs, device latency 56–67 µs),
//! so a u64 of nanoseconds covers ~584 years of simulated time.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// A point in (or duration of) simulated time, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// Construct from nanoseconds.
    #[inline]
    pub const fn ns(n: u64) -> Self {
        SimTime(n)
    }

    /// Construct from microseconds (saturating, like all `SimTime`
    /// arithmetic).
    #[inline]
    pub const fn us(n: u64) -> Self {
        SimTime(n.saturating_mul(1_000))
    }

    /// Construct from milliseconds (saturating).
    #[inline]
    pub const fn ms(n: u64) -> Self {
        SimTime(n.saturating_mul(1_000_000))
    }

    /// Construct from seconds (saturating).
    #[inline]
    pub const fn secs(n: u64) -> Self {
        SimTime(n.saturating_mul(1_000_000_000))
    }

    /// Value in nanoseconds.
    #[inline]
    pub const fn as_ns(self) -> u64 {
        self.0
    }

    /// Value in (fractional) microseconds.
    #[inline]
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    /// Value in (fractional) seconds.
    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000_000.0
    }

    /// Saturating subtraction (durations never go negative).
    #[inline]
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }

    /// max of two times.
    #[inline]
    pub fn max(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.max(rhs.0))
    }

    /// min of two times.
    #[inline]
    pub fn min(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.min(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 = self.0.saturating_add(rhs.0);
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    #[inline]
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl SubAssign for SimTime {
    #[inline]
    fn sub_assign(&mut self, rhs: SimTime) {
        self.0 = self.0.saturating_sub(rhs.0);
    }
}

impl Mul<u64> for SimTime {
    type Output = SimTime;
    #[inline]
    fn mul(self, rhs: u64) -> SimTime {
        SimTime(self.0.saturating_mul(rhs))
    }
}

impl Div<u64> for SimTime {
    type Output = SimTime;
    #[inline]
    fn div(self, rhs: u64) -> SimTime {
        SimTime(self.0 / rhs)
    }
}

impl Sum for SimTime {
    fn sum<I: Iterator<Item = SimTime>>(iter: I) -> SimTime {
        iter.fold(SimTime::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns >= 1_000_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if ns >= 1_000_000 {
            write!(f, "{:.3}ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            write!(f, "{:.3}us", ns as f64 / 1e3)
        } else {
            write!(f, "{ns}ns")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_scale() {
        assert_eq!(SimTime::us(25).as_ns(), 25_000);
        assert_eq!(SimTime::ms(3).as_ns(), 3_000_000);
        assert_eq!(SimTime::secs(2).as_ns(), 2_000_000_000);
    }

    #[test]
    fn arithmetic_saturates() {
        assert_eq!(SimTime::ns(5) - SimTime::ns(9), SimTime::ZERO);
        assert_eq!(SimTime::MAX + SimTime::ns(1), SimTime::MAX);
    }

    #[test]
    fn constructors_saturate_instead_of_overflowing() {
        // the module contract is saturating arithmetic everywhere; the
        // unit constructors must not be the one wrapping/panicking hole
        assert_eq!(SimTime::us(u64::MAX), SimTime::MAX);
        assert_eq!(SimTime::ms(u64::MAX), SimTime::MAX);
        assert_eq!(SimTime::secs(u64::MAX), SimTime::MAX);
        // just past the last representable whole unit saturates too
        assert_eq!(SimTime::secs(u64::MAX / 1_000_000_000 + 1), SimTime::MAX);
        assert_eq!(SimTime::ms(u64::MAX / 1_000_000 + 1), SimTime::MAX);
        assert_eq!(SimTime::us(u64::MAX / 1_000 + 1), SimTime::MAX);
        // the largest exactly-representable values stay exact
        let whole_secs = u64::MAX / 1_000_000_000;
        assert_eq!(SimTime::secs(whole_secs).as_ns(), whole_secs * 1_000_000_000);
    }

    #[test]
    fn ordering_and_max() {
        assert!(SimTime::ns(190) < SimTime::ns(880));
        assert_eq!(SimTime::ns(3).max(SimTime::ns(7)), SimTime::ns(7));
        assert_eq!(SimTime::ns(3).min(SimTime::ns(7)), SimTime::ns(3));
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", SimTime::ns(25)), "25ns");
        assert_eq!(format!("{}", SimTime::ns(1_190)), "1.190us");
        assert_eq!(format!("{}", SimTime::us(25_000)), "25.000ms");
    }

    #[test]
    fn sum_of_hops_matches_paper_fig2() {
        // Figure 2: two port crossings + switch hop for CXL HDM access.
        let hops = [SimTime::ns(25), SimTime::ns(70), SimTime::ns(25)];
        let total: SimTime = hops.into_iter().sum();
        assert_eq!(total, SimTime::ns(120));
    }
}
