//! Discrete-event simulation core.
//!
//! The LMB reproduction is a *hybrid* simulator:
//!
//! * control plane (allocation, fabric management, GC, page faults,
//!   failure injection) runs on an exact discrete-event engine
//!   ([`engine::Engine`]) with nanosecond resolution;
//! * data plane (per-IO latency/throughput of millions of IOs) runs on a
//!   vectorised batch model (see [`crate::runtime`]) whose numeric inner
//!   loop is the AOT-compiled JAX/Pallas program.
//!
//! Everything is deterministic: a seeded [`rng::Pcg64`] drives all
//! randomness, so every experiment in EXPERIMENTS.md is reproducible
//! bit-for-bit.

pub mod engine;
pub mod rng;
pub mod stats;
pub mod time;

pub use engine::{Engine, EventId};
pub use rng::Pcg64;
pub use stats::{LatencyHistogram, Throughput};
pub use time::SimTime;
