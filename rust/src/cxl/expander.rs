//! CXL memory expander — the GFD providing pooled HDM (§3.1, Figure 4).
//!
//! The expander translates host HPAs (through HDM decoder windows) or
//! device-originated DPAs into its internal media space, which is carved
//! into Device Media Partitions (DMPs) of possibly heterogeneous media
//! (DRAM / PM). Device-originated requests are checked against the SAT.
//!
//! The backing store is *functional*: bytes written through the fabric
//! can be read back, so the LMB alloc/share paths are verified end to
//! end, not just timed. Storage is a sparse 4 KiB page map, so a
//! simulated multi-TiB expander costs only what is actually touched.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::cxl::packet::{CxlMemReq, MemAddr, MemOp};
use crate::cxl::sat::{SatPerm, SatTable};
use crate::cxl::types::{Dpa, DmpId, Dpid, Hpa, MediaType, Range, Requester, Spid, GIB, PAGE_SIZE};
use crate::error::{Error, Result};
use crate::sim::time::SimTime;

/// Paper constant (Figure 2 derivation): one HDM media access.
pub const HDM_MEDIA_LATENCY: SimTime = SimTime::ns(70);

/// PM media access (several× DRAM; used for heterogeneous DMPs).
pub const PM_MEDIA_LATENCY: SimTime = SimTime::ns(350);

/// Which side of the two-tier media boundary an address (or an extent)
/// sits on. The fast tier is the DRAM DMP standing in for scarce
/// device-local DRAM; the slow tier is the PM DMP standing in for the
/// far side of the CXL link. The tiering engine (`crate::tier`)
/// classifies extents against this boundary and `migrate_extent` moves
/// them across it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MediaTier {
    /// Fast media: the DRAM DMP at `[0, dram_capacity)`.
    Dram,
    /// Slow media: the PM DMP at `[dram_capacity, capacity)`.
    Pm,
}

impl MediaTier {
    /// Stable wire name (the JSONL `detail` field of migrate events).
    pub fn name(self) -> &'static str {
        match self {
            MediaTier::Dram => "dram",
            MediaTier::Pm => "pm",
        }
    }

    /// Media latency scalar for this tier — the calibrated two-tier
    /// cost model the `TierPolicy` prices placements against.
    pub fn media_latency(self) -> SimTime {
        match self {
            MediaTier::Dram => HDM_MEDIA_LATENCY,
            MediaTier::Pm => PM_MEDIA_LATENCY,
        }
    }

    /// The opposite tier.
    pub fn other(self) -> MediaTier {
        match self {
            MediaTier::Dram => MediaTier::Pm,
            MediaTier::Pm => MediaTier::Dram,
        }
    }
}

/// A Device Media Partition: a DPA range with fixed media attributes
/// (Figure 4: "DPA space is organized according to DMP").
#[derive(Debug, Clone)]
pub struct Dmp {
    pub id: DmpId,
    pub range: Range,
    pub media: MediaType,
    /// Partitions can fail independently (§1: single point of failure).
    pub failed: bool,
}

impl Dmp {
    fn media_latency(&self) -> SimTime {
        match self.media {
            MediaType::Dram => HDM_MEDIA_LATENCY,
            MediaType::Pm => PM_MEDIA_LATENCY,
        }
    }
}

/// An HDM decoder: maps a host HPA window onto a DPA base.
#[derive(Debug, Clone, Copy)]
pub struct HdmDecoder {
    pub hpa_window: Range,
    pub dpa_base: Dpa,
}

/// Expander configuration.
#[derive(Debug, Clone)]
pub struct ExpanderConfig {
    /// DRAM capacity in bytes.
    pub dram_capacity: u64,
    /// Optional PM capacity in bytes (second DMP).
    pub pm_capacity: u64,
    /// Aggregate media bandwidth in bytes/sec (shared by all requesters —
    /// drives the multi-device contention model).
    pub bandwidth_bps: u64,
    /// SAT entry budget.
    pub sat_entries: usize,
}

impl Default for ExpanderConfig {
    fn default() -> Self {
        ExpanderConfig {
            dram_capacity: 64 * GIB,
            pm_capacity: 0,
            bandwidth_bps: 80_000_000_000, // ~2 DDR5 channels worth
            sat_entries: 4096,
        }
    }
}

/// The GFD memory expander.
#[derive(Debug)]
pub struct Expander {
    cfg: ExpanderConfig,
    /// DMPs, sorted by DPA base and non-overlapping — `dmp_for` binary
    /// searches them (real expanders decode partitions with fixed
    /// segment registers, not a table walk).
    dmps: Vec<Dmp>,
    /// HDM decoders, kept sorted by HPA window base and non-overlapping
    /// (enforced at insert time), so `decode_hpa` is a binary search
    /// instead of the old per-access linear scan.
    decoders: Vec<HdmDecoder>,
    sat: SatTable,
    /// Sparse functional backing store: DPA page index → page bytes.
    pages: HashMap<u64, Box<[u8]>>,
    /// Whole-device failure flag (§1 challenge; see `lmb::failure`).
    failed: bool,
    /// The GFD's own DPID, set at bring-up ([`Expander::set_gfd_dpid`]);
    /// reported in [`Error::SatViolation`] so the error names the real
    /// P2P destination, not a placeholder.
    gfd_dpid: Dpid,
    /// One-entry last-hit translation cache (device-TLB analogue):
    /// consecutive accesses inside one HDM window skip the decoder
    /// search entirely. Invalidated whenever a decoder is removed.
    /// Behind its own mutex (not the expander's outer `RwLock`) so the
    /// shared-read decode path can still refill it; refills are
    /// best-effort (`try_lock`) — losing the race costs one extra
    /// binary search, never a stall.
    tlb: Mutex<Option<HdmDecoder>>,
    tlb_hits: AtomicU64,
    tlb_misses: AtomicU64,
    /// Accesses served (ops, bytes) — used by contention accounting.
    pub served_ops: u64,
    pub served_bytes: u64,
}

impl Expander {
    pub fn new(cfg: ExpanderConfig) -> Self {
        let mut dmps = vec![Dmp {
            id: DmpId(0),
            range: Range::new(0, cfg.dram_capacity),
            media: MediaType::Dram,
            failed: false,
        }];
        if cfg.pm_capacity > 0 {
            dmps.push(Dmp {
                id: DmpId(1),
                range: Range::new(cfg.dram_capacity, cfg.pm_capacity),
                media: MediaType::Pm,
                failed: false,
            });
        }
        let sat = SatTable::new(cfg.sat_entries);
        Expander {
            cfg,
            dmps,
            decoders: Vec::new(),
            sat,
            pages: HashMap::new(),
            failed: false,
            gfd_dpid: Dpid(0),
            tlb: Mutex::new(None),
            tlb_hits: AtomicU64::new(0),
            tlb_misses: AtomicU64::new(0),
            served_ops: 0,
            served_bytes: 0,
        }
    }

    pub fn config(&self) -> &ExpanderConfig {
        &self.cfg
    }

    /// Total media capacity across DMPs.
    pub fn capacity(&self) -> u64 {
        self.cfg.dram_capacity + self.cfg.pm_capacity
    }

    /// The DPA at which the fast (DRAM) media ends and the slow (PM)
    /// media begins. Everything below is [`MediaTier::Dram`].
    pub fn tier_boundary(&self) -> u64 {
        self.cfg.dram_capacity
    }

    /// Which media tier `dpa` sits on.
    pub fn tier_of(&self, dpa: Dpa) -> MediaTier {
        if dpa.0 < self.cfg.dram_capacity {
            MediaTier::Dram
        } else {
            MediaTier::Pm
        }
    }

    pub fn dmps(&self) -> &[Dmp] {
        &self.dmps
    }

    pub fn sat(&self) -> &SatTable {
        &self.sat
    }

    pub fn sat_mut(&mut self) -> &mut SatTable {
        &mut self.sat
    }

    /// Program an HDM decoder (FM/host setup path). The decoder table is
    /// kept sorted by window base; because live windows are disjoint,
    /// only the two neighbours of the insertion point can overlap a new
    /// window, so the overlap check is O(log n) too.
    pub fn add_decoder(&mut self, hpa_window: Range, dpa_base: Dpa) -> Result<()> {
        let idx = self.decoders.partition_point(|d| d.hpa_window.base < hpa_window.base);
        let overlaps_at = |i: usize| self.decoders[i].hpa_window.overlaps(&hpa_window);
        if (idx > 0 && overlaps_at(idx - 1)) || (idx < self.decoders.len() && overlaps_at(idx)) {
            return Err(Error::FabricManager("overlapping HDM decoder window".into()));
        }
        if !self.dpa_valid(dpa_base, hpa_window.len) {
            return Err(Error::DecodeFault(format!(
                "decoder target {dpa_base:?}+{:#x} outside media",
                hpa_window.len
            )));
        }
        self.decoders.insert(idx, HdmDecoder { hpa_window, dpa_base });
        Ok(())
    }

    fn dpa_valid(&self, dpa: Dpa, len: u64) -> bool {
        self.dmp_lookup(dpa, len).is_some()
    }

    /// Remove the HDM decoder whose window starts at `hpa_base` (used by
    /// the LMB module when an extent is released back to the FM).
    pub fn remove_decoder(&mut self, hpa_base: u64) -> Result<()> {
        let idx = self.decoders.partition_point(|d| d.hpa_window.base < hpa_base);
        if idx >= self.decoders.len() || self.decoders[idx].hpa_window.base != hpa_base {
            return Err(Error::DecodeFault(format!("no decoder at {hpa_base:#x}")));
        }
        self.decoders.remove(idx);
        self.tlb_clear();
        Ok(())
    }

    /// Remove every HDM decoder whose target DPA window overlaps
    /// `range` (host teardown: a crashed host's windows must not
    /// survive into a re-lease of the same media). Returns the number
    /// of decoders removed.
    pub fn remove_decoders_overlapping_dpa(&mut self, range: Range) -> usize {
        let before = self.decoders.len();
        self.decoders.retain(|d| !Range::new(d.dpa_base.0, d.hpa_window.len).overlaps(&range));
        self.tlb_clear();
        before - self.decoders.len()
    }

    /// Invalidate the translation cache (decoder removal paths; `&mut`
    /// contexts go straight through the lock, tolerating poison).
    fn tlb_clear(&mut self) {
        *self.tlb.get_mut().unwrap_or_else(PoisonError::into_inner) = None;
    }

    /// Translate a host HPA to a DPA via the HDM decoders: a one-entry
    /// last-hit cache (device-TLB analogue) in front of a binary search
    /// over the sorted decoder table.
    pub fn decode_hpa(&self, hpa: Hpa) -> Result<Dpa> {
        // best-effort cache: if another reader holds it (or it is
        // poisoned), skip it — correctness never depends on the TLB
        let mut tlb = self.tlb.try_lock().ok();
        if let Some(Some(d)) = tlb.as_deref() {
            if d.hpa_window.contains(hpa.0) {
                self.tlb_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Dpa(d.dpa_base.0 + (hpa.0 - d.hpa_window.base)));
            }
        }
        self.tlb_misses.fetch_add(1, Ordering::Relaxed);
        let d = self.decoder_for(hpa)?;
        if let Some(slot) = tlb.as_deref_mut() {
            *slot = Some(d);
        }
        Ok(Dpa(d.dpa_base.0 + (hpa.0 - d.hpa_window.base)))
    }

    /// Uncached decoder lookup: windows are sorted and disjoint, so the
    /// only candidate is the last window whose base is <= the address.
    fn decoder_for(&self, hpa: Hpa) -> Result<HdmDecoder> {
        let idx = self.decoders.partition_point(|d| d.hpa_window.base <= hpa.0);
        idx.checked_sub(1)
            .map(|i| self.decoders[i])
            .filter(|d| d.hpa_window.contains(hpa.0))
            .ok_or_else(|| Error::DecodeFault(format!("no HDM decoder for {hpa:?}")))
    }

    /// Raw translation-cache counters, `(hits, misses)` — the numbers
    /// behind the unified `telemetry()` surface (the former
    /// `tlb_stats()` delegate is gone — its absence is pinned by
    /// `tests/api_surface.rs`). Public for standalone-expander drivers
    /// (microbenches) that have no fabric or service to ask.
    pub fn tlb_counters(&self) -> (u64, u64) {
        (self.tlb_hits.load(Ordering::Relaxed), self.tlb_misses.load(Ordering::Relaxed))
    }

    /// Binary search the sorted, disjoint DMP table for the partition
    /// wholly containing `[dpa, dpa+len)`.
    fn dmp_lookup(&self, dpa: Dpa, len: u64) -> Option<&Dmp> {
        let idx = self.dmps.partition_point(|d| d.range.base <= dpa.0);
        idx.checked_sub(1)
            .map(|i| &self.dmps[i])
            .filter(|d| d.range.contains_span(dpa.0, len.max(1)))
    }

    fn dmp_for(&self, dpa: Dpa, len: u64) -> Result<&Dmp> {
        self.dmp_lookup(dpa, len)
            .ok_or_else(|| Error::DecodeFault(format!("{dpa:?} outside media")))
    }

    /// Record the GFD's DPID at bring-up (called by
    /// [`FabricManager::attach_gfd`](crate::cxl::fm::FabricManager::attach_gfd))
    /// so SAT violations can name the real P2P destination.
    pub fn set_gfd_dpid(&mut self, dpid: Dpid) {
        self.gfd_dpid = dpid;
    }

    /// The GFD DPID reported in access-control errors (`Dpid(0)` before
    /// bring-up).
    pub fn gfd_dpid(&self) -> Dpid {
        self.gfd_dpid
    }

    /// Fail / recover the whole expander (failure-injection hooks).
    pub fn set_failed(&mut self, failed: bool) {
        self.failed = failed;
    }

    pub fn is_failed(&self) -> bool {
        self.failed
    }

    /// Fail a single DMP.
    pub fn set_dmp_failed(&mut self, id: DmpId, failed: bool) -> Result<()> {
        let dmp = self
            .dmps
            .iter_mut()
            .find(|d| d.id == id)
            .ok_or_else(|| Error::FabricManager(format!("unknown DMP {id:?}")))?;
        dmp.failed = failed;
        Ok(())
    }

    /// Service a CXL.mem access *with* access control and latency model,
    /// but without data movement. Returns the media latency.
    ///
    /// Hosts (`Requester::Host`) are trusted (the kernel module enforces
    /// IOMMU isolation upstream); device P2P requesters must pass SAT.
    pub fn access(&mut self, req: &CxlMemReq) -> Result<SimTime> {
        if self.failed {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        let dpa = match req.addr {
            MemAddr::Dpa(d) => d,
            MemAddr::Hpa(h) => self.decode_hpa(h)?,
        };
        let dmp = self.dmp_for(dpa, req.len as u64)?;
        if dmp.failed {
            return Err(Error::ExpanderFailed(format!("DMP {:?} offline", dmp.id)));
        }
        let latency = dmp.media_latency();
        if let Requester::CxlDevice(spid) = req.requester {
            let write = req.op == MemOp::MemWr;
            if !self.sat.check(spid, dpa, req.len as u64, write) {
                return Err(Error::SatViolation { spid, dpid: self.gfd_dpid });
            }
        }
        self.served_ops += 1;
        self.served_bytes += req.len as u64;
        Ok(latency)
    }

    /// Functional write at a DPA.
    pub fn write_dpa(&mut self, dpa: Dpa, data: &[u8]) -> Result<()> {
        if self.failed {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        self.dmp_for(dpa, data.len() as u64)?;
        let mut addr = dpa.0;
        let mut rest = data;
        while !rest.is_empty() {
            let page = addr / PAGE_SIZE;
            let off = (addr % PAGE_SIZE) as usize;
            let n = rest.len().min(PAGE_SIZE as usize - off);
            let buf = self
                .pages
                .entry(page)
                .or_insert_with(|| vec![0u8; PAGE_SIZE as usize].into_boxed_slice());
            buf[off..off + n].copy_from_slice(&rest[..n]);
            addr += n as u64;
            rest = &rest[n..];
        }
        Ok(())
    }

    /// Functional read at a DPA.
    pub fn read_dpa(&self, dpa: Dpa, out: &mut [u8]) -> Result<()> {
        if self.failed {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        self.dmp_for(dpa, out.len() as u64)?;
        let mut addr = dpa.0;
        let mut rest = out;
        while !rest.is_empty() {
            let page = addr / PAGE_SIZE;
            let off = (addr % PAGE_SIZE) as usize;
            let n = rest.len().min(PAGE_SIZE as usize - off);
            match self.pages.get(&page) {
                Some(buf) => rest[..n].copy_from_slice(&buf[off..off + n]),
                None => rest[..n].fill(0),
            }
            addr += n as u64;
            rest = &mut rest[n..];
        }
        Ok(())
    }

    /// Number of resident (touched) backing pages.
    pub fn resident_pages(&self) -> usize {
        self.pages.len()
    }

    /// Copy up to `max_pages` resident pages from `src` to the
    /// equal-length window at `dst` (both page-aligned; migration data
    /// plane). Sparse pages stay sparse — only touched pages move.
    /// Returns the number of pages copied; a partial copy (caller
    /// aborting mid-migration) leaves the source untouched so rollback
    /// is just [`wipe_dpa_range`](Self::wipe_dpa_range) on `dst`.
    pub(crate) fn copy_dpa_range(&mut self, src: Range, dst: Dpa, max_pages: usize) -> usize {
        debug_assert_eq!(src.base % PAGE_SIZE, 0);
        debug_assert_eq!(src.len % PAGE_SIZE, 0);
        debug_assert_eq!(dst.0 % PAGE_SIZE, 0);
        let first = src.base / PAGE_SIZE;
        let npages = src.len / PAGE_SIZE;
        let dst_first = dst.0 / PAGE_SIZE;
        let mut copied = 0usize;
        for i in 0..npages {
            if copied >= max_pages {
                break;
            }
            if let Some(buf) = self.pages.get(&(first + i)).cloned() {
                self.pages.insert(dst_first + i, buf);
                copied += 1;
            }
        }
        copied
    }

    /// Drop every resident page inside `range` (page-aligned): the
    /// source side of a committed migration, or the destination side of
    /// an aborted one. Returns pages dropped.
    pub(crate) fn wipe_dpa_range(&mut self, range: Range) -> usize {
        debug_assert_eq!(range.base % PAGE_SIZE, 0);
        debug_assert_eq!(range.len % PAGE_SIZE, 0);
        let first = range.base / PAGE_SIZE;
        let npages = range.len / PAGE_SIZE;
        let mut dropped = 0usize;
        for i in 0..npages {
            if self.pages.remove(&(first + i)).is_some() {
                dropped += 1;
            }
        }
        dropped
    }

    /// Re-target every HDM decoder whose DPA window lies wholly inside
    /// `src` onto the equal-length window at `dst`, preserving each
    /// window's HPA base and length (migration commit: the host-visible
    /// HPA mapping survives, the media behind it moves). Invalidates
    /// the translation cache. Returns the number of decoders moved.
    pub(crate) fn retarget_decoders_dpa(&mut self, src: Range, dst: Dpa) -> usize {
        let mut moved = 0usize;
        for d in self.decoders.iter_mut() {
            let win = Range::new(d.dpa_base.0, d.hpa_window.len);
            if src.contains_span(win.base, win.len.max(1)) {
                d.dpa_base = Dpa(dst.0 + (d.dpa_base.0 - src.base));
                moved += 1;
            }
        }
        if moved > 0 {
            self.tlb_clear();
        }
        moved
    }

    /// SAT grant plumbing used by the FM.
    pub fn sat_grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        self.sat.grant(spid, range, perm)
    }

    pub fn sat_revoke(&mut self, spid: Spid, range: Range) -> Result<()> {
        self.sat.revoke(spid, range)
    }

    /// Revoke every SAT grant overlapping `range`, across all SPIDs
    /// (media reclaim; see [`SatTable::revoke_overlapping`]).
    pub fn sat_revoke_overlapping(&mut self, range: Range) -> usize {
        self.sat.revoke_overlapping(range)
    }

    /// Indexing invariants the fast paths rely on: decoder and DMP
    /// tables sorted by base and disjoint, the cached TLB entry (if any)
    /// present in the decoder table, and the SAT's own sortedness.
    pub fn check_invariants(&self) -> Result<()> {
        for w in self.decoders.windows(2) {
            if w[1].hpa_window.base < w[0].hpa_window.end()
                || w[1].hpa_window.base < w[0].hpa_window.base
            {
                return Err(Error::FabricManager("decoder table unsorted or overlapping".into()));
            }
        }
        for w in self.dmps.windows(2) {
            if w[1].range.base < w[0].range.end() || w[1].range.base < w[0].range.base {
                return Err(Error::FabricManager("DMP table unsorted or overlapping".into()));
            }
        }
        let cached = *self.tlb.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(t) = cached {
            let cached_live = self
                .decoders
                .iter()
                .any(|d| d.hpa_window == t.hpa_window && d.dpa_base == t.dpa_base);
            if !cached_live {
                return Err(Error::FabricManager("stale decoder TLB entry".into()));
            }
        }
        self.sat.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::Spid;

    fn expander() -> Expander {
        Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() })
    }

    #[test]
    fn decoder_translation() {
        let mut e = expander();
        e.add_decoder(Range::new(0x1_0000_0000, 0x1000_0000), Dpa(0)).unwrap();
        assert_eq!(e.decode_hpa(Hpa(0x1_0000_0000)).unwrap(), Dpa(0));
        assert_eq!(e.decode_hpa(Hpa(0x1_0000_4242)).unwrap(), Dpa(0x4242));
        assert!(e.decode_hpa(Hpa(0x2_0000_0000)).is_err());
    }

    #[test]
    fn overlapping_decoders_rejected() {
        let mut e = expander();
        e.add_decoder(Range::new(0x1000, 0x1000), Dpa(0)).unwrap();
        assert!(e.add_decoder(Range::new(0x1800, 0x1000), Dpa(0x10_0000)).is_err());
    }

    #[test]
    fn host_access_latency_is_dram() {
        let mut e = expander();
        e.add_decoder(Range::new(0, GIB), Dpa(0)).unwrap();
        let req = CxlMemReq::read(MemAddr::Hpa(Hpa(0x40)), 64, Requester::Host(Spid(0)));
        assert_eq!(e.access(&req).unwrap(), HDM_MEDIA_LATENCY);
    }

    #[test]
    fn p2p_requires_sat() {
        let mut e = expander();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0x40)), 64, Requester::CxlDevice(Spid(7)));
        assert!(matches!(e.access(&req), Err(Error::SatViolation { .. })));
        e.sat_grant(Spid(7), Range::new(0, 0x1000), SatPerm::ReadWrite).unwrap();
        assert!(e.access(&req).is_ok());
    }

    #[test]
    fn sat_write_permission_enforced() {
        let mut e = expander();
        e.sat_grant(Spid(7), Range::new(0, 0x1000), SatPerm::ReadOnly).unwrap();
        let rd = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, Requester::CxlDevice(Spid(7)));
        let wr = CxlMemReq::write(MemAddr::Dpa(Dpa(0)), 64, Requester::CxlDevice(Spid(7)));
        assert!(e.access(&rd).is_ok());
        assert!(e.access(&wr).is_err());
    }

    #[test]
    fn functional_store_roundtrip_and_sparse() {
        let mut e = expander();
        let data = [0xabu8; 8192];
        e.write_dpa(Dpa(PAGE_SIZE - 4), &data).unwrap(); // crosses 3 pages
        let mut out = [0u8; 8192];
        e.read_dpa(Dpa(PAGE_SIZE - 4), &mut out).unwrap();
        assert_eq!(out, data);
        assert_eq!(e.resident_pages(), 3);
        // untouched memory reads as zero
        let mut z = [1u8; 16];
        e.read_dpa(Dpa(0x100000), &mut z).unwrap();
        assert_eq!(z, [0u8; 16]);
    }

    #[test]
    fn pm_dmp_has_higher_latency() {
        let mut e = Expander::new(ExpanderConfig {
            dram_capacity: GIB,
            pm_capacity: GIB,
            ..Default::default()
        });
        let pm_req =
            CxlMemReq::read(MemAddr::Dpa(Dpa(GIB + 0x40)), 64, Requester::Host(Spid(0)));
        assert_eq!(e.access(&pm_req).unwrap(), PM_MEDIA_LATENCY);
    }

    #[test]
    fn failure_blocks_everything() {
        let mut e = expander();
        e.set_failed(true);
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, Requester::Host(Spid(0)));
        assert!(matches!(e.access(&req), Err(Error::ExpanderFailed(_))));
        assert!(e.write_dpa(Dpa(0), &[1]).is_err());
        e.set_failed(false);
        assert!(e.access(&req).is_ok());
    }

    #[test]
    fn dmp_failure_is_partial() {
        let mut e = Expander::new(ExpanderConfig {
            dram_capacity: GIB,
            pm_capacity: GIB,
            ..Default::default()
        });
        e.set_dmp_failed(DmpId(0), true).unwrap();
        let dram = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, Requester::Host(Spid(0)));
        let pm = CxlMemReq::read(MemAddr::Dpa(Dpa(GIB)), 64, Requester::Host(Spid(0)));
        assert!(e.access(&dram).is_err());
        assert!(e.access(&pm).is_ok());
    }

    #[test]
    fn out_of_range_dpa_faults() {
        let mut e = expander();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(2 * GIB)), 64, Requester::Host(Spid(0)));
        assert!(matches!(e.access(&req), Err(Error::DecodeFault(_))));
    }

    #[test]
    fn out_of_order_decoder_inserts_keep_table_sorted() {
        let mut e = expander();
        // insert in descending / interleaved base order
        e.add_decoder(Range::new(0x9000, 0x1000), Dpa(0x3000)).unwrap();
        e.add_decoder(Range::new(0x1000, 0x1000), Dpa(0x1000)).unwrap();
        e.add_decoder(Range::new(0x5000, 0x1000), Dpa(0x2000)).unwrap();
        e.check_invariants().unwrap();
        assert_eq!(e.decode_hpa(Hpa(0x1010)).unwrap(), Dpa(0x1010));
        assert_eq!(e.decode_hpa(Hpa(0x5fff)).unwrap(), Dpa(0x2fff));
        assert_eq!(e.decode_hpa(Hpa(0x9000)).unwrap(), Dpa(0x3000));
        assert!(e.decode_hpa(Hpa(0x2000)).is_err(), "gap between windows");
        // overlap detection still works against both neighbours
        assert!(e.add_decoder(Range::new(0x800, 0x900), Dpa(0)).is_err());
        assert!(e.add_decoder(Range::new(0x5800, 0x100), Dpa(0)).is_err());
        assert!(e.add_decoder(Range::new(0x9000, 0x1000), Dpa(0)).is_err(), "same base");
    }

    #[test]
    fn translation_cache_hits_and_invalidates() {
        let mut e = expander();
        e.add_decoder(Range::new(0x1000, 0x1000), Dpa(0)).unwrap();
        e.add_decoder(Range::new(0x8000, 0x1000), Dpa(0x4000)).unwrap();
        assert_eq!(e.tlb_counters(), (0, 0));
        e.decode_hpa(Hpa(0x1000)).unwrap(); // miss, fills
        e.decode_hpa(Hpa(0x1040)).unwrap(); // hit
        e.decode_hpa(Hpa(0x1fff)).unwrap(); // hit
        assert_eq!(e.tlb_counters(), (2, 1));
        e.decode_hpa(Hpa(0x8000)).unwrap(); // miss, refills
        assert_eq!(e.tlb_counters(), (2, 2));
        e.check_invariants().unwrap();
        // removal invalidates: the stale window must fault, not hit
        e.remove_decoder(0x8000).unwrap();
        assert!(e.decode_hpa(Hpa(0x8000)).is_err());
        e.check_invariants().unwrap();
    }

    #[test]
    fn sat_violation_reports_real_gfd_dpid() {
        let mut e = expander();
        e.set_gfd_dpid(Dpid(7));
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0x40)), 64, Requester::CxlDevice(Spid(3)));
        match e.access(&req) {
            Err(Error::SatViolation { spid, dpid }) => {
                assert_eq!(spid, Spid(3));
                assert_eq!(dpid, Dpid(7), "error carries the GFD's real DPID");
            }
            other => panic!("expected SatViolation, got {other:?}"),
        }
    }
}
