//! Fabric topology + end-to-end path latency derivation (Figure 2).
//!
//! The paper's evaluation injects per-scheme latency constants into the
//! SSD's L2P indexing path: +190 ns (LMB-CXL), +880 ns (LMB-PCIe on a
//! Gen4 SSD), +1190 ns (LMB-PCIe on a Gen5 SSD), +25 µs (DFTL flash
//! read). Rather than hard-coding those, this module *derives* them from
//! the component latencies the paper cites:
//!
//! ```text
//! LMB-CXL  (device P2P → HDM)  = port + switch + port + media
//!                              = 25 + 70 + 25 + 70           = 190 ns
//! LMB-PCIe (PCIe dev → host bridge → HDM)
//!                              = pcie_dev_to_host(gen)
//!                                + TLP→CXL.mem conversion (220 ns)
//!                                + port + switch + port + media
//! Gen5: 780 + 220 + 190 = 1190 ns     Gen4: 470 + 220 + 190 = 880 ns
//! ```
//!
//! Figure 2 quotes 780 ns for "PCIe 5.0 devices accessing host memory";
//! the Gen4 value (470 ns) is back-derived from the paper's own 880 ns
//! injection constant (§4 prototype) — the paper does not state it
//! directly. All constants are configuration, not code.

use crate::cxl::expander::HDM_MEDIA_LATENCY;
use crate::cxl::port::PORT_LATENCY;
use crate::cxl::switch::SWITCH_LATENCY;
use crate::pcie::link::PcieGen;
use crate::sim::time::SimTime;

/// Component latencies of the modeled fabric.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// One CXL port crossing (Figure 2: 25 ns).
    pub port: SimTime,
    /// Switch crossing (Figure 2: 70 ns).
    pub switch: SimTime,
    /// HDM media access on the expander (70 ns DRAM).
    pub hdm_media: SimTime,
    /// Host-local DRAM access (DDR hit from the CPU).
    pub host_dram: SimTime,
    /// PCIe device → host memory round-trip, per generation.
    pub pcie_dev_to_host_gen4: SimTime,
    pub pcie_dev_to_host_gen5: SimTime,
    /// Root-complex TLP → CXL.mem conversion overhead (§3.2 data path).
    pub tlp_conversion: SimTime,
    /// SSD onboard DRAM access (controller-attached DDR).
    pub onboard_dram: SimTime,
    /// One NAND flash page read (the DFTL miss penalty, §4: 25 µs).
    pub flash_read: SimTime,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            port: PORT_LATENCY,
            switch: SWITCH_LATENCY,
            hdm_media: HDM_MEDIA_LATENCY,
            host_dram: SimTime::ns(100),
            pcie_dev_to_host_gen4: SimTime::ns(470),
            pcie_dev_to_host_gen5: SimTime::ns(780),
            tlp_conversion: SimTime::ns(220),
            onboard_dram: SimTime::ns(70),
            flash_read: SimTime::us(25),
        }
    }
}

/// The memory-access paths Figure 2 and §4 reason about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathKind {
    /// Device onboard DRAM (the *Ideal* scheme's index store).
    OnboardDram,
    /// Host CPU → its own DRAM.
    HostDram,
    /// Host CPU → expander HDM through the switch.
    HostToHdm,
    /// CXL device P2P → expander HDM (the *LMB-CXL* scheme).
    CxlP2pToHdm,
    /// PCIe device → host memory over PCIe (the HMB path).
    PcieToHostMem(PcieGen),
    /// PCIe device → expander HDM via host bridging (the *LMB-PCIe*
    /// scheme): TLP to host, conversion to CXL.mem, fabric, media.
    PcieToHdm(PcieGen),
    /// NAND flash page read (the *DFTL* scheme's miss path).
    FlashRead,
}

/// Static fabric latency model. The live topology (switch bindings, SAT,
/// leases) lives in [`crate::cxl::fm::FabricManager`]; `Fabric` answers
/// "what does one access over path X cost" — the quantity the paper's
/// evaluation injects into the SSD firmware.
#[derive(Debug, Clone, Default)]
pub struct Fabric {
    pub cfg: FabricConfig,
}

impl Fabric {
    pub fn new(cfg: FabricConfig) -> Self {
        Fabric { cfg }
    }

    /// One port+switch+port fabric crossing.
    fn crossing(&self) -> SimTime {
        self.cfg.port + self.cfg.switch + self.cfg.port
    }

    /// End-to-end latency of a single memory access over `path`.
    pub fn path_latency(&self, path: PathKind) -> SimTime {
        match path {
            PathKind::OnboardDram => self.cfg.onboard_dram,
            PathKind::HostDram => self.cfg.host_dram,
            PathKind::HostToHdm => self.crossing() + self.cfg.hdm_media,
            PathKind::CxlP2pToHdm => self.crossing() + self.cfg.hdm_media,
            PathKind::PcieToHostMem(gen) => self.pcie_to_host(gen),
            PathKind::PcieToHdm(gen) => {
                self.pcie_to_host(gen)
                    + self.cfg.tlp_conversion
                    + self.crossing()
                    + self.cfg.hdm_media
            }
            PathKind::FlashRead => self.cfg.flash_read,
        }
    }

    fn pcie_to_host(&self, gen: PcieGen) -> SimTime {
        match gen {
            PcieGen::Gen4 => self.cfg.pcie_dev_to_host_gen4,
            PcieGen::Gen5 => self.cfg.pcie_dev_to_host_gen5,
        }
    }

    /// The *added* indexing latency of a scheme relative to Ideal
    /// (onboard DRAM) — the constant the paper injects in §4.
    pub fn added_index_latency(&self, path: PathKind) -> SimTime {
        self.path_latency(path).saturating_sub(self.path_latency(PathKind::OnboardDram))
    }

    /// Figure 2 rows: (label, latency) series for the bench to print.
    pub fn figure2_rows(&self) -> Vec<(&'static str, SimTime)> {
        vec![
            ("CXL port crossing", self.cfg.port),
            ("CXL switch crossing", self.cfg.switch),
            ("HDM media (DRAM)", self.cfg.hdm_media),
            ("Host DRAM access", self.path_latency(PathKind::HostDram)),
            ("Host -> CXL HDM", self.path_latency(PathKind::HostToHdm)),
            ("CXL dev P2P -> HDM (LMB-CXL)", self.path_latency(PathKind::CxlP2pToHdm)),
            (
                "PCIe5 dev -> host memory",
                self.path_latency(PathKind::PcieToHostMem(PcieGen::Gen5)),
            ),
            (
                "PCIe4 dev -> HDM (LMB-PCIe)",
                self.path_latency(PathKind::PcieToHdm(PcieGen::Gen4)),
            ),
            (
                "PCIe5 dev -> HDM (LMB-PCIe)",
                self.path_latency(PathKind::PcieToHdm(PcieGen::Gen5)),
            ),
            ("NAND flash read (DFTL miss)", self.path_latency(PathKind::FlashRead)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> Fabric {
        Fabric::default()
    }

    #[test]
    fn lmb_cxl_derives_paper_190ns() {
        assert_eq!(fabric().path_latency(PathKind::CxlP2pToHdm), SimTime::ns(190));
    }

    #[test]
    fn lmb_pcie_gen4_derives_paper_880ns() {
        assert_eq!(
            fabric().path_latency(PathKind::PcieToHdm(PcieGen::Gen4)),
            SimTime::ns(880)
        );
    }

    #[test]
    fn lmb_pcie_gen5_derives_paper_1190ns() {
        assert_eq!(
            fabric().path_latency(PathKind::PcieToHdm(PcieGen::Gen5)),
            SimTime::ns(1190)
        );
    }

    #[test]
    fn pcie5_host_access_matches_figure2() {
        assert_eq!(
            fabric().path_latency(PathKind::PcieToHostMem(PcieGen::Gen5)),
            SimTime::ns(780)
        );
    }

    #[test]
    fn dftl_miss_is_25us() {
        assert_eq!(fabric().path_latency(PathKind::FlashRead), SimTime::us(25));
    }

    #[test]
    fn added_latency_subtracts_onboard() {
        let f = fabric();
        assert_eq!(f.added_index_latency(PathKind::CxlP2pToHdm), SimTime::ns(120));
        assert_eq!(f.added_index_latency(PathKind::OnboardDram), SimTime::ZERO);
    }

    #[test]
    fn figure2_rows_complete_and_ordered_sensibly() {
        let rows = fabric().figure2_rows();
        assert_eq!(rows.len(), 10);
        // CXL paths must be far cheaper than flash, the paper's thesis.
        let cxl = rows.iter().find(|r| r.0.contains("LMB-CXL")).unwrap().1;
        let flash = rows.iter().find(|r| r.0.contains("DFTL")).unwrap().1;
        assert!(cxl.as_ns() * 100 < flash.as_ns());
    }
}
