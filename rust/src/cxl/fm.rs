//! Fabric Manager (§3.1): binds ports, manages pooled capacity, and
//! programs the GFD on behalf of hosts.
//!
//! The FM owns the expander's DPA space at extent granularity. The LMB
//! kernel module (one per host) requests 256 MB extents through the FM
//! API and sub-allocates them locally (§3.2). Dynamic capacity: extents
//! are handed out on demand and reclaimed when a module releases them —
//! the FM arbitrates between multiple hosts sharing one expander.
//!
//! The FM also fronts the "GFD Component Management Command Set" used to
//! maintain SAT entries for CXL-device P2P access (§3.3).
//!
//! # Sharded concurrency
//!
//! The FM's mutable state is sharded so driver threads stop serialising
//! on one big fabric mutex:
//!
//! * **Region shards** — the DPA space is split into placement regions
//!   (the same boundaries the contention-aware policy prices), each
//!   holding its own free list, lease table and load counter behind its
//!   own `Mutex<RegionShard>`.
//! * **Control plane** — switch/port bindings and per-host lease totals
//!   behind one `Mutex<ControlPlane>` (cold path only).
//! * **Expander** — decoder/DMP/SAT tables and the backing store behind
//!   an `RwLock`, so `decode_hpa`/`dmp_for`/SAT checks are shared reads
//!   that never contend with each other or with allocation.
//! * **Counters** — mmids and the free-byte total are atomics; the
//!   steady-state module path (sub-allocator hit, no extent traffic)
//!   takes *no* fabric lock at all beyond a shared expander read.
//!
//! **Lock order** (outermost first): `seal` → `control` → region shards
//! in **ascending index** → `expander` → tier forward map. Extent-
//! granularity ops (alloc, release, crash reclaim) take the control
//! lock plus the region locks they span in ascending order — ordered
//! two-phase locking, so the global placement decision stays
//! byte-identical to the old single-lock FM while disjoint-region work
//! proceeds in parallel elsewhere. The tiering engine's virtual→physical
//! forward map ([`crate::tier`]) is a strict *leaf*: its mutex is held
//! only for point lookups/updates and never while acquiring any other
//! fabric lock. Live migration commits the map while holding control +
//! shards + the expander write lock, and every translating reader
//! resolves while holding at least one of those (or the seal), so a
//! half-committed move is unobservable. Acquisition / contention /
//! multi-region counters for all of this surface through the unified
//! `telemetry()` on the owning service/cluster.
//!
//! Ownership: since the shared-fabric split no single host owns the FM.
//! It lives behind [`FabricRef`], a cheap-clone `Send + Sync` handle
//! every [`LmbHost`](crate::lmb::LmbHost) (and the multi-host
//! [`Cluster`](crate::cluster::Cluster)) binds through. Leases are keyed
//! by [`HostId`] and mmids are drawn from a fabric-global namespace, so
//! no handle-holder can free or share memory it does not own. A panic
//! inside a fabric scope ([`FabricRef::with_fm`]) poisons the fabric
//! seal — later fallible callers see [`Error::FabricPoisoned`] instead
//! of deadlocking on torn state — while a panic holding a single region
//! lock poisons only that region: its waiters get
//! [`Error::FabricPoisoned`], disjoint regions keep allocating.

use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{
    Arc, Mutex, MutexGuard, OnceLock, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
    TryLockError,
};

use crate::coordinator::contention;
use crate::cxl::expander::{Expander, MediaTier};
use crate::cxl::sat::SatPerm;
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{align_up, Dpa, Dpid, MmId, Range, Spid, EXTENT_SIZE};
use crate::error::{Error, Result};
use crate::lmb::fault::FaultPoint;
use crate::observe::{Event, EventSink};
use crate::tier::{MigrateOutcome, TierSample, TierState};

/// Identifies a host that has bound to the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HostId(pub u32);

/// How the FM chooses *where* in the expander's DPA space a fresh
/// extent is carved.
///
/// The expander's media is split into a fixed number of equal regions
/// (DMP/port analogues). [`PlacementPolicy::ContentionAware`] prices
/// every candidate carve point with the same M/M/1 cost model the
/// device-level contention solver uses
/// ([`contention::placement_cost`]) and picks the candidate in the
/// least-loaded region; when every candidate region carries equal load
/// (e.g. a fresh pool) the tie-break is the lowest DPA — i.e. it falls
/// back to exactly first-fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Lowest-DPA free range that fits (the FM primitive's historical
    /// behaviour; the queue ablation's FIFO baseline).
    #[default]
    FirstFit,
    /// Minimise modeled region contention; ties fall back to first-fit.
    ContentionAware,
}

/// Number of placement regions the DPA space is divided into (each at
/// least one extent long, so tiny test expanders degenerate to one
/// region per extent and both policies coincide). Each region is also a
/// lock shard: lease state for disjoint regions is mutated under
/// disjoint locks.
const PLACEMENT_REGIONS: u64 = 8;

/// An extent of expander capacity leased to a host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub dpa: Dpa,
    pub len: u64,
    pub owner: HostId,
}

/// Cold-path fabric state: port bindings and per-host accounting. One
/// lock, taken only by bind/unbind and extent-granularity ops — never
/// by the module steady state.
#[derive(Debug)]
struct ControlPlane {
    switch: PbrSwitch,
    hosts: HashMap<HostId, Spid>,
    next_host: u32,
    /// Running per-host lease totals — keeps [`FabricManager::leased_to`]
    /// O(1) instead of a scan over every live lease.
    leased_bytes: HashMap<HostId, u64>,
}

/// One placement region's slice of the lease/free state. Guarded by its
/// own mutex; the struct itself is plain data.
#[derive(Debug)]
struct RegionShard {
    /// The DPA span this shard owns (the last shard may be short).
    span: Range,
    /// Free DPA sub-ranges inside `span` (sorted by base; adjacent
    /// frees coalesce *within* the shard — cross-shard adjacency is
    /// re-merged by the allocation-time view).
    free: Vec<Range>,
    /// Live leases homed here, keyed by base DPA. An extent is homed at
    /// its base's region even if its tail crosses into the next shard
    /// (matching the historical base-attributed `region_load`).
    leases: HashMap<u64, Extent>,
    /// Leased bytes attributed to this region — the load signal the
    /// contention-aware policy prices.
    load: u64,
}

/// Internal atomic counters behind the fabric's [`LockStats`] snapshot.
#[derive(Debug, Default)]
struct LockCounters {
    region_acquisitions: AtomicU64,
    region_contended: AtomicU64,
    control_acquisitions: AtomicU64,
    control_contended: AtomicU64,
    cross_region_ops: AtomicU64,
}

/// Snapshot of the fabric's lock-contention counters (observability:
/// the scaling bench asserts the steady-state module path stays off the
/// region locks entirely).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LockStats {
    /// Region-shard lock acquisitions (each shard counts once per
    /// multi-region op).
    pub region_acquisitions: u64,
    /// Region-shard acquisitions that found the lock held and had to
    /// block.
    pub region_contended: u64,
    /// Control-plane lock acquisitions.
    pub control_acquisitions: u64,
    /// Control-plane acquisitions that had to block.
    pub control_contended: u64,
    /// Ops that took the ordered multi-region path (extent placement
    /// over >1 shard, spanning releases, host crash reclaim).
    pub cross_region_ops: u64,
}

/// Acquire `m` through the stats-counting path: `try_lock` first (so an
/// uncontended acquisition is one atomic + one CAS), fall back to a
/// blocking `lock` and count the contention.
fn lock_counted<'a, T>(
    m: &'a Mutex<T>,
    acq: &AtomicU64,
    contended: &AtomicU64,
) -> std::result::Result<MutexGuard<'a, T>, PoisonError<MutexGuard<'a, T>>> {
    acq.fetch_add(1, Ordering::Relaxed);
    match m.try_lock() {
        Ok(g) => Ok(g),
        Err(TryLockError::Poisoned(p)) => Err(p),
        Err(TryLockError::WouldBlock) => {
            contended.fetch_add(1, Ordering::Relaxed);
            m.lock()
        }
    }
}

/// Shared read guard over the expander (decoder/DMP/SAT tables and the
/// backing store). Derefs to [`Expander`]; any number may be held
/// concurrently, so `decode_hpa`/SAT checks never contend with each
/// other or with allocation.
pub struct ExpanderRead<'a>(RwLockReadGuard<'a, Expander>);

impl Deref for ExpanderRead<'_> {
    type Target = Expander;
    fn deref(&self) -> &Expander {
        &self.0
    }
}

/// Exclusive write guard over the expander (decoder/SAT mutation, data
/// writes, failure injection). Crate-internal acquisition only.
pub struct ExpanderWrite<'a>(RwLockWriteGuard<'a, Expander>);

impl Deref for ExpanderWrite<'_> {
    type Target = Expander;
    fn deref(&self) -> &Expander {
        &self.0
    }
}

impl DerefMut for ExpanderWrite<'_> {
    fn deref_mut(&mut self) -> &mut Expander {
        &mut self.0
    }
}

/// The Fabric Manager.
///
/// Owns the switch and expander; everything else goes through its API —
/// mirroring the paper, where the FM "can be implemented as software in
/// the host or firmware on a switch". Every method takes `&self`: the
/// sharded locks described in the module docs are internal, so the FM
/// can sit directly behind an `Arc` and be driven from any number of
/// threads.
#[derive(Debug)]
pub struct FabricManager {
    /// Fabric-wide panic seal. Held only for the duration of
    /// [`FabricRef::with_fm`] scopes; a panic inside one poisons it,
    /// and every fallible entry point checks it first so torn state is
    /// reported as [`Error::FabricPoisoned`] instead of being re-used.
    seal: Mutex<()>,
    control: Mutex<ControlPlane>,
    /// One shard per placement region, in ascending DPA order. Multi-
    /// region ops lock ascending — the deadlock-freedom rule.
    regions: Vec<Mutex<RegionShard>>,
    expander: RwLock<Expander>,
    /// Running total of free bytes — keeps [`FabricManager::available`]
    /// O(1) and lock-free.
    free_bytes: AtomicU64,
    /// Length of one placement region (DPA space / [`PLACEMENT_REGIONS`],
    /// rounded up to whole extents).
    region_len: u64,
    /// Total media capacity (cached; the expander sits behind its lock).
    capacity: u64,
    /// Fabric-global mmid counter (§3.2): handles are unique across
    /// every host sharing the expander, so one host's mmid can never
    /// alias another's — cross-host isolation keys off this.
    next_mmid: AtomicU64,
    stats: LockCounters,
    /// Pending injected latency strikes (fault plan `slow_region`). Each
    /// pending strike makes the next placement stall for a bounded spin
    /// before proceeding — a latency fault, never a correctness fault.
    slow_region: AtomicU32,
    /// Structured-event sink, armed at most once (first ring wins).
    /// Lock-free to read on the hot path; emission happens only after
    /// the counted fabric locks are released, so observability never
    /// perturbs the lock-stats counters or the lock order.
    events: OnceLock<EventSink>,
    /// Tiering ledger: the virtual→physical extent forward map (leaf
    /// lock — see the module docs) plus the lock-free per-extent heat
    /// counters the [`crate::tier::TierDaemon`] epoch-folds.
    tier: TierState,
    /// Cached fast/slow media boundary (`Expander::tier_boundary`), so
    /// tier arithmetic never needs the expander lock.
    tier_boundary: u64,
}

impl FabricManager {
    pub fn new(switch: PbrSwitch, expander: Expander) -> Self {
        let capacity = expander.capacity();
        let tier_boundary = expander.tier_boundary();
        let region_len =
            align_up(capacity.div_ceil(PLACEMENT_REGIONS).max(1), EXTENT_SIZE).max(EXTENT_SIZE);
        let region_count = capacity.div_ceil(region_len).max(1);
        let regions = (0..region_count)
            .map(|i| {
                let base = i * region_len;
                let len = capacity.saturating_sub(base).min(region_len);
                Mutex::new(RegionShard {
                    span: Range::new(base, len),
                    free: if len > 0 { vec![Range::new(base, len)] } else { Vec::new() },
                    leases: HashMap::new(),
                    load: 0,
                })
            })
            .collect();
        FabricManager {
            seal: Mutex::new(()),
            control: Mutex::new(ControlPlane {
                switch,
                hosts: HashMap::new(),
                next_host: 0,
                leased_bytes: HashMap::new(),
            }),
            regions,
            expander: RwLock::new(expander),
            free_bytes: AtomicU64::new(capacity),
            region_len,
            capacity,
            next_mmid: AtomicU64::new(1),
            stats: LockCounters::default(),
            slow_region: AtomicU32::new(0),
            events: OnceLock::new(),
            tier: TierState::new(capacity),
            tier_boundary,
        }
    }

    /// Arm a structured-event sink on this fabric (set-once: the first
    /// sink wins; later calls are no-ops). Alloc/free/quarantine/
    /// failover events flow into it from every thread sharing the FM.
    pub fn set_event_sink(&self, sink: EventSink) {
        let _ = self.events.set(sink);
    }

    /// Arm `n` latency strikes: each makes one subsequent placement
    /// stall for a bounded spin before touching any lock. Used by the
    /// fault-injection layer to model a congested region without
    /// changing any allocation outcome.
    pub fn inject_slow_region(&self, n: u32) {
        self.slow_region.fetch_add(n, Ordering::Relaxed);
    }

    /// Consume one pending latency strike, if armed. The stall is a
    /// bounded `yield_now` spin so a slow region can never hang a test.
    fn consume_slow_region(&self) {
        let mut cur = self.slow_region.load(Ordering::Relaxed);
        while cur > 0 {
            match self.slow_region.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    for _ in 0..64 {
                        std::thread::yield_now();
                    }
                    return;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Wrap this FM in a shared [`FabricRef`] handle (the only way
    /// hosts bind after the ownership split).
    pub fn into_shared(self) -> FabricRef {
        FabricRef::new(self)
    }

    /// `Err(FabricPoisoned)` once a panic has struck inside a fabric
    /// scope. Lock-free; every fallible module entry point calls this
    /// first.
    pub(crate) fn seal_check(&self) -> Result<()> {
        if self.seal.is_poisoned() {
            return Err(Error::FabricPoisoned);
        }
        Ok(())
    }

    /// Draw the next mmid from the fabric-global namespace. Called by
    /// the LMB modules at allocation time so handles never collide
    /// across hosts. Lock-free: this sits on the steady-state path.
    pub(crate) fn alloc_mmid(&self) -> MmId {
        MmId(self.next_mmid.fetch_add(1, Ordering::Relaxed))
    }

    // ---- lock plumbing ----

    fn control(&self) -> Result<MutexGuard<'_, ControlPlane>> {
        let s = &self.stats;
        lock_counted(&self.control, &s.control_acquisitions, &s.control_contended)
            .map_err(|_| Error::FabricPoisoned)
    }

    fn control_ignore_poison(&self) -> MutexGuard<'_, ControlPlane> {
        let s = &self.stats;
        lock_counted(&self.control, &s.control_acquisitions, &s.control_contended)
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// One region shard, surfacing that shard's poison as
    /// [`Error::FabricPoisoned`] — a panic in region `i` fails region
    /// `i`'s waiters, not the whole fabric.
    fn region(&self, idx: usize) -> Result<MutexGuard<'_, RegionShard>> {
        let s = &self.stats;
        lock_counted(&self.regions[idx], &s.region_acquisitions, &s.region_contended)
            .map_err(|_| Error::FabricPoisoned)
    }

    /// All region shards in ascending index order, *skipping* poisoned
    /// shards: their capacity is quarantined (invisible to placement)
    /// until the invariant audit decides it is salvageable, while every
    /// healthy region keeps allocating.
    fn lock_regions_for_alloc(&self) -> Vec<(usize, MutexGuard<'_, RegionShard>)> {
        if self.regions.len() > 1 {
            self.stats.cross_region_ops.fetch_add(1, Ordering::Relaxed);
        }
        let mut guards = Vec::with_capacity(self.regions.len());
        for (idx, m) in self.regions.iter().enumerate() {
            match lock_counted(m, &self.stats.region_acquisitions, &self.stats.region_contended) {
                Ok(g) => guards.push((idx, g)),
                Err(_poisoned) => {
                    // capacity quarantined: record that this placement
                    // pass skipped the poisoned shard
                    if let Some(sink) = self.events.get() {
                        sink.emit(Event::Quarantine { tick: sink.now(), lane: 0, region: idx });
                    }
                }
            }
        }
        guards
    }

    /// Uncounted, poison-tolerant access to every shard at once —
    /// observability and the post-mortem audit only.
    fn peek_all_regions(&self) -> Vec<MutexGuard<'_, RegionShard>> {
        self.regions.iter().map(|m| m.lock().unwrap_or_else(PoisonError::into_inner)).collect()
    }

    fn peek_control(&self) -> MutexGuard<'_, ControlPlane> {
        self.control.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Shared (read) access to the expander. Poison-tolerant: the
    /// expander's own mutations are short library code, and reads are
    /// exactly what a post-mortem needs.
    pub fn expander(&self) -> ExpanderRead<'_> {
        ExpanderRead(self.expander.read().unwrap_or_else(PoisonError::into_inner))
    }

    /// Exclusive (write) access to the expander. Crate-internal: the
    /// expander carries the SAT, and handing write access to arbitrary
    /// callers would bypass the module's owner checks.
    pub(crate) fn expander_mut(&self) -> ExpanderWrite<'_> {
        ExpanderWrite(self.expander.write().unwrap_or_else(PoisonError::into_inner))
    }

    /// Poison a region lock by panicking while holding it — the fault
    /// injection behind `testing::poison_region`. Never called on a
    /// production path.
    pub(crate) fn panic_holding_region(&self, idx: usize) {
        let _guard = self.regions[idx].lock().unwrap_or_else(PoisonError::into_inner);
        panic!("fault injection: panicking while holding region {idx} lock");
    }

    // ---- control plane ----

    /// Bind a host root port to the fabric.
    pub(crate) fn bind_host(&self) -> Result<(HostId, Spid)> {
        let mut control = self.control()?;
        let (spid, _) = control.switch.bind_host()?;
        let id = HostId(control.next_host);
        control.next_host += 1;
        control.hosts.insert(id, spid);
        Ok((id, spid))
    }

    /// Bind a CXL device (accelerator, CXL-SSD) to the fabric.
    pub(crate) fn bind_cxl_device(&self) -> Result<Spid> {
        let (spid, _) = self.control()?.switch.bind_cxl_device()?;
        Ok(spid)
    }

    /// Attach the GFD expander port (done once during bring-up). Returns
    /// the GFD's DPID — the P2P destination id the LMB module hands to
    /// CXL consumers via the Table 2 alloc/share out-params.
    pub(crate) fn attach_gfd(&self) -> Result<Dpid> {
        let mut control = self.control()?;
        let (_port, dpid) = control.switch.attach_gfd()?;
        // the expander reports this DPID in SAT-violation errors, so a
        // rejected P2P access names the real GFD port
        self.expander_mut().set_gfd_dpid(dpid);
        Ok(dpid)
    }

    /// DPID of the attached GFD (None before bring-up).
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.peek_control().switch.gfd_dpid()
    }

    /// Capacity not currently leased. O(1) and lock-free: a running
    /// atomic counter, not a free-list walk.
    pub fn available(&self) -> u64 {
        self.free_bytes.load(Ordering::Relaxed)
    }

    /// Capacity currently leased to `host`. O(1): a running per-host
    /// counter, not a lease-table scan.
    pub fn leased_to(&self, host: HostId) -> u64 {
        self.peek_control().leased_bytes.get(&host).copied().unwrap_or(0)
    }

    /// Total media capacity (cached at construction).
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Internal reader behind the unified `telemetry()` surface (the
    /// per-accessor `lock_stats` delegate was removed in 0.4). Pure
    /// atomic loads — takes no lock and bumps no counter.
    pub(crate) fn lock_counters_snapshot(&self) -> LockStats {
        LockStats {
            region_acquisitions: self.stats.region_acquisitions.load(Ordering::Relaxed),
            region_contended: self.stats.region_contended.load(Ordering::Relaxed),
            control_acquisitions: self.stats.control_acquisitions.load(Ordering::Relaxed),
            control_contended: self.stats.control_contended.load(Ordering::Relaxed),
            cross_region_ops: self.stats.cross_region_ops.load(Ordering::Relaxed),
        }
    }

    /// One uncounted read of every telemetry counter the fabric owns:
    /// `(lock stats, TLB hits, TLB misses)`. Feeds the unified
    /// [`StatsSnapshot`](crate::observe::StatsSnapshot); reading it
    /// disturbs neither the lock counters nor the TLB counters.
    pub(crate) fn telemetry_counters(&self) -> (LockStats, u64, u64) {
        let (hits, misses) = self.expander().tlb_counters();
        (self.lock_counters_snapshot(), hits, misses)
    }

    // ---- tiering: translation, heat, live migration ----

    /// Translate a *virtual* DPA (the address the owning module's
    /// records were minted with) to its current physical placement.
    /// Identity for extents that have never migrated. Callers must hold
    /// at least one of {seal, control, expander} so the translation
    /// cannot interleave with a migration commit (see module docs).
    pub(crate) fn resolve_dpa(&self, dpa: Dpa) -> Dpa {
        self.tier.resolve(dpa)
    }

    /// Data-path heat hook: record one access to the physical extent
    /// containing `phys`. Lock-free (a single relaxed `fetch_add`).
    pub(crate) fn note_media_access(&self, phys: Dpa) {
        self.tier.note(phys);
    }

    /// Fast/slow media boundary (cached `Expander::tier_boundary`):
    /// DPAs below it are device-DRAM-tier, at/above it PM-tier.
    pub fn tier_boundary(&self) -> u64 {
        self.tier_boundary
    }

    /// Which media tier the physical DPA `phys` currently sits on.
    pub fn tier_of_dpa(&self, phys: Dpa) -> MediaTier {
        if phys.0 < self.tier_boundary {
            MediaTier::Dram
        } else {
            MediaTier::Pm
        }
    }

    /// Epoch fold for the [`crate::tier::TierDaemon`]: one sample per
    /// leased extent — stable virtual identity, current placement,
    /// owner, tier, and the raw touch count accrued since the last fold
    /// (consumed by this call). Sorted by physical base so daemon
    /// decisions are deterministic despite the lease tables being hash
    /// maps. Uncounted, poison-tolerant reads: the daemon keeps running
    /// around a quarantined shard.
    pub(crate) fn tier_fold(&self) -> Vec<TierSample> {
        let guards = self.peek_all_regions();
        let mut out = Vec::new();
        for g in &guards {
            for e in g.leases.values() {
                if e.len == EXTENT_SIZE && e.dpa.0 % EXTENT_SIZE == 0 {
                    out.push(TierSample {
                        virt: self.tier.virtual_of(e.dpa.0),
                        phys: e.dpa,
                        owner: e.owner,
                        tier: self.tier_of_dpa(e.dpa),
                        touches: self.tier.take(e.dpa.0),
                    });
                }
            }
        }
        out.sort_by_key(|s| s.phys.0);
        out
    }

    /// Live extent migration: move the whole extent at *physical* base
    /// `phys` to the opposite media tier, under the fence.
    ///
    /// The caller holds the fabric seal (every invocation goes through
    /// [`FabricRef::with_fm`]), which is the reader drain: an active
    /// `with_io_session` holds the seal for its whole scope, so no IO
    /// session can straddle the copy. Inside, this takes control → every
    /// healthy region shard ascending → the expander write lock (the
    /// standard ordered path), then:
    ///
    /// 1. verifies the lease (whole, extent-aligned, live),
    /// 2. carves the lowest free extent-aligned span wholly inside the
    ///    destination tier band (deterministic),
    /// 3. copies the resident pages, re-targets HDM decoders (TLB
    ///    invalidated), rebases SAT grants, re-keys the lease, and
    ///    commits the virtual→physical forward map — all before any
    ///    lock drops, so no reader observes a torn placement,
    /// 4. emits `Migrate` then the terminal `Promote`/`Demote` after
    ///    the locks drop.
    ///
    /// With `abort_mid_copy` (a `migrate_abort` fault strike) the copy
    /// dies halfway: the half-written destination is wiped and returned
    /// to the pool, the source placement stays authoritative, and the
    /// terminal event is `Fault{migrate_abort}` instead. Refusals
    /// (unknown lease, quarantined source shard, no destination span,
    /// failed expander) error out *before* anything is carved and emit
    /// no `Migrate` — every emitted `Migrate` is terminally paired.
    pub(crate) fn migrate_extent(&self, phys: Dpa, abort_mid_copy: bool) -> Result<MigrateOutcome> {
        if phys.0 % EXTENT_SIZE != 0 {
            return Err(Error::FabricManager(format!(
                "migration source {:#x} not extent-aligned",
                phys.0
            )));
        }
        let control = self.control()?;
        let mut shards = self.lock_regions_for_alloc();
        let home = self.region_index(phys.0)?;
        let Some(home_pos) = shards.iter().position(|(idx, _)| *idx == home) else {
            // source shard poisoned: its capacity is quarantined, so its
            // extents stay put until the audit salvages the region
            return Err(Error::FabricPoisoned);
        };
        let ext = match shards[home_pos].1.leases.get(&phys.0) {
            Some(e) if e.len == EXTENT_SIZE => *e,
            Some(_) => {
                return Err(Error::FabricManager(
                    "migration source is not one whole extent".into(),
                ))
            }
            None => return Err(Error::FabricManager("unknown extent".into())),
        };
        let from = self.tier_of_dpa(phys);
        let to = from.other();
        let band = match to {
            MediaTier::Dram => Range::new(0, self.tier_boundary),
            MediaTier::Pm => Range::new(self.tier_boundary, self.capacity - self.tier_boundary),
        };
        if band.len < EXTENT_SIZE {
            return Err(Error::OutOfCapacity { requested: EXTENT_SIZE, available: 0 });
        }
        let mut exp = self.expander_mut();
        if exp.is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        // deterministic destination: the lowest extent-aligned free span
        // wholly inside the destination band (healthy shards ascending,
        // each shard's free list ascending)
        let mut dst_base: Option<u64> = None;
        'scan: for (_, g) in shards.iter() {
            for r in &g.free {
                let lo = align_up(r.base.max(band.base), EXTENT_SIZE);
                let hi = r.end().min(band.end());
                if lo < hi && hi - lo >= EXTENT_SIZE {
                    dst_base = Some(lo);
                    break 'scan;
                }
            }
        }
        let Some(dst) = dst_base else {
            return Err(Error::OutOfCapacity { requested: EXTENT_SIZE, available: 0 });
        };
        let dst_home = (dst / self.region_len) as usize;
        let dst_pos = shards
            .iter()
            .position(|(idx, _)| *idx == dst_home)
            .expect("destination span came from a locked shard");
        carve_span(&mut shards[dst_pos].1, dst, dst + EXTENT_SIZE);
        self.free_bytes.fetch_sub(EXTENT_SIZE, Ordering::Relaxed);
        let src_range = Range::new(phys.0, EXTENT_SIZE);
        let virt = self.tier.virtual_of(phys.0);
        let owner = ext.owner;
        let committed = if abort_mid_copy {
            // fault strike: the copy dies partway through — wipe the
            // half-written destination, return its span, and leave the
            // source placement authoritative
            let half = (EXTENT_SIZE / crate::cxl::types::PAGE_SIZE / 2).max(1) as usize;
            exp.copy_dpa_range(src_range, Dpa(dst), half);
            exp.wipe_dpa_range(Range::new(dst, EXTENT_SIZE));
            free_span(&mut shards[dst_pos].1, dst, dst + EXTENT_SIZE);
            self.free_bytes.fetch_add(EXTENT_SIZE, Ordering::Relaxed);
            false
        } else {
            exp.copy_dpa_range(src_range, Dpa(dst), usize::MAX);
            exp.retarget_decoders_dpa(src_range, Dpa(dst));
            exp.sat_mut().rebase_range(src_range, dst);
            exp.wipe_dpa_range(src_range);
            // move the lease to its new home shard, keyed by the new
            // physical base; owner and per-host accounting are unchanged
            shards[home_pos].1.leases.remove(&phys.0);
            shards[home_pos].1.load -= EXTENT_SIZE;
            shards[dst_pos].1.leases.insert(dst, Extent { dpa: Dpa(dst), len: EXTENT_SIZE, owner });
            shards[dst_pos].1.load += EXTENT_SIZE;
            free_span(&mut shards[home_pos].1, phys.0, phys.0 + EXTENT_SIZE);
            self.free_bytes.fetch_add(EXTENT_SIZE, Ordering::Relaxed);
            // unfolded heat follows the extent; the forward map commits
            // while control + shards + expander write are all held, so
            // translating readers serialize against this point
            self.tier.move_heat(phys.0, dst);
            self.tier.commit_move(virt, dst);
            true
        };
        // emit with every counted lock released (the standard pattern);
        // Migrate first, then its terminal pairing
        drop(exp);
        drop(shards);
        drop(control);
        if let Some(sink) = self.events.get() {
            let lane = owner.0 as usize;
            sink.emit(Event::Migrate { tick: sink.now(), lane, mmid: virt, from, to });
            if committed {
                let tick = sink.now();
                match to {
                    MediaTier::Dram => sink.emit(Event::Promote { tick, lane, mmid: virt }),
                    MediaTier::Pm => sink.emit(Event::Demote { tick, lane, mmid: virt }),
                }
            } else {
                sink.emit(Event::Fault {
                    tick: sink.now(),
                    lane,
                    point: FaultPoint::MigrateAbort,
                });
            }
        }
        if committed {
            Ok(MigrateOutcome::Committed { from, to, src: phys, dst: Dpa(dst) })
        } else {
            Ok(MigrateOutcome::Aborted { from, to })
        }
    }

    // ---- extent granting (ordered multi-region path) ----

    /// FM API: lease one 256 MB extent to `host` (§3.2).
    pub(crate) fn allocate_extent(&self, host: HostId) -> Result<Extent> {
        self.allocate_extent_sized(host, EXTENT_SIZE)
    }

    /// Lease an extent of arbitrary (page-aligned) size — used by tests
    /// and by the dynamic-capacity ablation. First-fit (the historical
    /// primitive); policy-driven placement goes through
    /// [`FabricManager::allocate_extent_placed`].
    pub(crate) fn allocate_extent_sized(&self, host: HostId, len: u64) -> Result<Extent> {
        self.allocate_extent_placed(host, len, PlacementPolicy::FirstFit)
    }

    /// Lease an extent, choosing the carve point by `policy` (see
    /// [`PlacementPolicy`]). The LMB modules call this with the policy
    /// their host was configured with.
    ///
    /// Placement is a *global* decision (both policies scan the whole
    /// free space), so this is the ordered two-phase path: control lock,
    /// then every healthy region shard ascending. The per-shard free
    /// lists are stitched back into the exact free list the single-lock
    /// FM kept — adjacent spans merge across shard boundaries — so both
    /// policies pick byte-identical carve points.
    pub(crate) fn allocate_extent_placed(
        &self,
        host: HostId,
        len: u64,
        policy: PlacementPolicy,
    ) -> Result<Extent> {
        self.consume_slow_region();
        let mut control = self.control()?;
        if !control.hosts.contains_key(&host) {
            return Err(Error::FabricManager(format!("unknown host {host:?}")));
        }
        let mut shards = self.lock_regions_for_alloc();
        if self.expander().is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        // merged view: the historical global free list (sorted, fully
        // coalesced), plus per-region loads for the contention model
        let mut merged: Vec<Range> = Vec::new();
        let mut loads = vec![0u64; self.regions.len()];
        for (idx, g) in &shards {
            loads[*idx] = g.load;
            for r in &g.free {
                match merged.last_mut() {
                    Some(last) if last.end() == r.base => {
                        *last = Range::new(last.base, last.len + r.len)
                    }
                    _ => merged.push(*r),
                }
            }
        }
        let base = match policy {
            PlacementPolicy::FirstFit => merged.iter().find(|r| r.len >= len).map(|r| r.base),
            PlacementPolicy::ContentionAware => self.pick_least_contended(&merged, &loads, len),
        };
        let base = base.ok_or(Error::OutOfCapacity {
            requested: len,
            available: self.available(),
        })?;
        // carve [base, base+len) out of every shard it crosses; the
        // lease is homed at the base's shard (base-attributed load)
        let home = (base / self.region_len) as usize;
        let last = ((base + len - 1) / self.region_len) as usize;
        let ext = Extent { dpa: Dpa(base), len, owner: host };
        for (idx, g) in shards.iter_mut() {
            if *idx < home || *idx > last {
                continue;
            }
            carve_span(g, base, base + len);
            if *idx == home {
                g.load += len;
                g.leases.insert(base, ext);
            }
        }
        self.free_bytes.fetch_sub(len, Ordering::Relaxed);
        *control.leased_bytes.entry(host).or_insert(0) += len;
        // emit with every counted lock released: observability stays
        // off the fabric's critical sections
        drop(shards);
        drop(control);
        if let Some(sink) = self.events.get() {
            sink.emit(Event::Alloc { tick: sink.now(), lane: host.0 as usize, mmid: ext.dpa.0 });
        }
        Ok(ext)
    }

    /// Cheapest carve point under the contention model: every free
    /// range's base plus each region boundary inside it is a candidate;
    /// each is priced by [`contention::placement_cost`] on the load its
    /// region would carry after the lease. Candidates are visited in
    /// ascending DPA order and only a strictly cheaper one replaces the
    /// incumbent, so equal-cost choices resolve to the lowest DPA —
    /// first-fit — exactly as documented on [`PlacementPolicy`].
    fn pick_least_contended(&self, free: &[Range], loads: &[u64], len: u64) -> Option<u64> {
        let mut best: Option<(f64, u64)> = None;
        for r in free {
            if r.len < len {
                continue;
            }
            let mut candidate = r.base;
            loop {
                let load = loads[(candidate / self.region_len) as usize] + len;
                let cost = contention::placement_cost(load, self.region_len);
                let cheaper = match best {
                    None => true,
                    Some((incumbent, _)) => cost < incumbent,
                };
                if cheaper {
                    best = Some((cost, candidate));
                }
                // advance to the next region boundary inside this range
                let next = (candidate / self.region_len + 1) * self.region_len;
                if next <= candidate || next + len > r.end() {
                    break;
                }
                candidate = next;
            }
        }
        best.map(|(_, base)| base)
    }

    /// Placement region owning `dpa`, attributed strictly by range: a
    /// DPA at or past the media capacity is an error, **not** silently
    /// clamped into the last region (the historical `region_of` used
    /// `min(..)` saturation, which mis-attributed out-of-range DPAs to
    /// the final region).
    pub fn region_index(&self, dpa: u64) -> Result<usize> {
        if dpa >= self.capacity {
            return Err(Error::FabricManager(format!(
                "DPA {dpa:#x} beyond media capacity {:#x}",
                self.capacity
            )));
        }
        Ok((dpa / self.region_len) as usize)
    }

    /// Placement-region observability: `(region_len, per-region leased
    /// bytes)`. The contention ablation derives its modeled cost metric
    /// from this. Uncounted reads (does not disturb `lock_stats`).
    pub fn placement_regions(&self) -> (u64, Vec<u64>) {
        let loads = self.peek_all_regions().iter().map(|g| g.load).collect();
        (self.region_len, loads)
    }

    /// The global free list, stitched from the shards (sorted, merged
    /// across shard boundaries) — observability and tests.
    pub fn free_ranges(&self) -> Vec<Range> {
        let guards = self.peek_all_regions();
        let mut merged: Vec<Range> = Vec::new();
        for g in &guards {
            for r in &g.free {
                match merged.last_mut() {
                    Some(last) if last.end() == r.base => {
                        *last = Range::new(last.base, last.len + r.len)
                    }
                    _ => merged.push(*r),
                }
            }
        }
        merged
    }

    /// FM API: return an extent (must be wholly unused by the caller).
    /// Locks only the shards the extent spans, ascending. `ext.dpa` is
    /// the caller's *virtual* DPA; it is translated to the current
    /// physical placement under the control lock, so a concurrent
    /// migration commit cannot interleave with the lookup.
    pub(crate) fn release_extent(&self, host: HostId, ext: Extent) -> Result<()> {
        let mut control = self.control()?;
        let phys = self.tier.resolve(ext.dpa);
        let home = self.region_index(phys.0)?;
        let last = self.region_index(phys.0 + ext.len.max(1) - 1)?;
        if home != last {
            self.stats.cross_region_ops.fetch_add(1, Ordering::Relaxed);
        }
        let mut guards = Vec::with_capacity(last - home + 1);
        for idx in home..=last {
            guards.push(self.region(idx)?);
        }
        match guards[0].leases.get(&phys.0) {
            Some(e) if e.owner == host && e.len == ext.len => {}
            Some(_) => {
                return Err(Error::FabricManager("extent not owned by caller".into()));
            }
            None => return Err(Error::FabricManager("unknown extent".into())),
        }
        guards[0].leases.remove(&phys.0);
        guards[0].load -= ext.len;
        for g in guards.iter_mut() {
            free_span(g, phys.0, phys.0 + ext.len);
        }
        self.free_bytes.fetch_add(ext.len, Ordering::Relaxed);
        // drop the released extent's ledger entry and residual heat
        self.tier.forget_phys(phys.0);
        if let Some(v) = control.leased_bytes.get_mut(&host) {
            *v -= ext.len;
            if *v == 0 {
                control.leased_bytes.remove(&host);
            }
        }
        drop(guards);
        drop(control);
        if let Some(sink) = self.events.get() {
            sink.emit(Event::Free { tick: sink.now(), lane: host.0 as usize, mmid: ext.dpa.0 });
        }
        Ok(())
    }

    // ---- GFD management ----

    /// GFD management: add a SAT entry for a CXL device (§3.3). The
    /// control lock is held across the grant so a concurrent
    /// crash-reclaim cannot interleave between the bind check and the
    /// SAT write. `range` is module-virtual; it is translated to the
    /// current physical placement under the control lock (migration
    /// commits hold control too), so the SAT always describes physical
    /// media and `rebase_range` keeps it that way across migrations.
    pub(crate) fn sat_grant(&self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        let control = self.control()?;
        if !control.switch.is_bound(spid) {
            return Err(Error::FabricManager(format!("SPID {spid:?} not bound")));
        }
        let phys = self.tier.resolve_range(range);
        let res = self.expander_mut().sat_grant(spid, phys, perm);
        drop(control);
        res
    }

    /// GFD management: remove a SAT entry. The module-virtual range is
    /// translated inside the expander write scope — migration commits
    /// hold that lock, so the translation cannot go stale mid-revoke.
    pub(crate) fn sat_revoke(&self, spid: Spid, range: Range) -> Result<()> {
        let mut exp = self.expander_mut();
        let phys = self.tier.resolve_range(range);
        exp.sat_revoke(spid, phys)
    }

    /// Release everything a host holds (host crash / module unload).
    ///
    /// Before each extent returns to the pool, every SAT grant and HDM
    /// decoder covering its DPA range is torn down: a crashed host
    /// cannot clean up after itself, and a stale CXL device keeping P2P
    /// access to re-leased memory would be an isolation hole. Siblings'
    /// extents cover disjoint DPA ranges, so their grants, decoders and
    /// placements are untouched.
    ///
    /// Poison-tolerant throughout (crash cleanup must run even after a
    /// panic), and a full ordered sweep: control, every region
    /// ascending, then one expander write scope.
    pub(crate) fn release_host(&self, host: HostId) {
        self.stats.cross_region_ops.fetch_add(1, Ordering::Relaxed);
        let mut control = self.control_ignore_poison();
        let mut guards: Vec<MutexGuard<'_, RegionShard>> = self
            .regions
            .iter()
            .map(|m| {
                lock_counted(m, &self.stats.region_acquisitions, &self.stats.region_contended)
                    .unwrap_or_else(PoisonError::into_inner)
            })
            .collect();
        let owned: Vec<Extent> = guards
            .iter()
            .flat_map(|g| g.leases.values().filter(|e| e.owner == host).copied())
            .collect();
        {
            let mut exp = self.expander_mut();
            for e in &owned {
                let media = Range::new(e.dpa.0, e.len);
                exp.sat_revoke_overlapping(media);
                exp.remove_decoders_overlapping_dpa(media);
            }
        }
        let mut reclaimed = 0;
        for e in &owned {
            let home = (e.dpa.0 / self.region_len) as usize;
            let last = ((e.dpa.0 + e.len.max(1) - 1) / self.region_len) as usize;
            guards[home].leases.remove(&e.dpa.0);
            guards[home].load -= e.len;
            for g in guards[home..=last].iter_mut() {
                free_span(g, e.dpa.0, e.dpa.0 + e.len);
            }
            // the lease tables store physical placements: drop each
            // extent's forward-map entry and residual heat with it
            self.tier.forget_phys(e.dpa.0);
            reclaimed += e.len;
        }
        self.free_bytes.fetch_add(reclaimed, Ordering::Relaxed);
        control.leased_bytes.remove(&host);
        if let Some(spid) = control.hosts.remove(&host) {
            let _ = control.switch.unbind(spid);
        }
    }

    /// Number of live leases (for invariant checks). Uncounted reads.
    pub fn lease_count(&self) -> usize {
        self.peek_all_regions().iter().map(|g| g.leases.len()).sum()
    }

    /// Invariant: every shard's free list is sorted, non-overlapping,
    /// coalesced and inside its span; every lease is homed in the right
    /// shard; the running `free_bytes` / `leased_bytes` / per-region
    /// load counters agree with the ground-truth tables; free+leased
    /// covers exactly the media; and the expander's own indexing
    /// invariants (sorted decoder/DMP/SAT tables) hold. Used by
    /// property tests. Poison-tolerant: after a panic this is the audit
    /// that decides whether the state underneath is still sound.
    pub fn check_invariants(&self) -> Result<()> {
        let control = self.peek_control();
        let guards = self.peek_all_regions();
        let mut free_sum = 0u64;
        let mut leased_sum = 0u64;
        let mut per_host: HashMap<HostId, u64> = HashMap::new();
        for (idx, g) in guards.iter().enumerate() {
            let mut prev_end = None;
            for r in &g.free {
                if r.base < g.span.base || r.end() > g.span.end() {
                    return Err(Error::FabricManager(format!(
                        "region {idx}: free range outside shard span"
                    )));
                }
                if let Some(pe) = prev_end {
                    if r.base < pe {
                        return Err(Error::FabricManager("free list overlap".into()));
                    }
                    if r.base == pe {
                        return Err(Error::FabricManager("free list not coalesced".into()));
                    }
                }
                prev_end = Some(r.end());
                free_sum += r.len;
            }
            let mut shard_leased = 0u64;
            for e in g.leases.values() {
                if (e.dpa.0 / self.region_len) as usize != idx {
                    return Err(Error::FabricManager(format!(
                        "lease {:#x} homed in wrong region {idx}",
                        e.dpa.0
                    )));
                }
                *per_host.entry(e.owner).or_insert(0) += e.len;
                shard_leased += e.len;
            }
            if shard_leased != g.load {
                return Err(Error::FabricManager(format!(
                    "region {idx} load drift: counter {} != lease sum {shard_leased}",
                    g.load
                )));
            }
            leased_sum += shard_leased;
        }
        if free_sum != self.available() {
            return Err(Error::FabricManager(format!(
                "free_bytes drift: counter {} != free list sum {free_sum}",
                self.available()
            )));
        }
        if per_host != control.leased_bytes {
            return Err(Error::FabricManager(format!(
                "leased_bytes drift: counters {:?} != lease table {per_host:?}",
                control.leased_bytes
            )));
        }
        if free_sum + leased_sum != self.capacity {
            return Err(Error::FabricManager(format!(
                "capacity leak: free+leased={} != {}",
                free_sum + leased_sum,
                self.capacity
            )));
        }
        // tier forward map audit: every entry forwards one extent-
        // aligned virtual base to a *distinct*, extent-aligned, live
        // physical lease — a dangling or duplicated entry would alias
        // two extents through translation
        let mut phys_seen: HashMap<u64, u64> = HashMap::new();
        for (virt, phys) in self.tier.forward_snapshot() {
            if virt % EXTENT_SIZE != 0 || phys % EXTENT_SIZE != 0 {
                return Err(Error::FabricManager(format!(
                    "tier map entry {virt:#x}->{phys:#x} not extent-aligned"
                )));
            }
            if virt == phys {
                return Err(Error::FabricManager(format!(
                    "tier map identity entry {virt:#x} should be absent"
                )));
            }
            if let Some(prior) = phys_seen.insert(phys, virt) {
                return Err(Error::FabricManager(format!(
                    "tier map aliases {prior:#x} and {virt:#x} to {phys:#x}"
                )));
            }
            let home = (phys / self.region_len) as usize;
            match guards.get(home).and_then(|g| g.leases.get(&phys)) {
                Some(e) if e.len == EXTENT_SIZE => {}
                _ => {
                    return Err(Error::FabricManager(format!(
                        "tier map entry {virt:#x}->{phys:#x} dangles (no live extent lease)"
                    )));
                }
            }
        }
        drop(guards);
        drop(control);
        self.expander().check_invariants()
    }
}

/// Carve `[lo, hi)` (clamped to the shard's span) out of the shard's
/// free list. The span to remove always lies inside a single free range
/// of the shard: the allocation view only merges *adjacent* pieces, and
/// a shard's own free list is kept coalesced.
fn carve_span(shard: &mut RegionShard, lo: u64, hi: u64) {
    let lo = lo.max(shard.span.base);
    let hi = hi.min(shard.span.end());
    if lo >= hi {
        return;
    }
    let pos = shard.free.partition_point(|r| r.base <= lo) - 1;
    let r = shard.free[pos];
    debug_assert!(lo >= r.base && hi <= r.end());
    let left = lo - r.base;
    let right = r.end() - hi;
    match (left > 0, right > 0) {
        (false, false) => {
            shard.free.remove(pos);
        }
        (true, false) => shard.free[pos] = Range::new(r.base, left),
        (false, true) => shard.free[pos] = Range::new(hi, right),
        (true, true) => {
            shard.free[pos] = Range::new(r.base, left);
            shard.free.insert(pos + 1, Range::new(hi, right));
        }
    }
}

/// Return `[lo, hi)` (clamped to the shard's span) to the shard's free
/// list, inserting sorted and coalescing with both neighbours.
fn free_span(shard: &mut RegionShard, lo: u64, hi: u64) {
    let lo = lo.max(shard.span.base);
    let hi = hi.min(shard.span.end());
    if lo >= hi {
        return;
    }
    let mut r = Range::new(lo, hi - lo);
    let idx = shard.free.partition_point(|f| f.base < r.base);
    // coalesce with next
    if idx < shard.free.len() && r.end() == shard.free[idx].base {
        r = Range::new(r.base, r.len + shard.free[idx].len);
        shard.free.remove(idx);
    }
    // coalesce with previous
    if idx > 0 && shard.free[idx - 1].end() == r.base {
        let prev = shard.free[idx - 1];
        shard.free[idx - 1] = Range::new(prev.base, prev.len + r.len);
    } else {
        shard.free.insert(idx, r);
    }
}

/// Shared, cheap-to-clone, `Send + Sync` handle to the
/// [`FabricManager`].
///
/// The ownership split for multi-host sharding: no `LmbHost` owns the
/// FM any more — the switch, expander, lease table and fabric-global
/// mmid namespace live behind this handle, and any number of hosts
/// (and their driver threads) bind through clones of it. Since the FM
/// shards its own locks (module docs), the handle is a plain `Arc`:
/// concurrent callers contend only on the specific region / control /
/// expander lock their operation needs, not on one fabric-wide mutex.
///
/// **Poisoning.** A panic inside a [`FabricRef::with_fm`] scope poisons
/// the fabric *seal*; fallible operations then return
/// [`Error::FabricPoisoned`] instead of running on torn state. A panic
/// holding a single region lock poisons only that region: its waiters
/// see [`Error::FabricPoisoned`], while allocation quarantines the
/// shard and keeps serving from healthy regions. The infallible
/// observability reads (`available`, `leased_to`, …) and
/// [`FabricRef::check_invariants`] deliberately bypass both poison
/// flags — the invariant checker is exactly the tool that decides
/// whether post-panic state is salvageable.
///
/// There is deliberately **no** public way to mutate lease or
/// access-control state through the handle — the FM's extent / SAT /
/// binding mutators are crate-internal and only reachable through the
/// owner-checked `LmbHost`/`LmbModule`/`Cluster` surfaces, so lease
/// ownership and grant checks cannot be bypassed. Publicly the handle
/// offers scoped reads ([`FabricRef::with_fm`], `available`,
/// `leased_to`, …), the host-trusted data plane
/// ([`FabricRef::write_dpa`] / [`FabricRef::read_dpa`]), failure
/// injection, and device binding.
#[derive(Debug, Clone)]
pub struct FabricRef {
    inner: Arc<FabricManager>,
}

impl FabricRef {
    pub fn new(fm: FabricManager) -> Self {
        FabricRef { inner: Arc::new(fm) }
    }

    /// Run `f` with a shared view of the FM. The fabric seal is held
    /// for the closure's duration: a panic inside `f` poisons it and
    /// later fallible callers see [`Error::FabricPoisoned`]. Reads
    /// inside the closure take the FM's internal shard locks as needed;
    /// do not stash borrows past the closure.
    pub fn with_fm<R>(&self, f: impl FnOnce(&FabricManager) -> R) -> Result<R> {
        let _seal = self.inner.seal.lock().map_err(|_| Error::FabricPoisoned)?;
        Ok(f(&self.inner))
    }

    /// Direct crate-internal access to the sharded FM (no seal scope):
    /// the module/queue execute paths take exactly the locks they need.
    pub(crate) fn manager(&self) -> &FabricManager {
        &self.inner
    }

    /// Number of live handles sharing this fabric (hosts + clusters +
    /// caller clones).
    pub fn handle_count(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Region-poison fault injection for tests (see
    /// `testing::poison_region`).
    pub(crate) fn poison_region_for_test(&self, idx: usize) {
        self.inner.panic_holding_region(idx)
    }

    // ---- forwarded FM control plane (scoped locks) ----

    /// [`FabricManager::bind_cxl_device`] — attaching a CXL consumer
    /// takes a switch port but cannot touch any host's leases.
    pub fn bind_cxl_device(&self) -> Result<Spid> {
        self.inner.seal_check()?;
        self.inner.bind_cxl_device()
    }

    /// [`FabricManager::gfd_dpid`]. Poison-tolerant read.
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.inner.gfd_dpid()
    }

    /// [`FabricManager::available`]. Poison-tolerant, lock-free read.
    pub fn available(&self) -> u64 {
        self.inner.available()
    }

    /// [`FabricManager::leased_to`]. Poison-tolerant read.
    pub fn leased_to(&self, host: HostId) -> u64 {
        self.inner.leased_to(host)
    }

    /// [`FabricManager::lease_count`]. Poison-tolerant read.
    pub fn lease_count(&self) -> usize {
        self.inner.lease_count()
    }

    /// Total expander media capacity. Poison-tolerant read, so the
    /// cluster-level capacity audit keeps working after a panic.
    pub fn capacity(&self) -> u64 {
        self.inner.capacity()
    }

    /// [`FabricManager::set_event_sink`] — arm the structured-event
    /// sink on the shared fabric (set-once; first ring wins).
    pub fn set_event_sink(&self, sink: EventSink) {
        self.inner.set_event_sink(sink)
    }

    /// [`FabricManager::telemetry_counters`] — every fabric-owned
    /// telemetry counter in one uncounted read.
    pub(crate) fn telemetry_counters(&self) -> (LockStats, u64, u64) {
        self.inner.telemetry_counters()
    }

    /// The fabric-side slice of the unified telemetry snapshot: lock
    /// and decoder-TLB counters, with the service-owned fields (queue,
    /// retries, faults, events) zeroed. For standalone-fabric drivers
    /// — benches sampling contention with no [`crate::lmb::FmService`]
    /// alive — now that the per-accessor `lock_stats` delegate is gone.
    pub fn telemetry(&self) -> crate::observe::StatsSnapshot {
        let (lock, tlb_hits, tlb_misses) = self.telemetry_counters();
        crate::observe::StatsSnapshot { lock, tlb_hits, tlb_misses, ..Default::default() }
    }

    /// [`FabricManager::release_host`] — crate-internal: reclaiming a
    /// host is the [`Cluster`](crate::cluster::Cluster) crash path, not
    /// something an arbitrary handle-holder may do to a sibling.
    /// Poison-tolerant: crash cleanup must run even after a panic.
    pub(crate) fn release_host(&self, host: HostId) {
        self.inner.release_host(host)
    }

    /// [`FabricManager::check_invariants`]. Deliberately
    /// poison-tolerant: after a panic inside a fabric scope this is the
    /// audit that decides whether the state underneath is still sound.
    pub fn check_invariants(&self) -> Result<()> {
        self.inner.check_invariants()
    }

    // ---- expander data plane / failure injection ----

    /// Functional write at a (module-virtual) DPA through the shared
    /// expander. The address is translated to its current physical
    /// placement inside the expander write scope — migration commits
    /// hold that lock, so the translation cannot go stale mid-write —
    /// and the access heats the physical extent for the tiering engine.
    pub fn write_dpa(&self, dpa: Dpa, data: &[u8]) -> Result<()> {
        self.inner.seal_check()?;
        let mut exp = self.inner.expander_mut();
        let phys = self.inner.resolve_dpa(dpa);
        self.inner.note_media_access(phys);
        exp.write_dpa(phys, data)
    }

    /// Functional read at a (module-virtual) DPA through the shared
    /// expander. Takes only the expander read lock: concurrent readers
    /// proceed in parallel, while a migration commit (expander *write*)
    /// excludes them — so the translate-then-read pair is atomic.
    pub fn read_dpa(&self, dpa: Dpa, out: &mut [u8]) -> Result<()> {
        self.inner.seal_check()?;
        let exp = self.inner.expander();
        let phys = self.inner.resolve_dpa(dpa);
        self.inner.note_media_access(phys);
        exp.read_dpa(phys, out)
    }

    // ---- tiering ----

    /// Live-migrate the extent containing (module-virtual) `dpa` to the
    /// opposite media tier. The seal is held for the whole operation —
    /// the same fence active IO sessions hold — so readers drain before
    /// the copy and no one observes a torn placement. See
    /// `FabricManager::migrate_extent` for the full protocol.
    pub fn migrate_extent(&self, dpa: Dpa) -> Result<MigrateOutcome> {
        self.with_fm(|fm| {
            let phys = fm.resolve_dpa(dpa);
            fm.migrate_extent(phys, false)
        })?
    }

    /// Fault-injection variant of [`FabricRef::migrate_extent`]: the
    /// copy aborts halfway (as a `migrate_abort` strike would make it)
    /// and rolls back to the source placement. Test/drill hook, like
    /// [`FabricRef::inject_slow_region`].
    pub fn migrate_extent_aborting(&self, dpa: Dpa) -> Result<MigrateOutcome> {
        self.with_fm(|fm| {
            let phys = fm.resolve_dpa(dpa);
            fm.migrate_extent(phys, true)
        })?
    }

    /// [`FabricManager::tier_boundary`] — the fast/slow media boundary.
    pub fn tier_boundary(&self) -> u64 {
        self.inner.tier_boundary()
    }

    /// Which media tier the extent containing (module-virtual) `dpa`
    /// currently sits on. Seal-scoped so the answer is not torn by a
    /// concurrent migration.
    pub fn tier_of(&self, dpa: Dpa) -> Result<MediaTier> {
        self.with_fm(|fm| fm.tier_of_dpa(fm.resolve_dpa(dpa)))
    }

    /// Fail / recover the shared expander (failure-injection hook; one
    /// expander failure hits every bound host). Poison-tolerant so
    /// failure drills can still run after an unrelated panic.
    pub fn set_expander_failed(&self, failed: bool) {
        self.inner.expander_mut().set_failed(failed);
        if let Some(sink) = self.inner.events.get() {
            sink.emit(Event::Failover { tick: sink.now(), lane: 0, restored: !failed });
        }
    }

    /// Poison-tolerant read.
    pub fn expander_failed(&self) -> bool {
        self.inner.expander().is_failed()
    }

    /// [`FabricManager::inject_slow_region`] — arm `n` bounded latency
    /// strikes against subsequent placements (failure-injection hook).
    pub fn inject_slow_region(&self, n: u32) {
        self.inner.inject_slow_region(n)
    }

    /// Scoped mutable access to the expander for in-crate data-plane
    /// helpers that need `&mut Expander` (e.g. the L2P table's
    /// `flush_to_fabric`). Crate-internal on purpose: the expander
    /// carries the SAT, and handing `&mut Expander` to arbitrary
    /// callers would let them program grants without the module's owner
    /// checks. External data-plane access goes through
    /// [`FabricRef::write_dpa`] / [`FabricRef::read_dpa`].
    pub(crate) fn with_expander_mut<R>(&self, f: impl FnOnce(&mut Expander) -> R) -> Result<R> {
        self.inner.seal_check()?;
        let mut exp = self.inner.expander_mut();
        Ok(f(&mut exp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::ExpanderConfig;
    use crate::cxl::types::{GIB, PAGE_SIZE};

    fn fm(cap: u64) -> FabricManager {
        let f = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: cap, ..Default::default() }),
        );
        f.attach_gfd().unwrap();
        f
    }

    #[test]
    fn extent_lease_and_release_roundtrip() {
        let f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let e = f.allocate_extent(h).unwrap();
        assert_eq!(e.len, EXTENT_SIZE);
        assert_eq!(f.available(), GIB - EXTENT_SIZE);
        f.release_extent(h, e).unwrap();
        assert_eq!(f.available(), GIB);
        f.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhaustion_reports_available() {
        let f = fm(EXTENT_SIZE); // room for exactly one extent
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        match f.allocate_extent(h) {
            Err(Error::OutOfCapacity { available, .. }) => assert_eq!(available, 0),
            other => panic!("expected OutOfCapacity, got {other:?}"),
        }
    }

    #[test]
    fn release_coalesces_neighbours() {
        let f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h).unwrap();
        let b = f.allocate_extent(h).unwrap();
        let c = f.allocate_extent(h).unwrap();
        f.release_extent(h, a).unwrap();
        f.release_extent(h, c).unwrap();
        f.release_extent(h, b).unwrap(); // middle release must merge all
        f.check_invariants().unwrap();
        assert_eq!(f.available(), GIB);
        assert_eq!(f.free_ranges().len(), 1, "free list fully coalesced");
    }

    #[test]
    fn free_ranges_merge_across_shard_boundaries() {
        // a fresh pool is split across region shards internally, but
        // the merged observability view is the one historical range
        let f = fm(GIB);
        assert!(f.placement_regions().1.len() > 1, "sharded pool");
        assert_eq!(f.free_ranges(), vec![Range::new(0, GIB)]);
    }

    #[test]
    fn multi_host_isolation() {
        let f = fm(GIB);
        let (h1, _) = f.bind_host().unwrap();
        let (h2, _) = f.bind_host().unwrap();
        let e1 = f.allocate_extent(h1).unwrap();
        assert!(f.release_extent(h2, e1).is_err(), "host2 cannot release host1's extent");
        assert_eq!(f.leased_to(h1), EXTENT_SIZE);
        assert_eq!(f.leased_to(h2), 0);
    }

    #[test]
    fn release_host_reclaims_everything() {
        let f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        f.allocate_extent(h).unwrap();
        f.release_host(h);
        assert_eq!(f.available(), GIB);
        assert_eq!(f.lease_count(), 0);
        assert!(f.allocate_extent(h).is_err(), "host is gone");
    }

    #[test]
    fn release_host_revokes_stale_sat_grants() {
        // Regression: release_host used to free a host's extents and
        // unbind its SPID without touching the SAT, so a CXL device
        // kept P2P access to memory later re-leased to another host.
        let f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let e = f.allocate_extent(h).unwrap();
        f.sat_grant(dev, Range::new(e.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        assert!(f.expander().sat().check(dev, e.dpa, 64, true));

        f.release_host(h);
        assert!(
            !f.expander().sat().check(dev, e.dpa, 64, false),
            "stale P2P grant revoked with the lease"
        );

        // the reclaimed DPA re-leases cleanly: a fresh grant over the
        // same range is not rejected as overlapping
        let (h2, _) = f.bind_host().unwrap();
        let e2 = f.allocate_extent(h2).unwrap();
        assert_eq!(e2.dpa, e.dpa, "first-fit re-leases the freed extent");
        f.sat_grant(dev, Range::new(e2.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        f.check_invariants().unwrap();
    }

    #[test]
    fn release_host_preserves_sibling_grants_and_decoders() {
        let f = fm(GIB);
        let (ha, _) = f.bind_host().unwrap();
        let (hb, _) = f.bind_host().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let ea = f.allocate_extent(ha).unwrap();
        let eb = f.allocate_extent(hb).unwrap();
        f.sat_grant(dev, Range::new(eb.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        f.expander_mut().add_decoder(Range::new(1 << 40, eb.len), eb.dpa).unwrap();

        f.release_host(ha);
        assert_eq!(f.available(), GIB - EXTENT_SIZE, "only ha's extent returned");
        assert_eq!(f.leased_to(hb), EXTENT_SIZE);
        assert!(f.expander().sat().check(dev, eb.dpa, 64, true), "sibling grant untouched");
        assert_eq!(f.expander().decode_hpa(crate::cxl::types::Hpa(1 << 40)).unwrap(), eb.dpa);
        let _ = ea;
    }

    #[test]
    fn running_counters_track_alloc_release_and_crash() {
        let f = fm(GIB);
        let (h1, _) = f.bind_host().unwrap();
        let (h2, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h1).unwrap();
        let b = f.allocate_extent(h2).unwrap();
        f.allocate_extent(h1).unwrap();
        assert_eq!(f.available(), GIB - 3 * EXTENT_SIZE);
        assert_eq!(f.leased_to(h1), 2 * EXTENT_SIZE);
        assert_eq!(f.leased_to(h2), EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_extent(h1, a).unwrap();
        assert_eq!(f.leased_to(h1), EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_host(h1);
        assert_eq!(f.leased_to(h1), 0);
        assert_eq!(f.available(), GIB - EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_extent(h2, b).unwrap();
        assert_eq!(f.available(), GIB);
        assert_eq!(f.leased_to(h2), 0);
        f.check_invariants().unwrap();
    }

    #[test]
    fn p2p_violation_through_fm_names_real_gfd_dpid() {
        use crate::cxl::packet::{CxlMemReq, MemAddr};
        use crate::cxl::types::Requester;
        let f = fm(GIB);
        let gfd = f.gfd_dpid().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0x40)), 64, Requester::CxlDevice(dev));
        match f.expander_mut().access(&req) {
            Err(Error::SatViolation { dpid, .. }) => assert_eq!(dpid, gfd),
            other => panic!("expected SatViolation, got {other:?}"),
        }
    }

    #[test]
    fn failed_expander_blocks_allocation() {
        let f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.expander_mut().set_failed(true);
        assert!(matches!(f.allocate_extent(h), Err(Error::ExpanderFailed(_))));
    }

    #[test]
    fn region_index_attributes_by_range_not_clamp() {
        // 9 extents of media → 512 MiB regions, with a short 256 MiB
        // final region: [0,2E) [2E,4E) [4E,6E) [6E,8E) [8E,9E)
        let f = fm(9 * EXTENT_SIZE);
        let (region_len, loads) = f.placement_regions();
        assert_eq!(region_len, 2 * EXTENT_SIZE);
        assert_eq!(loads.len(), 5);
        assert_eq!(f.region_index(0).unwrap(), 0);
        assert_eq!(f.region_index(8 * EXTENT_SIZE).unwrap(), 4);
        // the final boundary: last valid byte is region 4 ...
        assert_eq!(f.region_index(9 * EXTENT_SIZE - 1).unwrap(), 4);
        // ... but capacity itself, and anything past it, is an error —
        // the old `min(..)` clamp silently attributed these to region 4
        assert!(f.region_index(9 * EXTENT_SIZE).is_err());
        assert!(f.region_index(9 * EXTENT_SIZE + region_len).is_err());
        assert!(f.region_index(u64::MAX).is_err());
        // the short final region is still allocatable end to end
        let (h, _) = f.bind_host().unwrap();
        let mut last = None;
        for _ in 0..9 {
            last = Some(f.allocate_extent(h).unwrap());
        }
        assert_eq!(last.unwrap().dpa, Dpa(8 * EXTENT_SIZE), "9th extent fills the short region");
        assert!(f.allocate_extent(h).is_err(), "pool exactly full");
        f.check_invariants().unwrap();
    }

    #[test]
    fn fabric_ref_shares_one_fm_across_clones() {
        let fabric = fm(GIB).into_shared();
        let other = fabric.clone();
        assert_eq!(fabric.handle_count(), 2);
        // lease mutation is crate-internal (module/cluster paths); the
        // test reaches it through the same scoped seal they use
        let (h1, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let (h2, _) = other.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        assert_ne!(h1, h2, "clones bind against the same id space");
        fabric.with_fm(|fm| fm.allocate_extent(h1)).unwrap().unwrap();
        other.with_fm(|fm| fm.allocate_extent(h2)).unwrap().unwrap();
        assert_eq!(fabric.available(), GIB - 2 * EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h1), EXTENT_SIZE);
        assert_eq!(other.leased_to(h2), EXTENT_SIZE);
        fabric.release_host(h1);
        assert_eq!(other.available(), GIB - EXTENT_SIZE, "capacity back in the shared pool");
        other.check_invariants().unwrap();
    }

    #[test]
    fn fabric_ref_expander_data_plane_round_trip() {
        let fabric = fm(GIB).into_shared();
        fabric.write_dpa(Dpa(0x4000), b"shared-bytes").unwrap();
        let mut buf = [0u8; 12];
        fabric.read_dpa(Dpa(0x4000), &mut buf).unwrap();
        assert_eq!(&buf, b"shared-bytes");
        fabric.set_expander_failed(true);
        assert!(fabric.expander_failed());
        assert!(fabric.read_dpa(Dpa(0x4000), &mut buf).is_err());
        fabric.set_expander_failed(false);
        let pages = fabric.with_expander_mut(|e| e.resident_pages()).unwrap();
        assert!(pages > 0);
    }

    #[test]
    fn fabric_ref_is_send_sync_and_shares_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FabricRef>();

        let fabric = fm(GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let worker = {
            let fabric = fabric.clone();
            std::thread::spawn(move || {
                fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
                fabric.available()
            })
        };
        let seen = worker.join().unwrap();
        assert_eq!(seen, GIB - EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h), EXTENT_SIZE, "lease visible from the spawning thread");
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn panic_inside_scope_poisons_and_surfaces_fabric_poisoned() {
        let fabric = fm(GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();

        // panic on another thread mid-scope: the seal poisons, the
        // process does not abort
        let victim = {
            let fabric = fabric.clone();
            std::thread::spawn(move || {
                let _: Result<()> =
                    fabric.with_fm(|_fm| panic!("driver thread died holding the fabric seal"));
            })
        };
        assert!(victim.join().is_err(), "the panicking thread reports the panic");

        // fallible paths surface the poison as a typed error...
        assert!(matches!(fabric.with_fm(|fm| fm.lease_count()), Err(Error::FabricPoisoned)));
        assert!(matches!(fabric.write_dpa(Dpa(0), b"x"), Err(Error::FabricPoisoned)));
        assert!(matches!(fabric.bind_cxl_device(), Err(Error::FabricPoisoned)));
        assert!(matches!(
            fabric.with_expander_mut(|e| e.resident_pages()),
            Err(Error::FabricPoisoned)
        ));

        // ...while the poison-tolerant audit surface still works: the
        // panic struck before any mutation, so the state is sound
        fabric.check_invariants().unwrap();
        assert_eq!(fabric.available(), GIB - EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h), EXTENT_SIZE);

        // and crash reclaim still runs post-poison
        fabric.release_host(h);
        assert_eq!(fabric.available(), GIB);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn poisoned_region_is_quarantined_not_fatal() {
        // 4 GiB pool → 8 regions of 512 MiB. Poison region 0's lock;
        // the rest of the fabric must keep allocating.
        let f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let e0 = f.allocate_extent(h).unwrap();
        assert_eq!(e0.dpa, Dpa(0), "first-fit starts in region 0");
        let (region_len, _) = f.placement_regions();

        let fabric = f.into_shared();
        let t = {
            let fabric = fabric.clone();
            std::thread::spawn(move || fabric.poison_region_for_test(0))
        };
        assert!(t.join().is_err(), "fault injection panics by design");

        let fm = fabric.manager();
        // waiters on the poisoned region get the typed error...
        assert!(
            matches!(fm.release_extent(h, e0), Err(Error::FabricPoisoned)),
            "release into the poisoned region reports FabricPoisoned"
        );
        // ...the fabric seal is NOT poisoned, and disjoint regions keep
        // serving: first-fit now skips region 0's quarantined free space
        fabric.with_fm(|_| ()).unwrap();
        let e1 = fm.allocate_extent(h).unwrap();
        assert_eq!(e1.dpa, Dpa(region_len), "placement skips the quarantined shard");
        fm.release_extent(h, e1).unwrap();
        // the audit still runs (poison-tolerant) and the books balance:
        // the injected panic mutated nothing
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn lock_stats_count_acquisitions_and_cross_region_ops() {
        let f = fm(GIB); // 4 regions of 256 MiB
        let s0 = f.lock_counters_snapshot();
        assert_eq!(s0, LockStats::default());

        let (h, _) = f.bind_host().unwrap();
        let s1 = f.lock_counters_snapshot();
        assert_eq!(s1.control_acquisitions, 1, "bind takes only the control lock");
        assert_eq!(s1.region_acquisitions, 0);

        let e = f.allocate_extent(h).unwrap();
        let s2 = f.lock_counters_snapshot();
        assert_eq!(s2.region_acquisitions, 4, "placement locks every shard once");
        assert_eq!(s2.cross_region_ops, s1.cross_region_ops + 1);

        f.release_extent(h, e).unwrap();
        let s3 = f.lock_counters_snapshot();
        assert_eq!(s3.region_acquisitions, 5, "release locks only the spanned shard");
        assert_eq!(s3.cross_region_ops, s2.cross_region_ops, "single-shard release");

        f.release_host(h);
        let s4 = f.lock_counters_snapshot();
        assert_eq!(s4.cross_region_ops, s3.cross_region_ops + 1, "crash reclaim is a full sweep");

        // single-threaded: nothing ever blocked
        assert_eq!(s4.region_contended, 0);
        assert_eq!(s4.control_contended, 0);

        // observability reads are uncounted by design
        let _ = f.placement_regions();
        let _ = f.free_ranges();
        let _ = f.lease_count();
        f.check_invariants().unwrap();
        assert_eq!(f.lock_counters_snapshot(), s4);
    }

    #[test]
    fn mmid_namespace_is_fabric_global() {
        let f = fm(GIB);
        let a = f.alloc_mmid();
        let b = f.alloc_mmid();
        assert_ne!(a, b);
        assert!(b > a, "monotone, never reused");
    }

    #[test]
    fn contention_aware_placement_spreads_across_regions() {
        // 4 GiB pool → 512 MiB regions (two extents each). First-fit
        // packs sequentially; contention-aware places each new extent in
        // the least-loaded region, so the first 8 extents land in 8
        // distinct regions.
        let f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let (region_len, loads) = f.placement_regions();
        assert_eq!(region_len, 512 * 1024 * 1024);
        assert_eq!(loads.len(), 8);
        let mut regions_hit = std::collections::HashSet::new();
        for _ in 0..8 {
            let e = f
                .allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware)
                .unwrap();
            regions_hit.insert(e.dpa.0 / region_len);
            f.check_invariants().unwrap();
        }
        assert_eq!(regions_hit.len(), 8, "one extent per region before any region doubles up");
        let (_, loads) = f.placement_regions();
        assert!(loads.iter().all(|&l| l == EXTENT_SIZE), "perfectly balanced: {loads:?}");
    }

    #[test]
    fn contention_aware_ties_fall_back_to_first_fit() {
        // on an empty pool every region prices identically, so the
        // cheapest candidate is the lowest DPA — first-fit
        let f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let aware =
            f.allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware).unwrap();
        assert_eq!(aware.dpa, Dpa(0), "tie-break is first-fit");
        // and mid-range carving keeps the free list sorted + counted
        f.check_invariants().unwrap();
        f.release_extent(h, aware).unwrap();
        f.check_invariants().unwrap();
        assert_eq!(f.available(), 4 * GIB);
    }

    #[test]
    fn placed_and_first_fit_leases_share_one_accounting_path() {
        // interleave both policies; counters and invariants must hold,
        // and a mid-free-range carve must split the range cleanly
        let f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h).unwrap(); // first-fit → dpa 0
        let b =
            f.allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware).unwrap();
        assert_ne!(a.dpa.0 / (512 * 1024 * 1024), b.dpa.0 / (512 * 1024 * 1024));
        f.check_invariants().unwrap();
        // releasing the mid-space lease re-coalesces around it
        f.release_extent(h, b).unwrap();
        f.check_invariants().unwrap();
        f.release_extent(h, a).unwrap();
        assert_eq!(f.available(), 4 * GIB);
        f.check_invariants().unwrap();
    }

    #[test]
    fn sat_grant_requires_bound_spid() {
        let f = fm(GIB);
        assert!(f.sat_grant(Spid(99), Range::new(0, 4096), SatPerm::ReadWrite).is_err());
        let spid = f.bind_cxl_device().unwrap();
        f.sat_grant(spid, Range::new(0, 4096), SatPerm::ReadWrite).unwrap();
    }

    // ---- tiering / live migration ----

    /// Two-tier fabric: `dram` bytes of fast media + `pm` bytes of slow.
    fn fm2(dram: u64, pm: u64) -> FabricManager {
        let f = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig {
                dram_capacity: dram,
                pm_capacity: pm,
                ..Default::default()
            }),
        );
        f.attach_gfd().unwrap();
        f
    }

    #[test]
    fn migrate_roundtrip_preserves_data_under_virtual_dpa() {
        let fabric = fm2(GIB, GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
        assert_eq!(e.dpa, Dpa(0), "first-fit lands on the fast tier");
        let virt = e.dpa;
        fabric.write_dpa(Dpa(virt.0 + 0x2000), b"tiered-bytes").unwrap();

        // demote: the extent physically moves past the tier boundary,
        // but the module-virtual address keeps resolving
        let out = fabric.migrate_extent(virt).unwrap();
        let dst = match out {
            MigrateOutcome::Committed { from, to, src, dst } => {
                assert_eq!((from, to), (MediaTier::Dram, MediaTier::Pm));
                assert_eq!(src, virt);
                assert!(dst.0 >= fabric.tier_boundary(), "destination inside the PM band");
                dst
            }
            other => panic!("expected commit, got {other:?}"),
        };
        assert_eq!(fabric.tier_of(virt).unwrap(), MediaTier::Pm);
        let mut buf = [0u8; 12];
        fabric.read_dpa(Dpa(virt.0 + 0x2000), &mut buf).unwrap();
        assert_eq!(&buf, b"tiered-bytes", "data follows the extent across tiers");
        fabric.check_invariants().unwrap();

        // promote back: the freed fast-tier span is the lowest candidate,
        // so the extent returns home and the forward map collapses to
        // identity
        match fabric.migrate_extent(virt).unwrap() {
            MigrateOutcome::Committed { to, dst: back, .. } => {
                assert_eq!(to, MediaTier::Dram);
                assert_eq!(back, virt, "promotion reuses the freed home span");
            }
            other => panic!("expected commit, got {other:?}"),
        }
        let _ = dst;
        fabric.with_fm(|fm| assert!(fm.tier.forward_snapshot().is_empty())).unwrap();
        fabric.read_dpa(Dpa(virt.0 + 0x2000), &mut buf).unwrap();
        assert_eq!(&buf, b"tiered-bytes");

        // release through the original virtual extent record
        fabric.with_fm(|fm| fm.release_extent(h, e)).unwrap().unwrap();
        assert_eq!(fabric.available(), 2 * GIB);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn migrate_retargets_decoders_and_sat_grants() {
        let fabric = fm2(GIB, GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let dev = fabric.bind_cxl_device().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
        fabric
            .with_fm(|fm| fm.sat_grant(dev, Range::new(e.dpa.0, PAGE_SIZE), SatPerm::ReadWrite))
            .unwrap()
            .unwrap();
        fabric
            .with_expander_mut(|x| x.add_decoder(Range::new(1 << 40, e.len), e.dpa))
            .unwrap()
            .unwrap();

        let dst = match fabric.migrate_extent(e.dpa).unwrap() {
            MigrateOutcome::Committed { dst, .. } => dst,
            other => panic!("expected commit, got {other:?}"),
        };
        let fm_ref = &fabric;
        fm_ref
            .with_fm(|fm| {
                let exp = fm.expander();
                assert_eq!(
                    exp.decode_hpa(crate::cxl::types::Hpa(1 << 40)).unwrap(),
                    dst,
                    "HDM decoder re-targeted to the new physical base"
                );
                assert!(exp.sat().check(dev, dst, 64, true), "SAT grant rebased");
                assert!(!exp.sat().check(dev, e.dpa, 64, false), "no grant dangles at the source");
            })
            .unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn migrate_abort_rolls_back_to_source_placement() {
        let fabric = fm2(GIB, GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
        fabric.write_dpa(Dpa(e.dpa.0 + 0x1000), b"survives-abort").unwrap();
        let before = fabric.available();

        match fabric.migrate_extent_aborting(e.dpa).unwrap() {
            MigrateOutcome::Aborted { from, to } => {
                assert_eq!((from, to), (MediaTier::Dram, MediaTier::Pm));
            }
            other => panic!("expected abort, got {other:?}"),
        }
        assert_eq!(fabric.tier_of(e.dpa).unwrap(), MediaTier::Dram, "source stays authoritative");
        assert_eq!(fabric.available(), before, "destination carve returned to the pool");
        fabric.with_fm(|fm| assert!(fm.tier.forward_snapshot().is_empty())).unwrap();
        let mut buf = [0u8; 14];
        fabric.read_dpa(Dpa(e.dpa.0 + 0x1000), &mut buf).unwrap();
        assert_eq!(&buf, b"survives-abort");
        fabric.check_invariants().unwrap();
        // the half-written destination was wiped: nothing readable leaks
        // past the boundary
        let mut probe = [0u8; 8];
        fabric.read_dpa(Dpa(fabric.tier_boundary() + 0x1000), &mut probe).unwrap();
        assert_eq!(probe, [0u8; 8]);
    }

    #[test]
    fn migrate_refuses_without_a_destination_band() {
        // DRAM-only fabric: there is no slow tier to demote into
        let fabric = fm(GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
        assert!(matches!(fabric.migrate_extent(e.dpa), Err(Error::OutOfCapacity { .. })));
        // refusal emitted no Migrate and carved nothing
        assert_eq!(fabric.available(), GIB - EXTENT_SIZE);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn migrate_refuses_unknown_and_unaligned_sources() {
        let fabric = fm2(GIB, GIB).into_shared();
        assert!(fabric.migrate_extent(Dpa(0)).is_err(), "no lease at the source");
        assert!(
            fabric.with_fm(|fm| fm.migrate_extent(Dpa(0x1000), false)).unwrap().is_err(),
            "unaligned physical base"
        );
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn data_path_heat_folds_into_tier_census() {
        let fabric = fm2(GIB, GIB).into_shared();
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();
        fabric.write_dpa(e.dpa, b"warm").unwrap();
        let mut buf = [0u8; 4];
        fabric.read_dpa(e.dpa, &mut buf).unwrap();
        fabric.read_dpa(e.dpa, &mut buf).unwrap();

        let fold = fabric.with_fm(|fm| fm.tier_fold()).unwrap();
        assert_eq!(fold.len(), 1);
        assert_eq!(fold[0].virt, e.dpa.0);
        assert_eq!(fold[0].tier, MediaTier::Dram);
        assert_eq!(fold[0].touches, 3, "one write + two reads");
        let fold2 = fabric.with_fm(|fm| fm.tier_fold()).unwrap();
        assert_eq!(fold2[0].touches, 0, "the fold consumes the raw counters");
    }

    #[test]
    fn migration_events_are_terminally_paired() {
        use crate::observe::{EventKind, EventRing};
        let ring = EventRing::new(64);
        let fabric = fm2(GIB, GIB).into_shared();
        fabric.set_event_sink(ring.sink());
        let (h, _) = fabric.with_fm(|fm| fm.bind_host()).unwrap().unwrap();
        let e = fabric.with_fm(|fm| fm.allocate_extent(h)).unwrap().unwrap();

        fabric.migrate_extent(e.dpa).unwrap(); // demote: Migrate + Demote
        fabric.migrate_extent(e.dpa).unwrap(); // promote: Migrate + Promote
        fabric.migrate_extent_aborting(e.dpa).unwrap(); // Migrate + Fault
        assert!(fabric.migrate_extent(Dpa(EXTENT_SIZE)).is_err(), "refusal");

        let counts = ring.counts();
        assert_eq!(counts.of(EventKind::Migrate), 3);
        assert_eq!(counts.of(EventKind::Promote), 1);
        assert_eq!(counts.of(EventKind::Demote), 1);
        assert_eq!(counts.of(EventKind::Fault), 1, "abort terminates its Migrate");
        assert_eq!(
            counts.of(EventKind::Migrate),
            counts.of(EventKind::Promote) + counts.of(EventKind::Demote) + counts.of(EventKind::Fault),
            "every Migrate terminally paired; refusals emit nothing"
        );
    }
}
