//! Fabric Manager (§3.1): binds ports, manages pooled capacity, and
//! programs the GFD on behalf of hosts.
//!
//! The FM owns the expander's DPA space at extent granularity. The LMB
//! kernel module (one per host) requests 256 MB extents through the FM
//! API and sub-allocates them locally (§3.2). Dynamic capacity: extents
//! are handed out on demand and reclaimed when a module releases them —
//! the FM arbitrates between multiple hosts sharing one expander.
//!
//! The FM also fronts the "GFD Component Management Command Set" used to
//! maintain SAT entries for CXL-device P2P access (§3.3).

use std::collections::HashMap;

use crate::cxl::expander::Expander;
use crate::cxl::sat::SatPerm;
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{Dpa, Dpid, Range, Spid, EXTENT_SIZE};
use crate::error::{Error, Result};

/// Identifies a host that has bound to the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HostId(pub u32);

/// An extent of expander capacity leased to a host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub dpa: Dpa,
    pub len: u64,
    pub owner: HostId,
}

/// The Fabric Manager.
///
/// Owns the switch and expander; everything else goes through its API —
/// mirroring the paper, where the FM "can be implemented as software in
/// the host or firmware on a switch".
#[derive(Debug)]
pub struct FabricManager {
    switch: PbrSwitch,
    expander: Expander,
    /// Free DPA extents (sorted by base; adjacent frees coalesce).
    free: Vec<Range>,
    /// Live leases keyed by DPA base.
    leases: HashMap<u64, Extent>,
    hosts: HashMap<HostId, Spid>,
    next_host: u32,
}

impl FabricManager {
    pub fn new(switch: PbrSwitch, expander: Expander) -> Self {
        let free = vec![Range::new(0, expander.capacity())];
        FabricManager {
            switch,
            expander,
            free,
            leases: HashMap::new(),
            hosts: HashMap::new(),
            next_host: 0,
        }
    }

    pub fn switch(&self) -> &PbrSwitch {
        &self.switch
    }

    pub fn switch_mut(&mut self) -> &mut PbrSwitch {
        &mut self.switch
    }

    pub fn expander(&self) -> &Expander {
        &self.expander
    }

    pub fn expander_mut(&mut self) -> &mut Expander {
        &mut self.expander
    }

    /// Bind a host root port to the fabric.
    pub fn bind_host(&mut self) -> Result<(HostId, Spid)> {
        let (spid, _) = self.switch.bind_host()?;
        let id = HostId(self.next_host);
        self.next_host += 1;
        self.hosts.insert(id, spid);
        Ok((id, spid))
    }

    /// Bind a CXL device (accelerator, CXL-SSD) to the fabric.
    pub fn bind_cxl_device(&mut self) -> Result<Spid> {
        let (spid, _) = self.switch.bind_cxl_device()?;
        Ok(spid)
    }

    /// Attach the GFD expander port (done once during bring-up). Returns
    /// the GFD's DPID — the P2P destination id the LMB module hands to
    /// CXL consumers via the Table 2 alloc/share out-params.
    pub fn attach_gfd(&mut self) -> Result<Dpid> {
        let (_port, dpid) = self.switch.attach_gfd()?;
        Ok(dpid)
    }

    /// DPID of the attached GFD (None before bring-up).
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.switch.gfd_dpid()
    }

    /// Capacity not currently leased.
    pub fn available(&self) -> u64 {
        self.free.iter().map(|r| r.len).sum()
    }

    /// Capacity currently leased to `host`.
    pub fn leased_to(&self, host: HostId) -> u64 {
        self.leases.values().filter(|e| e.owner == host).map(|e| e.len).sum()
    }

    /// FM API: lease one 256 MB extent to `host` (§3.2).
    pub fn allocate_extent(&mut self, host: HostId) -> Result<Extent> {
        self.allocate_extent_sized(host, EXTENT_SIZE)
    }

    /// Lease an extent of arbitrary (page-aligned) size — used by tests
    /// and by the dynamic-capacity ablation.
    pub fn allocate_extent_sized(&mut self, host: HostId, len: u64) -> Result<Extent> {
        if !self.hosts.contains_key(&host) {
            return Err(Error::FabricManager(format!("unknown host {host:?}")));
        }
        if self.expander.is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        // first-fit over the free list
        let pos = self.free.iter().position(|r| r.len >= len).ok_or(Error::OutOfCapacity {
            requested: len,
            available: self.available(),
        })?;
        let r = self.free[pos];
        let ext = Extent { dpa: Dpa(r.base), len, owner: host };
        if r.len == len {
            self.free.remove(pos);
        } else {
            self.free[pos] = Range::new(r.base + len, r.len - len);
        }
        self.leases.insert(ext.dpa.0, ext);
        Ok(ext)
    }

    /// FM API: return an extent (must be wholly unused by the caller).
    pub fn release_extent(&mut self, host: HostId, ext: Extent) -> Result<()> {
        match self.leases.get(&ext.dpa.0) {
            Some(e) if e.owner == host && e.len == ext.len => {}
            Some(_) => {
                return Err(Error::FabricManager("extent not owned by caller".into()));
            }
            None => return Err(Error::FabricManager("unknown extent".into())),
        }
        self.leases.remove(&ext.dpa.0);
        // insert into the sorted free list and coalesce neighbours
        let mut r = Range::new(ext.dpa.0, ext.len);
        let idx = self.free.partition_point(|f| f.base < r.base);
        // coalesce with next
        if idx < self.free.len() && r.end() == self.free[idx].base {
            r = Range::new(r.base, r.len + self.free[idx].len);
            self.free.remove(idx);
        }
        // coalesce with previous
        if idx > 0 && self.free[idx - 1].end() == r.base {
            let prev = self.free[idx - 1];
            self.free[idx - 1] = Range::new(prev.base, prev.len + r.len);
        } else {
            self.free.insert(idx, r);
        }
        Ok(())
    }

    /// GFD management: add a SAT entry for a CXL device (§3.3).
    pub fn sat_grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        if !self.switch.is_bound(spid) {
            return Err(Error::FabricManager(format!("SPID {spid:?} not bound")));
        }
        self.expander.sat_grant(spid, range, perm)
    }

    /// GFD management: remove a SAT entry.
    pub fn sat_revoke(&mut self, spid: Spid, range: Range) -> Result<()> {
        self.expander.sat_revoke(spid, range)
    }

    /// Release everything a host holds (host crash / module unload).
    pub fn release_host(&mut self, host: HostId) {
        let to_release: Vec<Extent> =
            self.leases.values().filter(|e| e.owner == host).copied().collect();
        for e in to_release {
            let _ = self.release_extent(host, e);
        }
        if let Some(spid) = self.hosts.remove(&host) {
            let _ = self.switch.unbind(spid);
        }
    }

    /// Number of live leases (for invariant checks).
    pub fn lease_count(&self) -> usize {
        self.leases.len()
    }

    /// Invariant: free list is sorted, non-overlapping, coalesced, and
    /// free+leased covers exactly the media. Used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let mut prev_end = None;
        for r in &self.free {
            if let Some(pe) = prev_end {
                if r.base < pe {
                    return Err(Error::FabricManager("free list overlap".into()));
                }
                if r.base == pe {
                    return Err(Error::FabricManager("free list not coalesced".into()));
                }
            }
            prev_end = Some(r.end());
        }
        let total: u64 = self.available() + self.leases.values().map(|e| e.len).sum::<u64>();
        if total != self.expander.capacity() {
            return Err(Error::FabricManager(format!(
                "capacity leak: free+leased={total} != {}",
                self.expander.capacity()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::ExpanderConfig;
    use crate::cxl::types::GIB;

    fn fm(cap: u64) -> FabricManager {
        let mut f = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: cap, ..Default::default() }),
        );
        f.attach_gfd().unwrap();
        f
    }

    #[test]
    fn extent_lease_and_release_roundtrip() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let e = f.allocate_extent(h).unwrap();
        assert_eq!(e.len, EXTENT_SIZE);
        assert_eq!(f.available(), GIB - EXTENT_SIZE);
        f.release_extent(h, e).unwrap();
        assert_eq!(f.available(), GIB);
        f.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhaustion_reports_available() {
        let mut f = fm(EXTENT_SIZE); // room for exactly one extent
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        match f.allocate_extent(h) {
            Err(Error::OutOfCapacity { available, .. }) => assert_eq!(available, 0),
            other => panic!("expected OutOfCapacity, got {other:?}"),
        }
    }

    #[test]
    fn release_coalesces_neighbours() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h).unwrap();
        let b = f.allocate_extent(h).unwrap();
        let c = f.allocate_extent(h).unwrap();
        f.release_extent(h, a).unwrap();
        f.release_extent(h, c).unwrap();
        f.release_extent(h, b).unwrap(); // middle release must merge all
        f.check_invariants().unwrap();
        assert_eq!(f.available(), GIB);
        assert_eq!(f.free.len(), 1, "free list fully coalesced");
    }

    #[test]
    fn multi_host_isolation() {
        let mut f = fm(GIB);
        let (h1, _) = f.bind_host().unwrap();
        let (h2, _) = f.bind_host().unwrap();
        let e1 = f.allocate_extent(h1).unwrap();
        assert!(f.release_extent(h2, e1).is_err(), "host2 cannot release host1's extent");
        assert_eq!(f.leased_to(h1), EXTENT_SIZE);
        assert_eq!(f.leased_to(h2), 0);
    }

    #[test]
    fn release_host_reclaims_everything() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        f.allocate_extent(h).unwrap();
        f.release_host(h);
        assert_eq!(f.available(), GIB);
        assert_eq!(f.lease_count(), 0);
        assert!(f.allocate_extent(h).is_err(), "host is gone");
    }

    #[test]
    fn failed_expander_blocks_allocation() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.expander_mut().set_failed(true);
        assert!(matches!(f.allocate_extent(h), Err(Error::ExpanderFailed(_))));
    }

    #[test]
    fn sat_grant_requires_bound_spid() {
        let mut f = fm(GIB);
        assert!(f
            .sat_grant(Spid(99), Range::new(0, 4096), SatPerm::ReadWrite)
            .is_err());
        let spid = f.bind_cxl_device().unwrap();
        f.sat_grant(spid, Range::new(0, 4096), SatPerm::ReadWrite).unwrap();
    }
}
