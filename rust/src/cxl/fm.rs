//! Fabric Manager (§3.1): binds ports, manages pooled capacity, and
//! programs the GFD on behalf of hosts.
//!
//! The FM owns the expander's DPA space at extent granularity. The LMB
//! kernel module (one per host) requests 256 MB extents through the FM
//! API and sub-allocates them locally (§3.2). Dynamic capacity: extents
//! are handed out on demand and reclaimed when a module releases them —
//! the FM arbitrates between multiple hosts sharing one expander.
//!
//! The FM also fronts the "GFD Component Management Command Set" used to
//! maintain SAT entries for CXL-device P2P access (§3.3).
//!
//! Ownership: since the shared-fabric split no single host owns the FM.
//! It lives behind [`FabricRef`], a cheap-clone `Send + Sync` handle
//! every [`LmbHost`](crate::lmb::LmbHost) (and the multi-host
//! [`Cluster`](crate::cluster::Cluster)) binds through. Leases are keyed
//! by [`HostId`] and mmids are drawn from a fabric-global namespace, so
//! no handle-holder can free or share memory it does not own. Access is
//! scoped ([`FabricRef::with_fm`] and friends): no lock guard type ever
//! escapes this module, and a panic inside a scope poisons the lock —
//! later callers see [`Error::FabricPoisoned`] instead of deadlocking
//! on torn state.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::coordinator::contention;
use crate::cxl::expander::Expander;
use crate::cxl::sat::SatPerm;
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{align_up, Dpa, Dpid, MmId, Range, Spid, EXTENT_SIZE};
use crate::error::{Error, Result};

/// Identifies a host that has bound to the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HostId(pub u32);

/// How the FM chooses *where* in the expander's DPA space a fresh
/// extent is carved.
///
/// The expander's media is split into a fixed number of equal regions
/// (DMP/port analogues). [`PlacementPolicy::ContentionAware`] prices
/// every candidate carve point with the same M/M/1 cost model the
/// device-level contention solver uses
/// ([`contention::placement_cost`]) and picks the candidate in the
/// least-loaded region; when every candidate region carries equal load
/// (e.g. a fresh pool) the tie-break is the lowest DPA — i.e. it falls
/// back to exactly first-fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Lowest-DPA free range that fits (the FM primitive's historical
    /// behaviour; the queue ablation's FIFO baseline).
    #[default]
    FirstFit,
    /// Minimise modeled region contention; ties fall back to first-fit.
    ContentionAware,
}

/// Number of placement regions the DPA space is divided into (each at
/// least one extent long, so tiny test expanders degenerate to one
/// region per extent and both policies coincide).
const PLACEMENT_REGIONS: u64 = 8;

/// An extent of expander capacity leased to a host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    pub dpa: Dpa,
    pub len: u64,
    pub owner: HostId,
}

/// The Fabric Manager.
///
/// Owns the switch and expander; everything else goes through its API —
/// mirroring the paper, where the FM "can be implemented as software in
/// the host or firmware on a switch".
#[derive(Debug)]
pub struct FabricManager {
    switch: PbrSwitch,
    expander: Expander,
    /// Free DPA extents (sorted by base; adjacent frees coalesce).
    free: Vec<Range>,
    /// Running total of `free` — keeps [`FabricManager::available`] O(1)
    /// (it sits on the `OutOfCapacity` error path and in every invariant
    /// check, so re-summing the free list there scaled with pool churn).
    free_bytes: u64,
    /// Live leases keyed by DPA base.
    leases: HashMap<u64, Extent>,
    /// Running per-host lease totals — keeps [`FabricManager::leased_to`]
    /// O(1) instead of a scan over every live lease.
    leased_bytes: HashMap<HostId, u64>,
    /// Length of one placement region (DPA space / [`PLACEMENT_REGIONS`],
    /// rounded up to whole extents).
    region_len: u64,
    /// Leased bytes per placement region, attributed by each lease's
    /// base DPA — the load signal the contention-aware policy prices.
    region_load: Vec<u64>,
    hosts: HashMap<HostId, Spid>,
    next_host: u32,
    /// Fabric-global mmid counter (§3.2): handles are unique across
    /// every host sharing the expander, so one host's mmid can never
    /// alias another's — cross-host isolation keys off this.
    next_mmid: u64,
}

impl FabricManager {
    pub fn new(switch: PbrSwitch, expander: Expander) -> Self {
        let free_bytes = expander.capacity();
        let free = vec![Range::new(0, free_bytes)];
        let region_len =
            align_up(free_bytes.div_ceil(PLACEMENT_REGIONS).max(1), EXTENT_SIZE).max(EXTENT_SIZE);
        let region_count = free_bytes.div_ceil(region_len).max(1) as usize;
        FabricManager {
            switch,
            expander,
            free,
            free_bytes,
            leases: HashMap::new(),
            leased_bytes: HashMap::new(),
            region_len,
            region_load: vec![0; region_count],
            hosts: HashMap::new(),
            next_host: 0,
            next_mmid: 1,
        }
    }

    /// Wrap this FM in a shared [`FabricRef`] handle (the only way
    /// hosts bind after the ownership split).
    pub fn into_shared(self) -> FabricRef {
        FabricRef::new(self)
    }

    /// Draw the next mmid from the fabric-global namespace. Called by
    /// the LMB modules at allocation time so handles never collide
    /// across hosts.
    pub fn alloc_mmid(&mut self) -> MmId {
        let id = MmId(self.next_mmid);
        self.next_mmid += 1;
        id
    }

    pub fn switch(&self) -> &PbrSwitch {
        &self.switch
    }

    pub fn switch_mut(&mut self) -> &mut PbrSwitch {
        &mut self.switch
    }

    pub fn expander(&self) -> &Expander {
        &self.expander
    }

    pub fn expander_mut(&mut self) -> &mut Expander {
        &mut self.expander
    }

    /// Bind a host root port to the fabric.
    pub fn bind_host(&mut self) -> Result<(HostId, Spid)> {
        let (spid, _) = self.switch.bind_host()?;
        let id = HostId(self.next_host);
        self.next_host += 1;
        self.hosts.insert(id, spid);
        Ok((id, spid))
    }

    /// Bind a CXL device (accelerator, CXL-SSD) to the fabric.
    pub fn bind_cxl_device(&mut self) -> Result<Spid> {
        let (spid, _) = self.switch.bind_cxl_device()?;
        Ok(spid)
    }

    /// Attach the GFD expander port (done once during bring-up). Returns
    /// the GFD's DPID — the P2P destination id the LMB module hands to
    /// CXL consumers via the Table 2 alloc/share out-params.
    pub fn attach_gfd(&mut self) -> Result<Dpid> {
        let (_port, dpid) = self.switch.attach_gfd()?;
        // the expander reports this DPID in SAT-violation errors, so a
        // rejected P2P access names the real GFD port
        self.expander.set_gfd_dpid(dpid);
        Ok(dpid)
    }

    /// DPID of the attached GFD (None before bring-up).
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.switch.gfd_dpid()
    }

    /// Capacity not currently leased. O(1): a running counter, not a
    /// free-list walk.
    pub fn available(&self) -> u64 {
        self.free_bytes
    }

    /// Capacity currently leased to `host`. O(1): a running per-host
    /// counter, not a lease-table scan.
    pub fn leased_to(&self, host: HostId) -> u64 {
        self.leased_bytes.get(&host).copied().unwrap_or(0)
    }

    /// FM API: lease one 256 MB extent to `host` (§3.2).
    pub fn allocate_extent(&mut self, host: HostId) -> Result<Extent> {
        self.allocate_extent_sized(host, EXTENT_SIZE)
    }

    /// Lease an extent of arbitrary (page-aligned) size — used by tests
    /// and by the dynamic-capacity ablation. First-fit (the historical
    /// primitive); policy-driven placement goes through
    /// [`FabricManager::allocate_extent_placed`].
    pub fn allocate_extent_sized(&mut self, host: HostId, len: u64) -> Result<Extent> {
        self.allocate_extent_placed(host, len, PlacementPolicy::FirstFit)
    }

    /// Lease an extent, choosing the carve point by `policy` (see
    /// [`PlacementPolicy`]). The LMB modules call this with the policy
    /// their host was configured with.
    pub fn allocate_extent_placed(
        &mut self,
        host: HostId,
        len: u64,
        policy: PlacementPolicy,
    ) -> Result<Extent> {
        if !self.hosts.contains_key(&host) {
            return Err(Error::FabricManager(format!("unknown host {host:?}")));
        }
        if self.expander.is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        let candidate = match policy {
            PlacementPolicy::FirstFit => self
                .free
                .iter()
                .position(|r| r.len >= len)
                .map(|pos| (pos, self.free[pos].base)),
            PlacementPolicy::ContentionAware => self.pick_least_contended(len),
        };
        let (pos, base) = candidate.ok_or(Error::OutOfCapacity {
            requested: len,
            available: self.available(),
        })?;
        Ok(self.carve(pos, base, len, host))
    }

    /// Cheapest carve point under the contention model: every free
    /// range's base plus each region boundary inside it is a candidate;
    /// each is priced by [`contention::placement_cost`] on the load its
    /// region would carry after the lease. Candidates are visited in
    /// ascending DPA order and only a strictly cheaper one replaces the
    /// incumbent, so equal-cost choices resolve to the lowest DPA —
    /// first-fit — exactly as documented on [`PlacementPolicy`].
    fn pick_least_contended(&self, len: u64) -> Option<(usize, u64)> {
        let mut best: Option<(f64, usize, u64)> = None;
        for (pos, r) in self.free.iter().enumerate() {
            if r.len < len {
                continue;
            }
            let mut candidate = r.base;
            loop {
                let load = self.region_load[self.region_of(candidate)] + len;
                let cost = contention::placement_cost(load, self.region_len);
                let cheaper = match best {
                    None => true,
                    Some((incumbent, _, _)) => cost < incumbent,
                };
                if cheaper {
                    best = Some((cost, pos, candidate));
                }
                // advance to the next region boundary inside this range
                let next = (candidate / self.region_len + 1) * self.region_len;
                if next <= candidate || next + len > r.end() {
                    break;
                }
                candidate = next;
            }
        }
        best.map(|(_, pos, base)| (pos, base))
    }

    /// Carve `[base, base+len)` out of free-list entry `pos` and record
    /// the lease — the single mutation point shared by both placement
    /// policies, so the running counters can never diverge between them.
    fn carve(&mut self, pos: usize, base: u64, len: u64, host: HostId) -> Extent {
        let r = self.free[pos];
        debug_assert!(base >= r.base && base + len <= r.end());
        let left = base - r.base;
        let right = r.end() - (base + len);
        match (left > 0, right > 0) {
            (false, false) => {
                self.free.remove(pos);
            }
            (true, false) => self.free[pos] = Range::new(r.base, left),
            (false, true) => self.free[pos] = Range::new(base + len, right),
            (true, true) => {
                self.free[pos] = Range::new(r.base, left);
                self.free.insert(pos + 1, Range::new(base + len, right));
            }
        }
        self.free_bytes -= len;
        *self.leased_bytes.entry(host).or_insert(0) += len;
        let region = self.region_of(base);
        self.region_load[region] += len;
        let ext = Extent { dpa: Dpa(base), len, owner: host };
        self.leases.insert(base, ext);
        ext
    }

    /// Placement region holding `dpa` (by base address).
    fn region_of(&self, dpa: u64) -> usize {
        ((dpa / self.region_len) as usize).min(self.region_load.len() - 1)
    }

    /// Placement-region observability: `(region_len, per-region leased
    /// bytes)`. The contention ablation derives its modeled cost metric
    /// from this.
    pub fn placement_regions(&self) -> (u64, &[u64]) {
        (self.region_len, &self.region_load)
    }

    /// FM API: return an extent (must be wholly unused by the caller).
    pub fn release_extent(&mut self, host: HostId, ext: Extent) -> Result<()> {
        match self.leases.get(&ext.dpa.0) {
            Some(e) if e.owner == host && e.len == ext.len => {}
            Some(_) => {
                return Err(Error::FabricManager("extent not owned by caller".into()));
            }
            None => return Err(Error::FabricManager("unknown extent".into())),
        }
        self.leases.remove(&ext.dpa.0);
        self.free_bytes += ext.len;
        let region = self.region_of(ext.dpa.0);
        self.region_load[region] -= ext.len;
        if let Some(v) = self.leased_bytes.get_mut(&host) {
            *v -= ext.len;
            if *v == 0 {
                self.leased_bytes.remove(&host);
            }
        }
        // insert into the sorted free list and coalesce neighbours
        let mut r = Range::new(ext.dpa.0, ext.len);
        let idx = self.free.partition_point(|f| f.base < r.base);
        // coalesce with next
        if idx < self.free.len() && r.end() == self.free[idx].base {
            r = Range::new(r.base, r.len + self.free[idx].len);
            self.free.remove(idx);
        }
        // coalesce with previous
        if idx > 0 && self.free[idx - 1].end() == r.base {
            let prev = self.free[idx - 1];
            self.free[idx - 1] = Range::new(prev.base, prev.len + r.len);
        } else {
            self.free.insert(idx, r);
        }
        Ok(())
    }

    /// GFD management: add a SAT entry for a CXL device (§3.3).
    pub fn sat_grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        if !self.switch.is_bound(spid) {
            return Err(Error::FabricManager(format!("SPID {spid:?} not bound")));
        }
        self.expander.sat_grant(spid, range, perm)
    }

    /// GFD management: remove a SAT entry.
    pub fn sat_revoke(&mut self, spid: Spid, range: Range) -> Result<()> {
        self.expander.sat_revoke(spid, range)
    }

    /// Release everything a host holds (host crash / module unload).
    ///
    /// Before each extent returns to the pool, every SAT grant and HDM
    /// decoder covering its DPA range is torn down: a crashed host
    /// cannot clean up after itself, and a stale CXL device keeping P2P
    /// access to re-leased memory would be an isolation hole. Siblings'
    /// extents cover disjoint DPA ranges, so their grants, decoders and
    /// placements are untouched.
    pub fn release_host(&mut self, host: HostId) {
        let to_release: Vec<Extent> =
            self.leases.values().filter(|e| e.owner == host).copied().collect();
        for e in to_release {
            let media = Range::new(e.dpa.0, e.len);
            self.expander.sat_revoke_overlapping(media);
            self.expander.remove_decoders_overlapping_dpa(media);
            let _ = self.release_extent(host, e);
        }
        if let Some(spid) = self.hosts.remove(&host) {
            let _ = self.switch.unbind(spid);
        }
    }

    /// Number of live leases (for invariant checks).
    pub fn lease_count(&self) -> usize {
        self.leases.len()
    }

    /// Invariant: free list is sorted, non-overlapping, coalesced, the
    /// running `free_bytes`/`leased_bytes` counters agree with the
    /// ground-truth tables, free+leased covers exactly the media, and
    /// the expander's own indexing invariants (sorted decoder/DMP/SAT
    /// tables) hold. Used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let mut prev_end = None;
        let mut free_sum = 0;
        for r in &self.free {
            if let Some(pe) = prev_end {
                if r.base < pe {
                    return Err(Error::FabricManager("free list overlap".into()));
                }
                if r.base == pe {
                    return Err(Error::FabricManager("free list not coalesced".into()));
                }
            }
            prev_end = Some(r.end());
            free_sum += r.len;
        }
        if free_sum != self.free_bytes {
            return Err(Error::FabricManager(format!(
                "free_bytes drift: counter {} != free list sum {free_sum}",
                self.free_bytes
            )));
        }
        let mut per_host: HashMap<HostId, u64> = HashMap::new();
        let mut per_region = vec![0u64; self.region_load.len()];
        for e in self.leases.values() {
            *per_host.entry(e.owner).or_insert(0) += e.len;
            per_region[self.region_of(e.dpa.0)] += e.len;
        }
        if per_host != self.leased_bytes {
            return Err(Error::FabricManager(format!(
                "leased_bytes drift: counters {:?} != lease table {per_host:?}",
                self.leased_bytes
            )));
        }
        if per_region != self.region_load {
            return Err(Error::FabricManager(format!(
                "region_load drift: counters {:?} != lease table {per_region:?}",
                self.region_load
            )));
        }
        let total: u64 = self.available() + self.leases.values().map(|e| e.len).sum::<u64>();
        if total != self.expander.capacity() {
            return Err(Error::FabricManager(format!(
                "capacity leak: free+leased={total} != {}",
                self.expander.capacity()
            )));
        }
        self.expander.check_invariants()
    }
}

/// Shared, cheap-to-clone, `Send + Sync` handle to the
/// [`FabricManager`].
///
/// The ownership split for multi-host sharding: no `LmbHost` owns the
/// FM any more — the switch, expander, lease table and fabric-global
/// mmid namespace live behind this handle, and any number of hosts
/// (and their driver threads) bind through clones of it. The
/// `Arc<Mutex<_>>` is an implementation detail: every method scopes
/// its lock internally or hands a borrow to a caller closure
/// ([`FabricRef::with_fm`]), so no guard type escapes this module and
/// nothing can hold the fabric locked across unrelated work.
///
/// **Poisoning.** If a thread panics inside a fabric scope the lock is
/// poisoned. Fallible operations then return
/// [`Error::FabricPoisoned`] instead of panicking again; the
/// infallible observability reads (`available`, `leased_to`, …) and
/// [`FabricRef::check_invariants`] deliberately bypass the poison flag
/// — the invariant checker is exactly the tool that decides whether
/// post-panic state is salvageable.
///
/// There is deliberately **no** public way to mutate lease or
/// access-control state through the handle — no `&mut FabricManager`,
/// no `&mut Expander` (whose SAT is the access-control state), and no
/// forwarded `allocate_extent`/`release_extent`/`sat_grant` taking a
/// caller-supplied [`HostId`]. Those paths are crate-internal and only
/// reachable through the owner-checked `LmbHost`/`LmbModule`/`Cluster`
/// surfaces, so lease ownership and grant checks cannot be bypassed.
/// Publicly the handle offers scoped reads ([`FabricRef::with_fm`],
/// `available`, `leased_to`, …), the host-trusted data plane
/// ([`FabricRef::write_dpa`] / [`FabricRef::read_dpa`]), failure
/// injection, and device binding.
#[derive(Debug, Clone)]
pub struct FabricRef {
    inner: Arc<Mutex<FabricManager>>,
}

impl FabricRef {
    pub fn new(fm: FabricManager) -> Self {
        FabricRef { inner: Arc::new(Mutex::new(fm)) }
    }

    /// Take the lock, surfacing poison as [`Error::FabricPoisoned`].
    /// Private: guards must not outlive a method of this module.
    fn guard(&self) -> Result<MutexGuard<'_, FabricManager>> {
        self.inner.lock().map_err(|_| Error::FabricPoisoned)
    }

    /// Take the lock even when poisoned. Reserved for observability
    /// reads and the invariant checker: the state behind a poisoned
    /// lock is exactly what a post-mortem needs to look at.
    fn guard_ignore_poison(&self) -> MutexGuard<'_, FabricManager> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Run `f` with a shared view of the FM. The lock is held only for
    /// the closure's duration; do not call back into this handle from
    /// inside `f` (the lock is not reentrant).
    pub fn with_fm<R>(&self, f: impl FnOnce(&FabricManager) -> R) -> Result<R> {
        let fm = self.guard()?;
        Ok(f(&fm))
    }

    /// Run `f` with exclusive access to the FM. Crate-internal: handing
    /// `&mut FabricManager` to arbitrary callers would let them skip
    /// the per-host lease ownership checks. A panic inside `f` poisons
    /// the lock; the next caller sees [`Error::FabricPoisoned`].
    pub(crate) fn with_fm_mut<R>(&self, f: impl FnOnce(&mut FabricManager) -> R) -> Result<R> {
        let mut fm = self.guard()?;
        Ok(f(&mut fm))
    }

    /// Number of live handles sharing this fabric (hosts + clusters +
    /// caller clones).
    pub fn handle_count(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    // ---- forwarded FM control plane (scoped locks) ----

    /// [`FabricManager::bind_cxl_device`] — attaching a CXL consumer
    /// takes a switch port but cannot touch any host's leases.
    pub fn bind_cxl_device(&self) -> Result<Spid> {
        self.guard()?.bind_cxl_device()
    }

    /// [`FabricManager::gfd_dpid`]. Poison-tolerant read.
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.guard_ignore_poison().gfd_dpid()
    }

    /// [`FabricManager::available`]. Poison-tolerant read.
    pub fn available(&self) -> u64 {
        self.guard_ignore_poison().available()
    }

    /// [`FabricManager::leased_to`]. Poison-tolerant read.
    pub fn leased_to(&self, host: HostId) -> u64 {
        self.guard_ignore_poison().leased_to(host)
    }

    /// [`FabricManager::lease_count`]. Poison-tolerant read.
    pub fn lease_count(&self) -> usize {
        self.guard_ignore_poison().lease_count()
    }

    /// Total expander media capacity. Poison-tolerant read, so the
    /// cluster-level capacity audit keeps working after a panic.
    pub fn capacity(&self) -> u64 {
        self.guard_ignore_poison().expander().capacity()
    }

    /// [`FabricManager::release_host`] — crate-internal: reclaiming a
    /// host is the [`Cluster`](crate::cluster::Cluster) crash path, not
    /// something an arbitrary handle-holder may do to a sibling.
    /// Poison-tolerant: crash cleanup must run even after a panic.
    pub(crate) fn release_host(&self, host: HostId) {
        self.guard_ignore_poison().release_host(host)
    }

    /// [`FabricManager::check_invariants`]. Deliberately
    /// poison-tolerant: after a panic inside a fabric scope this is the
    /// audit that decides whether the state underneath is still sound.
    pub fn check_invariants(&self) -> Result<()> {
        self.guard_ignore_poison().check_invariants()
    }

    // ---- expander data plane / failure injection ----

    /// Functional write at a DPA through the shared expander.
    pub fn write_dpa(&self, dpa: Dpa, data: &[u8]) -> Result<()> {
        self.guard()?.expander_mut().write_dpa(dpa, data)
    }

    /// Functional read at a DPA through the shared expander.
    pub fn read_dpa(&self, dpa: Dpa, out: &mut [u8]) -> Result<()> {
        self.guard()?.expander().read_dpa(dpa, out)
    }

    /// Fail / recover the shared expander (failure-injection hook; one
    /// expander failure hits every bound host). Poison-tolerant so
    /// failure drills can still run after an unrelated panic.
    pub fn set_expander_failed(&self, failed: bool) {
        self.guard_ignore_poison().expander_mut().set_failed(failed);
    }

    /// Poison-tolerant read.
    pub fn expander_failed(&self) -> bool {
        self.guard_ignore_poison().expander().is_failed()
    }

    /// Scoped mutable access to the expander for in-crate data-plane
    /// helpers that need `&mut Expander` (e.g. the L2P table's
    /// `flush_to_fabric`). Crate-internal on purpose: the expander
    /// carries the SAT, and handing `&mut Expander` to arbitrary
    /// callers would let them program grants without the module's owner
    /// checks. External data-plane access goes through
    /// [`FabricRef::write_dpa`] / [`FabricRef::read_dpa`].
    pub(crate) fn with_expander_mut<R>(&self, f: impl FnOnce(&mut Expander) -> R) -> Result<R> {
        let mut fm = self.guard()?;
        Ok(f(fm.expander_mut()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::ExpanderConfig;
    use crate::cxl::types::{GIB, PAGE_SIZE};

    fn fm(cap: u64) -> FabricManager {
        let mut f = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: cap, ..Default::default() }),
        );
        f.attach_gfd().unwrap();
        f
    }

    #[test]
    fn extent_lease_and_release_roundtrip() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let e = f.allocate_extent(h).unwrap();
        assert_eq!(e.len, EXTENT_SIZE);
        assert_eq!(f.available(), GIB - EXTENT_SIZE);
        f.release_extent(h, e).unwrap();
        assert_eq!(f.available(), GIB);
        f.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhaustion_reports_available() {
        let mut f = fm(EXTENT_SIZE); // room for exactly one extent
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        match f.allocate_extent(h) {
            Err(Error::OutOfCapacity { available, .. }) => assert_eq!(available, 0),
            other => panic!("expected OutOfCapacity, got {other:?}"),
        }
    }

    #[test]
    fn release_coalesces_neighbours() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h).unwrap();
        let b = f.allocate_extent(h).unwrap();
        let c = f.allocate_extent(h).unwrap();
        f.release_extent(h, a).unwrap();
        f.release_extent(h, c).unwrap();
        f.release_extent(h, b).unwrap(); // middle release must merge all
        f.check_invariants().unwrap();
        assert_eq!(f.available(), GIB);
        assert_eq!(f.free.len(), 1, "free list fully coalesced");
    }

    #[test]
    fn multi_host_isolation() {
        let mut f = fm(GIB);
        let (h1, _) = f.bind_host().unwrap();
        let (h2, _) = f.bind_host().unwrap();
        let e1 = f.allocate_extent(h1).unwrap();
        assert!(f.release_extent(h2, e1).is_err(), "host2 cannot release host1's extent");
        assert_eq!(f.leased_to(h1), EXTENT_SIZE);
        assert_eq!(f.leased_to(h2), 0);
    }

    #[test]
    fn release_host_reclaims_everything() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.allocate_extent(h).unwrap();
        f.allocate_extent(h).unwrap();
        f.release_host(h);
        assert_eq!(f.available(), GIB);
        assert_eq!(f.lease_count(), 0);
        assert!(f.allocate_extent(h).is_err(), "host is gone");
    }

    #[test]
    fn release_host_revokes_stale_sat_grants() {
        // Regression: release_host used to free a host's extents and
        // unbind its SPID without touching the SAT, so a CXL device
        // kept P2P access to memory later re-leased to another host.
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let e = f.allocate_extent(h).unwrap();
        f.sat_grant(dev, Range::new(e.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        assert!(f.expander().sat().check(dev, e.dpa, 64, true));

        f.release_host(h);
        assert!(
            !f.expander().sat().check(dev, e.dpa, 64, false),
            "stale P2P grant revoked with the lease"
        );

        // the reclaimed DPA re-leases cleanly: a fresh grant over the
        // same range is not rejected as overlapping
        let (h2, _) = f.bind_host().unwrap();
        let e2 = f.allocate_extent(h2).unwrap();
        assert_eq!(e2.dpa, e.dpa, "first-fit re-leases the freed extent");
        f.sat_grant(dev, Range::new(e2.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        f.check_invariants().unwrap();
    }

    #[test]
    fn release_host_preserves_sibling_grants_and_decoders() {
        let mut f = fm(GIB);
        let (ha, _) = f.bind_host().unwrap();
        let (hb, _) = f.bind_host().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let ea = f.allocate_extent(ha).unwrap();
        let eb = f.allocate_extent(hb).unwrap();
        f.sat_grant(dev, Range::new(eb.dpa.0, PAGE_SIZE), SatPerm::ReadWrite).unwrap();
        f.expander_mut().add_decoder(Range::new(1 << 40, eb.len), eb.dpa).unwrap();

        f.release_host(ha);
        assert_eq!(f.available(), GIB - EXTENT_SIZE, "only ha's extent returned");
        assert_eq!(f.leased_to(hb), EXTENT_SIZE);
        assert!(f.expander().sat().check(dev, eb.dpa, 64, true), "sibling grant untouched");
        assert_eq!(f.expander().decode_hpa(crate::cxl::types::Hpa(1 << 40)).unwrap(), eb.dpa);
        let _ = ea;
    }

    #[test]
    fn running_counters_track_alloc_release_and_crash() {
        let mut f = fm(GIB);
        let (h1, _) = f.bind_host().unwrap();
        let (h2, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h1).unwrap();
        let b = f.allocate_extent(h2).unwrap();
        f.allocate_extent(h1).unwrap();
        assert_eq!(f.available(), GIB - 3 * EXTENT_SIZE);
        assert_eq!(f.leased_to(h1), 2 * EXTENT_SIZE);
        assert_eq!(f.leased_to(h2), EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_extent(h1, a).unwrap();
        assert_eq!(f.leased_to(h1), EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_host(h1);
        assert_eq!(f.leased_to(h1), 0);
        assert_eq!(f.available(), GIB - EXTENT_SIZE);
        f.check_invariants().unwrap();
        f.release_extent(h2, b).unwrap();
        assert_eq!(f.available(), GIB);
        assert_eq!(f.leased_to(h2), 0);
        f.check_invariants().unwrap();
    }

    #[test]
    fn p2p_violation_through_fm_names_real_gfd_dpid() {
        use crate::cxl::packet::{CxlMemReq, MemAddr};
        use crate::cxl::types::Requester;
        let mut f = fm(GIB);
        let gfd = f.gfd_dpid().unwrap();
        let dev = f.bind_cxl_device().unwrap();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0x40)), 64, Requester::CxlDevice(dev));
        match f.expander_mut().access(&req) {
            Err(Error::SatViolation { dpid, .. }) => assert_eq!(dpid, gfd),
            other => panic!("expected SatViolation, got {other:?}"),
        }
    }

    #[test]
    fn failed_expander_blocks_allocation() {
        let mut f = fm(GIB);
        let (h, _) = f.bind_host().unwrap();
        f.expander_mut().set_failed(true);
        assert!(matches!(f.allocate_extent(h), Err(Error::ExpanderFailed(_))));
    }

    #[test]
    fn fabric_ref_shares_one_fm_across_clones() {
        let fabric = fm(GIB).into_shared();
        let other = fabric.clone();
        assert_eq!(fabric.handle_count(), 2);
        // lease mutation is crate-internal (module/cluster paths); the
        // test reaches it through the same scoped lock they use
        let (h1, _) = fabric.with_fm_mut(|fm| fm.bind_host()).unwrap().unwrap();
        let (h2, _) = other.with_fm_mut(|fm| fm.bind_host()).unwrap().unwrap();
        assert_ne!(h1, h2, "clones bind against the same id space");
        fabric.with_fm_mut(|fm| fm.allocate_extent(h1)).unwrap().unwrap();
        other.with_fm_mut(|fm| fm.allocate_extent(h2)).unwrap().unwrap();
        assert_eq!(fabric.available(), GIB - 2 * EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h1), EXTENT_SIZE);
        assert_eq!(other.leased_to(h2), EXTENT_SIZE);
        fabric.release_host(h1);
        assert_eq!(other.available(), GIB - EXTENT_SIZE, "capacity back in the shared pool");
        other.check_invariants().unwrap();
    }

    #[test]
    fn fabric_ref_expander_data_plane_round_trip() {
        let fabric = fm(GIB).into_shared();
        fabric.write_dpa(Dpa(0x4000), b"shared-bytes").unwrap();
        let mut buf = [0u8; 12];
        fabric.read_dpa(Dpa(0x4000), &mut buf).unwrap();
        assert_eq!(&buf, b"shared-bytes");
        fabric.set_expander_failed(true);
        assert!(fabric.expander_failed());
        assert!(fabric.read_dpa(Dpa(0x4000), &mut buf).is_err());
        fabric.set_expander_failed(false);
        let pages = fabric.with_expander_mut(|e| e.resident_pages()).unwrap();
        assert!(pages > 0);
    }

    #[test]
    fn fabric_ref_is_send_sync_and_shares_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FabricRef>();

        let fabric = fm(GIB).into_shared();
        let (h, _) = fabric.with_fm_mut(|fm| fm.bind_host()).unwrap().unwrap();
        let worker = {
            let fabric = fabric.clone();
            std::thread::spawn(move || {
                fabric.with_fm_mut(|fm| fm.allocate_extent(h)).unwrap().unwrap();
                fabric.available()
            })
        };
        let seen = worker.join().unwrap();
        assert_eq!(seen, GIB - EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h), EXTENT_SIZE, "lease visible from the spawning thread");
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn panic_inside_scope_poisons_and_surfaces_fabric_poisoned() {
        let fabric = fm(GIB).into_shared();
        let (h, _) = fabric.with_fm_mut(|fm| fm.bind_host()).unwrap().unwrap();
        fabric.with_fm_mut(|fm| fm.allocate_extent(h)).unwrap().unwrap();

        // panic on another thread mid-scope: the lock poisons, the
        // process does not abort
        let victim = {
            let fabric = fabric.clone();
            std::thread::spawn(move || {
                let _: Result<()> = fabric
                    .with_fm_mut(|_fm| panic!("driver thread died holding the fabric lock"));
            })
        };
        assert!(victim.join().is_err(), "the panicking thread reports the panic");

        // fallible paths surface the poison as a typed error...
        assert!(matches!(fabric.with_fm(|fm| fm.lease_count()), Err(Error::FabricPoisoned)));
        assert!(matches!(fabric.with_fm_mut(|fm| fm.alloc_mmid()), Err(Error::FabricPoisoned)));
        assert!(matches!(fabric.write_dpa(Dpa(0), b"x"), Err(Error::FabricPoisoned)));
        assert!(matches!(fabric.bind_cxl_device(), Err(Error::FabricPoisoned)));

        // ...while the poison-tolerant audit surface still works: the
        // panic struck before any mutation, so the state is sound
        fabric.check_invariants().unwrap();
        assert_eq!(fabric.available(), GIB - EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h), EXTENT_SIZE);

        // and crash reclaim still runs post-poison
        fabric.release_host(h);
        assert_eq!(fabric.available(), GIB);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn mmid_namespace_is_fabric_global() {
        let mut f = fm(GIB);
        let a = f.alloc_mmid();
        let b = f.alloc_mmid();
        assert_ne!(a, b);
        assert!(b > a, "monotone, never reused");
    }

    #[test]
    fn contention_aware_placement_spreads_across_regions() {
        // 4 GiB pool → 512 MiB regions (two extents each). First-fit
        // packs sequentially; contention-aware places each new extent in
        // the least-loaded region, so the first 8 extents land in 8
        // distinct regions.
        let mut f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let (region_len, loads) = f.placement_regions();
        assert_eq!(region_len, 512 * 1024 * 1024);
        assert_eq!(loads.len(), 8);
        let mut regions_hit = std::collections::HashSet::new();
        for _ in 0..8 {
            let e = f
                .allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware)
                .unwrap();
            regions_hit.insert(e.dpa.0 / region_len);
            f.check_invariants().unwrap();
        }
        assert_eq!(regions_hit.len(), 8, "one extent per region before any region doubles up");
        let (_, loads) = f.placement_regions();
        assert!(loads.iter().all(|&l| l == EXTENT_SIZE), "perfectly balanced: {loads:?}");
    }

    #[test]
    fn contention_aware_ties_fall_back_to_first_fit() {
        // on an empty pool every region prices identically, so the
        // cheapest candidate is the lowest DPA — first-fit
        let mut f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let aware =
            f.allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware).unwrap();
        assert_eq!(aware.dpa, Dpa(0), "tie-break is first-fit");
        // and mid-range carving keeps the free list sorted + counted
        f.check_invariants().unwrap();
        f.release_extent(h, aware).unwrap();
        f.check_invariants().unwrap();
        assert_eq!(f.available(), 4 * GIB);
    }

    #[test]
    fn placed_and_first_fit_leases_share_one_accounting_path() {
        // interleave both policies; counters and invariants must hold,
        // and a mid-free-range carve must split the range cleanly
        let mut f = fm(4 * GIB);
        let (h, _) = f.bind_host().unwrap();
        let a = f.allocate_extent(h).unwrap(); // first-fit → dpa 0
        let b =
            f.allocate_extent_placed(h, EXTENT_SIZE, PlacementPolicy::ContentionAware).unwrap();
        assert_ne!(a.dpa.0 / (512 * 1024 * 1024), b.dpa.0 / (512 * 1024 * 1024));
        f.check_invariants().unwrap();
        // releasing the mid-space lease re-coalesces around it
        f.release_extent(h, b).unwrap();
        f.check_invariants().unwrap();
        f.release_extent(h, a).unwrap();
        assert_eq!(f.available(), 4 * GIB);
        f.check_invariants().unwrap();
    }

    #[test]
    fn sat_grant_requires_bound_spid() {
        let mut f = fm(GIB);
        assert!(f
            .sat_grant(Spid(99), Range::new(0, 4096), SatPerm::ReadWrite)
            .is_err());
        let spid = f.bind_cxl_device().unwrap();
        f.sat_grant(spid, Range::new(0, 4096), SatPerm::ReadWrite).unwrap();
    }
}
