//! Core CXL vocabulary (paper Table 1) as strongly-typed newtypes.
//!
//! Using newtypes rather than bare `u64`s makes address-space confusion
//! (HPA vs DPA vs device bus address) a compile error — exactly the class
//! of bug the paper's kernel module must not have.

use std::fmt;

/// Kibibyte/mebibyte/gibibyte helpers.
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Size of the extent the LMB kernel module requests from the FM (§3.2:
/// "it requests a single 256MB block from the Expander").
pub const EXTENT_SIZE: u64 = 256 * MIB;

/// Memory page granularity used by the allocator and IOMMU.
pub const PAGE_SIZE: u64 = 4 * KIB;

/// Host Physical Address — an address in the host's physical space,
/// possibly resolving to an HDM window rather than host DRAM.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Hpa(pub u64);

/// Device Physical Address — an address inside the expander's media
/// space (paper Table 1: "DPA").
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Dpa(pub u64);

/// Device bus address as seen by a PCIe device through the IOMMU
/// (an IOVA). Distinct from [`Hpa`] on purpose.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct BusAddr(pub u64);

/// Source PBR ID — identifies the requester of a CXL.mem transaction at
/// the switch/GFD (paper Table 1: "SPID").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Spid(pub u16);

/// Destination PBR ID of a GFD port (the paper's API hands a "DPID" back
/// to CXL devices so they can address P2P requests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Dpid(pub u16);

/// Switch port identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PortId(pub u8);

/// Device Media Partition id within the expander (paper Table 1: "DMP").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DmpId(pub u16);

/// Memory id returned by the LMB alloc APIs (Table 2: "mmid"); the handle
/// drivers use for free/share.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MmId(pub u64);

/// PCI bus/device/function triple identifying a PCIe endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Bdf {
    pub bus: u8,
    pub dev: u8,
    pub func: u8,
}

impl Bdf {
    pub const fn new(bus: u8, dev: u8, func: u8) -> Self {
        Bdf { bus, dev, func }
    }
}

impl fmt::Display for Bdf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:02x}:{:02x}.{:x}", self.bus, self.dev, self.func)
    }
}

/// Media backing a DMP (§3.1: "supports DRAM and PM heterogeneous media").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MediaType {
    /// DDR DRAM — the paper's primary target.
    Dram,
    /// Persistent memory — slower, retained across failure.
    Pm,
}

/// Identity of a fabric requester as seen by the switch and GFD.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Requester {
    /// A host root port (its SPID).
    Host(Spid),
    /// A CXL device doing direct P2P (its SPID).
    CxlDevice(Spid),
}

impl Requester {
    pub fn spid(&self) -> Spid {
        match *self {
            Requester::Host(s) | Requester::CxlDevice(s) => s,
        }
    }
}

/// Half-open address range helper used across address spaces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    pub base: u64,
    pub len: u64,
}

impl Range {
    pub const fn new(base: u64, len: u64) -> Self {
        Range { base, len }
    }

    #[inline]
    pub const fn end(&self) -> u64 {
        self.base + self.len
    }

    #[inline]
    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.end()
    }

    /// Whether the non-empty span `[addr, addr+len)` lies entirely
    /// inside this range (empty spans are never contained).
    #[inline]
    pub fn contains_span(&self, addr: u64, len: u64) -> bool {
        len > 0 && addr >= self.base && len <= self.len && addr - self.base <= self.len - len
    }

    #[inline]
    pub fn overlaps(&self, other: &Range) -> bool {
        self.base < other.end() && other.base < self.end()
    }
}

macro_rules! impl_addr_fmt {
    ($($t:ident),*) => {$(
        impl fmt::Debug for $t {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!(stringify!($t), "({:#x})"), self.0)
            }
        }
        impl fmt::Display for $t {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{:#x}", self.0)
            }
        }
        impl $t {
            /// Offset this address by `delta` bytes.
            #[inline]
            pub const fn offset(self, delta: u64) -> Self {
                $t(self.0 + delta)
            }
            /// Align down to `align` (power of two).
            #[inline]
            pub const fn align_down(self, align: u64) -> Self {
                $t(self.0 & !(align - 1))
            }
            /// Whether the address is `align`-aligned.
            #[inline]
            pub const fn is_aligned(self, align: u64) -> bool {
                self.0 & (align - 1) == 0
            }
        }
    )*};
}

impl_addr_fmt!(Hpa, Dpa, BusAddr);

/// Round `v` up to a multiple of `align` (power of two).
#[inline]
pub const fn align_up(v: u64, align: u64) -> u64 {
    (v + align - 1) & !(align - 1)
}

/// `gib` GiB in bytes, panicking with a clear message on `u64` overflow.
/// Builder sugar (`expander_gib`, `host_dram_gib`, …) funnels through
/// this: a silently wrapped size would build a tiny (or empty) expander
/// and surface as a baffling `OutOfCapacity` much later.
#[inline]
pub fn gib_to_bytes(gib: u64) -> u64 {
    gib.checked_mul(GIB)
        .unwrap_or_else(|| panic!("{gib} GiB overflows u64 — use a capacity below 2^34 GiB"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_contains_span_edges() {
        let r = Range::new(0x1000, 0x1000);
        assert!(r.contains_span(0x1000, 0x1000));
        assert!(r.contains_span(0x1fff, 1));
        assert!(!r.contains_span(0x1fff, 2));
        assert!(!r.contains_span(0xfff, 1));
        assert!(!r.contains_span(0x2000, 0));
    }

    #[test]
    fn range_overlap() {
        let a = Range::new(0, 100);
        assert!(a.overlaps(&Range::new(99, 1)));
        assert!(!a.overlaps(&Range::new(100, 10)));
        assert!(a.overlaps(&Range::new(0, 1)));
    }

    #[test]
    fn addr_alignment() {
        let a = Hpa(0x1234);
        assert_eq!(a.align_down(0x1000), Hpa(0x1000));
        assert!(!a.is_aligned(PAGE_SIZE));
        assert!(Hpa(0x2000).is_aligned(PAGE_SIZE));
        assert_eq!(align_up(1, PAGE_SIZE), PAGE_SIZE);
        assert_eq!(align_up(PAGE_SIZE, PAGE_SIZE), PAGE_SIZE);
    }

    #[test]
    fn extent_size_matches_paper() {
        assert_eq!(EXTENT_SIZE, 256 * 1024 * 1024);
    }

    #[test]
    fn gib_conversion_is_exact_in_range() {
        assert_eq!(gib_to_bytes(0), 0);
        assert_eq!(gib_to_bytes(4), 4 * GIB);
        assert_eq!(gib_to_bytes((1 << 34) - 1), ((1 << 34) - 1) * GIB);
    }

    #[test]
    #[should_panic(expected = "overflows u64")]
    fn gib_conversion_rejects_overflow() {
        gib_to_bytes(1 << 34);
    }

    #[test]
    fn bdf_display() {
        assert_eq!(Bdf::new(3, 0, 1).to_string(), "03:00.1");
    }
}
