//! CXL fabric substrate.
//!
//! Models the hardware the paper's LMB framework runs on (§2.3, §3,
//! Table 1): a Port-Based-Routing (PBR) switch, a Global FAM Device
//! (GFD) memory expander exposing Host-managed Device Memory (HDM)
//! organised into Device Media Partitions (DMPs), the SPID Access Table
//! (SAT) that enforces device-level isolation, and the Fabric Manager
//! (FM) that binds ports and doles out capacity.
//!
//! Latency constants default to the paper's Figure 2 estimates (25 ns
//! port crossing, 70 ns switch, 780 ns PCIe 5.0 device→host memory) and
//! the fabric model *derives* the per-scheme injection constants the
//! paper uses in §4 (+190 ns LMB-CXL, +880/+1190 ns LMB-PCIe on
//! Gen4/Gen5) — see [`fabric::Fabric::path_latency`].

pub mod expander;
pub mod fabric;
pub mod fm;
pub mod packet;
pub mod port;
pub mod sat;
pub mod switch;
pub mod types;

pub use expander::{Expander, ExpanderConfig};
pub use fabric::{Fabric, FabricConfig, PathKind};
pub use fm::FabricManager;
pub use sat::SatTable;
pub use switch::PbrSwitch;
