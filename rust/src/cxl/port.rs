//! CXL port model.
//!
//! Every fabric hop crosses a port; the paper (citing Das Sharma, HOTI'22)
//! puts a single port crossing at 25 ns. Ports also carry a bandwidth
//! figure used by the contention model when several devices funnel into
//! the same expander port.

use crate::cxl::types::PortId;
use crate::sim::time::SimTime;

/// Paper constant: one CXL port crossing (Figure 2).
pub const PORT_LATENCY: SimTime = SimTime::ns(25);

/// What is plugged into a switch edge port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortBinding {
    /// Unoccupied.
    Empty,
    /// A host root port.
    Host,
    /// A CXL type-2/3 device (accelerator, memory device).
    CxlDevice,
    /// The GFD memory expander itself.
    Gfd,
}

/// An edge or fabric port.
#[derive(Debug, Clone)]
pub struct Port {
    pub id: PortId,
    pub binding: PortBinding,
    /// Per-crossing latency.
    pub latency: SimTime,
    /// Link bandwidth in bytes/sec (x16 CXL 3.0 ≈ 64 GB/s raw; we default
    /// to a usable 50 GB/s).
    pub bandwidth_bps: u64,
}

impl Port {
    pub fn new(id: PortId) -> Self {
        Port {
            id,
            binding: PortBinding::Empty,
            latency: PORT_LATENCY,
            bandwidth_bps: 50_000_000_000,
        }
    }

    pub fn bound(id: PortId, binding: PortBinding) -> Self {
        let mut p = Self::new(id);
        p.binding = binding;
        p
    }

    /// Serialization time for `bytes` at this port's bandwidth.
    pub fn serialize(&self, bytes: u64) -> SimTime {
        // ns = bytes / (bytes_per_sec / 1e9); u128 avoids overflow
        SimTime::ns((bytes as u128 * 1_000_000_000 / self.bandwidth_bps as u128) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_latency_matches_paper() {
        assert_eq!(Port::new(PortId(0)).latency, SimTime::ns(25));
    }

    #[test]
    fn serialization_scales_with_bytes() {
        let p = Port::new(PortId(0));
        // 50 GB/s → 64 B line ≈ 1.28 ns → rounds to 1 ns
        assert_eq!(p.serialize(64), SimTime::ns(1));
        assert_eq!(p.serialize(50_000_000_000), SimTime::secs(1));
    }

    #[test]
    fn binding_assignment() {
        let p = Port::bound(PortId(4), PortBinding::Gfd);
        assert_eq!(p.binding, PortBinding::Gfd);
    }
}
