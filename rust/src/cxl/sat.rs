//! SPID Access Table (SAT) — GFD-side access control (§3.3).
//!
//! The GFD identifies the originator of every CXL.mem request by the SPID
//! field and consults the SAT to decide whether that requester may touch
//! the addressed DPA range. The LMB kernel module programs SAT entries
//! through the FM's "GFD Component Management Command Set" on alloc and
//! share, and removes them on free.

use std::collections::HashMap;

use crate::cxl::types::{Dpa, Range, Spid};
use crate::error::{Error, Result};

/// Access rights carried by a SAT entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SatPerm {
    ReadOnly,
    ReadWrite,
}

/// One SAT entry: a DPA window granted to an SPID.
#[derive(Debug, Clone, Copy)]
pub struct SatEntry {
    pub range: Range,
    pub perm: SatPerm,
}

/// The SPID Access Table.
///
/// Organised as SPID → list of granted DPA windows, kept sorted by
/// window base and non-overlapping (enforced at grant time), so the
/// per-access [`SatTable::check`] is a binary search rather than a
/// linear walk of the grant list. Real GFDs use a fixed number of
/// segment registers; we model that with a configurable entry budget so
/// table exhaustion is an observable failure mode.
#[derive(Debug)]
pub struct SatTable {
    grants: HashMap<Spid, Vec<SatEntry>>,
    capacity: usize,
    entries: usize,
}

impl SatTable {
    /// `capacity` = maximum number of live entries across all SPIDs.
    pub fn new(capacity: usize) -> Self {
        SatTable { grants: HashMap::new(), capacity, entries: 0 }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Grant `spid` access to a DPA window. Overlapping same-SPID grants
    /// are rejected — the kernel module must not double-program.
    pub fn grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        if self.entries >= self.capacity {
            return Err(Error::FabricManager(format!(
                "SAT exhausted ({} entries)",
                self.capacity
            )));
        }
        let list = self.grants.entry(spid).or_default();
        // sorted + disjoint: only the insertion point's neighbours can
        // overlap a new window, so the reject check is O(log n)
        let idx = list.partition_point(|e| e.range.base < range.base);
        let overlaps_at = |i: usize| list[i].range.overlaps(&range);
        if (idx > 0 && overlaps_at(idx - 1)) || (idx < list.len() && overlaps_at(idx)) {
            return Err(Error::FabricManager(format!(
                "overlapping SAT grant for SPID {spid:?} at {:#x}+{:#x}",
                range.base, range.len
            )));
        }
        list.insert(idx, SatEntry { range, perm });
        self.entries += 1;
        Ok(())
    }

    /// Revoke the grant that exactly matches `range`.
    pub fn revoke(&mut self, spid: Spid, range: Range) -> Result<()> {
        let list = self
            .grants
            .get_mut(&spid)
            .ok_or_else(|| Error::FabricManager(format!("no grants for SPID {spid:?}")))?;
        // entries are disjoint, so at most one can sit at `range.base`
        let idx = list.partition_point(|e| e.range.base < range.base);
        let found = idx < list.len() && list[idx].range == range;
        if !found {
            return Err(Error::FabricManager(format!(
                "no matching SAT entry for SPID {spid:?} at {:#x}",
                range.base
            )));
        }
        list.remove(idx);
        if list.is_empty() {
            self.grants.remove(&spid);
        }
        self.entries -= 1;
        Ok(())
    }

    /// Revoke every grant held by `spid` (device unbind / failure path).
    pub fn revoke_all(&mut self, spid: Spid) {
        if let Some(list) = self.grants.remove(&spid) {
            self.entries -= list.len();
        }
    }

    /// Revoke every grant — for any SPID — whose window overlaps `range`.
    /// Used when media is reclaimed (host crash / extent release): a
    /// stale device grant must not survive into a re-lease of the same
    /// DPA range. Returns the number of entries removed.
    pub fn revoke_overlapping(&mut self, range: Range) -> usize {
        let mut removed = 0;
        for list in self.grants.values_mut() {
            let before = list.len();
            list.retain(|e| !e.range.overlaps(&range));
            removed += before - list.len();
        }
        self.grants.retain(|_, list| !list.is_empty());
        self.entries -= removed;
        removed
    }

    /// Re-base every grant — for any SPID — whose window lies wholly
    /// inside `src` onto the equal-length window at `dst_base`,
    /// preserving each entry's offset, length, and permission. The
    /// migration commit path: device grants follow the media they were
    /// issued against, atomically with the placement switch (the caller
    /// holds the expander write lock). Entry count and capacity charge
    /// are unchanged. Returns the number of entries moved.
    pub fn rebase_range(&mut self, src: Range, dst_base: u64) -> usize {
        let mut moved = 0;
        for list in self.grants.values_mut() {
            let mut touched = false;
            for e in list.iter_mut() {
                if src.contains_span(e.range.base, e.range.len.max(1)) {
                    e.range = Range::new(dst_base + (e.range.base - src.base), e.range.len);
                    moved += 1;
                    touched = true;
                }
            }
            if touched {
                // Re-establish the sorted order `check` binary-searches
                // on; disjointness is preserved because the moved
                // windows keep their relative offsets inside a window
                // (`dst`) that held no other grants.
                list.sort_by_key(|e| e.range.base);
            }
        }
        moved
    }

    /// Check an access of `len` bytes at `dpa`. Write accesses require
    /// [`SatPerm::ReadWrite`]. Binary search over the sorted grant list:
    /// windows are disjoint, so the only candidate is the last entry
    /// whose base is <= the address.
    pub fn check(&self, spid: Spid, dpa: Dpa, len: u64, write: bool) -> bool {
        let Some(list) = self.grants.get(&spid) else {
            return false;
        };
        let idx = list.partition_point(|e| e.range.base <= dpa.0);
        let Some(e) = idx.checked_sub(1).map(|i| &list[i]) else {
            return false;
        };
        e.range.contains_span(dpa.0, len.max(1)) && (!write || e.perm == SatPerm::ReadWrite)
    }

    /// Indexing invariants the binary-search fast path relies on: every
    /// SPID's grant list sorted by base and disjoint, and the live-entry
    /// counter exact.
    pub fn check_invariants(&self) -> Result<()> {
        let mut counted = 0;
        for (spid, list) in &self.grants {
            for w in list.windows(2) {
                if w[1].range.base < w[0].range.end() || w[1].range.base < w[0].range.base {
                    return Err(Error::FabricManager(format!(
                        "SAT grants for SPID {spid:?} unsorted or overlapping"
                    )));
                }
            }
            counted += list.len();
        }
        if counted != self.entries {
            return Err(Error::FabricManager(format!(
                "SAT entry count drift: counted {counted}, cached {}",
                self.entries
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SatTable {
        SatTable::new(16)
    }

    #[test]
    fn grant_then_check() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x1000, 0x1000), SatPerm::ReadWrite).unwrap();
        assert!(t.check(Spid(1), Dpa(0x1000), 64, true));
        assert!(t.check(Spid(1), Dpa(0x1fc0), 64, false));
        assert!(!t.check(Spid(1), Dpa(0x1fc1), 64, false), "crosses end");
        assert!(!t.check(Spid(2), Dpa(0x1000), 64, false), "other SPID");
    }

    #[test]
    fn read_only_blocks_writes() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0, 0x1000), SatPerm::ReadOnly).unwrap();
        assert!(t.check(Spid(1), Dpa(0), 64, false));
        assert!(!t.check(Spid(1), Dpa(0), 64, true));
    }

    #[test]
    fn overlapping_grant_rejected() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0, 0x1000), SatPerm::ReadWrite).unwrap();
        assert!(t.grant(Spid(1), Range::new(0x800, 0x1000), SatPerm::ReadWrite).is_err());
        // other SPID may overlap (sharing!)
        t.grant(Spid(2), Range::new(0x800, 0x1000), SatPerm::ReadOnly).unwrap();
    }

    #[test]
    fn revoke_removes_access() {
        let mut t = table();
        let r = Range::new(0x2000, 0x1000);
        t.grant(Spid(3), r, SatPerm::ReadWrite).unwrap();
        assert!(t.check(Spid(3), Dpa(0x2000), 8, true));
        t.revoke(Spid(3), r).unwrap();
        assert!(!t.check(Spid(3), Dpa(0x2000), 8, false));
        assert!(t.revoke(Spid(3), r).is_err(), "double revoke");
        assert!(t.is_empty());
    }

    #[test]
    fn capacity_exhaustion() {
        let mut t = SatTable::new(2);
        t.grant(Spid(1), Range::new(0, 64), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(1), Range::new(64, 64), SatPerm::ReadWrite).unwrap();
        assert!(t.grant(Spid(1), Range::new(128, 64), SatPerm::ReadWrite).is_err());
    }

    #[test]
    fn revoke_overlapping_sweeps_every_spid() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x1000, 0x1000), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(2), Range::new(0x1800, 0x1000), SatPerm::ReadOnly).unwrap();
        t.grant(Spid(1), Range::new(0x8000, 0x1000), SatPerm::ReadWrite).unwrap();
        // reclaim [0x1000, 0x3000): both overlapping grants go, the
        // disjoint one survives
        assert_eq!(t.revoke_overlapping(Range::new(0x1000, 0x2000)), 2);
        assert!(!t.check(Spid(1), Dpa(0x1000), 64, false));
        assert!(!t.check(Spid(2), Dpa(0x1800), 64, false));
        assert!(t.check(Spid(1), Dpa(0x8000), 64, true));
        assert_eq!(t.len(), 1);
        // nothing left to revoke in that window
        assert_eq!(t.revoke_overlapping(Range::new(0x1000, 0x2000)), 0);
    }

    #[test]
    fn out_of_order_grants_keep_lists_sorted() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x8000, 0x1000), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(1), Range::new(0x1000, 0x1000), SatPerm::ReadOnly).unwrap();
        t.grant(Spid(1), Range::new(0x4000, 0x1000), SatPerm::ReadWrite).unwrap();
        t.check_invariants().unwrap();
        assert!(t.check(Spid(1), Dpa(0x1000), 64, false));
        assert!(!t.check(Spid(1), Dpa(0x1000), 64, true), "read-only window");
        assert!(t.check(Spid(1), Dpa(0x4fc0), 64, true));
        assert!(t.check(Spid(1), Dpa(0x8000), 64, true));
        assert!(!t.check(Spid(1), Dpa(0x2000), 64, false), "gap between windows");
        // overlap rejection against both neighbours of the insert point
        assert!(t.grant(Spid(1), Range::new(0x4800, 0x1000), SatPerm::ReadWrite).is_err());
        assert!(t.grant(Spid(1), Range::new(0x3800, 0x900), SatPerm::ReadWrite).is_err());
        t.revoke(Spid(1), Range::new(0x4000, 0x1000)).unwrap();
        assert!(!t.check(Spid(1), Dpa(0x4000), 64, false));
        t.check_invariants().unwrap();
    }

    #[test]
    fn rebase_range_moves_contained_grants_for_every_spid() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x1000, 0x100), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(1), Range::new(0x1800, 0x100), SatPerm::ReadOnly).unwrap();
        t.grant(Spid(2), Range::new(0x1400, 0x100), SatPerm::ReadOnly).unwrap();
        t.grant(Spid(1), Range::new(0x8000, 0x100), SatPerm::ReadWrite).unwrap();
        // migrate [0x1000, 0x2000) down to 0x9000
        assert_eq!(t.rebase_range(Range::new(0x1000, 0x1000), 0x9000), 3);
        t.check_invariants().unwrap();
        assert_eq!(t.len(), 4, "rebase must not change the entry count");
        // old windows are dead, new windows carry the old offsets+perms
        assert!(!t.check(Spid(1), Dpa(0x1000), 64, false));
        assert!(t.check(Spid(1), Dpa(0x9000), 64, true));
        assert!(t.check(Spid(1), Dpa(0x9800), 64, false));
        assert!(!t.check(Spid(1), Dpa(0x9800), 64, true), "perm preserved");
        assert!(t.check(Spid(2), Dpa(0x9400), 64, false));
        assert!(t.check(Spid(1), Dpa(0x8000), 64, true), "disjoint grant untouched");
        // rebase back keeps the list sorted even though dst < existing
        assert_eq!(t.rebase_range(Range::new(0x9000, 0x1000), 0x1000), 3);
        t.check_invariants().unwrap();
        assert!(t.check(Spid(1), Dpa(0x1000), 64, true));
    }

    #[test]
    fn revoke_requires_exact_range_match() {
        let mut t = table();
        let r = Range::new(0x2000, 0x1000);
        t.grant(Spid(3), r, SatPerm::ReadWrite).unwrap();
        assert!(t.revoke(Spid(3), Range::new(0x2000, 0x800)).is_err(), "length mismatch");
        assert!(t.revoke(Spid(3), Range::new(0x2800, 0x800)).is_err(), "base mismatch");
        t.revoke(Spid(3), r).unwrap();
        assert!(t.is_empty());
    }

    #[test]
    fn revoke_all_clears_spid() {
        let mut t = table();
        t.grant(Spid(9), Range::new(0, 64), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(9), Range::new(64, 64), SatPerm::ReadOnly).unwrap();
        t.revoke_all(Spid(9));
        assert_eq!(t.len(), 0);
        assert!(!t.check(Spid(9), Dpa(0), 1, false));
    }
}
