//! SPID Access Table (SAT) — GFD-side access control (§3.3).
//!
//! The GFD identifies the originator of every CXL.mem request by the SPID
//! field and consults the SAT to decide whether that requester may touch
//! the addressed DPA range. The LMB kernel module programs SAT entries
//! through the FM's "GFD Component Management Command Set" on alloc and
//! share, and removes them on free.

use std::collections::HashMap;

use crate::cxl::types::{Dpa, Range, Spid};
use crate::error::{Error, Result};

/// Access rights carried by a SAT entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SatPerm {
    ReadOnly,
    ReadWrite,
}

/// One SAT entry: a DPA window granted to an SPID.
#[derive(Debug, Clone, Copy)]
pub struct SatEntry {
    pub range: Range,
    pub perm: SatPerm,
}

/// The SPID Access Table.
///
/// Organised as SPID → sorted list of granted DPA windows. Real GFDs use
/// a fixed number of segment registers; we model that with a configurable
/// entry budget so table exhaustion is an observable failure mode.
#[derive(Debug)]
pub struct SatTable {
    grants: HashMap<Spid, Vec<SatEntry>>,
    capacity: usize,
    entries: usize,
}

impl SatTable {
    /// `capacity` = maximum number of live entries across all SPIDs.
    pub fn new(capacity: usize) -> Self {
        SatTable { grants: HashMap::new(), capacity, entries: 0 }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Grant `spid` access to a DPA window. Overlapping same-SPID grants
    /// are rejected — the kernel module must not double-program.
    pub fn grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> Result<()> {
        if self.entries >= self.capacity {
            return Err(Error::FabricManager(format!(
                "SAT exhausted ({} entries)",
                self.capacity
            )));
        }
        let list = self.grants.entry(spid).or_default();
        if list.iter().any(|e| e.range.overlaps(&range)) {
            return Err(Error::FabricManager(format!(
                "overlapping SAT grant for SPID {spid:?} at {:#x}+{:#x}",
                range.base, range.len
            )));
        }
        list.push(SatEntry { range, perm });
        self.entries += 1;
        Ok(())
    }

    /// Revoke the grant that exactly matches `range`.
    pub fn revoke(&mut self, spid: Spid, range: Range) -> Result<()> {
        let list = self
            .grants
            .get_mut(&spid)
            .ok_or_else(|| Error::FabricManager(format!("no grants for SPID {spid:?}")))?;
        let before = list.len();
        list.retain(|e| !(e.range.base == range.base && e.range.len == range.len));
        if list.len() == before {
            return Err(Error::FabricManager(format!(
                "no matching SAT entry for SPID {spid:?} at {:#x}",
                range.base
            )));
        }
        self.entries -= 1;
        Ok(())
    }

    /// Revoke every grant held by `spid` (device unbind / failure path).
    pub fn revoke_all(&mut self, spid: Spid) {
        if let Some(list) = self.grants.remove(&spid) {
            self.entries -= list.len();
        }
    }

    /// Revoke every grant — for any SPID — whose window overlaps `range`.
    /// Used when media is reclaimed (host crash / extent release): a
    /// stale device grant must not survive into a re-lease of the same
    /// DPA range. Returns the number of entries removed.
    pub fn revoke_overlapping(&mut self, range: Range) -> usize {
        let mut removed = 0;
        for list in self.grants.values_mut() {
            let before = list.len();
            list.retain(|e| !e.range.overlaps(&range));
            removed += before - list.len();
        }
        self.grants.retain(|_, list| !list.is_empty());
        self.entries -= removed;
        removed
    }

    /// Check an access of `len` bytes at `dpa`. Write accesses require
    /// [`SatPerm::ReadWrite`].
    pub fn check(&self, spid: Spid, dpa: Dpa, len: u64, write: bool) -> bool {
        let Some(list) = self.grants.get(&spid) else {
            return false;
        };
        list.iter().any(|e| {
            e.range.contains_span(dpa.0, len.max(1))
                && (!write || e.perm == SatPerm::ReadWrite)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SatTable {
        SatTable::new(16)
    }

    #[test]
    fn grant_then_check() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x1000, 0x1000), SatPerm::ReadWrite).unwrap();
        assert!(t.check(Spid(1), Dpa(0x1000), 64, true));
        assert!(t.check(Spid(1), Dpa(0x1fc0), 64, false));
        assert!(!t.check(Spid(1), Dpa(0x1fc1), 64, false), "crosses end");
        assert!(!t.check(Spid(2), Dpa(0x1000), 64, false), "other SPID");
    }

    #[test]
    fn read_only_blocks_writes() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0, 0x1000), SatPerm::ReadOnly).unwrap();
        assert!(t.check(Spid(1), Dpa(0), 64, false));
        assert!(!t.check(Spid(1), Dpa(0), 64, true));
    }

    #[test]
    fn overlapping_grant_rejected() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0, 0x1000), SatPerm::ReadWrite).unwrap();
        assert!(t.grant(Spid(1), Range::new(0x800, 0x1000), SatPerm::ReadWrite).is_err());
        // other SPID may overlap (sharing!)
        t.grant(Spid(2), Range::new(0x800, 0x1000), SatPerm::ReadOnly).unwrap();
    }

    #[test]
    fn revoke_removes_access() {
        let mut t = table();
        let r = Range::new(0x2000, 0x1000);
        t.grant(Spid(3), r, SatPerm::ReadWrite).unwrap();
        assert!(t.check(Spid(3), Dpa(0x2000), 8, true));
        t.revoke(Spid(3), r).unwrap();
        assert!(!t.check(Spid(3), Dpa(0x2000), 8, false));
        assert!(t.revoke(Spid(3), r).is_err(), "double revoke");
        assert!(t.is_empty());
    }

    #[test]
    fn capacity_exhaustion() {
        let mut t = SatTable::new(2);
        t.grant(Spid(1), Range::new(0, 64), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(1), Range::new(64, 64), SatPerm::ReadWrite).unwrap();
        assert!(t.grant(Spid(1), Range::new(128, 64), SatPerm::ReadWrite).is_err());
    }

    #[test]
    fn revoke_overlapping_sweeps_every_spid() {
        let mut t = table();
        t.grant(Spid(1), Range::new(0x1000, 0x1000), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(2), Range::new(0x1800, 0x1000), SatPerm::ReadOnly).unwrap();
        t.grant(Spid(1), Range::new(0x8000, 0x1000), SatPerm::ReadWrite).unwrap();
        // reclaim [0x1000, 0x3000): both overlapping grants go, the
        // disjoint one survives
        assert_eq!(t.revoke_overlapping(Range::new(0x1000, 0x2000)), 2);
        assert!(!t.check(Spid(1), Dpa(0x1000), 64, false));
        assert!(!t.check(Spid(2), Dpa(0x1800), 64, false));
        assert!(t.check(Spid(1), Dpa(0x8000), 64, true));
        assert_eq!(t.len(), 1);
        // nothing left to revoke in that window
        assert_eq!(t.revoke_overlapping(Range::new(0x1000, 0x2000)), 0);
    }

    #[test]
    fn revoke_all_clears_spid() {
        let mut t = table();
        t.grant(Spid(9), Range::new(0, 64), SatPerm::ReadWrite).unwrap();
        t.grant(Spid(9), Range::new(64, 64), SatPerm::ReadOnly).unwrap();
        t.revoke_all(Spid(9));
        assert_eq!(t.len(), 0);
        assert!(!t.check(Spid(9), Dpa(0), 1, false));
    }
}
