//! CXL.mem / CXL.io transaction modelling (§3.2 "Data path").
//!
//! We model the protocol at message granularity: Master-to-Subordinate
//! (M2S) requests and Subordinate-to-Master (S2M) responses. The paper's
//! data path converts PCIe TLPs into `MemRd`/`MemWr` at the host bridge;
//! PCIe-originated requests are marked *uncached* because PCIe devices
//! cannot participate in CXL coherency (they never see Back-Invalidate
//! Snoops — §3.2 notes why this is still consistent).

use crate::cxl::types::{Dpa, Hpa, Requester};

/// M2S request opcode subset relevant to LMB.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemOp {
    /// 64-byte read (MemRd).
    MemRd,
    /// 64-byte write (MemWr).
    MemWr,
    /// Cache-line invalidate (MemInv) — host-side coherency management.
    MemInv,
}

/// Cacheability attribute of a request (§3.2: PCIe-originated accesses
/// use the *uncached* type).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheAttr {
    Cacheable,
    Uncached,
}

/// Address carried by a request: hosts address HDM through HPA windows,
/// P2P devices address the GFD by DPA (after FM setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemAddr {
    Hpa(Hpa),
    Dpa(Dpa),
}

/// A CXL.mem request message.
#[derive(Debug, Clone, Copy)]
pub struct CxlMemReq {
    pub op: MemOp,
    pub addr: MemAddr,
    /// Transfer size in bytes; the protocol moves 64 B lines, larger
    /// spans are split by [`CxlMemReq::lines`].
    pub len: u32,
    pub requester: Requester,
    pub attr: CacheAttr,
}

/// CXL.mem line size.
pub const LINE: u32 = 64;

impl CxlMemReq {
    pub fn read(addr: MemAddr, len: u32, requester: Requester) -> Self {
        CxlMemReq { op: MemOp::MemRd, addr, len, requester, attr: CacheAttr::Cacheable }
    }

    pub fn write(addr: MemAddr, len: u32, requester: Requester) -> Self {
        CxlMemReq { op: MemOp::MemWr, addr, len, requester, attr: CacheAttr::Cacheable }
    }

    /// Mark the request uncached (PCIe-originated path).
    pub fn uncached(mut self) -> Self {
        self.attr = CacheAttr::Uncached;
        self
    }

    /// Number of 64 B lines this request occupies on the link.
    pub fn lines(&self) -> u32 {
        let off = match self.addr {
            MemAddr::Hpa(h) => h.0,
            MemAddr::Dpa(d) => d.0,
        } % LINE as u64;
        (off as u32 + self.len).div_ceil(LINE)
    }
}

/// S2M response subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CxlMemResp {
    /// Completion without data (writes).
    Cmp,
    /// Completion with data (reads).
    CmpData,
    /// Poison/error completion — e.g. SAT violation or failed media.
    Err,
}

/// CXL.io (UIO) access — the non-coherent mailbox/config path a CXL
/// device may use instead of CXL.mem (§3: "UIO access via CXL.io").
#[derive(Debug, Clone, Copy)]
pub struct CxlIoReq {
    pub write: bool,
    pub addr: Hpa,
    pub len: u32,
    pub requester: Requester,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::Spid;

    fn rq() -> Requester {
        Requester::CxlDevice(Spid(3))
    }

    #[test]
    fn line_splitting_aligned() {
        let r = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, rq());
        assert_eq!(r.lines(), 1);
        let r = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 256, rq());
        assert_eq!(r.lines(), 4);
    }

    #[test]
    fn line_splitting_unaligned_crosses_boundary() {
        // 4 bytes at offset 62 straddles two lines.
        let r = CxlMemReq::read(MemAddr::Dpa(Dpa(62)), 4, rq());
        assert_eq!(r.lines(), 2);
    }

    #[test]
    fn uncached_builder() {
        let r = CxlMemReq::write(MemAddr::Hpa(Hpa(0x1000)), 8, rq()).uncached();
        assert_eq!(r.attr, CacheAttr::Uncached);
        assert_eq!(r.op, MemOp::MemWr);
    }
}
