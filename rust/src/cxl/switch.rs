//! PBR switch (§2.3): edge ports, SPID routing, fabric crossing latency.
//!
//! Hosts and devices acquire a PBR ID by binding to an edge port; the
//! switch routes CXL.mem requests toward the GFD and enforces that only
//! bound requesters inject traffic. The paper quotes 70 ns for a switch
//! crossing (including HDM decode at the fabric level).

use std::collections::HashMap;

use crate::cxl::packet::CxlMemReq;
use crate::cxl::port::{Port, PortBinding, PORT_LATENCY};
use crate::cxl::types::{Dpid, PortId, Spid};
use crate::error::{Error, Result};
use crate::sim::time::SimTime;

/// Paper constant: switch crossing (Figure 2).
pub const SWITCH_LATENCY: SimTime = SimTime::ns(70);

/// The Port-Based-Routing switch.
#[derive(Debug)]
pub struct PbrSwitch {
    ports: Vec<Port>,
    /// SPID → edge port it is bound to.
    bindings: HashMap<Spid, PortId>,
    /// Port the GFD hangs off.
    gfd_port: Option<PortId>,
    /// The GFD's PBR id — the DPID P2P requesters address (§3.3).
    gfd_dpid: Option<Dpid>,
    next_spid: u16,
    pub latency: SimTime,
}

impl PbrSwitch {
    /// A switch with `nports` empty edge ports.
    pub fn new(nports: u8) -> Self {
        PbrSwitch {
            ports: (0..nports).map(|i| Port::new(PortId(i))).collect(),
            bindings: HashMap::new(),
            gfd_port: None,
            gfd_dpid: None,
            next_spid: 1,
            latency: SWITCH_LATENCY,
        }
    }

    fn free_port(&self) -> Option<PortId> {
        self.ports.iter().find(|p| p.binding == PortBinding::Empty).map(|p| p.id)
    }

    fn port_mut(&mut self, id: PortId) -> &mut Port {
        &mut self.ports[id.0 as usize]
    }

    fn alloc_spid(&mut self) -> Spid {
        let s = Spid(self.next_spid);
        self.next_spid += 1;
        s
    }

    /// Bind a host root port to the next free edge port, returning its SPID.
    pub fn bind_host(&mut self) -> Result<(Spid, PortId)> {
        let port = self
            .free_port()
            .ok_or_else(|| Error::FabricManager("no free edge port".into()))?;
        self.port_mut(port).binding = PortBinding::Host;
        let spid = self.alloc_spid();
        self.bindings.insert(spid, port);
        Ok((spid, port))
    }

    /// Bind a CXL device, returning its SPID.
    pub fn bind_cxl_device(&mut self) -> Result<(Spid, PortId)> {
        let port = self
            .free_port()
            .ok_or_else(|| Error::FabricManager("no free edge port".into()))?;
        self.port_mut(port).binding = PortBinding::CxlDevice;
        let spid = self.alloc_spid();
        self.bindings.insert(spid, port);
        Ok((spid, port))
    }

    /// Attach the GFD expander to an edge port, assigning it a PBR id
    /// from the same id space as SPIDs. Returns the port and the DPID
    /// that P2P requesters must address.
    pub fn attach_gfd(&mut self) -> Result<(PortId, Dpid)> {
        if self.gfd_port.is_some() {
            return Err(Error::FabricManager("GFD already attached".into()));
        }
        let port = self
            .free_port()
            .ok_or_else(|| Error::FabricManager("no free edge port".into()))?;
        self.port_mut(port).binding = PortBinding::Gfd;
        self.gfd_port = Some(port);
        let dpid = Dpid(self.next_spid);
        self.next_spid += 1;
        self.gfd_dpid = Some(dpid);
        Ok((port, dpid))
    }

    /// Unbind an SPID (device removal / failure).
    pub fn unbind(&mut self, spid: Spid) -> Result<()> {
        let port = self
            .bindings
            .remove(&spid)
            .ok_or_else(|| Error::FabricManager(format!("SPID {spid:?} not bound")))?;
        self.port_mut(port).binding = PortBinding::Empty;
        Ok(())
    }

    pub fn is_bound(&self, spid: Spid) -> bool {
        self.bindings.contains_key(&spid)
    }

    pub fn gfd_port(&self) -> Option<PortId> {
        self.gfd_port
    }

    /// DPID of the attached GFD, if bring-up has happened.
    pub fn gfd_dpid(&self) -> Option<Dpid> {
        self.gfd_dpid
    }

    /// Latency for routing `req` from its (bound) requester to the GFD:
    /// ingress port + switch crossing + egress port.
    pub fn route_to_gfd(&self, req: &CxlMemReq) -> Result<SimTime> {
        let spid = req.requester.spid();
        let ingress = *self
            .bindings
            .get(&spid)
            .ok_or_else(|| Error::FabricManager(format!("SPID {spid:?} not bound")))?;
        let egress = self
            .gfd_port
            .ok_or_else(|| Error::FabricManager("no GFD attached".into()))?;
        let t = self.ports[ingress.0 as usize].latency
            + self.latency
            + self.ports[egress.0 as usize].latency;
        Ok(t)
    }

    /// Number of bound (non-GFD) requesters.
    pub fn bound_count(&self) -> usize {
        self.bindings.len()
    }
}

/// Convenience: the canonical one-hop fabric crossing (port+switch+port),
/// i.e. what any requester pays to reach the GFD before media access.
pub fn fabric_crossing() -> SimTime {
    PORT_LATENCY + SWITCH_LATENCY + PORT_LATENCY
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::packet::{CxlMemReq, MemAddr};
    use crate::cxl::types::{Dpa, Requester};

    #[test]
    fn binding_assigns_unique_spids() {
        let mut sw = PbrSwitch::new(8);
        let (s1, p1) = sw.bind_host().unwrap();
        let (s2, p2) = sw.bind_cxl_device().unwrap();
        assert_ne!(s1, s2);
        assert_ne!(p1, p2);
        assert_eq!(sw.bound_count(), 2);
    }

    #[test]
    fn port_exhaustion() {
        let mut sw = PbrSwitch::new(2);
        sw.bind_host().unwrap();
        sw.attach_gfd().unwrap();
        assert!(sw.bind_cxl_device().is_err());
    }

    #[test]
    fn route_latency_is_two_ports_plus_switch() {
        let mut sw = PbrSwitch::new(4);
        let (spid, _) = sw.bind_cxl_device().unwrap();
        sw.attach_gfd().unwrap();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, Requester::CxlDevice(spid));
        // 25 + 70 + 25 = 120 ns
        assert_eq!(sw.route_to_gfd(&req).unwrap(), SimTime::ns(120));
        assert_eq!(fabric_crossing(), SimTime::ns(120));
    }

    #[test]
    fn unbound_requester_rejected() {
        let mut sw = PbrSwitch::new(4);
        sw.attach_gfd().unwrap();
        let req = CxlMemReq::read(MemAddr::Dpa(Dpa(0)), 64, Requester::CxlDevice(Spid(42)));
        assert!(sw.route_to_gfd(&req).is_err());
    }

    #[test]
    fn unbind_frees_port() {
        let mut sw = PbrSwitch::new(2);
        let (spid, _) = sw.bind_host().unwrap();
        sw.attach_gfd().unwrap();
        sw.unbind(spid).unwrap();
        assert!(!sw.is_bound(spid));
        // the freed port is reusable
        sw.bind_cxl_device().unwrap();
    }

    #[test]
    fn single_gfd_enforced() {
        let mut sw = PbrSwitch::new(4);
        sw.attach_gfd().unwrap();
        assert!(sw.attach_gfd().is_err());
    }

    #[test]
    fn gfd_dpid_shares_pbr_id_space() {
        let mut sw = PbrSwitch::new(4);
        let (s1, _) = sw.bind_host().unwrap();
        let (_, dpid) = sw.attach_gfd().unwrap();
        let (s2, _) = sw.bind_cxl_device().unwrap();
        assert_eq!(sw.gfd_dpid(), Some(dpid));
        // one id space: the GFD's DPID collides with no requester SPID
        assert_ne!(dpid.0, s1.0);
        assert_ne!(dpid.0, s2.0);
    }
}
