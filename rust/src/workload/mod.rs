//! Workload engine: FIO-like job specs and LBA stream generators.
//!
//! The paper evaluates with FIO (libaio, QD 64, 4 KB IOs) under four
//! patterns: sequential/random × read/write (§4). We mirror that job
//! model and add zipfian skew and trace record/replay for the locality
//! ablation (§4.1's closing remark about "the locality of actual
//! workloads").

pub mod fio;
pub mod tenants;
pub mod trace;
pub mod zipf;

pub use fio::{FioJob, IoEngine, IoPattern, IoRequest};
pub use tenants::TenantPopulation;
pub use trace::Trace;
pub use zipf::Zipfian;
