//! FIO-like job model.
//!
//! A [`FioJob`] mirrors the fio options the paper fixes (§4): pattern
//! (`rw=`), block size (`bs=`), queue depth (`iodepth=`), engine
//! (`ioengine=libaio`), plus `numjobs` (parallel submitters — enterprise
//! IOPS specs assume several). The generator yields a deterministic
//! [`IoRequest`] stream for the simulator.

use crate::error::{Error, Result};
use crate::sim::rng::Pcg64;
use crate::workload::zipf::Zipfian;

/// Access pattern (fio `rw=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IoPattern {
    SeqRead,
    SeqWrite,
    RandRead,
    RandWrite,
}

impl IoPattern {
    pub const ALL: [IoPattern; 4] =
        [IoPattern::SeqWrite, IoPattern::RandWrite, IoPattern::SeqRead, IoPattern::RandRead];

    pub fn is_write(self) -> bool {
        matches!(self, IoPattern::SeqWrite | IoPattern::RandWrite)
    }

    pub fn is_seq(self) -> bool {
        matches!(self, IoPattern::SeqRead | IoPattern::SeqWrite)
    }

    pub fn label(self) -> &'static str {
        match self {
            IoPattern::SeqRead => "seq-read",
            IoPattern::SeqWrite => "seq-write",
            IoPattern::RandRead => "rand-read",
            IoPattern::RandWrite => "rand-write",
        }
    }
}

/// Submission engine (fio `ioengine=`). Only the async engine the paper
/// uses plus a sync engine for latency-oriented tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoEngine {
    /// Asynchronous, `qd` outstanding per job (the paper's setting).
    Libaio,
    /// Synchronous: one outstanding per job regardless of `qd`.
    Sync,
}

/// One IO of the generated stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoRequest {
    /// Logical page address (block-size units).
    pub lpa: u64,
    pub is_write: bool,
}

/// A fio-style job description.
#[derive(Debug, Clone)]
pub struct FioJob {
    pub pattern: IoPattern,
    /// Block size in bytes (`bs=`).
    pub block_size: u32,
    /// Queue depth per job (`iodepth=`).
    pub qd: u32,
    /// Parallel submitters (`numjobs=`).
    pub numjobs: u32,
    pub engine: IoEngine,
    /// Total IOs to generate.
    pub total_ios: u64,
    /// Addressable span in bytes (`size=`).
    pub span_bytes: u64,
    /// Optional zipfian skew for random patterns (`random_distribution=zipf:θ`).
    pub zipf_theta: Option<f64>,
    /// RNG seed.
    pub seed: u64,
}

impl FioJob {
    /// The paper's configuration: libaio, QD 64, 4 KB, over `span_bytes`.
    pub fn paper(pattern: IoPattern, span_bytes: u64) -> Self {
        FioJob {
            pattern,
            block_size: 4096,
            qd: 64,
            numjobs: 4,
            engine: IoEngine::Libaio,
            total_ios: 200_000,
            span_bytes,
            zipf_theta: None,
            seed: 0x10b5,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.block_size == 0 || !self.block_size.is_power_of_two() {
            return Err(Error::Config(format!("bad block size {}", self.block_size)));
        }
        if self.qd == 0 || self.numjobs == 0 {
            return Err(Error::Config("qd and numjobs must be >= 1".into()));
        }
        if self.span_bytes < self.block_size as u64 {
            return Err(Error::Config("span smaller than one block".into()));
        }
        if let Some(theta) = self.zipf_theta {
            if !(0.0..2.0).contains(&theta) {
                return Err(Error::Config(format!("zipf theta {theta} out of range")));
            }
        }
        Ok(())
    }

    /// Number of addressable logical pages.
    pub fn span_pages(&self) -> u64 {
        self.span_bytes / self.block_size as u64
    }

    /// Effective outstanding IOs across jobs.
    pub fn outstanding(&self) -> u32 {
        match self.engine {
            IoEngine::Libaio => self.qd * self.numjobs,
            IoEngine::Sync => self.numjobs,
        }
    }

    /// Deterministic request stream.
    pub fn generate(&self) -> Generator {
        Generator {
            job: self.clone(),
            rng: Pcg64::with_stream(self.seed, 0xf10),
            zipf: self.zipf_theta.map(|t| Zipfian::new(self.span_pages(), t)),
            next_seq: 0,
            emitted: 0,
        }
    }
}

/// Iterator over a job's IO stream.
pub struct Generator {
    job: FioJob,
    rng: Pcg64,
    zipf: Option<Zipfian>,
    next_seq: u64,
    emitted: u64,
}

impl Iterator for Generator {
    type Item = IoRequest;

    fn next(&mut self) -> Option<IoRequest> {
        if self.emitted >= self.job.total_ios {
            return None;
        }
        self.emitted += 1;
        let pages = self.job.span_pages();
        let lpa = if self.job.pattern.is_seq() {
            let l = self.next_seq % pages;
            self.next_seq += 1;
            l
        } else if let Some(z) = &mut self.zipf {
            z.sample(&mut self.rng)
        } else {
            self.rng.next_below(pages)
        };
        Some(IoRequest { lpa, is_write: self.job.pattern.is_write() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;

    fn job(pattern: IoPattern) -> FioJob {
        FioJob { total_ios: 10_000, ..FioJob::paper(pattern, GIB) }
    }

    #[test]
    fn paper_defaults_match_section4() {
        let j = FioJob::paper(IoPattern::RandRead, GIB);
        assert_eq!(j.block_size, 4096);
        assert_eq!(j.qd, 64);
        assert_eq!(j.engine, IoEngine::Libaio);
        j.validate().unwrap();
    }

    #[test]
    fn sequential_stream_is_sequential_and_wraps() {
        let mut g = job(IoPattern::SeqRead).generate();
        let pages = job(IoPattern::SeqRead).span_pages();
        for i in 0..(pages + 5) {
            let r = g.next().unwrap();
            assert_eq!(r.lpa, i % pages);
            assert!(!r.is_write);
            if i > 10_000 - 6 {
                break;
            }
        }
    }

    #[test]
    fn random_stream_covers_span_uniformly() {
        let j = job(IoPattern::RandRead);
        let pages = j.span_pages();
        let lpas: Vec<u64> = j.generate().map(|r| r.lpa).collect();
        assert_eq!(lpas.len(), 10_000);
        let mean = lpas.iter().sum::<u64>() as f64 / lpas.len() as f64;
        let expect = pages as f64 / 2.0;
        assert!((mean - expect).abs() / expect < 0.05, "mean {mean} vs {expect}");
        assert!(lpas.iter().all(|&l| l < pages));
    }

    #[test]
    fn write_patterns_mark_writes() {
        assert!(job(IoPattern::RandWrite).generate().all(|r| r.is_write));
        assert!(job(IoPattern::SeqRead).generate().all(|r| !r.is_write));
    }

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<_> = job(IoPattern::RandWrite).generate().take(100).collect();
        let b: Vec<_> = job(IoPattern::RandWrite).generate().take(100).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn outstanding_accounts_numjobs_and_engine() {
        let mut j = job(IoPattern::RandRead);
        assert_eq!(j.outstanding(), 256); // 64 × 4
        j.engine = IoEngine::Sync;
        assert_eq!(j.outstanding(), 4);
    }

    #[test]
    fn zipfian_stream_is_skewed() {
        let mut j = job(IoPattern::RandRead);
        j.zipf_theta = Some(0.99);
        j.validate().unwrap();
        let lpas: Vec<u64> = j.generate().map(|r| r.lpa).collect();
        // top-1 page should appear far more often than 1/span
        let mut counts = std::collections::HashMap::new();
        for l in &lpas {
            *counts.entry(l).or_insert(0u64) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        assert!(max > 100, "zipf hot page count = {max}");
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut j = job(IoPattern::RandRead);
        j.block_size = 1000;
        assert!(j.validate().is_err());
        let mut j = job(IoPattern::RandRead);
        j.qd = 0;
        assert!(j.validate().is_err());
        let mut j = job(IoPattern::RandRead);
        j.zipf_theta = Some(5.0);
        assert!(j.validate().is_err());
    }
}
