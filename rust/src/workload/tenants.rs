//! Zipf-skewed tenant populations.
//!
//! The scenario engine multiplexes 10^5–10^6 simulated tenants over a
//! handful of hosts; real multi-tenant pools are never uniform, so the
//! population is sampled Zipfian (YCSB-style θ): tenant 0 is the
//! hottest, and under θ ≈ 0.99 a tiny head of tenants generates most of
//! the control-plane traffic — exactly the contention profile a shared
//! CXL memory pool has to arbitrate.

use crate::sim::rng::Pcg64;
use crate::workload::zipf::Zipfian;

/// A population of `len` tenants with Zipf-skewed activity.
///
/// Only a sampler — per-tenant *state* stays with the caller (the
/// population may be 10^6 strong while only the sampled head ever
/// materialises any bookkeeping).
#[derive(Debug, Clone)]
pub struct TenantPopulation {
    zipf: Zipfian,
}

impl TenantPopulation {
    /// `tenants` must be ≥ 1; `theta` in `[0,1) ∪ (1,2)` (0 ≈ uniform,
    /// 0.99 = classic YCSB skew).
    pub fn new(tenants: u64, theta: f64) -> Self {
        TenantPopulation { zipf: Zipfian::new(tenants, theta) }
    }

    /// Draw the tenant behind the next arrival (tenant 0 is hottest).
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        self.zipf.sample(rng)
    }

    /// Population size.
    pub fn len(&self) -> u64 {
        self.zipf.n()
    }

    pub fn is_empty(&self) -> bool {
        self.zipf.n() == 0
    }

    /// Probability mass of the hottest tenant (diagnostics: how
    /// pathological the head of the population is).
    pub fn p_hottest(&self) -> f64 {
        self.zipf.p_top()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_population() {
        let pop = TenantPopulation::new(100_000, 0.99);
        let mut rng = Pcg64::new(11);
        for _ in 0..10_000 {
            assert!(pop.sample(&mut rng) < pop.len());
        }
    }

    #[test]
    fn skew_concentrates_on_the_head() {
        let pop = TenantPopulation::new(1_000_000, 0.99);
        let mut rng = Pcg64::new(12);
        let n = 50_000;
        let head_hits = (0..n).filter(|_| pop.sample(&mut rng) < 100).count();
        // under θ=0.99 the top 100 of a million tenants should carry a
        // conspicuously outsized share of arrivals
        assert!(
            head_hits as f64 / n as f64 > 0.2,
            "head share = {}",
            head_hits as f64 / n as f64
        );
        assert!(pop.p_hottest() > 0.05);
    }

    #[test]
    fn deterministic_for_a_seed() {
        let pop = TenantPopulation::new(10_000, 0.9);
        let a: Vec<u64> = {
            let mut rng = Pcg64::new(13);
            (0..64).map(|_| pop.sample(&mut rng)).collect()
        };
        let b: Vec<u64> = {
            let mut rng = Pcg64::new(13);
            (0..64).map(|_| pop.sample(&mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}
