//! IO trace record/replay.
//!
//! Simple line-oriented text format (`R|W <lpa>`), so traces are
//! greppable and diffable. Used to feed recorded or externally-derived
//! workloads (e.g. a production-like skewed trace) through the same
//! pipeline as the synthetic fio jobs.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::workload::fio::IoRequest;

/// An in-memory IO trace.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub requests: Vec<IoRequest>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, req: IoRequest) {
        self.requests.push(req);
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Capture a generator's output.
    pub fn from_iter<I: IntoIterator<Item = IoRequest>>(iter: I) -> Self {
        Trace { requests: iter.into_iter().collect() }
    }

    /// Save as `R|W <lpa>` lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for r in &self.requests {
            writeln!(f, "{} {}", if r.is_write { "W" } else { "R" }, r.lpa)?;
        }
        Ok(())
    }

    /// Load from the text format.
    pub fn load(path: &Path) -> Result<Self> {
        let f = BufReader::new(std::fs::File::open(path)?);
        let mut t = Trace::new();
        for (lineno, line) in f.lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let op = parts.next().ok_or_else(|| bad_line(lineno, line))?;
            let lpa: u64 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| bad_line(lineno, line))?;
            let is_write = match op {
                "W" | "w" => true,
                "R" | "r" => false,
                _ => return Err(bad_line(lineno, line)),
            };
            t.record(IoRequest { lpa, is_write });
        }
        Ok(t)
    }

    /// Fraction of requests that are writes.
    pub fn write_fraction(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests.iter().filter(|r| r.is_write).count() as f64 / self.requests.len() as f64
    }

    /// Unique footprint in pages.
    pub fn footprint(&self) -> usize {
        let mut s: Vec<u64> = self.requests.iter().map(|r| r.lpa).collect();
        s.sort_unstable();
        s.dedup();
        s.len()
    }
}

fn bad_line(lineno: usize, line: &str) -> Error {
    Error::Config(format!("trace line {}: unparseable '{line}'", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;
    use crate::workload::fio::{FioJob, IoPattern};

    #[test]
    fn save_load_roundtrip() {
        let job = FioJob { total_ios: 500, ..FioJob::paper(IoPattern::RandWrite, GIB) };
        let t = Trace::from_iter(job.generate());
        let path = std::env::temp_dir().join("lmb_trace_test.txt");
        t.save(&path).unwrap();
        let t2 = Trace::load(&path).unwrap();
        assert_eq!(t, t2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stats() {
        let mut t = Trace::new();
        t.record(IoRequest { lpa: 1, is_write: true });
        t.record(IoRequest { lpa: 1, is_write: false });
        t.record(IoRequest { lpa: 2, is_write: false });
        assert_eq!(t.len(), 3);
        assert!((t.write_fraction() - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(t.footprint(), 2);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("lmb_trace_bad.txt");
        std::fs::write(&path, "R 1\nX 2\n").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let path = std::env::temp_dir().join("lmb_trace_comments.txt");
        std::fs::write(&path, "# header\n\nW 7\n").unwrap();
        let t = Trace::load(&path).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.requests[0], IoRequest { lpa: 7, is_write: true });
        std::fs::remove_file(&path).ok();
    }
}
