//! Zipfian sampler (Gray et al.'s rejection-free method with a
//! precomputed harmonic normaliser approximation).
//!
//! Drives the locality ablation: the paper's closing claim (§4.1) is
//! that "by exploiting the locality of actual workloads where most
//! indices hit on-board memory, the impact … will be considerably
//! dismissed." Skewed LBA streams let us measure exactly that.

use crate::sim::rng::Pcg64;

/// Zipfian distribution over `[0, n)` with skew `theta` (0 = uniform-ish,
/// 0.99 = classic YCSB skew).
#[derive(Debug, Clone)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zeta_n: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "zipfian over empty domain");
        assert!((0.0..1.0).contains(&theta) || (1.0..2.0).contains(&theta));
        let zeta_n = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zeta_n);
        Zipfian { n, theta, alpha, zeta_n, eta, zeta2 }
    }

    /// Exact zeta for small n; sampled approximation above 10⁶ elements
    /// (error < 1% for the thetas we use, and the sampler only needs a
    /// normaliser, not exact probabilities).
    fn zeta(n: u64, theta: f64) -> f64 {
        if n <= 1_000_000 {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            // zeta(n) ≈ zeta(m) + integral tail
            let m = 1_000_000u64;
            let head: f64 = (1..=m).map(|i| 1.0 / (i as f64).powf(theta)).sum();
            let tail = ((n as f64).powf(1.0 - theta) - (m as f64).powf(1.0 - theta))
                / (1.0 - theta);
            head + tail
        }
    }

    /// Draw one sample (rank 0 is the hottest item).
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zeta_n;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = ((self.eta * u - self.eta + 1.0).powf(self.alpha) * self.n as f64) as u64;
        v.min(self.n - 1)
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Probability mass of the hottest item (diagnostics).
    pub fn p_top(&self) -> f64 {
        1.0 / self.zeta_n
    }

    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_concentrates_mass() {
        let z = Zipfian::new(10_000, 0.99);
        let mut rng = Pcg64::new(5);
        let n = 100_000;
        let top_hits = (0..n).filter(|_| z.sample(&mut rng) == 0).count();
        let frac = top_hits as f64 / n as f64;
        // hottest item should get ≈ p_top
        assert!((frac - z.p_top()).abs() < 0.02, "frac={frac} p_top={}", z.p_top());
        assert!(frac > 0.05, "theta=0.99 top item should be hot, frac={frac}");
    }

    #[test]
    fn samples_in_range() {
        let z = Zipfian::new(100, 0.5);
        let mut rng = Pcg64::new(6);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn low_theta_close_to_uniform() {
        let z = Zipfian::new(1000, 0.01);
        let mut rng = Pcg64::new(7);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| z.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        // uniform mean would be 499.5; allow generous tolerance
        assert!(mean > 350.0 && mean < 650.0, "mean={mean}");
    }

    #[test]
    fn large_domain_normaliser_approximation() {
        // must not hang or produce out-of-range values
        let z = Zipfian::new(2_000_000_000, 0.99);
        let mut rng = Pcg64::new(8);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 2_000_000_000);
        }
    }
}
