//! Whole-system wiring: one host, its CXL fabric, the LMB module, and
//! attached devices — the object examples and integration tests build.
//!
//! The LMB control plane lives in the composed [`LmbHost`] context; the
//! `System` adds device enumeration (BDFs, SPIDs) on top and forwards
//! the unified `alloc`/`free`/`share` surface. The Table-2-named shims
//! (`pcie_*`/`cxl_*`) completed their deprecation cycle and are gone —
//! `tests/api_surface.rs` pins their absence.

use crate::cxl::expander::{Expander, ExpanderConfig};
use crate::cxl::fabric::{Fabric, FabricConfig};
use crate::cxl::fm::{FabricManager, FabricRef, HostId};
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{gib_to_bytes, Bdf, MmId, Spid, GIB};
use crate::error::{Error, Result};
use crate::host::AddressSpace;
use crate::lmb::queue::{AllocQueue, Completion, QueueStatus, Request, SubmitHandle, Ticket};
use crate::lmb::{Consumer, IoSession, LmbAlloc, LmbHost, LmbModule};
use crate::pcie::iommu::Iommu;
use crate::ssd::spec::SsdSpec;

/// Handle for an attached PCIe device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId(pub usize);

/// An attached PCIe SSD.
#[derive(Debug)]
pub struct PcieSsd {
    pub bdf: Bdf,
    pub spec: SsdSpec,
}

/// An attached CXL device (accelerator / CXL-SSD).
#[derive(Debug)]
pub struct CxlDevice {
    pub spid: Spid,
    pub name: String,
}

/// The simulated machine.
#[derive(Debug)]
pub struct System {
    pub fabric: Fabric,
    lmb: LmbHost,
    pcie_devices: Vec<PcieSsd>,
    cxl_devices: Vec<CxlDevice>,
    next_bus: u8,
}

/// Builder for [`System`].
#[derive(Debug)]
pub struct SystemBuilder {
    expander: ExpanderConfig,
    fabric: FabricConfig,
    host_dram: u64,
    switch_ports: u8,
    shared: Option<FabricRef>,
}

impl Default for SystemBuilder {
    fn default() -> Self {
        SystemBuilder {
            expander: ExpanderConfig::default(),
            fabric: FabricConfig::default(),
            host_dram: 16 * GIB,
            switch_ports: 32,
            shared: None,
        }
    }
}

impl SystemBuilder {
    /// Expander DRAM capacity in GiB (checked: an overflowing size
    /// panics instead of silently wrapping to a tiny expander).
    pub fn expander_gib(mut self, gib: u64) -> Self {
        self.expander.dram_capacity = gib_to_bytes(gib);
        self
    }

    /// Add a PM partition of `gib` GiB (checked like `expander_gib`).
    pub fn pm_gib(mut self, gib: u64) -> Self {
        self.expander.pm_capacity = gib_to_bytes(gib);
        self
    }

    /// Override fabric latency constants.
    pub fn fabric_config(mut self, cfg: FabricConfig) -> Self {
        self.fabric = cfg;
        self
    }

    /// Host DRAM size in GiB (checked like `expander_gib`).
    pub fn host_dram_gib(mut self, gib: u64) -> Self {
        self.host_dram = gib_to_bytes(gib);
        self
    }

    /// Bind this System's host to an existing shared fabric instead of
    /// building a private switch + expander (multi-host sharding; see
    /// also [`crate::cluster::Cluster`]). The expander and switch-port
    /// settings on this builder are ignored when joining.
    pub fn join_fabric(mut self, fabric: FabricRef) -> Self {
        self.shared = Some(fabric);
        self
    }

    pub fn build(self) -> Result<System> {
        let fabric_ref = match self.shared {
            Some(f) => f,
            None => FabricRef::new(FabricManager::new(
                PbrSwitch::new(self.switch_ports),
                Expander::new(self.expander),
            )),
        };
        // §3.1: LmbHost::bind attaches the GFD, binds the host, and loads
        // the LMB module before any device driver initialises.
        let lmb = LmbHost::bind(fabric_ref, self.host_dram)?;
        Ok(System {
            fabric: Fabric::new(self.fabric),
            lmb,
            pcie_devices: Vec::new(),
            cxl_devices: Vec::new(),
            next_bus: 1,
        })
    }
}

impl System {
    pub fn builder() -> SystemBuilder {
        SystemBuilder::default()
    }

    pub fn host(&self) -> HostId {
        self.lmb.host()
    }

    /// The per-host LMB context (unified control plane).
    pub fn lmb(&self) -> &LmbHost {
        &self.lmb
    }

    pub fn lmb_mut(&mut self) -> &mut LmbHost {
        &mut self.lmb
    }

    /// The shared fabric handle this System's host is bound through
    /// (clone it + [`SystemBuilder::join_fabric`] to add more hosts).
    pub fn fabric_ref(&self) -> &FabricRef {
        self.lmb.fabric_ref()
    }

    /// Scoped read-only view of the shared FM: the closure runs with
    /// the fabric locked; no guard type escapes. Mutations go through
    /// the [`FabricRef`] API, which keys every lease operation by host
    /// — no `&mut FabricManager` escape hatch exists.
    pub fn with_fm<R>(&self, f: impl FnOnce(&FabricManager) -> R) -> Result<R> {
        self.lmb.with_fm(f)
    }

    /// Module + FM invariants in one sweep (property tests; also the
    /// post-panic audit — see [`FabricRef::check_invariants`]).
    pub fn check_invariants(&self) -> Result<()> {
        self.lmb.check_invariants()
    }

    pub fn iommu(&self) -> &Iommu {
        self.lmb.iommu()
    }

    pub fn iommu_mut(&mut self) -> &mut Iommu {
        self.lmb.iommu_mut()
    }

    pub fn space(&self) -> &AddressSpace {
        self.lmb.space()
    }

    pub fn module(&self) -> &LmbModule {
        self.lmb.module()
    }

    /// Attach a PCIe SSD: enumerates a BDF and creates its IOMMU domain.
    pub fn attach_pcie_ssd(&mut self, spec: SsdSpec) -> DeviceId {
        assert!(
            self.lmb.module().is_loaded(),
            "LMB module must load before device drivers (§3.1)"
        );
        let bdf = Bdf::new(self.next_bus, 0, 0);
        self.next_bus += 1;
        self.lmb.attach_pcie(bdf);
        self.pcie_devices.push(PcieSsd { bdf, spec });
        DeviceId(self.pcie_devices.len() - 1)
    }

    /// Attach a CXL device, binding it to the switch for P2P.
    pub fn attach_cxl_device(&mut self, name: &str) -> Result<Spid> {
        let spid = self.lmb.attach_cxl_device()?;
        self.cxl_devices.push(CxlDevice { spid, name: name.to_string() });
        Ok(spid)
    }

    pub fn pcie_device(&self, id: DeviceId) -> Result<&PcieSsd> {
        self.pcie_devices
            .get(id.0)
            .ok_or_else(|| Error::Device(format!("no device {id:?}")))
    }

    /// The [`Consumer`] identity of an attached PCIe device (CXL devices
    /// are addressed by the `Spid` returned at attach time).
    pub fn consumer(&self, id: DeviceId) -> Result<Consumer> {
        Ok(Consumer::Pcie(self.pcie_device(id)?.bdf))
    }

    pub fn device_count(&self) -> usize {
        self.pcie_devices.len() + self.cxl_devices.len()
    }

    // ---- unified LMB API (forwarded to the LmbHost context) ----

    /// Allocate LMB memory for any consumer class.
    pub fn alloc(&mut self, consumer: impl Into<Consumer>, size: u64) -> Result<LmbAlloc> {
        self.lmb.alloc(consumer, size)
    }

    /// All-or-nothing batch allocation (rolls back on partial failure).
    pub fn alloc_many(
        &mut self,
        consumer: impl Into<Consumer>,
        sizes: &[u64],
    ) -> Result<Vec<LmbAlloc>> {
        self.lmb.alloc_many(consumer, sizes)
    }

    /// Free an allocation owned by `consumer`.
    pub fn free(&mut self, consumer: impl Into<Consumer>, mmid: MmId) -> Result<()> {
        self.lmb.free(consumer, mmid)
    }

    /// Owner-authorised zero-copy share into `target`'s view.
    pub fn share(
        &mut self,
        owner: impl Into<Consumer>,
        target: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        self.lmb.share(owner, target, mmid)
    }

    // ---- queued allocation (forwarded to the LmbHost queue) ----

    /// Enqueue a control-plane request; see [`LmbHost::submit`].
    pub fn submit(&mut self, request: Request) -> Ticket {
        self.lmb.submit(request)
    }

    /// Where a submission is in its lifecycle.
    pub fn poll_submission(&self, ticket: Ticket) -> QueueStatus {
        self.lmb.poll_submission(ticket)
    }

    /// Claim a serviced submission's completion.
    pub fn take_completion(&mut self, ticket: Ticket) -> Option<Completion> {
        self.lmb.take_completion(ticket)
    }

    /// A cloneable, `Send` submission endpoint onto this System's host
    /// queue; see [`LmbHost::submit_handle`].
    pub fn submit_handle(&self) -> Result<SubmitHandle> {
        self.lmb.submit_handle()
    }

    /// One deterministic queue tick; see [`LmbHost::tick_queue`].
    pub fn tick_queue(&mut self) -> usize {
        self.lmb.tick_queue()
    }

    /// Tick until the queue is idle; see [`LmbHost::drain_queue`].
    pub fn drain_queue(&mut self) -> usize {
        self.lmb.drain_queue()
    }

    /// The host's allocation queue (stats / pending inspection).
    pub fn queue(&self) -> &AllocQueue {
        self.lmb.queue()
    }

    // ---- data path ----

    /// Functional write into an LMB allocation (host-mediated path).
    pub fn write_alloc(&mut self, mmid: MmId, offset: u64, data: &[u8]) -> Result<()> {
        self.lmb.write(mmid, offset, data)
    }

    /// Functional read from an LMB allocation.
    pub fn read_alloc(&self, mmid: MmId, offset: u64, out: &mut [u8]) -> Result<()> {
        self.lmb.read(mmid, offset, out)
    }

    /// Batched data path: resolve `mmid` once and stream N ops under
    /// one scoped fabric lock (see [`LmbHost::with_io_session`]).
    pub fn with_io_session<R>(
        &mut self,
        mmid: MmId,
        f: impl FnOnce(&mut IoSession<'_>) -> Result<R>,
    ) -> Result<R> {
        self.lmb.with_io_session(mmid, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::PAGE_SIZE;

    #[test]
    fn builder_and_alloc_roundtrip() {
        let mut sys = System::builder().expander_gib(4).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen5());
        let dev = sys.consumer(ssd).unwrap();
        let a = sys.alloc(dev, 8 * PAGE_SIZE).unwrap();
        assert!(a.bus_addr.is_some());
        // data written through the system is readable back
        sys.write_alloc(a.mmid, 128, b"lmb!").unwrap();
        let mut buf = [0u8; 4];
        sys.read_alloc(a.mmid, 128, &mut buf).unwrap();
        assert_eq!(&buf, b"lmb!");
        sys.free(dev, a.mmid).unwrap();
        assert_eq!(sys.module().live_allocs(), 0);
    }

    #[test]
    fn ssd_to_accelerator_sharing_scenario() {
        // Figure 5 + §3.3 zero-copy path across device classes.
        let mut sys = System::builder().expander_gib(4).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen4());
        let dev = sys.consumer(ssd).unwrap();
        let accel = sys.attach_cxl_device("accelerator").unwrap();
        let a = sys.alloc(dev, PAGE_SIZE).unwrap();
        sys.write_alloc(a.mmid, 0, b"tensor-bytes").unwrap();
        let shared = sys.share(dev, accel, a.mmid).unwrap();
        assert_eq!(shared.dpa, a.dpa, "same physical bytes, no copy");
        let granted = sys.with_fm(|fm| fm.expander().sat().check(accel, shared.dpa, 64, true));
        assert!(granted.unwrap());
        let gfd = sys.with_fm(|fm| fm.gfd_dpid()).unwrap();
        assert_eq!(shared.dpid, gfd, "P2P handle names the real GFD");
    }

    #[test]
    fn share_authorization_enforced_at_system_level() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let a_dev = sys.attach_pcie_ssd(SsdSpec::gen4());
        let b_dev = sys.attach_pcie_ssd(SsdSpec::gen5());
        let a = sys.consumer(a_dev).unwrap();
        let b = sys.consumer(b_dev).unwrap();
        let alloc = sys.alloc(a, PAGE_SIZE).unwrap();
        // only the owner may share
        assert!(matches!(
            sys.share(b, b, alloc.mmid),
            Err(Error::NotOwner { .. })
        ));
        sys.share(a, b, alloc.mmid).unwrap();
    }

    #[test]
    fn bounds_checked_access() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen4());
        let dev = sys.consumer(ssd).unwrap();
        let a = sys.alloc(dev, PAGE_SIZE).unwrap();
        assert!(sys.write_alloc(a.mmid, PAGE_SIZE - 2, b"xxxx").is_err());
        let mut buf = [0u8; 8];
        assert!(sys.read_alloc(a.mmid, PAGE_SIZE - 4, &mut buf).is_err());
    }

    #[test]
    fn two_systems_share_one_fabric() {
        use crate::cxl::types::EXTENT_SIZE;
        let mut a = System::builder().expander_gib(1).build().unwrap(); // 4 extents
        let mut b = System::builder().join_fabric(a.fabric_ref().clone()).build().unwrap();
        assert_ne!(a.host(), b.host());
        let a_dev = a.attach_pcie_ssd(SsdSpec::gen4());
        let b_dev = b.attach_pcie_ssd(SsdSpec::gen5());
        let ac = a.consumer(a_dev).unwrap();
        let bc = b.consumer(b_dev).unwrap();
        // leases draw from the one pool...
        a.alloc(ac, EXTENT_SIZE).unwrap();
        let bm = b.alloc(bc, EXTENT_SIZE).unwrap();
        assert_eq!(a.with_fm(|fm| fm.available()).unwrap(), 2 * EXTENT_SIZE);
        // ...and host A cannot touch host B's allocation
        assert!(matches!(a.free(ac, bm.mmid), Err(Error::UnknownMmId(_))));
        b.free(bc, bm.mmid).unwrap();
        assert_eq!(a.with_fm(|fm| fm.available()).unwrap(), 3 * EXTENT_SIZE);
        a.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "overflows u64")]
    fn builder_rejects_overflowing_expander_size() {
        let _ = System::builder().expander_gib(u64::MAX);
    }

    #[test]
    #[should_panic(expected = "overflows u64")]
    fn builder_rejects_overflowing_host_dram_size() {
        let _ = System::builder().host_dram_gib(1 << 40);
    }

    #[test]
    fn queued_surface_forwards_to_host_queue() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen4());
        let dev = sys.consumer(ssd).unwrap();
        let t = sys.submit(Request::Alloc { consumer: dev, size: PAGE_SIZE });
        assert_eq!(sys.poll_submission(t), QueueStatus::Queued);
        assert_eq!(sys.drain_queue(), 1);
        let a = sys.take_completion(t).unwrap().into_alloc().unwrap();
        sys.write_alloc(a.mmid, 0, b"queued").unwrap();
        let mut buf = [0u8; 6];
        sys.read_alloc(a.mmid, 0, &mut buf).unwrap();
        assert_eq!(&buf, b"queued");
        sys.free(dev, a.mmid).unwrap();
        assert_eq!(sys.queue().stats().completed, 2);
    }

    #[test]
    fn multiple_devices_unique_bdfs() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let a = sys.attach_pcie_ssd(SsdSpec::gen4());
        let b = sys.attach_pcie_ssd(SsdSpec::gen5());
        assert_ne!(
            sys.pcie_device(a).unwrap().bdf,
            sys.pcie_device(b).unwrap().bdf
        );
        assert_eq!(sys.device_count(), 2);
    }
}
