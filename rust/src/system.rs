//! Whole-system wiring: one host, its CXL fabric, the LMB module, and
//! attached devices — the object examples and integration tests build.

use crate::cxl::expander::{Expander, ExpanderConfig};
use crate::cxl::fabric::{Fabric, FabricConfig};
use crate::cxl::fm::{FabricManager, HostId};
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{Bdf, Dpa, MmId, Spid, GIB};
use crate::error::{Error, Result};
use crate::host::AddressSpace;
use crate::lmb::{LmbAlloc, LmbModule};
use crate::pcie::iommu::Iommu;
use crate::ssd::spec::SsdSpec;

/// Handle for an attached PCIe device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId(pub usize);

/// An attached PCIe SSD.
#[derive(Debug)]
pub struct PcieSsd {
    pub bdf: Bdf,
    pub spec: SsdSpec,
}

/// An attached CXL device (accelerator / CXL-SSD).
#[derive(Debug)]
pub struct CxlDevice {
    pub spid: Spid,
    pub name: String,
}

/// The simulated machine.
#[derive(Debug)]
pub struct System {
    pub fabric: Fabric,
    fm: FabricManager,
    iommu: Iommu,
    space: AddressSpace,
    module: LmbModule,
    host: HostId,
    pcie_devices: Vec<PcieSsd>,
    cxl_devices: Vec<CxlDevice>,
    next_bus: u8,
}

/// Builder for [`System`].
#[derive(Debug)]
pub struct SystemBuilder {
    expander: ExpanderConfig,
    fabric: FabricConfig,
    host_dram: u64,
    switch_ports: u8,
}

impl Default for SystemBuilder {
    fn default() -> Self {
        SystemBuilder {
            expander: ExpanderConfig::default(),
            fabric: FabricConfig::default(),
            host_dram: 16 * GIB,
            switch_ports: 32,
        }
    }
}

impl SystemBuilder {
    /// Expander DRAM capacity in GiB.
    pub fn expander_gib(mut self, gib: u64) -> Self {
        self.expander.dram_capacity = gib * GIB;
        self
    }

    /// Add a PM partition of `gib` GiB.
    pub fn pm_gib(mut self, gib: u64) -> Self {
        self.expander.pm_capacity = gib * GIB;
        self
    }

    /// Override fabric latency constants.
    pub fn fabric_config(mut self, cfg: FabricConfig) -> Self {
        self.fabric = cfg;
        self
    }

    /// Host DRAM size in GiB.
    pub fn host_dram_gib(mut self, gib: u64) -> Self {
        self.host_dram = gib * GIB;
        self
    }

    pub fn build(self) -> Result<System> {
        let mut fm = FabricManager::new(
            PbrSwitch::new(self.switch_ports),
            Expander::new(self.expander),
        );
        fm.attach_gfd()?;
        let (host, _spid) = fm.bind_host()?;
        // §3.1: the LMB module loads before any device driver initialises.
        let module = LmbModule::load(host);
        Ok(System {
            fabric: Fabric::new(self.fabric),
            fm,
            iommu: Iommu::new(),
            space: AddressSpace::new(self.host_dram),
            module,
            host,
            pcie_devices: Vec::new(),
            cxl_devices: Vec::new(),
            next_bus: 1,
        })
    }
}

impl System {
    pub fn builder() -> SystemBuilder {
        SystemBuilder::default()
    }

    pub fn host(&self) -> HostId {
        self.host
    }

    pub fn fm(&self) -> &FabricManager {
        &self.fm
    }

    pub fn fm_mut(&mut self) -> &mut FabricManager {
        &mut self.fm
    }

    pub fn iommu(&self) -> &Iommu {
        &self.iommu
    }

    pub fn iommu_mut(&mut self) -> &mut Iommu {
        &mut self.iommu
    }

    pub fn space(&self) -> &AddressSpace {
        &self.space
    }

    pub fn module(&self) -> &LmbModule {
        &self.module
    }

    /// Split borrow for failure handling: the FM mutably plus the module
    /// immutably (see [`crate::lmb::failure::FailureDomain`]).
    pub fn failure_parts(&mut self) -> (&mut FabricManager, &LmbModule) {
        (&mut self.fm, &self.module)
    }

    /// Attach a PCIe SSD: enumerates a BDF and creates its IOMMU domain.
    pub fn attach_pcie_ssd(&mut self, spec: SsdSpec) -> DeviceId {
        assert!(self.module.is_loaded(), "LMB module must load before device drivers (§3.1)");
        let bdf = Bdf::new(self.next_bus, 0, 0);
        self.next_bus += 1;
        self.iommu.attach(bdf);
        self.pcie_devices.push(PcieSsd { bdf, spec });
        DeviceId(self.pcie_devices.len() - 1)
    }

    /// Attach a CXL device, binding it to the switch for P2P.
    pub fn attach_cxl_device(&mut self, name: &str) -> Result<Spid> {
        let spid = self.fm.bind_cxl_device()?;
        self.cxl_devices.push(CxlDevice { spid, name: name.to_string() });
        Ok(spid)
    }

    pub fn pcie_device(&self, id: DeviceId) -> Result<&PcieSsd> {
        self.pcie_devices
            .get(id.0)
            .ok_or_else(|| Error::Device(format!("no device {id:?}")))
    }

    pub fn device_count(&self) -> usize {
        self.pcie_devices.len() + self.cxl_devices.len()
    }

    // ---- LMB API surface (Table 2), with the borrows pre-split ----

    /// `lmb_PCIe_alloc` for an attached SSD.
    pub fn pcie_alloc(&mut self, dev: DeviceId, size: u64) -> Result<LmbAlloc> {
        let bdf = self.pcie_device(dev)?.bdf;
        self.module
            .pcie_alloc(&mut self.fm, &mut self.iommu, &mut self.space, bdf, size)
    }

    /// `lmb_CXL_alloc` for an attached CXL device.
    pub fn cxl_alloc(&mut self, spid: Spid, size: u64) -> Result<LmbAlloc> {
        self.module.cxl_alloc(&mut self.fm, &mut self.space, spid, size)
    }

    /// `lmb_PCIe_free`.
    pub fn pcie_free(&mut self, dev: DeviceId, mmid: MmId) -> Result<()> {
        let bdf = self.pcie_device(dev)?.bdf;
        self.module
            .pcie_free(&mut self.fm, &mut self.iommu, &mut self.space, bdf, mmid)
    }

    /// `lmb_CXL_free`.
    pub fn cxl_free(&mut self, spid: Spid, mmid: MmId) -> Result<()> {
        self.module
            .cxl_free(&mut self.fm, &mut self.iommu, &mut self.space, spid, mmid)
    }

    /// `lmb_PCIe_share`: map `mmid` into another PCIe device's domain.
    pub fn pcie_share(&mut self, target: DeviceId, mmid: MmId) -> Result<LmbAlloc> {
        let bdf = self.pcie_device(target)?.bdf;
        self.module.pcie_share(&mut self.iommu, bdf, mmid)
    }

    /// `lmb_CXL_share`: grant another CXL device P2P access to `mmid`.
    pub fn cxl_share(&mut self, target: Spid, mmid: MmId) -> Result<LmbAlloc> {
        self.module.cxl_share(&mut self.fm, target, mmid)
    }

    /// Functional write into an LMB allocation (host-mediated path).
    pub fn write_alloc(&mut self, mmid: MmId, offset: u64, data: &[u8]) -> Result<()> {
        let a = self.module.get(mmid).ok_or(Error::UnknownMmId(mmid))?;
        if offset + data.len() as u64 > a.size {
            return Err(Error::Config("write beyond allocation".into()));
        }
        self.fm.expander_mut().write_dpa(Dpa(a.dpa.0 + offset), data)
    }

    /// Functional read from an LMB allocation.
    pub fn read_alloc(&self, mmid: MmId, offset: u64, out: &mut [u8]) -> Result<()> {
        let a = self.module.get(mmid).ok_or(Error::UnknownMmId(mmid))?;
        if offset + out.len() as u64 > a.size {
            return Err(Error::Config("read beyond allocation".into()));
        }
        self.fm.expander().read_dpa(Dpa(a.dpa.0 + offset), out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::PAGE_SIZE;

    #[test]
    fn builder_and_alloc_roundtrip() {
        let mut sys = System::builder().expander_gib(4).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen5());
        let a = sys.pcie_alloc(ssd, 8 * PAGE_SIZE).unwrap();
        assert!(a.bus_addr.is_some());
        // data written through the system is readable back
        sys.write_alloc(a.mmid, 128, b"lmb!").unwrap();
        let mut buf = [0u8; 4];
        sys.read_alloc(a.mmid, 128, &mut buf).unwrap();
        assert_eq!(&buf, b"lmb!");
        sys.pcie_free(ssd, a.mmid).unwrap();
        assert_eq!(sys.module().live_allocs(), 0);
    }

    #[test]
    fn ssd_to_accelerator_sharing_scenario() {
        // Figure 5 + §3.3 zero-copy path across device classes.
        let mut sys = System::builder().expander_gib(4).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen4());
        let accel = sys.attach_cxl_device("accelerator").unwrap();
        let a = sys.pcie_alloc(ssd, PAGE_SIZE).unwrap();
        sys.write_alloc(a.mmid, 0, b"tensor-bytes").unwrap();
        let shared = sys.cxl_share(accel, a.mmid).unwrap();
        assert_eq!(shared.dpa, a.dpa, "same physical bytes, no copy");
        assert!(sys.fm().expander().sat().check(accel, shared.dpa, 64, true));
    }

    #[test]
    fn bounds_checked_access() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let ssd = sys.attach_pcie_ssd(SsdSpec::gen4());
        let a = sys.pcie_alloc(ssd, PAGE_SIZE).unwrap();
        assert!(sys.write_alloc(a.mmid, PAGE_SIZE - 2, b"xxxx").is_err());
        let mut buf = [0u8; 8];
        assert!(sys.read_alloc(a.mmid, PAGE_SIZE - 4, &mut buf).is_err());
    }

    #[test]
    fn multiple_devices_unique_bdfs() {
        let mut sys = System::builder().expander_gib(1).build().unwrap();
        let a = sys.attach_pcie_ssd(SsdSpec::gen4());
        let b = sys.attach_pcie_ssd(SsdSpec::gen5());
        assert_ne!(
            sys.pcie_device(a).unwrap().bdf,
            sys.pcie_device(b).unwrap().bdf
        );
        assert_eq!(sys.device_count(), 2);
    }
}
