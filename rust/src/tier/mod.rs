//! Tiering engine — hotness-driven device-DRAM ↔ CXL placement with
//! live extent migration.
//!
//! The paper's LMB extends scarce device-local DRAM with a CXL-linked
//! buffer; this module *manages* that two-tier boundary instead of
//! merely extending it. Three pieces:
//!
//! * **Heat ledger** ([`TierState`] inside the `FabricManager`): one
//!   atomic counter per physical extent, bumped lock-free on the
//!   `with_io_session` data path (same pattern as the `observe` sinks —
//!   no new fabric-wide lock). The [`TierDaemon`] epoch-folds the raw
//!   counters into a per-extent EWMA, mirroring the model spec in
//!   `python/compile/kernels/hotness.py`:
//!   `new_hot = decay * prev + (1 - decay) * counts`.
//! * **Policy** ([`TierPolicy`]): ranks extents by EWMA heat and keeps
//!   the top `dram_slots` of them on the fast media, pricing the two
//!   tiers with the calibrated media-latency scalars
//!   ([`HDM_MEDIA_LATENCY`] / [`PM_MEDIA_LATENCY`] — the same constants
//!   `benches/table3_calibration.rs` pins against the paper's tables).
//! * **Live migration** (`FabricManager::migrate_extent`): copies an
//!   extent across the boundary under the fabric seal (the same fence
//!   `with_io_session` holds, so readers drain before the copy starts),
//!   re-targets HDM decoders and SAT grants atomically under the
//!   expander write lock, and forwards the extent's *virtual* DPA — the
//!   address the owning module keeps forever — to its new physical
//!   placement. A mid-copy abort (a [`FaultPoint::MigrateAbort`] strike
//!   or a quarantined shard) rolls back to the source placement with
//!   nothing torn.
//!
//! The [`TierDaemon`] ticks deterministically inside `FmService`
//! (SimTime-driven epochs, budget-bounded migrations per tick) and
//! emits `EventKind::{Promote, Demote, Migrate}` into the observability
//! ring: every `Migrate` is terminally paired with a `Promote`/`Demote`
//! or a `Fault` at `migrate_abort`.
//!
//! **Lock-order position of the tier ledger**: the forward map's mutex
//! is a *leaf* — held only for point lookups/updates, never while
//! acquiring any other fabric lock. Migration commits the map while
//! holding control + shards + the expander write lock, and every
//! translating reader resolves while holding at least one of those (or
//! the seal), so no reader can observe a half-committed move. The heat
//! counters are plain atomics with no lock at all.
//!
//! [`HDM_MEDIA_LATENCY`]: crate::cxl::expander::HDM_MEDIA_LATENCY
//! [`PM_MEDIA_LATENCY`]: crate::cxl::expander::PM_MEDIA_LATENCY
//! [`FaultPoint::MigrateAbort`]: crate::lmb::fault::FaultPoint::MigrateAbort

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::cxl::expander::{MediaTier, HDM_MEDIA_LATENCY, PM_MEDIA_LATENCY};
use crate::cxl::fm::{FabricRef, HostId};
use crate::cxl::types::{Dpa, Range, EXTENT_SIZE};
use crate::error::Result;
use crate::sim::time::SimTime;

/// Extent-align a DPA down to its extent base.
fn extent_base(dpa: u64) -> u64 {
    (dpa / EXTENT_SIZE) * EXTENT_SIZE
}

/// Fabric-resident tier state: the virtual→physical forward map plus
/// the per-extent heat counters. Owned by `FabricManager`; every method
/// is `&self` and safe from any thread.
///
/// The *virtual* DPA of an extent is the physical base it was first
/// leased at — the address baked into the owning module's records, SAT
/// grant requests and `with_io_session` calls. Migration never rewrites
/// those records; it updates this map instead, and the FM translates at
/// its API boundaries. An extent that has never migrated has no entry
/// (identity).
#[derive(Debug)]
pub(crate) struct TierState {
    /// Virtual extent base → current physical extent base. Leaf lock:
    /// held only for point lookups/updates (see module docs).
    forward: Mutex<HashMap<u64, u64>>,
    /// Raw access counts per physical extent slot, bumped lock-free on
    /// the data path and swapped to zero by each daemon epoch fold.
    heat: Box<[AtomicU64]>,
}

impl TierState {
    pub(crate) fn new(capacity: u64) -> Self {
        let slots = capacity.div_ceil(EXTENT_SIZE) as usize;
        let heat = (0..slots).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice();
        TierState { forward: Mutex::new(HashMap::new()), heat }
    }

    fn forward_map(&self) -> std::sync::MutexGuard<'_, HashMap<u64, u64>> {
        // The ledger must stay readable after an unrelated panic: the
        // map is only ever mutated to a consistent whole under the
        // fabric's own locks.
        self.forward.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Translate a virtual DPA (any offset inside an extent) to its
    /// current physical DPA. Identity for never-migrated extents.
    pub(crate) fn resolve(&self, dpa: Dpa) -> Dpa {
        let base = extent_base(dpa.0);
        match self.forward_map().get(&base) {
            Some(phys) => Dpa(phys + (dpa.0 - base)),
            None => dpa,
        }
    }

    /// Translate a virtual range (contained in one extent) wholesale.
    pub(crate) fn resolve_range(&self, range: Range) -> Range {
        Range::new(self.resolve(Dpa(range.base)).0, range.len)
    }

    /// The virtual base an extent currently placed at `phys_base` is
    /// known by. Identity when the extent never migrated.
    pub(crate) fn virtual_of(&self, phys_base: u64) -> u64 {
        self.forward_map()
            .iter()
            .find(|(_, p)| **p == phys_base)
            .map(|(v, _)| *v)
            .unwrap_or(phys_base)
    }

    /// Commit a migration: the extent known as `virt` now lives at
    /// `phys_base`. Caller holds control + shards + the expander write
    /// lock, so translating readers serialize against this.
    pub(crate) fn commit_move(&self, virt: u64, phys_base: u64) {
        let mut map = self.forward_map();
        if virt == phys_base {
            map.remove(&virt);
        } else {
            map.insert(virt, phys_base);
        }
    }

    /// Drop the ledger entry (and heat) for the extent currently placed
    /// at `phys_base` — the extent was released back to the pool.
    pub(crate) fn forget_phys(&self, phys_base: u64) {
        self.forward_map().retain(|_, p| *p != phys_base);
        if let Some(slot) = self.heat.get((phys_base / EXTENT_SIZE) as usize) {
            slot.store(0, Ordering::Relaxed);
        }
    }

    /// Bump the heat counter for the physical extent containing `phys`.
    /// Lock-free; the data-path hook.
    pub(crate) fn note(&self, phys: Dpa) {
        if let Some(slot) = self.heat.get((phys.0 / EXTENT_SIZE) as usize) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Consume (swap to zero) the raw counts for one physical extent —
    /// the epoch fold.
    pub(crate) fn take(&self, phys_base: u64) -> u64 {
        match self.heat.get((phys_base / EXTENT_SIZE) as usize) {
            Some(slot) => slot.swap(0, Ordering::Relaxed),
            None => 0,
        }
    }

    /// Carry unfolded heat with a migrating extent: whatever accrued at
    /// `src` since the last fold moves to `dst`.
    pub(crate) fn move_heat(&self, src_base: u64, dst_base: u64) {
        let carried = self.take(src_base);
        if carried > 0 {
            if let Some(slot) = self.heat.get((dst_base / EXTENT_SIZE) as usize) {
                slot.fetch_add(carried, Ordering::Relaxed);
            }
        }
    }

    /// Snapshot of the forward map (invariant audit / tests).
    pub(crate) fn forward_snapshot(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self.forward_map().iter().map(|(a, b)| (*a, *b)).collect();
        v.sort_unstable();
        v
    }
}

/// One leased extent as the daemon sees it at an epoch fold: its stable
/// virtual identity, current physical placement, owner, tier, and the
/// raw touch count accrued since the previous fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierSample {
    /// Stable identity: the DPA the owning module knows the extent by.
    pub virt: u64,
    /// Current physical extent base.
    pub phys: Dpa,
    /// Leaseholder.
    pub owner: HostId,
    /// Which media the extent currently sits on.
    pub tier: MediaTier,
    /// Raw accesses since the last fold (consumed by the fold).
    pub touches: u64,
}

/// How a `migrate_extent` attempt resolved. Refusals (quarantined
/// source shard, no destination span, unknown lease) are `Err`s instead
/// — they happen before anything is carved and emit no event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrateOutcome {
    /// The extent now lives at `dst` on the `to` tier; decoders, SAT
    /// grants and the forward map all re-targeted atomically.
    Committed { from: MediaTier, to: MediaTier, src: Dpa, dst: Dpa },
    /// A mid-copy abort rolled everything back to the source placement;
    /// the destination carve was returned to the pool and wiped.
    Aborted { from: MediaTier, to: MediaTier },
}

/// Classifies extents against the two-tier latency model: the
/// `dram_slots` hottest extents (by EWMA heat) deserve the fast media,
/// everything else belongs on the slow media.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierPolicy {
    /// Fast-tier (device-DRAM analogue) media latency.
    pub fast_latency: SimTime,
    /// Slow-tier (CXL expander PM) media latency.
    pub slow_latency: SimTime,
    /// Minimum EWMA heat before an extent is worth promoting — keeps an
    /// all-cold pool from churning placements for no modeled benefit.
    pub min_heat: f64,
}

impl TierPolicy {
    /// The policy calibrated against the crate's two-tier latency
    /// scalars — the same constants `benches/table3_calibration.rs`
    /// pins against the paper's measured tables.
    pub fn calibrated() -> Self {
        TierPolicy { fast_latency: HDM_MEDIA_LATENCY, slow_latency: PM_MEDIA_LATENCY, min_heat: 1.0 }
    }

    /// Modeled media latency of one access to an extent on `tier`.
    pub fn latency_of(&self, tier: MediaTier) -> SimTime {
        match tier {
            MediaTier::Dram => self.fast_latency,
            MediaTier::Pm => self.slow_latency,
        }
    }

    /// Rank extents by `(EWMA heat desc, virtual base asc)` and split
    /// them against `dram_slots`: extents inside the top set but on PM
    /// become promotions (hottest first); extents outside it but on
    /// DRAM become demotions (coldest first, so demotions open room
    /// before the promotions that need it). Deterministic: ties break
    /// on the stable virtual base, so an equal-heat pair never
    /// flip-flops across epochs.
    pub fn plan(
        &self,
        samples: &[TierSample],
        heat: &HashMap<u64, f64>,
        dram_slots: usize,
    ) -> TierPlan {
        let mut ranked: Vec<(f64, u64, Dpa, MediaTier)> = samples
            .iter()
            .map(|s| (heat.get(&s.virt).copied().unwrap_or(0.0), s.virt, s.phys, s.tier))
            .collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1)));
        let desired: std::collections::HashSet<u64> = ranked
            .iter()
            .take(dram_slots)
            .filter(|(h, ..)| *h >= self.min_heat)
            .map(|(_, virt, ..)| *virt)
            .collect();
        let mut demote: Vec<Dpa> = ranked
            .iter()
            .rev() // coldest first
            .filter(|(_, virt, _, tier)| *tier == MediaTier::Dram && !desired.contains(virt))
            .map(|(.., phys, _)| *phys)
            .collect();
        // An idle DRAM extent with zero heat is not worth evicting
        // unless a hot PM extent actually wants its slot; the promote
        // list below is what justifies each demotion, so cap demotions
        // at the number of pending promotions.
        let promote: Vec<Dpa> = ranked
            .iter()
            .filter(|(_, virt, _, tier)| *tier == MediaTier::Pm && desired.contains(virt))
            .map(|(.., phys, _)| *phys)
            .collect();
        demote.truncate(promote.len());
        TierPlan { demote, promote }
    }
}

/// One epoch's migration worklist (physical extent bases, in execution
/// order: demotions first to open fast-tier room, then promotions).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TierPlan {
    /// DRAM extents to move to PM, coldest first.
    pub demote: Vec<Dpa>,
    /// PM extents to move to DRAM, hottest first.
    pub promote: Vec<Dpa>,
}

/// Configuration for the background [`TierDaemon`].
#[derive(Debug, Clone, Copy)]
pub struct TierConfig {
    /// Fold/replan interval in simulated time.
    pub epoch: SimTime,
    /// EWMA decay `d` in `new = d*prev + (1-d)*counts` (the
    /// `hotness.py` model spec). `0.0` = memoryless, `→1.0` = glacial.
    pub decay: f64,
    /// Maximum migration *attempts* per epoch tick (aborts count).
    pub budget: usize,
    /// The classification policy.
    pub policy: TierPolicy,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            epoch: SimTime::us(100),
            decay: 0.5,
            budget: 4,
            policy: TierPolicy::calibrated(),
        }
    }
}

/// Running totals the daemon keeps (observability; the scenario harness
/// reconciles these against the event stream).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCounters {
    /// Migration attempts that carved a destination (== emitted
    /// `Migrate` events).
    pub migrations: u64,
    /// Commits onto the fast tier.
    pub promotes: u64,
    /// Commits onto the slow tier.
    pub demotes: u64,
    /// Mid-copy aborts rolled back to the source.
    pub aborts: u64,
}

/// The background tiering daemon: deterministic, SimTime-driven,
/// budget-bounded. Owns the EWMA ledger (keyed by stable virtual base)
/// and turns each epoch's fold into a bounded batch of live migrations
/// through `FabricManager::migrate_extent`.
#[derive(Debug)]
pub struct TierDaemon {
    cfg: TierConfig,
    /// EWMA heat per extent, keyed by the stable virtual base.
    ewma: HashMap<u64, f64>,
    next_epoch: SimTime,
    counters: TierCounters,
}

impl TierDaemon {
    pub fn new(cfg: TierConfig) -> Self {
        let first = cfg.epoch;
        TierDaemon { cfg, ewma: HashMap::new(), next_epoch: first, counters: TierCounters::default() }
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// Current EWMA heat of the extent known by virtual base `virt`.
    pub fn heat_of(&self, virt: u64) -> f64 {
        self.ewma.get(&virt).copied().unwrap_or(0.0)
    }

    pub fn counters(&self) -> TierCounters {
        self.counters
    }

    /// Drive the daemon at simulated time `now`. A no-op until the next
    /// epoch boundary; at a boundary it folds the raw heat counters
    /// into the EWMA ledger, replans, and executes at most
    /// `cfg.budget` migration attempts. `strike` is consulted once per
    /// attempt (the service wires it to the fault plan's
    /// `migrate_abort` point); `true` aborts that attempt mid-copy.
    /// Returns the number of attempts performed.
    pub fn on_tick(
        &mut self,
        now: SimTime,
        fabric: &FabricRef,
        mut strike: impl FnMut() -> bool,
    ) -> Result<usize> {
        if now < self.next_epoch || self.cfg.epoch.as_ns() == 0 {
            return Ok(0);
        }
        // Catch up in one fold: missing several boundaries (a stalled
        // service) must not replay several epochs of decay.
        while self.next_epoch <= now {
            self.next_epoch = SimTime(self.next_epoch.as_ns() + self.cfg.epoch.as_ns());
        }
        let (samples, dram_slots) =
            fabric.with_fm(|fm| (fm.tier_fold(), (fm.tier_boundary() / EXTENT_SIZE) as usize))?;
        let d = self.cfg.decay;
        let mut next: HashMap<u64, f64> = HashMap::with_capacity(samples.len());
        for s in &samples {
            let prev = self.ewma.get(&s.virt).copied().unwrap_or(0.0);
            // hotness.py model spec: out = d * prev + (1 - d) * counts
            next.insert(s.virt, d * prev + (1.0 - d) * s.touches as f64);
        }
        // released extents fall out of the ledger (absent from census)
        self.ewma = next;
        let plan = self.cfg.policy.plan(&samples, &self.ewma, dram_slots);
        let mut moved = 0usize;
        for phys in plan.demote.into_iter().chain(plan.promote) {
            if moved >= self.cfg.budget {
                break;
            }
            let abort = strike();
            match fabric.with_fm(|fm| fm.migrate_extent(phys, abort))? {
                Ok(MigrateOutcome::Committed { to, .. }) => {
                    moved += 1;
                    self.counters.migrations += 1;
                    match to {
                        MediaTier::Dram => self.counters.promotes += 1,
                        MediaTier::Pm => self.counters.demotes += 1,
                    }
                }
                Ok(MigrateOutcome::Aborted { .. }) => {
                    moved += 1;
                    self.counters.migrations += 1;
                    self.counters.aborts += 1;
                }
                // Refusal (no destination span, lease gone, quarantined
                // source): nothing was carved, no event was emitted —
                // skip without consuming budget-visible work.
                Err(_) => {}
            }
        }
        Ok(moved)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(virt: u64, tier: MediaTier) -> TierSample {
        TierSample { virt, phys: Dpa(virt), owner: HostId(0), tier, touches: 0 }
    }

    #[test]
    fn ewma_mirrors_hotness_kernel_spec() {
        // python/compile/kernels/hotness.py: out = d*prev + (1-d)*counts
        let d = 0.875f64;
        let mut prev = 0.0f64;
        for (counts, expect) in [(8.0, 1.0), (0.0, 0.875), (16.0, 2.765625)] {
            prev = d * prev + (1.0 - d) * counts;
            assert!((prev - expect).abs() < 1e-12, "ewma step: {prev} != {expect}");
        }
    }

    #[test]
    fn tier_state_resolves_identity_then_forwarded() {
        let st = TierState::new(8 * EXTENT_SIZE);
        let virt = 2 * EXTENT_SIZE;
        assert_eq!(st.resolve(Dpa(virt + 0x40)), Dpa(virt + 0x40), "identity before migration");
        st.commit_move(virt, 5 * EXTENT_SIZE);
        assert_eq!(st.resolve(Dpa(virt + 0x40)), Dpa(5 * EXTENT_SIZE + 0x40));
        assert_eq!(st.virtual_of(5 * EXTENT_SIZE), virt);
        assert_eq!(st.virtual_of(virt), virt, "freed source base reads as identity");
        // migrating home again erases the entry
        st.commit_move(virt, virt);
        assert!(st.forward_snapshot().is_empty());
    }

    #[test]
    fn heat_counters_fold_and_follow_migration() {
        let st = TierState::new(4 * EXTENT_SIZE);
        st.note(Dpa(EXTENT_SIZE + 10));
        st.note(Dpa(EXTENT_SIZE + 20));
        st.move_heat(EXTENT_SIZE, 3 * EXTENT_SIZE);
        assert_eq!(st.take(EXTENT_SIZE), 0, "heat moved away from the source slot");
        assert_eq!(st.take(3 * EXTENT_SIZE), 2, "heat arrived at the destination slot");
        assert_eq!(st.take(3 * EXTENT_SIZE), 0, "take() consumes");
    }

    #[test]
    fn plan_promotes_hot_pm_and_demotes_displaced_dram() {
        let policy = TierPolicy::calibrated();
        let samples = vec![
            sample(0, MediaTier::Dram),               // cold incumbent
            sample(EXTENT_SIZE, MediaTier::Pm),       // hot challenger
            sample(2 * EXTENT_SIZE, MediaTier::Pm),   // lukewarm challenger
        ];
        let mut heat = HashMap::new();
        heat.insert(0, 0.5);
        heat.insert(EXTENT_SIZE, 10.0);
        heat.insert(2 * EXTENT_SIZE, 2.0);
        // one DRAM slot: the hot PM extent displaces the cold incumbent
        let plan = policy.plan(&samples, &heat, 1);
        assert_eq!(plan.promote, vec![Dpa(EXTENT_SIZE)]);
        assert_eq!(plan.demote, vec![Dpa(0)]);
        // two DRAM slots: both PM extents fit; the incumbent is below
        // min_heat and outside the top set, but with a free slot there
        // is only one displacement to justify a demotion... both
        // promotions proceed, and the incumbent is evicted only because
        // two hotter extents want in
        let plan = policy.plan(&samples, &heat, 2);
        assert_eq!(plan.promote, vec![Dpa(EXTENT_SIZE), Dpa(2 * EXTENT_SIZE)]);
        assert_eq!(plan.demote, vec![Dpa(0)]);
    }

    #[test]
    fn plan_is_quiet_when_everything_is_cold() {
        let policy = TierPolicy::calibrated();
        let samples =
            vec![sample(0, MediaTier::Dram), sample(EXTENT_SIZE, MediaTier::Pm)];
        let heat = HashMap::new(); // all below min_heat
        let plan = policy.plan(&samples, &heat, 1);
        assert!(plan.promote.is_empty(), "nothing hot enough to promote");
        assert!(plan.demote.is_empty(), "no promotion pending, so no eviction churn");
    }

    #[test]
    fn plan_ties_break_on_virtual_base_stably() {
        let policy = TierPolicy::calibrated();
        let samples =
            vec![sample(0, MediaTier::Dram), sample(EXTENT_SIZE, MediaTier::Pm)];
        let mut heat = HashMap::new();
        heat.insert(0, 4.0);
        heat.insert(EXTENT_SIZE, 4.0);
        let plan = policy.plan(&samples, &heat, 1);
        assert!(plan.promote.is_empty(), "equal heat: the lower virtual base keeps the slot");
        assert!(plan.demote.is_empty());
    }

    #[test]
    fn calibrated_policy_prices_tiers_with_crate_scalars() {
        let p = TierPolicy::calibrated();
        assert_eq!(p.latency_of(MediaTier::Dram), HDM_MEDIA_LATENCY);
        assert_eq!(p.latency_of(MediaTier::Pm), PM_MEDIA_LATENCY);
        assert!(p.slow_latency.as_ns() > p.fast_latency.as_ns());
    }
}
