//! The coordinator: end-to-end experiment driver (Layer 3).
//!
//! Wires the fabric model, the controller pipeline, the workload engine
//! and the batched data plane (XLA via PJRT, or the native mirror when
//! artifacts are absent) into the experiments the paper reports:
//! Figure 6's scheme × pattern grids, Table 3 calibration, and the
//! ablations (locality, queue depth, shared-expander contention).
//!
//! Execution model per (device, scheme, pattern): compute the analytic
//! steady-state rate from the stage capacities, then drive the batched
//! pipeline model at that rate to obtain per-IO latency distributions
//! and the measured completion rate. The hot loop reuses buffers and
//! dispatches one XLA execution per batch.

pub mod contention;

use crate::cxl::fabric::Fabric;
use crate::error::Result;
use crate::pcie::link::PcieGen;
use crate::runtime::{Artifacts, BatchBuilder, NativeModel, StageWidths};
use crate::sim::stats::LatencyHistogram;
use crate::sim::time::SimTime;
use crate::ssd::controller::Controller;
use crate::ssd::spec::SsdSpec;
use crate::ssd::IndexPlacement;
use crate::workload::fio::{FioJob, IoPattern};

/// Result row for one (scheme, pattern) cell.
#[derive(Debug, Clone)]
pub struct SchemeRow {
    pub device: &'static str,
    pub scheme: IndexPlacement,
    pub pattern: IoPattern,
    /// Analytic steady-state throughput (KIOPS).
    pub kiops: f64,
    /// Throughput measured from batch completions (KIOPS).
    pub measured_kiops: f64,
    pub gbps: f64,
    pub mean_latency: SimTime,
    pub p50: SimTime,
    pub p99: SimTime,
    pub bottleneck: &'static str,
}

/// A titled collection of rows (one figure/table).
#[derive(Debug, Clone)]
pub struct ExperimentReport {
    pub title: String,
    pub rows: Vec<SchemeRow>,
}

impl ExperimentReport {
    /// Render as a markdown table (what the benches print).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(
            "| pattern | scheme | KIOPS | measured | GB/s | mean | p50 | p99 | bottleneck |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {} | {:.0} | {:.0} | {:.2} | {} | {} | {} | {} |\n",
                r.pattern.label(),
                r.scheme.label(),
                r.kiops,
                r.measured_kiops,
                r.gbps,
                r.mean_latency,
                r.p50,
                r.p99,
                r.bottleneck,
            ));
        }
        s
    }

    /// Find a row.
    pub fn get(&self, scheme: IndexPlacement, pattern: IoPattern) -> Option<&SchemeRow> {
        self.rows.iter().find(|r| r.scheme == scheme && r.pattern == pattern)
    }

    /// Ratio of Ideal to `scheme` throughput for a pattern (the "N×"
    /// numbers the paper quotes).
    pub fn ratio_vs_ideal(&self, scheme: IndexPlacement, pattern: IoPattern) -> Option<f64> {
        let ideal = self.get(IndexPlacement::Ideal, pattern)?.kiops;
        let other = self.get(scheme, pattern)?.kiops;
        Some(ideal / other)
    }
}

/// Which data-plane backend executes batches.
enum Backend {
    Xla(Artifacts),
    Native,
}

/// The experiment coordinator.
pub struct Coordinator {
    pub fabric: Fabric,
    backend: Backend,
    /// Batches per (scheme, pattern) run.
    pub batches: usize,
    pub seed: u64,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator")
            .field("backend", &self.backend_name())
            .field("batches", &self.batches)
            .finish()
    }
}

/// Batch geometry per device variant (must match aot.py).
pub fn variant_for(gen: PcieGen) -> (&'static str, usize, StageWidths) {
    match gen {
        PcieGen::Gen4 => ("io_batch_gen4", 2048, StageWidths { index: 2, media: 128, link: 1 }),
        PcieGen::Gen5 => ("io_batch_gen5", 2560, StageWidths { index: 2, media: 160, link: 1 }),
    }
}

impl Coordinator {
    /// Native backend (no artifacts needed).
    pub fn native() -> Self {
        Coordinator { fabric: Fabric::default(), backend: Backend::Native, batches: 8, seed: 7 }
    }

    /// XLA backend from an artifacts directory.
    pub fn with_artifacts(dir: &std::path::Path) -> Result<Self> {
        let artifacts = Artifacts::load(dir)?;
        Ok(Coordinator {
            fabric: Fabric::default(),
            backend: Backend::Xla(artifacts),
            batches: 8,
            seed: 7,
        })
    }

    /// Set the number of batches per cell (builder-style).
    pub fn with_batches(mut self, batches: usize) -> Self {
        self.batches = batches;
        self
    }

    /// XLA if `artifacts/` is built, else native.
    pub fn auto() -> Self {
        let dir = Artifacts::default_dir();
        if Artifacts::available(&dir) {
            match Self::with_artifacts(&dir) {
                Ok(c) => return c,
                Err(e) => eprintln!("warning: artifacts unusable ({e}); using native backend"),
            }
        }
        Self::native()
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Xla(_) => "xla-pjrt",
            Backend::Native => "native",
        }
    }

    /// Run one (controller, job) cell.
    pub fn run_cell(&self, ctl: &Controller, job: &FioJob) -> Result<SchemeRow> {
        let analytic = ctl.throughput_iops(job);
        let (name, batch, widths) = variant_for(ctl.spec.gen);
        // de-rate injection slightly so the open-loop queue stays stable
        let rate = analytic * 0.98;
        let mut builder = BatchBuilder::new(ctl, job, rate, batch, self.seed);
        let mut hist = LatencyHistogram::new();
        let mut total_span_ns = 0f64;
        let mut total_ios = 0u64;
        // PERF iteration 3: the native path reuses one scratch across
        // batches — the hot loop performs no allocation after warm-up.
        let mut scratch = crate::runtime::native::NativeScratch::new(batch);
        let native = NativeModel::new(widths);
        for _ in 0..self.batches {
            let inputs = builder.next_batch();
            match &self.backend {
                Backend::Xla(a) => {
                    let out = a.get(name)?.run(inputs)?;
                    scratch.completion.copy_from_slice(&out.completion);
                    scratch.latency.copy_from_slice(&out.latency);
                }
                Backend::Native => {
                    native.run_with_scratch(inputs, &mut scratch)?;
                }
            }
            for &l in &scratch.latency {
                hist.record(SimTime::ns(l.max(0.0) as u64));
            }
            let last = scratch.completion.iter().cloned().fold(0f32, f32::max);
            total_span_ns += last as f64;
            total_ios += batch as u64;
        }
        let measured_iops = total_ios as f64 / (total_span_ns * 1e-9);
        Ok(SchemeRow {
            device: ctl.spec.name,
            scheme: ctl.placement,
            pattern: job.pattern,
            kiops: analytic / 1e3,
            measured_kiops: measured_iops / 1e3,
            gbps: analytic * job.block_size as f64 / 1e9,
            mean_latency: hist.mean(),
            p50: hist.p50(),
            p99: hist.p99(),
            bottleneck: ctl.stage_caps(job.pattern, job.block_size).bottleneck_name(),
        })
    }

    /// One scheme under the paper's fio settings.
    pub fn run_scheme(
        &self,
        spec: &SsdSpec,
        scheme: IndexPlacement,
        job: &FioJob,
    ) -> Result<SchemeRow> {
        let ctl = Controller::new(spec.clone(), scheme, self.fabric.clone());
        self.run_cell(&ctl, job)
    }

    /// Figure 6 grid for one device: 4 patterns × 4 schemes.
    pub fn figure6(&self, gen: PcieGen) -> Result<ExperimentReport> {
        let spec = SsdSpec::for_gen(gen);
        let mut rows = Vec::new();
        for pattern in IoPattern::ALL {
            let job = FioJob::paper(pattern, 64 * crate::cxl::types::GIB);
            for scheme in IndexPlacement::ALL {
                rows.push(self.run_scheme(&spec, scheme, &job)?);
            }
        }
        Ok(ExperimentReport {
            title: format!(
                "Figure 6 ({}): L2P index placement on the {} SSD [{} backend]",
                gen.label(),
                spec.name,
                self.backend_name()
            ),
            rows,
        })
    }

    /// Table 3 calibration: the Ideal scheme must land on the spec sheet.
    pub fn table3(&self) -> Result<Vec<(String, f64, f64)>> {
        let mut out = Vec::new();
        for spec in [SsdSpec::gen4(), SsdSpec::gen5()] {
            let ctl = Controller::new(spec.clone(), IndexPlacement::Ideal, self.fabric.clone());
            for (label, pattern, spec_val, unit_kiops) in [
                ("4K rand read KIOPS", IoPattern::RandRead, spec.spec_rand_read_kiops, true),
                ("4K rand write KIOPS", IoPattern::RandWrite, spec.spec_rand_write_kiops, true),
                ("128K seq read GB/s", IoPattern::SeqRead, spec.spec_seq_read_gbps, false),
                ("128K seq write GB/s", IoPattern::SeqWrite, spec.spec_seq_write_gbps, false),
            ] {
                let mut job = FioJob::paper(pattern, 64 * crate::cxl::types::GIB);
                if !unit_kiops {
                    job.block_size = 128 * 1024;
                }
                let measured = if unit_kiops {
                    ctl.throughput_iops(&job) / 1e3
                } else {
                    ctl.throughput_gbps(&job)
                };
                out.push((format!("{} {label}", spec.name), spec_val, measured));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;

    fn coord() -> Coordinator {
        Coordinator { batches: 2, ..Coordinator::native() }
    }

    #[test]
    fn figure6_gen4_shape() {
        let report = coord().figure6(PcieGen::Gen4).unwrap();
        assert_eq!(report.rows.len(), 16);
        // writes: LMB ≈ Ideal
        let ideal_w = report.get(IndexPlacement::Ideal, IoPattern::RandWrite).unwrap().kiops;
        let pcie_w = report.get(IndexPlacement::LmbPcie, IoPattern::RandWrite).unwrap().kiops;
        assert!((pcie_w - ideal_w).abs() / ideal_w < 0.01);
        // DFTL far worse on reads
        let ratio = report
            .ratio_vs_ideal(IndexPlacement::Dftl, IoPattern::RandRead)
            .unwrap();
        assert!(ratio > 10.0, "DFTL read ratio {ratio}");
    }

    #[test]
    fn measured_tracks_analytic() {
        let c = coord();
        let spec = SsdSpec::gen4();
        let job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
        let row = c.run_scheme(&spec, IndexPlacement::Ideal, &job).unwrap();
        let rel = (row.measured_kiops - row.kiops).abs() / row.kiops;
        assert!(rel < 0.10, "measured {} vs analytic {}", row.measured_kiops, row.kiops);
    }

    #[test]
    fn latency_distribution_sane() {
        let c = coord();
        let spec = SsdSpec::gen4();
        let job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
        let row = c.run_scheme(&spec, IndexPlacement::Ideal, &job).unwrap();
        assert!(row.p50 <= row.p99, "p50 {} p99 {}", row.p50, row.p99);
        // unloaded base is ~74 µs; saturated mean must exceed it
        assert!(row.mean_latency >= SimTime::us(60), "mean {}", row.mean_latency);
    }

    #[test]
    fn dftl_latency_bimodal_p99_reflects_misses() {
        let c = coord();
        let spec = SsdSpec::gen4();
        let job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
        let ideal = c.run_scheme(&spec, IndexPlacement::Ideal, &job).unwrap();
        let dftl = c.run_scheme(&spec, IndexPlacement::Dftl, &job).unwrap();
        assert!(dftl.p99 > ideal.p99, "DFTL p99 {} vs ideal {}", dftl.p99, ideal.p99);
    }

    #[test]
    fn table3_within_five_percent() {
        for (label, spec_val, measured) in coord().table3().unwrap() {
            let rel = (measured - spec_val).abs() / spec_val;
            assert!(rel < 0.06, "{label}: spec {spec_val} measured {measured:.1}");
        }
    }

    #[test]
    fn markdown_rendering() {
        let report = coord().figure6(PcieGen::Gen4).unwrap();
        let md = report.to_markdown();
        assert!(md.contains("| rand-read | LMB-PCIe |"));
        assert!(md.contains("Figure 6"));
    }
}
