//! Shared-expander contention model (§1: "Performance interference due
//! to multiple devices accessing shared memory adds complexity").
//!
//! N devices place their L2P tables in one expander. Each device's index
//! traffic loads the expander's media: an M/M/1-style queueing inflation
//! lengthens every index access, which lowers each device's throughput,
//! which lowers the offered load — a fixed point the solver iterates to.

use crate::cxl::fabric::Fabric;
use crate::cxl::packet::LINE;
use crate::error::Result;
use crate::ssd::controller::Controller;
use crate::ssd::spec::SsdSpec;
use crate::ssd::IndexPlacement;
use crate::workload::fio::FioJob;

/// M/M/1 queueing-delay factor ρ/(1−ρ) — the curve [`solve`] iterates
/// to a fixed point. Exposed on its own so other layers (the FM's
/// contention-aware extent placement, the alloc-queue ablation) price
/// load with the *same* model the device-level solver uses, not a
/// reimplementation that could drift.
pub fn queueing_delay(rho: f64) -> f64 {
    let rho = rho.clamp(0.0, 0.999);
    rho / (1.0 - rho)
}

/// Modeled contention cost of directing `load` bytes of traffic at a
/// region/port of `capacity` bytes: the queueing delay at the implied
/// utilisation. Monotone in `load`, convex as the region saturates, so
/// a placement policy minimising it spreads load across regions long
/// before any one region hits its knee.
pub fn placement_cost(load: u64, capacity: u64) -> f64 {
    if capacity == 0 {
        return f64::INFINITY;
    }
    queueing_delay(load as f64 / capacity as f64)
}

/// Result of a contention run.
#[derive(Debug, Clone)]
pub struct ContentionPoint {
    pub devices: u32,
    /// Per-device throughput, KIOPS.
    pub per_device_kiops: f64,
    /// Aggregate throughput, KIOPS.
    pub aggregate_kiops: f64,
    /// Expander utilisation [0,1).
    pub utilisation: f64,
    /// Inflated index-access latency, ns.
    pub access_ns: u64,
}

/// Solve the contention fixed point for `devices` identical SSDs sharing
/// one expander.
pub fn solve(
    spec: &SsdSpec,
    scheme: IndexPlacement,
    fabric: &Fabric,
    job: &FioJob,
    devices: u32,
    expander_bandwidth_bps: f64,
) -> Result<ContentionPoint> {
    assert!(devices >= 1);
    // expander capacity in index accesses/sec (64 B lines)
    let access_cap = expander_bandwidth_bps / LINE as f64;
    let k = spec.pipeline.index_accesses as f64;
    let base_ctl = Controller::new(spec.clone(), scheme, fabric.clone());
    let base_access = base_ctl.index_access().as_ns() as f64;
    let media_ns = fabric.cfg.hdm_media.as_ns() as f64;

    let mut inflation = 1.0f64;
    let mut x = base_ctl.throughput_iops(job);
    let mut rho = 0.0;
    for _ in 0..32 {
        // offered index-access load from all devices (reads only carry
        // synchronous accesses; writes are posted)
        let per_io_accesses = if job.pattern.is_write() { 0.2 } else { k };
        let load = devices as f64 * x * per_io_accesses;
        rho = (load / access_cap).min(0.999);
        // queueing inflates the *media* component of each access
        let extra = media_ns * queueing_delay(rho);
        let new_inflation = (base_access + extra) / base_access;
        // damped update for stable convergence
        inflation = 0.5 * inflation + 0.5 * new_inflation;
        let mut ctl = Controller::new(spec.clone(), scheme, fabric.clone());
        ctl.index_access_inflation = inflation;
        let nx = ctl.throughput_iops(job);
        if (nx - x).abs() / x < 1e-6 {
            x = nx;
            break;
        }
        x = nx;
    }
    Ok(ContentionPoint {
        devices,
        per_device_kiops: x / 1e3,
        aggregate_kiops: devices as f64 * x / 1e3,
        utilisation: rho,
        access_ns: (base_access * inflation) as u64,
    })
}

/// Sweep 1..=max_devices.
pub fn sweep(
    spec: &SsdSpec,
    scheme: IndexPlacement,
    fabric: &Fabric,
    job: &FioJob,
    max_devices: u32,
    expander_bandwidth_bps: f64,
) -> Result<Vec<ContentionPoint>> {
    (1..=max_devices)
        .map(|n| solve(spec, scheme, fabric, job, n, expander_bandwidth_bps))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;
    use crate::workload::fio::IoPattern;

    fn rig() -> (SsdSpec, Fabric, FioJob) {
        (
            SsdSpec::gen5(),
            Fabric::default(),
            FioJob::paper(IoPattern::RandRead, 64 * GIB),
        )
    }

    #[test]
    fn single_device_matches_uncontended() {
        let (spec, fabric, job) = rig();
        let p = solve(&spec, IndexPlacement::LmbCxl, &fabric, &job, 1, 80e9).unwrap();
        let ctl = Controller::new(spec, IndexPlacement::LmbCxl, fabric);
        let base = ctl.throughput_iops(&job) / 1e3;
        assert!((p.per_device_kiops - base).abs() / base < 0.05, "{p:?} vs {base}");
    }

    #[test]
    fn contention_degrades_per_device_throughput() {
        let (spec, fabric, job) = rig();
        let pts = sweep(&spec, IndexPlacement::LmbCxl, &fabric, &job, 8, 80e9).unwrap();
        assert!(pts[7].per_device_kiops < pts[0].per_device_kiops);
        assert!(pts[7].utilisation > pts[0].utilisation);
        // aggregate still grows (sub-linearly)
        assert!(pts[7].aggregate_kiops > pts[0].aggregate_kiops);
        // monotone decline
        for w in pts.windows(2) {
            assert!(w[1].per_device_kiops <= w[0].per_device_kiops * 1.001);
        }
    }

    #[test]
    fn writes_barely_contend() {
        // posted updates → little synchronous expander load
        let (spec, fabric, _) = rig();
        let wjob = FioJob::paper(IoPattern::RandWrite, 64 * GIB);
        let pts = sweep(&spec, IndexPlacement::LmbCxl, &fabric, &wjob, 8, 80e9).unwrap();
        let drop = 1.0 - pts[7].per_device_kiops / pts[0].per_device_kiops;
        assert!(drop < 0.05, "write contention drop {drop}");
    }

    #[test]
    fn bigger_expander_bandwidth_relieves_contention() {
        let (spec, fabric, job) = rig();
        let small = solve(&spec, IndexPlacement::LmbCxl, &fabric, &job, 8, 40e9).unwrap();
        let large = solve(&spec, IndexPlacement::LmbCxl, &fabric, &job, 8, 160e9).unwrap();
        assert!(large.per_device_kiops > small.per_device_kiops);
    }

    #[test]
    fn queueing_delay_shape() {
        assert_eq!(queueing_delay(0.0), 0.0);
        assert!((queueing_delay(0.5) - 1.0).abs() < 1e-12);
        // monotone and clamped: past the 0.999 knee the cost saturates
        assert!(queueing_delay(0.9) > queueing_delay(0.5));
        assert_eq!(queueing_delay(1.0), queueing_delay(2.0));
        assert_eq!(queueing_delay(-0.5), 0.0, "negative utilisation clamps to idle");
    }

    #[test]
    fn placement_cost_prefers_less_loaded_regions() {
        // the decision the FM's contention-aware placement makes: a
        // half-full region always prices below a nearly-full one
        assert!(placement_cost(1 << 28, 1 << 31) < placement_cost(3 << 29, 1 << 31));
        assert_eq!(placement_cost(0, 1 << 30), 0.0);
        assert!(placement_cost(5, 0).is_infinite(), "zero-capacity region is unplaceable");
    }
}
