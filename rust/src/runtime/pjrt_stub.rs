//! Stub PJRT loader, compiled when the `pjrt` feature is off (the
//! default — the vendored `xla` crate is absent from hermetic builds).
//!
//! Mirrors the public surface of the real `pjrt.rs` so every consumer
//! (coordinator backend selection, `perf_hotpath`, `xla_parity`)
//! compiles unchanged: artifacts are simply never *available*, so all of
//! them take their native-data-plane fallback paths. Enable the `pjrt`
//! feature (and add the `xla` dependency) to restore the XLA path.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::{ModelInputs, ModelOutputs, StageWidths};

fn unavailable(what: &str) -> Error {
    Error::Runtime(format!(
        "{what}: built without the `pjrt` feature — the XLA data plane is \
         stubbed out; use the native backend (Coordinator::native / ::auto)"
    ))
}

/// One compiled model variant (stub: cannot be constructed).
#[derive(Debug)]
pub struct XlaModel {
    pub batch: usize,
    pub widths: StageWidths,
    pub name: String,
    _private: (),
}

impl XlaModel {
    /// Execute one batch (stub: always an error).
    pub fn run(&self, _inputs: &ModelInputs) -> Result<ModelOutputs> {
        Err(unavailable("XlaModel::run"))
    }
}

/// The artifacts directory (stub: never reports available).
#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
}

impl Artifacts {
    /// Default artifacts location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // honour $LMB_ARTIFACTS, else ./artifacts (same as the real impl)
        std::env::var_os("LMB_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether artifacts can be used. Without the `pjrt` feature the
    /// answer is always no, even if the files exist on disk.
    pub fn available(_dir: &Path) -> bool {
        false
    }

    /// Load the manifest (stub: always an error).
    pub fn load(_dir: &Path) -> Result<Self> {
        Err(unavailable("Artifacts::load"))
    }

    pub fn get(&self, name: &str) -> Result<&XlaModel> {
        Err(unavailable(&format!("model '{name}'")))
    }

    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_everywhere() {
        let dir = Artifacts::default_dir();
        assert!(!Artifacts::available(&dir));
        assert!(Artifacts::load(&dir).is_err());
    }
}
