//! Batch builder: turns (controller, job) into model inputs.
//!
//! The coordinator simulates in windows of one batch: arrivals are an
//! open-loop Poisson stream at the analytic steady-state rate (slightly
//! de-rated for queue stability), DFTL hit masks are sampled from the
//! CMT hit ratio, and media jitter is uniform. Buffers are reused across
//! batches — the hot loop performs no allocation after warm-up.

use crate::sim::rng::Pcg64;
use crate::ssd::controller::Controller;
use crate::ssd::IndexPlacement;
use crate::workload::fio::{FioJob, IoPattern};

use super::{ModelInputs, ModelParams};

/// Stateful builder producing successive batches of model inputs.
#[derive(Debug)]
pub struct BatchBuilder {
    batch: usize,
    rng: Pcg64,
    /// arrival clock carried across batches (ns).
    clock: f64,
    /// mean inter-arrival time (ns).
    interarrival_ns: f64,
    is_write: f32,
    hit_ratio: f64,
    params: ModelParams,
    inputs: ModelInputs,
}

impl BatchBuilder {
    /// Build for a (controller, job) pair. `rate_iops` is the injection
    /// rate; callers typically pass `controller.throughput_iops(job)`
    /// de-rated by ~2% so queues stay finite.
    pub fn new(ctl: &Controller, job: &FioJob, rate_iops: f64, batch: usize, seed: u64) -> Self {
        let params = Self::params_for(ctl, job);
        let is_write = if job.pattern.is_write() { 1.0 } else { 0.0 };
        let hit_ratio = if ctl.placement == IndexPlacement::Dftl {
            ctl.dftl_hit_ratio
        } else {
            1.0
        };
        let inputs = ModelInputs {
            arrival: vec![0.0; batch],
            is_write: vec![is_write; batch],
            hit: vec![1.0; batch],
            jitter: vec![0.0; batch],
            params,
        };
        BatchBuilder {
            batch,
            rng: Pcg64::with_stream(seed, 0xba7c4),
            clock: 0.0,
            interarrival_ns: 1e9 / rate_iops,
            is_write,
            hit_ratio,
            params,
            inputs,
        }
    }

    /// Derive the scalar pack from the controller state.
    pub fn params_for(ctl: &Controller, job: &FioJob) -> ModelParams {
        let spec = &ctl.spec;
        ModelParams {
            firmware_ns: spec.pipeline.firmware_ns as f32,
            index_accesses: spec.pipeline.index_accesses as f32,
            index_access_ns: ctl.index_access().as_ns() as f32,
            dram_ns: ctl.fabric.cfg.onboard_dram.as_ns() as f32,
            flash_read_ns: ctl.fabric.cfg.flash_read.as_ns() as f32,
            dftl_ops_read: spec.pipeline.dftl_flash_ops_read as f32,
            dftl_ops_write: spec.pipeline.dftl_flash_ops_write as f32,
            t_read_ns: spec.nand.t_read.as_ns() as f32,
            t_buf_ns: spec.write_buffer_latency.as_ns() as f32,
            xfer_ns: spec.link().serialize(job.block_size as u64).as_ns() as f32,
            is_dftl: if ctl.placement == IndexPlacement::Dftl { 1.0 } else { 0.0 },
            jitter_amp: if job.pattern == IoPattern::RandRead
                || job.pattern == IoPattern::SeqRead
            {
                0.1
            } else {
                0.0
            },
        }
    }

    /// Fill the reused input buffers with the next batch; returns them.
    pub fn next_batch(&mut self) -> &ModelInputs {
        // Arrivals restart near zero each batch (f32 precision: keeping
        // absolute ns values small preserves sub-ns resolution). The
        // pipeline state does not carry across batches; with batch ≫
        // outstanding the boundary error is negligible (PERF note).
        self.clock = 0.0;
        for i in 0..self.batch {
            self.clock += self.rng.exp(self.interarrival_ns);
            self.inputs.arrival[i] = self.clock as f32;
            self.inputs.is_write[i] = self.is_write;
            self.inputs.hit[i] = if self.hit_ratio >= 1.0 {
                1.0
            } else if self.rng.chance(self.hit_ratio) {
                1.0
            } else {
                0.0
            };
            self.inputs.jitter[i] = self.rng.next_f64() as f32;
        }
        &self.inputs
    }

    pub fn params(&self) -> ModelParams {
        self.params
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::fabric::Fabric;
    use crate::cxl::types::GIB;
    use crate::ssd::spec::SsdSpec;

    fn rig(placement: IndexPlacement, pattern: IoPattern) -> (Controller, FioJob) {
        let ctl = Controller::new(SsdSpec::gen4(), placement, Fabric::default());
        (ctl, FioJob::paper(pattern, 64 * GIB))
    }

    #[test]
    fn arrivals_are_monotone_at_requested_rate() {
        let (ctl, job) = rig(IndexPlacement::Ideal, IoPattern::RandRead);
        let mut b = BatchBuilder::new(&ctl, &job, 1_000_000.0, 2048, 1);
        let inputs = b.next_batch();
        let mut prev = 0.0f32;
        for &a in &inputs.arrival {
            assert!(a >= prev);
            prev = a;
        }
        // 2048 IOs at 1M IOPS ≈ 2.048 ms span (±20% for Poisson noise)
        let span = inputs.arrival[2047] as f64;
        assert!((1.6e6..2.5e6).contains(&span), "span {span} ns");
    }

    #[test]
    fn dftl_hit_mask_matches_ratio() {
        let (mut ctl, job) = rig(IndexPlacement::Dftl, IoPattern::RandRead);
        ctl.dftl_hit_ratio = 0.3;
        let mut b = BatchBuilder::new(&ctl, &job, 100_000.0, 4096, 2);
        let inputs = b.next_batch();
        let hits: f32 = inputs.hit.iter().sum();
        let ratio = hits / 4096.0;
        assert!((0.25..0.35).contains(&ratio), "hit ratio {ratio}");
        assert_eq!(inputs.params.is_dftl, 1.0);
    }

    #[test]
    fn non_dftl_hit_mask_all_ones() {
        let (ctl, job) = rig(IndexPlacement::LmbCxl, IoPattern::RandRead);
        let mut b = BatchBuilder::new(&ctl, &job, 1e6, 512, 3);
        let inputs = b.next_batch();
        assert!(inputs.hit.iter().all(|&h| h == 1.0));
        assert_eq!(inputs.params.index_access_ns, 190.0);
    }

    #[test]
    fn write_jobs_set_write_flags_and_no_jitter() {
        let (ctl, job) = rig(IndexPlacement::Ideal, IoPattern::RandWrite);
        let mut b = BatchBuilder::new(&ctl, &job, 3e5, 256, 4);
        let inputs = b.next_batch();
        assert!(inputs.is_write.iter().all(|&w| w == 1.0));
        assert_eq!(inputs.params.jitter_amp, 0.0);
    }

    #[test]
    fn params_derive_from_fabric_not_hardcoded() {
        let (ctl, job) = rig(IndexPlacement::LmbPcie, IoPattern::RandRead);
        let p = BatchBuilder::params_for(&ctl, &job);
        assert_eq!(p.index_access_ns, 880.0); // gen4 LMB-PCIe via fabric
        assert_eq!(p.flash_read_ns, 25_000.0);
    }
}
