//! Pure-Rust mirror of the XLA data-plane model.
//!
//! Bit-for-bit the same math as `python/compile/model.py` (modulo f32
//! rounding): the Pallas `latency_compose` kernel's service composition
//! and the three max-plus lag-C pipeline scans. Used to cross-check the
//! AOT path in integration tests and as a fallback when artifacts are
//! absent.

use crate::runtime::{ModelInputs, ModelOutputs, StageWidths};

/// Native implementation of the model contract.
#[derive(Debug, Clone, Copy)]
pub struct NativeModel {
    pub widths: StageWidths,
}

impl NativeModel {
    pub fn new(widths: StageWidths) -> Self {
        NativeModel { widths }
    }

    /// Per-IO (index_service, media_service) — the Pallas kernel's math.
    fn compose(inputs: &ModelInputs, i: usize) -> (f32, f32) {
        let p = &inputs.params;
        let w = inputs.is_write[i];
        let hit = inputs.hit[i];
        let miss = 1.0 - hit;
        // DFTL: synchronous translation fetch for reads AND writes
        let dftl_ops = w * p.dftl_ops_write + (1.0 - w) * p.dftl_ops_read;
        let idx_dftl = p.dram_ns + miss * dftl_ops * p.flash_read_ns;
        // Ideal/LMB: k dependent accesses for reads; posted updates for writes
        let idx_plain = (1.0 - w) * p.index_accesses * p.index_access_ns;
        let idx = p.firmware_ns + p.is_dftl * idx_dftl + (1.0 - p.is_dftl) * idx_plain;
        // media: reads pay tR (jittered), writes the buffer ack
        let jit = 1.0 + p.jitter_amp * (2.0 * inputs.jitter[i] - 1.0);
        let media = w * p.t_buf_ns + (1.0 - w) * p.t_read_ns * jit;
        (idx, media)
    }

    /// max-plus lag-C scan: finish_i = max(arrival_i, finish_{i-C}) + s_i.
    fn lag_scan(arrival: &[f32], service: &[f32], width: usize, out: &mut [f32]) {
        let n = arrival.len();
        debug_assert_eq!(n % width, 0);
        for i in 0..n {
            let prev = if i >= width { out[i - width] } else { f32::NEG_INFINITY };
            out[i] = arrival[i].max(prev) + service[i];
        }
    }

    /// Run the model (allocating variant; see [`Self::run_with_scratch`]
    /// for the zero-allocation hot path).
    pub fn run(&self, inputs: &ModelInputs) -> crate::Result<ModelOutputs> {
        let mut scratch = NativeScratch::new(inputs.batch());
        self.run_with_scratch(inputs, &mut scratch)?;
        Ok(ModelOutputs {
            completion: scratch.completion.clone(),
            latency: scratch.latency.clone(),
        })
    }

    /// Zero-allocation hot path: all intermediates live in `scratch`,
    /// results land in `scratch.completion` / `scratch.latency`
    /// (PERF iteration 3 — see EXPERIMENTS.md §Perf).
    pub fn run_with_scratch(
        &self,
        inputs: &ModelInputs,
        scratch: &mut NativeScratch,
    ) -> crate::Result<()> {
        inputs.validate(inputs.batch(), self.widths)?;
        let n = inputs.batch();
        scratch.resize(n);
        for i in 0..n {
            let (a, b) = Self::compose(inputs, i);
            scratch.idx_service[i] = a;
            scratch.media_service[i] = b;
        }
        scratch.xfer.fill(inputs.params.xfer_ns);
        Self::lag_scan(&inputs.arrival, &scratch.idx_service, self.widths.index, &mut scratch.f1);
        Self::lag_scan(&scratch.f1, &scratch.media_service, self.widths.media, &mut scratch.f2);
        Self::lag_scan(&scratch.f2, &scratch.xfer, self.widths.link, &mut scratch.completion);
        for i in 0..n {
            scratch.latency[i] = scratch.completion[i] - inputs.arrival[i];
        }
        Ok(())
    }
}

/// Reusable buffers for [`NativeModel::run_with_scratch`].
#[derive(Debug, Clone)]
pub struct NativeScratch {
    idx_service: Vec<f32>,
    media_service: Vec<f32>,
    xfer: Vec<f32>,
    f1: Vec<f32>,
    f2: Vec<f32>,
    pub completion: Vec<f32>,
    pub latency: Vec<f32>,
}

impl NativeScratch {
    pub fn new(n: usize) -> Self {
        NativeScratch {
            idx_service: vec![0.0; n],
            media_service: vec![0.0; n],
            xfer: vec![0.0; n],
            f1: vec![0.0; n],
            f2: vec![0.0; n],
            completion: vec![0.0; n],
            latency: vec![0.0; n],
        }
    }

    fn resize(&mut self, n: usize) {
        for v in [
            &mut self.idx_service,
            &mut self.media_service,
            &mut self.xfer,
            &mut self.f1,
            &mut self.f2,
            &mut self.completion,
            &mut self.latency,
        ] {
            v.resize(n, 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelParams;

    fn params() -> ModelParams {
        ModelParams {
            firmware_ns: 440.0,
            index_accesses: 1.0,
            index_access_ns: 70.0,
            dram_ns: 70.0,
            flash_read_ns: 25_000.0,
            dftl_ops_read: 1.0,
            dftl_ops_write: 2.0,
            t_read_ns: 73_000.0,
            t_buf_ns: 9_000.0,
            xfer_ns: 570.0,
            is_dftl: 0.0,
            jitter_amp: 0.0,
        }
    }

    fn inputs(n: usize, p: ModelParams) -> ModelInputs {
        ModelInputs {
            arrival: (0..n).map(|i| i as f32 * 100.0).collect(),
            is_write: vec![0.0; n],
            hit: vec![1.0; n],
            jitter: vec![0.5; n],
            params: p,
        }
    }

    fn model() -> NativeModel {
        NativeModel::new(StageWidths { index: 2, media: 128, link: 1 })
    }

    #[test]
    fn single_io_latency_is_service_sum() {
        let m = NativeModel::new(StageWidths { index: 1, media: 1, link: 1 });
        let mut inp = inputs(1, params());
        inp.arrival = vec![0.0];
        let out = m.run(&inp).unwrap();
        // idx (440+70) + media 73000 + xfer 570 = 74080
        assert_eq!(out.latency[0], 74_080.0);
    }

    #[test]
    fn unloaded_stream_latency_constant() {
        // arrivals far apart → no queueing → every IO sees base latency
        let m = model();
        let mut inp = inputs(256, params());
        inp.arrival = (0..256).map(|i| i as f32 * 1e6).collect();
        let out = m.run(&inp).unwrap();
        for l in &out.latency {
            assert_eq!(*l, 74_080.0);
        }
    }

    #[test]
    fn saturating_stream_throughput_matches_bottleneck() {
        // all arrive at t=0 → completions drain at the bottleneck rate.
        // bottleneck: index width 2 / 510ns = 3.92M IOPS vs media
        // 128/73µs = 1.75M vs link 1/570ns = 1.75M.
        let m = model();
        let n = 2048;
        let mut inp = inputs(n, params());
        inp.arrival = vec![0.0; n];
        let out = m.run(&inp).unwrap();
        let span_ns = out.completion.iter().cloned().fold(0f32, f32::max);
        let iops = (n as f64) / (span_ns as f64 * 1e-9);
        assert!(
            (1.5e6..1.9e6).contains(&iops),
            "drain rate {iops:.3e} should be ≈1.75M IOPS"
        );
    }

    #[test]
    fn writes_bypass_index_memory() {
        let m = NativeModel::new(StageWidths { index: 1, media: 1, link: 1 });
        let mut p = params();
        p.index_access_ns = 1190.0; // LMB-PCIe gen5
        let mut inp = inputs(1, p);
        inp.is_write = vec![1.0];
        let out = m.run(&inp).unwrap();
        // write: f(440) + buf(9000) + xfer(570); no 1190 anywhere
        assert_eq!(out.latency[0], 10_010.0);
    }

    #[test]
    fn dftl_miss_pays_flash() {
        let m = NativeModel::new(StageWidths { index: 1, media: 1, link: 1 });
        let mut p = params();
        p.is_dftl = 1.0;
        let mut inp = inputs(2, p);
        // keep arrivals small: integers < 2^24 are exact in f32
        inp.arrival = vec![0.0, 200_000.0];
        inp.hit = vec![1.0, 0.0];
        let out = m.run(&inp).unwrap();
        // hit: 440+70 + 73000 + 570; miss adds 25000
        assert_eq!(out.latency[0], 74_080.0);
        assert_eq!(out.latency[1], 99_080.0);
    }

    #[test]
    fn media_jitter_spreads_latency() {
        let m = model();
        let mut p = params();
        p.jitter_amp = 0.1;
        let mut inp = inputs(256, p);
        inp.arrival = (0..256).map(|i| i as f32 * 1e6).collect();
        inp.jitter = (0..256).map(|i| (i as f32) / 256.0).collect();
        let out = m.run(&inp).unwrap();
        let min = out.latency.iter().cloned().fold(f32::MAX, f32::min);
        let max = out.latency.iter().cloned().fold(f32::MIN, f32::max);
        assert!(max - min > 10_000.0, "jitter range {min}..{max}");
    }

    #[test]
    fn lag_scan_respects_width() {
        // width 2: IOs 0,1 start immediately; IO 2 waits for IO 0.
        let mut out = vec![0f32; 4];
        NativeModel::lag_scan(&[0.0, 0.0, 0.0, 0.0], &[10.0, 10.0, 10.0, 10.0], 2, &mut out);
        assert_eq!(out, vec![10.0, 10.0, 20.0, 20.0]);
    }
}
