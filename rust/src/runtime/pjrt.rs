//! PJRT loader/executor for the AOT artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py`, which lowers the L2
//! model (with the L1 Pallas kernels inlined) to **HLO text** — the
//! interchange format this XLA build round-trips (serialized protos from
//! jax ≥ 0.5 are rejected; see /opt/xla-example/README.md) — plus a
//! manifest. This module loads the manifest, compiles each variant on
//! the PJRT CPU client once, and executes batches from the simulation
//! hot path. Python is never invoked here.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::{ModelInputs, ModelOutputs, StageWidths};

/// One compiled model variant.
pub struct XlaModel {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub widths: StageWidths,
    pub name: String,
    /// Executions so far (hot-path observability).
    pub dispatches: std::cell::Cell<u64>,
}

impl std::fmt::Debug for XlaModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaModel")
            .field("name", &self.name)
            .field("batch", &self.batch)
            .field("widths", &self.widths)
            .finish()
    }
}

impl XlaModel {
    /// Compile an HLO-text file on a PJRT client.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        name: &str,
        batch: usize,
        widths: StageWidths,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(XlaModel {
            exe,
            batch,
            widths,
            name: name.to_string(),
            dispatches: std::cell::Cell::new(0),
        })
    }

    /// Execute one batch.
    pub fn run(&self, inputs: &ModelInputs) -> Result<ModelOutputs> {
        inputs.validate(self.batch, self.widths)?;
        let lits = [
            xla::Literal::vec1(&inputs.arrival),
            xla::Literal::vec1(&inputs.is_write),
            xla::Literal::vec1(&inputs.hit),
            xla::Literal::vec1(&inputs.jitter),
            xla::Literal::vec1(&inputs.params.to_vec()),
        ];
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // model.py lowers with return_tuple=True → 1-tuple of f32[2, N]
        let stacked = result.to_tuple1()?;
        let flat = stacked.to_vec::<f32>()?;
        if flat.len() != 2 * self.batch {
            return Err(Error::Runtime(format!(
                "model '{}' returned {} values, expected {}",
                self.name,
                flat.len(),
                2 * self.batch
            )));
        }
        self.dispatches.set(self.dispatches.get() + 1);
        let (completion, latency) = flat.split_at(self.batch);
        Ok(ModelOutputs { completion: completion.to_vec(), latency: latency.to_vec() })
    }
}

/// The artifacts directory: manifest + compiled variants.
pub struct Artifacts {
    pub dir: PathBuf,
    client: xla::PjRtClient,
    models: HashMap<String, XlaModel>,
}

impl std::fmt::Debug for Artifacts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Artifacts")
            .field("dir", &self.dir)
            .field("models", &self.models.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl Artifacts {
    /// Default artifacts location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        // honour $LMB_ARTIFACTS, else ./artifacts
        std::env::var_os("LMB_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether a manifest exists (artifacts built).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.txt").is_file()
    }

    /// Load the manifest and compile every variant.
    ///
    /// Manifest line format (written by aot.py):
    /// `name=<id> file=<relpath> batch=<N> widths=<W>,<M>,<L>`
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest.display()
            ))
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut models = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let kv: HashMap<&str, &str> = line
                .split_whitespace()
                .filter_map(|t| t.split_once('='))
                .collect();
            let (Some(name), Some(file), Some(batch), Some(widths)) =
                (kv.get("name"), kv.get("file"), kv.get("batch"), kv.get("widths"))
            else {
                return Err(Error::Runtime(format!("bad manifest line: '{line}'")));
            };
            let batch: usize = batch
                .parse()
                .map_err(|_| Error::Runtime(format!("bad batch in '{line}'")))?;
            let ws: Vec<usize> = widths
                .split(',')
                .map(|w| w.parse::<usize>())
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| Error::Runtime(format!("bad widths in '{line}'")))?;
            if ws.len() != 3 {
                return Err(Error::Runtime(format!("need 3 widths in '{line}'")));
            }
            let widths = StageWidths { index: ws[0], media: ws[1], link: ws[2] };
            let model = XlaModel::load(&client, &dir.join(file), name, batch, widths)?;
            models.insert(name.to_string(), model);
        }
        if models.is_empty() {
            return Err(Error::Runtime("empty manifest".into()));
        }
        Ok(Artifacts { dir: dir.to_path_buf(), client, models })
    }

    pub fn get(&self, name: &str) -> Result<&XlaModel> {
        self.models.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}
