//! Runtime: executes the AOT-compiled JAX/Pallas data plane from Rust.
//!
//! ## The model contract (shared with `python/compile/model.py`)
//!
//! One compiled module per device variant, batch size `N` (divisible by
//! every stage width). Inputs, all `f32[N]` except `params`:
//!
//! | tensor     | meaning                                            |
//! |------------|----------------------------------------------------|
//! | `arrival`  | IO arrival times, ns, non-decreasing               |
//! | `is_write` | 1.0 for writes                                     |
//! | `hit`      | DFTL CMT hit mask (1.0 = hit); all-ones otherwise  |
//! | `jitter`   | uniform [0,1) per-IO media jitter                  |
//! | `params`   | `f32[12]` scalar pack, see [`ModelParams`]         |
//!
//! Output: `f32[2, N]` — row 0 completion times (ns), row 1 per-IO
//! latency (completion − arrival).
//!
//! The computation: a Pallas kernel composes per-IO index/media service
//! times; three chained *max-plus lag-C scans* resolve the controller
//! pipeline (index stage width W, media width M, link width 1):
//! `finish_i = max(arrival_i, finish_{i−C}) + s_i`.
//!
//! [`native::NativeModel`] implements the identical contract in pure
//! Rust: it cross-checks the XLA path in integration tests and serves
//! as a fallback when `artifacts/` has not been built.

pub mod batch;
pub mod native;
// The real PJRT loader needs the vendored `xla` crate; the default
// build substitutes a stub with the same surface that always reports
// artifacts unavailable, keeping every consumer on the native mirror.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use batch::BatchBuilder;
pub use native::NativeModel;
pub use pjrt::{Artifacts, XlaModel};

/// Scalar parameter pack (order must match model.py `PARAMS` doc).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelParams {
    /// p0: firmware time per IO in the index stage, ns.
    pub firmware_ns: f32,
    /// p1: dependent index-memory accesses per read lookup (k).
    pub index_accesses: f32,
    /// p2: one index-memory access at the scheme's placement, ns.
    pub index_access_ns: f32,
    /// p3: onboard DRAM access (DFTL hit cost), ns.
    pub dram_ns: f32,
    /// p4: flash read (DFTL miss penalty), ns.
    pub flash_read_ns: f32,
    /// p5: expected flash ops per DFTL read miss.
    pub dftl_ops_read: f32,
    /// p6: expected flash ops per DFTL write miss.
    pub dftl_ops_write: f32,
    /// p7: media read service (tR), ns.
    pub t_read_ns: f32,
    /// p8: write-buffer ack, ns.
    pub t_buf_ns: f32,
    /// p9: link transfer per IO, ns.
    pub xfer_ns: f32,
    /// p10: 1.0 if the scheme is DFTL.
    pub is_dftl: f32,
    /// p11: media jitter amplitude (fraction of tR).
    pub jitter_amp: f32,
}

impl ModelParams {
    pub const LEN: usize = 12;

    pub fn to_vec(self) -> Vec<f32> {
        vec![
            self.firmware_ns,
            self.index_accesses,
            self.index_access_ns,
            self.dram_ns,
            self.flash_read_ns,
            self.dftl_ops_read,
            self.dftl_ops_write,
            self.t_read_ns,
            self.t_buf_ns,
            self.xfer_ns,
            self.is_dftl,
            self.jitter_amp,
        ]
    }
}

/// Stage widths of a compiled variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageWidths {
    pub index: usize,
    pub media: usize,
    pub link: usize,
}

/// Batched model inputs.
#[derive(Debug, Clone)]
pub struct ModelInputs {
    pub arrival: Vec<f32>,
    pub is_write: Vec<f32>,
    pub hit: Vec<f32>,
    pub jitter: Vec<f32>,
    pub params: ModelParams,
}

impl ModelInputs {
    pub fn batch(&self) -> usize {
        self.arrival.len()
    }

    /// Validate shape invariants before dispatch.
    pub fn validate(&self, batch: usize, widths: StageWidths) -> crate::Result<()> {
        let n = self.arrival.len();
        if n != batch {
            return Err(crate::Error::Runtime(format!(
                "batch mismatch: inputs {n}, model {batch}"
            )));
        }
        for (name, v) in
            [("is_write", &self.is_write), ("hit", &self.hit), ("jitter", &self.jitter)]
        {
            if v.len() != n {
                return Err(crate::Error::Runtime(format!("{name} length {} != {n}", v.len())));
            }
        }
        for w in [widths.index, widths.media, widths.link] {
            if w == 0 || n % w != 0 {
                return Err(crate::Error::Runtime(format!(
                    "stage width {w} must divide batch {n}"
                )));
            }
        }
        Ok(())
    }
}

/// Batched model outputs.
#[derive(Debug, Clone)]
pub struct ModelOutputs {
    pub completion: Vec<f32>,
    pub latency: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ModelParams {
        ModelParams {
            firmware_ns: 440.0,
            index_accesses: 1.0,
            index_access_ns: 70.0,
            dram_ns: 70.0,
            flash_read_ns: 25_000.0,
            dftl_ops_read: 1.0,
            dftl_ops_write: 2.0,
            t_read_ns: 73_000.0,
            t_buf_ns: 9_000.0,
            xfer_ns: 570.0,
            is_dftl: 0.0,
            jitter_amp: 0.1,
        }
    }

    #[test]
    fn params_pack_order() {
        let v = params().to_vec();
        assert_eq!(v.len(), ModelParams::LEN);
        assert_eq!(v[0], 440.0);
        assert_eq!(v[7], 73_000.0);
        assert_eq!(v[11], 0.1);
    }

    #[test]
    fn inputs_validation() {
        let widths = StageWidths { index: 2, media: 128, link: 1 };
        let inputs = ModelInputs {
            arrival: vec![0.0; 256],
            is_write: vec![0.0; 256],
            hit: vec![1.0; 256],
            jitter: vec![0.5; 256],
            params: params(),
        };
        inputs.validate(256, widths).unwrap();
        assert!(inputs.validate(512, widths).is_err());
        let bad = StageWidths { index: 3, media: 128, link: 1 };
        assert!(inputs.validate(256, bad).is_err(), "3 does not divide 256");
    }
}
