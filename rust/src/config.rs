//! Configuration: presets + a tiny `key=value` parser.
//!
//! serde/toml are unavailable offline, so config files and CLI overrides
//! use flat `key=value` pairs (one per line in files, space-separated on
//! the command line) — enough for every knob the experiments expose.

use std::collections::HashMap;

use crate::cxl::types::GIB;
use crate::error::{Error, Result};
use crate::pcie::link::PcieGen;
use crate::ssd::IndexPlacement;
use crate::workload::fio::{FioJob, IoPattern};

/// Parsed key=value bag.
#[derive(Debug, Clone, Default)]
pub struct Kv {
    map: HashMap<String, String>,
}

impl Kv {
    /// Parse `k=v` tokens (whitespace separated; `#` starts a comment).
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = HashMap::new();
        for tok in text.split_whitespace() {
            if tok.starts_with('#') {
                break;
            }
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("expected key=value, got '{tok}'")))?;
            if k.is_empty() || v.is_empty() {
                return Err(Error::Config(format!("empty key or value in '{tok}'")));
            }
            map.insert(k.to_string(), v.to_string());
        }
        Ok(Kv { map })
    }

    /// Parse a file of `key=value` lines.
    pub fn parse_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut all = HashMap::new();
        for line in text.lines() {
            let kv = Kv::parse(line)?;
            all.extend(kv.map);
        }
        Ok(Kv { map: all })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.map
            .get(key)
            .map(|v| parse_size(v))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.map
            .get(key)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| Error::Config(format!("bad float for {key}: '{v}'")))
            })
            .transpose()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
}

/// Parse sizes with k/m/g/t suffixes (binary).
pub fn parse_size(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1u64 << 10),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1 << 20),
        Some('g') | Some('G') => (&s[..s.len() - 1], 1 << 30),
        Some('t') | Some('T') => (&s[..s.len() - 1], 1 << 40),
        _ => (s, 1),
    };
    num.parse::<u64>()
        .map(|n| n * mult)
        .map_err(|_| Error::Config(format!("bad size '{s}'")))
}

/// Parse a scheme name as the paper spells them.
pub fn parse_scheme(s: &str) -> Result<IndexPlacement> {
    match s.to_ascii_lowercase().as_str() {
        "ideal" => Ok(IndexPlacement::Ideal),
        "lmb-cxl" | "lmbcxl" | "cxl" => Ok(IndexPlacement::LmbCxl),
        "lmb-pcie" | "lmbpcie" | "pcie" => Ok(IndexPlacement::LmbPcie),
        "dftl" => Ok(IndexPlacement::Dftl),
        "hmb" => Ok(IndexPlacement::Hmb),
        _ => Err(Error::Config(format!(
            "unknown scheme '{s}' (ideal|lmb-cxl|lmb-pcie|dftl|hmb)"
        ))),
    }
}

/// Parse a PCIe generation.
pub fn parse_gen(s: &str) -> Result<PcieGen> {
    match s.to_ascii_lowercase().as_str() {
        "gen4" | "4" => Ok(PcieGen::Gen4),
        "gen5" | "5" => Ok(PcieGen::Gen5),
        _ => Err(Error::Config(format!("unknown generation '{s}' (gen4|gen5)"))),
    }
}

/// Parse a workload pattern (fio `rw=` spellings accepted).
pub fn parse_pattern(s: &str) -> Result<IoPattern> {
    match s.to_ascii_lowercase().as_str() {
        "read" | "seqread" | "seq-read" => Ok(IoPattern::SeqRead),
        "write" | "seqwrite" | "seq-write" => Ok(IoPattern::SeqWrite),
        "randread" | "rand-read" => Ok(IoPattern::RandRead),
        "randwrite" | "rand-write" => Ok(IoPattern::RandWrite),
        _ => Err(Error::Config(format!(
            "unknown pattern '{s}' (read|write|randread|randwrite)"
        ))),
    }
}

/// Build a [`FioJob`] from a pattern plus `key=value` overrides
/// (bs, qd, numjobs, ios, span, zipf, seed).
pub fn job_from_kv(pattern: IoPattern, kv: &Kv) -> Result<FioJob> {
    let mut job = FioJob::paper(pattern, 64 * GIB);
    if let Some(bs) = kv.get_u64("bs")? {
        job.block_size = bs as u32;
    }
    if let Some(qd) = kv.get_u64("qd")? {
        job.qd = qd as u32;
    }
    if let Some(nj) = kv.get_u64("numjobs")? {
        job.numjobs = nj as u32;
    }
    if let Some(ios) = kv.get_u64("ios")? {
        job.total_ios = ios;
    }
    if let Some(span) = kv.get_u64("span")? {
        job.span_bytes = span;
    }
    if let Some(theta) = kv.get_f64("zipf")? {
        job.zipf_theta = Some(theta);
    }
    if let Some(seed) = kv.get_u64("seed")? {
        job.seed = seed;
    }
    job.validate()?;
    Ok(job)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_parses_tokens_and_comments() {
        let kv = Kv::parse("qd=64 bs=4k # trailing comment ignored").unwrap();
        assert_eq!(kv.get("qd"), Some("64"));
        assert_eq!(kv.get_u64("bs").unwrap(), Some(4096));
        assert_eq!(kv.len(), 2);
    }

    #[test]
    fn kv_rejects_malformed() {
        assert!(Kv::parse("noequals").is_err());
        assert!(Kv::parse("=v").is_err());
        assert!(Kv::parse("k=").is_err());
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("4096").unwrap(), 4096);
        assert_eq!(parse_size("4k").unwrap(), 4096);
        assert_eq!(parse_size("64G").unwrap(), 64 << 30);
        assert!(parse_size("4x").is_err());
    }

    #[test]
    fn scheme_gen_pattern_names() {
        assert_eq!(parse_scheme("LMB-CXL").unwrap(), IndexPlacement::LmbCxl);
        assert_eq!(parse_scheme("ideal").unwrap(), IndexPlacement::Ideal);
        assert!(parse_scheme("bogus").is_err());
        assert_eq!(parse_gen("gen5").unwrap(), PcieGen::Gen5);
        assert_eq!(parse_pattern("randread").unwrap(), IoPattern::RandRead);
    }

    #[test]
    fn job_overrides() {
        let kv = Kv::parse("bs=8k qd=32 ios=1000 zipf=0.9").unwrap();
        let j = job_from_kv(IoPattern::RandRead, &kv).unwrap();
        assert_eq!(j.block_size, 8192);
        assert_eq!(j.qd, 32);
        assert_eq!(j.total_ios, 1000);
        assert_eq!(j.zipf_theta, Some(0.9));
    }

    #[test]
    fn job_overrides_validated() {
        let kv = Kv::parse("bs=1000").unwrap(); // not a power of two
        assert!(job_from_kv(IoPattern::RandRead, &kv).is_err());
    }
}
