//! Observability plane — one canonical structured event stream plus a
//! single telemetry snapshot for the whole stack.
//!
//! Diagnostics used to be scattered across ad-hoc accessors
//! (`lock_stats()` on the fabric, `tlb_stats()` on the expander,
//! `retries_performed()`/`fault_strikes_at()` on the service — all
//! removed now, their absence pinned by `tests/api_surface.rs`). This
//! module replaces them with two surfaces:
//!
//! - **Events** ([`Event`], [`EventRing`], [`EventSink`]): every
//!   consequential transition — submit, schedule, execute, complete,
//!   retry, fault strike, alloc/free/share at the fabric, crash, join,
//!   failover, quarantine, timeout — is emitted as one typed record
//!   carrying its simulated tick, lane, and identifiers. Events land in
//!   a fixed-capacity ring (drop-oldest, with a dropped-count
//!   watermark) behind a cheap-clone [`EventSink`] handle, so service
//!   workers, fabric shards, and the scenario harness all emit without
//!   introducing a fabric-wide lock. The stream serializes to JSONL in
//!   fixed key order, so two runs under the same seed produce
//!   byte-identical dumps — the stream *is* the replay transcript.
//! - **Telemetry** ([`StatsSnapshot`]): one value aggregating queue
//!   depth, lock/TLB counters, retry and fault-strike totals, and
//!   per-event-kind counts, returned by a single `telemetry()` entry
//!   point on `FmService`/`Cluster`/`ScenarioHarness`.
//!
//! The ring never blocks emitters on readers: `emit` takes only the
//! ring's own mutex (never a counted fabric lock), and the per-kind
//! counters are plain atomics. When no sink is armed, the instrumented
//! paths skip emission entirely — the hot path stays hot.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cxl::expander::MediaTier;
use crate::cxl::fm::LockStats;
use crate::lmb::fault::FaultPoint;
use crate::lmb::queue::{QueueStats, Ticket};
use crate::sim::time::SimTime;

/// Number of event kinds — the width of every per-kind counter array.
pub const EVENT_KINDS: usize = 17;

/// The taxonomy of observable transitions, one discriminant per
/// [`Event`] variant. Order is fixed: it is the index into
/// [`EventCounts::by_kind`] and must never be reshuffled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// A request passed admission into a lane FIFO.
    Submit,
    /// The rotating-quota scheduler popped a request for execution.
    Schedule,
    /// A lane-contiguous group was handed to a host for execution.
    Execute,
    /// A completion was posted (success or terminal error).
    Complete,
    /// A queued request expired past its deadline.
    Timeout,
    /// A transient failure was re-executed by the bounded retry loop.
    Retry,
    /// A seeded fault plan struck an injection point.
    Fault,
    /// The fabric leased an extent to a host.
    Alloc,
    /// The fabric reclaimed an extent.
    Free,
    /// A completed share grant (cross-consumer SAT entry).
    Share,
    /// A host was crashed out of the service (lane cancelled, leases
    /// reclaimed).
    Crash,
    /// A host joined (or re-joined) a service lane.
    Join,
    /// The shared expander was failed or restored.
    Failover,
    /// A poisoned region shard was skipped by placement.
    Quarantine,
    /// The tiering engine moved an extent onto the fast (DRAM) media.
    Promote,
    /// The tiering engine moved an extent onto the slow (PM) media.
    Demote,
    /// A live extent migration attempt began (terminal pair: a
    /// `Promote`/`Demote` on success, a `Fault` at `migrate_abort` on
    /// rollback).
    Migrate,
}

impl EventKind {
    /// Every kind, in counter-index order.
    pub const ALL: [EventKind; EVENT_KINDS] = [
        EventKind::Submit,
        EventKind::Schedule,
        EventKind::Execute,
        EventKind::Complete,
        EventKind::Timeout,
        EventKind::Retry,
        EventKind::Fault,
        EventKind::Alloc,
        EventKind::Free,
        EventKind::Share,
        EventKind::Crash,
        EventKind::Join,
        EventKind::Failover,
        EventKind::Quarantine,
        EventKind::Promote,
        EventKind::Demote,
        EventKind::Migrate,
    ];

    /// Stable wire name (the JSONL `kind` field).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Schedule => "schedule",
            EventKind::Execute => "execute",
            EventKind::Complete => "complete",
            EventKind::Timeout => "timeout",
            EventKind::Retry => "retry",
            EventKind::Fault => "fault",
            EventKind::Alloc => "alloc",
            EventKind::Free => "free",
            EventKind::Share => "share",
            EventKind::Crash => "crash",
            EventKind::Join => "join",
            EventKind::Failover => "failover",
            EventKind::Quarantine => "quarantine",
            EventKind::Promote => "promote",
            EventKind::Demote => "demote",
            EventKind::Migrate => "migrate",
        }
    }

    /// Index into [`EventCounts::by_kind`].
    pub fn index(self) -> usize {
        match self {
            EventKind::Submit => 0,
            EventKind::Schedule => 1,
            EventKind::Execute => 2,
            EventKind::Complete => 3,
            EventKind::Timeout => 4,
            EventKind::Retry => 5,
            EventKind::Fault => 6,
            EventKind::Alloc => 7,
            EventKind::Free => 8,
            EventKind::Share => 9,
            EventKind::Crash => 10,
            EventKind::Join => 11,
            EventKind::Failover => 12,
            EventKind::Quarantine => 13,
            EventKind::Promote => 14,
            EventKind::Demote => 15,
            EventKind::Migrate => 16,
        }
    }
}

/// How a completed submission resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventOutcome {
    /// The request executed successfully.
    Ok,
    /// Terminal `Error::Cancelled` (crashed lane, dead-lane submit, or
    /// crash-between fault).
    Cancelled,
    /// Terminal `Error::TimedOut` (deadline expired in the queue).
    TimedOut,
    /// Any other terminal error (capacity, permanent fabric fault,
    /// eager admission rejection, ...).
    Failed,
}

impl EventOutcome {
    /// Stable wire name (the JSONL `outcome` field).
    pub fn name(self) -> &'static str {
        match self {
            EventOutcome::Ok => "ok",
            EventOutcome::Cancelled => "cancelled",
            EventOutcome::TimedOut => "timed_out",
            EventOutcome::Failed => "failed",
        }
    }
}

/// One observed transition. Every variant carries the simulated tick at
/// which it happened and the lane (host slot) it is attributed to;
/// fabric-side events use the leasing host's id as the lane and the
/// extent's DPA as the `mmid` field (extents have no mmid of their own).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A request passed admission into `lane`'s FIFO.
    Submit { tick: SimTime, lane: usize, ticket: Ticket, tenant: Option<u64> },
    /// The scheduler popped `ticket` from `lane` into the next batch.
    Schedule { tick: SimTime, lane: usize, ticket: Ticket },
    /// A contiguous group of `group` requests for `lane` began
    /// execution.
    Execute { tick: SimTime, lane: usize, group: usize },
    /// A completion was posted. `ticket` is `None` for eager admission
    /// rejections (the request never entered the queue).
    Complete {
        tick: SimTime,
        lane: usize,
        ticket: Option<Ticket>,
        outcome: EventOutcome,
        tenant: Option<u64>,
    },
    /// `ticket` expired past its deadline while queued on `lane`.
    Timeout { tick: SimTime, lane: usize, ticket: Ticket },
    /// `ticket` was re-executed after a transient failure; `attempt`
    /// counts from 2 (the first re-execution).
    Retry { tick: SimTime, lane: usize, ticket: Ticket, attempt: u32 },
    /// A seeded fault plan struck `point` on `lane`.
    Fault { tick: SimTime, lane: usize, point: FaultPoint },
    /// The fabric leased the extent at DPA `mmid` to host `lane`.
    Alloc { tick: SimTime, lane: usize, mmid: u64 },
    /// The fabric reclaimed the extent at DPA `mmid` from host `lane`.
    Free { tick: SimTime, lane: usize, mmid: u64 },
    /// Allocation `mmid` was shared by its owner on `lane`.
    Share { tick: SimTime, lane: usize, mmid: u64 },
    /// Host `lane` was crashed out of the service.
    Crash { tick: SimTime, lane: usize },
    /// A host joined (or re-joined) `lane`.
    Join { tick: SimTime, lane: usize },
    /// The shared expander failed (`restored == false`) or recovered
    /// (`restored == true`). Lane is the initiating host where known,
    /// else 0.
    Failover { tick: SimTime, lane: usize, restored: bool },
    /// Placement skipped poisoned region shard `region` on behalf of
    /// host `lane`.
    Quarantine { tick: SimTime, lane: usize, region: usize },
    /// The extent at virtual DPA `mmid` (owner host `lane`) now resides
    /// on the fast (DRAM) media.
    Promote { tick: SimTime, lane: usize, mmid: u64 },
    /// The extent at virtual DPA `mmid` (owner host `lane`) now resides
    /// on the slow (PM) media.
    Demote { tick: SimTime, lane: usize, mmid: u64 },
    /// A live migration attempt for the extent at virtual DPA `mmid`
    /// began, moving `from` → `to`. Terminates as a `Promote`/`Demote`
    /// on success or a `Fault` at `migrate_abort` on rollback.
    Migrate { tick: SimTime, lane: usize, mmid: u64, from: MediaTier, to: MediaTier },
}

impl Event {
    /// This event's discriminant.
    pub fn kind(&self) -> EventKind {
        match self {
            Event::Submit { .. } => EventKind::Submit,
            Event::Schedule { .. } => EventKind::Schedule,
            Event::Execute { .. } => EventKind::Execute,
            Event::Complete { .. } => EventKind::Complete,
            Event::Timeout { .. } => EventKind::Timeout,
            Event::Retry { .. } => EventKind::Retry,
            Event::Fault { .. } => EventKind::Fault,
            Event::Alloc { .. } => EventKind::Alloc,
            Event::Free { .. } => EventKind::Free,
            Event::Share { .. } => EventKind::Share,
            Event::Crash { .. } => EventKind::Crash,
            Event::Join { .. } => EventKind::Join,
            Event::Failover { .. } => EventKind::Failover,
            Event::Quarantine { .. } => EventKind::Quarantine,
            Event::Promote { .. } => EventKind::Promote,
            Event::Demote { .. } => EventKind::Demote,
            Event::Migrate { .. } => EventKind::Migrate,
        }
    }

    /// Simulated time at which the event was observed.
    pub fn tick(&self) -> SimTime {
        match *self {
            Event::Submit { tick, .. }
            | Event::Schedule { tick, .. }
            | Event::Execute { tick, .. }
            | Event::Complete { tick, .. }
            | Event::Timeout { tick, .. }
            | Event::Retry { tick, .. }
            | Event::Fault { tick, .. }
            | Event::Alloc { tick, .. }
            | Event::Free { tick, .. }
            | Event::Share { tick, .. }
            | Event::Crash { tick, .. }
            | Event::Join { tick, .. }
            | Event::Failover { tick, .. }
            | Event::Quarantine { tick, .. }
            | Event::Promote { tick, .. }
            | Event::Demote { tick, .. }
            | Event::Migrate { tick, .. } => tick,
        }
    }

    /// Lane (host slot) the event is attributed to.
    pub fn lane(&self) -> usize {
        match *self {
            Event::Submit { lane, .. }
            | Event::Schedule { lane, .. }
            | Event::Execute { lane, .. }
            | Event::Complete { lane, .. }
            | Event::Timeout { lane, .. }
            | Event::Retry { lane, .. }
            | Event::Fault { lane, .. }
            | Event::Alloc { lane, .. }
            | Event::Free { lane, .. }
            | Event::Share { lane, .. }
            | Event::Crash { lane, .. }
            | Event::Join { lane, .. }
            | Event::Failover { lane, .. }
            | Event::Quarantine { lane, .. }
            | Event::Promote { lane, .. }
            | Event::Demote { lane, .. }
            | Event::Migrate { lane, .. } => lane,
        }
    }

    /// Ticket, for the variants that carry one.
    pub fn ticket(&self) -> Option<Ticket> {
        match *self {
            Event::Submit { ticket, .. }
            | Event::Schedule { ticket, .. }
            | Event::Timeout { ticket, .. }
            | Event::Retry { ticket, .. } => Some(ticket),
            Event::Complete { ticket, .. } => ticket,
            _ => None,
        }
    }

    /// Completion outcome, for `Complete` events.
    pub fn outcome(&self) -> Option<EventOutcome> {
        match *self {
            Event::Complete { outcome, .. } => Some(outcome),
            _ => None,
        }
    }

    /// Tenant attribution, where a tenant id flowed through the queue.
    pub fn tenant(&self) -> Option<u64> {
        match *self {
            Event::Submit { tenant, .. } | Event::Complete { tenant, .. } => tenant,
            _ => None,
        }
    }

    /// One JSONL record in fixed key order:
    /// `tick_ns, kind, lane, ticket, mmid, tenant, outcome, detail`.
    /// Absent fields serialize as `null` so every line has the same
    /// shape (line-by-line parseable, greppable by key).
    pub fn to_jsonl_line(&self) -> String {
        let mmid = match *self {
            Event::Alloc { mmid, .. }
            | Event::Free { mmid, .. }
            | Event::Share { mmid, .. }
            | Event::Promote { mmid, .. }
            | Event::Demote { mmid, .. }
            | Event::Migrate { mmid, .. } => Some(mmid),
            _ => None,
        };
        let detail = match *self {
            Event::Execute { group, .. } => Some(format!("group={group}")),
            Event::Retry { attempt, .. } => Some(format!("attempt={attempt}")),
            Event::Fault { point, .. } => Some(format!("point={}", point.name())),
            Event::Failover { restored, .. } => Some(format!("restored={restored}")),
            Event::Quarantine { region, .. } => Some(format!("region={region}")),
            Event::Migrate { from, to, .. } => {
                Some(format!("from={} to={}", from.name(), to.name()))
            }
            _ => None,
        };
        let mut line = String::with_capacity(128);
        let _ = write!(
            line,
            "{{\"tick_ns\": {}, \"kind\": \"{}\", \"lane\": {}",
            self.tick().as_ns(),
            self.kind().name(),
            self.lane()
        );
        match self.ticket() {
            Some(t) => {
                let _ = write!(line, ", \"ticket\": {}", t.0);
            }
            None => line.push_str(", \"ticket\": null"),
        }
        match mmid {
            Some(m) => {
                let _ = write!(line, ", \"mmid\": {m}");
            }
            None => line.push_str(", \"mmid\": null"),
        }
        match self.tenant() {
            Some(t) => {
                let _ = write!(line, ", \"tenant\": {t}");
            }
            None => line.push_str(", \"tenant\": null"),
        }
        match self.outcome() {
            Some(o) => {
                let _ = write!(line, ", \"outcome\": \"{}\"", o.name());
            }
            None => line.push_str(", \"outcome\": null"),
        }
        match detail {
            Some(d) => {
                let _ = write!(line, ", \"detail\": \"{d}\"");
            }
            None => line.push_str(", \"detail\": null"),
        }
        line.push('}');
        line
    }
}

/// Per-kind event counters plus the ring's emit/drop watermarks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventCounts {
    /// Total events emitted since the ring was created (or cleared),
    /// including those since evicted by capacity.
    pub emitted: u64,
    /// Events evicted from the ring by capacity pressure. The ring
    /// still holds `emitted - dropped` of the most recent events.
    pub dropped: u64,
    /// Emission count per [`EventKind`], indexed by
    /// [`EventKind::index`].
    pub by_kind: [u64; EVENT_KINDS],
}

impl EventCounts {
    /// Emission count for one kind.
    pub fn of(&self, kind: EventKind) -> u64 {
        self.by_kind[kind.index()]
    }
}

/// One snapshot of every diagnostic the stack exposes, returned by the
/// `telemetry()` entry points. Collapses the formerly scattered
/// accessors (`lock_stats`, `tlb_stats`, `retries_performed`,
/// `fault_strikes_at`, `stats`) into a single value.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Submission-plane counters (depth, posted, cancelled, timed out).
    pub queue: QueueStats,
    /// Transient-failure re-executions performed by the service.
    pub retries: u64,
    /// Total seeded fault strikes across every injection point.
    pub fault_strikes: u64,
    /// Strikes per [`FaultPoint`], indexed by `FaultPoint::ALL` order.
    pub fault_strikes_by_point: [u64; 6],
    /// Fabric lock acquisition/contention counters.
    pub lock: LockStats,
    /// Decoder one-entry TLB hits across the shared expander.
    pub tlb_hits: u64,
    /// Decoder one-entry TLB misses across the shared expander.
    pub tlb_misses: u64,
    /// Event-stream counters (zero when no ring is armed).
    pub events: EventCounts,
}

struct RingInner {
    buf: Mutex<VecDeque<Event>>,
    cap: usize,
    /// Current simulated time, published by the driving loop so
    /// emitters below the service (queue table, fabric) can stamp
    /// events without threading `SimTime` through every call.
    now: AtomicU64,
    emitted: AtomicU64,
    dropped: AtomicU64,
    counts: [AtomicU64; EVENT_KINDS],
}

impl RingInner {
    fn lock_buf(&self) -> std::sync::MutexGuard<'_, VecDeque<Event>> {
        // Observability must survive panics elsewhere: audit through
        // poison rather than propagating it.
        self.buf.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cheap-clone emitter handle onto an [`EventRing`]. Cloning shares the
/// ring; emission takes only the ring's own mutex — never a counted
/// fabric lock — so arming a sink cannot change lock-stat assertions or
/// add fabric-wide contention.
#[derive(Clone)]
pub struct EventSink {
    inner: Arc<RingInner>,
}

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventSink").field("cap", &self.inner.cap).finish()
    }
}

impl EventSink {
    /// Record one event. Drop-oldest on capacity; never blocks on
    /// readers longer than the ring mutex.
    pub fn emit(&self, event: Event) {
        let inner = &*self.inner;
        inner.emitted.fetch_add(1, Ordering::Relaxed);
        inner.counts[event.kind().index()].fetch_add(1, Ordering::Relaxed);
        let mut buf = inner.lock_buf();
        if buf.len() >= inner.cap {
            buf.pop_front();
            inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(event);
    }

    /// Publish the current simulated time for emitters that are not
    /// handed a tick explicitly.
    pub fn set_now(&self, now: SimTime) {
        self.inner.now.store(now.as_ns(), Ordering::Relaxed);
    }

    /// The last published simulated time.
    pub fn now(&self) -> SimTime {
        SimTime(self.inner.now.load(Ordering::Relaxed))
    }
}

/// Fixed-capacity in-memory event log. Create one, hand [`sink`]
/// (cheap-clone) handles to the emitting layers, then [`snapshot`] /
/// [`to_jsonl`] / [`dump_jsonl`] the stream after the run.
///
/// [`sink`]: EventRing::sink
/// [`snapshot`]: EventRing::snapshot
/// [`to_jsonl`]: EventRing::to_jsonl
/// [`dump_jsonl`]: EventRing::dump_jsonl
#[derive(Clone)]
pub struct EventRing {
    inner: Arc<RingInner>,
}

impl std::fmt::Debug for EventRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventRing")
            .field("cap", &self.inner.cap)
            .field("len", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl EventRing {
    /// A ring holding at most `capacity` events (minimum 1); older
    /// events are evicted and counted in the dropped watermark.
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        EventRing {
            inner: Arc::new(RingInner {
                buf: Mutex::new(VecDeque::with_capacity(cap.min(1 << 16))),
                cap,
                now: AtomicU64::new(0),
                emitted: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                counts: Default::default(),
            }),
        }
    }

    /// A cheap-clone emitter handle sharing this ring.
    pub fn sink(&self) -> EventSink {
        EventSink { inner: Arc::clone(&self.inner) }
    }

    /// Events currently retained, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        self.inner.lock_buf().iter().copied().collect()
    }

    /// Number of events currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock_buf().len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by capacity pressure since creation/clear.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Emit/drop watermarks and per-kind counters.
    pub fn counts(&self) -> EventCounts {
        let inner = &*self.inner;
        let mut by_kind = [0u64; EVENT_KINDS];
        for (slot, counter) in by_kind.iter_mut().zip(inner.counts.iter()) {
            *slot = counter.load(Ordering::Relaxed);
        }
        EventCounts {
            emitted: inner.emitted.load(Ordering::Relaxed),
            dropped: inner.dropped.load(Ordering::Relaxed),
            by_kind,
        }
    }

    /// Drop all retained events and reset every counter, keeping the
    /// sinks armed (handles stay valid).
    pub fn clear(&self) {
        let inner = &*self.inner;
        inner.lock_buf().clear();
        inner.emitted.store(0, Ordering::Relaxed);
        inner.dropped.store(0, Ordering::Relaxed);
        for counter in &inner.counts {
            counter.store(0, Ordering::Relaxed);
        }
    }

    /// The retained stream as JSONL (one fixed-key-order object per
    /// line, oldest first). Byte-identical across runs under a pinned
    /// seed on the serial replay path.
    pub fn to_jsonl(&self) -> String {
        let buf = self.inner.lock_buf();
        let mut out = String::with_capacity(buf.len() * 128);
        for ev in buf.iter() {
            out.push_str(&ev.to_jsonl_line());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL stream to `path` (see `LMB_EVENT_LOG`).
    pub fn dump_jsonl(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn ev(ns: u64, lane: usize) -> Event {
        Event::Submit { tick: SimTime(ns), lane, ticket: Ticket(ns), tenant: None }
    }

    #[test]
    fn kind_index_is_all_order_and_names_unique() {
        let mut names = std::collections::BTreeSet::new();
        for (i, k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{:?} out of ALL order", k);
            assert!(names.insert(k.name()), "duplicate wire name {}", k.name());
        }
        assert_eq!(names.len(), EVENT_KINDS);
    }

    #[test]
    fn capacity_wrap_drops_oldest_and_counts() {
        let ring = EventRing::new(4);
        let sink = ring.sink();
        for i in 0..10u64 {
            sink.emit(ev(i, 0));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let kept = ring.snapshot();
        assert_eq!(
            kept.iter().map(|e| e.tick().as_ns()).collect::<Vec<_>>(),
            vec![6, 7, 8, 9],
            "oldest events must be the ones evicted"
        );
        let counts = ring.counts();
        assert_eq!(counts.emitted, 10);
        assert_eq!(counts.dropped, 6);
        assert_eq!(counts.of(EventKind::Submit), 10);
        assert_eq!(counts.of(EventKind::Fault), 0);
    }

    #[test]
    fn concurrent_emit_conserves_events() {
        const THREADS: usize = 8;
        const PER_THREAD: usize = 500;
        let ring = EventRing::new(THREADS * PER_THREAD / 4);
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let sink = ring.sink();
                scope.spawn(move || {
                    for _ in 0..PER_THREAD {
                        let n = NEXT.fetch_add(1, Ordering::Relaxed) as u64;
                        sink.emit(ev(n, t));
                    }
                });
            }
        });
        let counts = ring.counts();
        assert_eq!(counts.emitted, (THREADS * PER_THREAD) as u64);
        // retained + watermark accounts for every emission — nothing
        // lost beyond what the drop counter admits to
        assert_eq!(ring.len() as u64 + counts.dropped, counts.emitted);
        assert_eq!(ring.len(), THREADS * PER_THREAD / 4, "ring must sit at capacity");
    }

    #[test]
    fn jsonl_lines_have_fixed_shape() {
        let ring = EventRing::new(16);
        let sink = ring.sink();
        sink.emit(Event::Submit { tick: SimTime(5), lane: 1, ticket: Ticket(7), tenant: Some(42) });
        sink.emit(Event::Complete {
            tick: SimTime(9),
            lane: 1,
            ticket: Some(Ticket(7)),
            outcome: EventOutcome::Ok,
            tenant: Some(42),
        });
        sink.emit(Event::Fault { tick: SimTime(9), lane: 0, point: FaultPoint::ExpanderNak });
        sink.emit(Event::Failover { tick: SimTime(10), lane: 0, restored: false });
        sink.emit(Event::Migrate {
            tick: SimTime(11),
            lane: 2,
            mmid: 0x1000_0000,
            from: MediaTier::Pm,
            to: MediaTier::Dram,
        });
        sink.emit(Event::Promote { tick: SimTime(12), lane: 2, mmid: 0x1000_0000 });
        let dump = ring.to_jsonl();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 6);
        assert_eq!(
            lines[0],
            "{\"tick_ns\": 5, \"kind\": \"submit\", \"lane\": 1, \"ticket\": 7, \
             \"mmid\": null, \"tenant\": 42, \"outcome\": null, \"detail\": null}"
        );
        assert_eq!(
            lines[1],
            "{\"tick_ns\": 9, \"kind\": \"complete\", \"lane\": 1, \"ticket\": 7, \
             \"mmid\": null, \"tenant\": 42, \"outcome\": \"ok\", \"detail\": null}"
        );
        assert!(lines[2].contains("\"kind\": \"fault\""));
        assert!(lines[2].contains("\"detail\": \"point=expander_nak\""));
        assert!(lines[3].contains("\"detail\": \"restored=false\""));
        assert_eq!(
            lines[4],
            "{\"tick_ns\": 11, \"kind\": \"migrate\", \"lane\": 2, \"ticket\": null, \
             \"mmid\": 268435456, \"tenant\": null, \"outcome\": null, \
             \"detail\": \"from=pm to=dram\"}"
        );
        assert!(lines[5].contains("\"kind\": \"promote\""));
        assert!(lines[5].contains("\"mmid\": 268435456"));
        for line in lines {
            assert!(line.starts_with("{\"tick_ns\": "), "fixed key order broken: {line}");
            assert!(line.ends_with('}'));
        }
    }

    #[test]
    fn clear_resets_but_keeps_sinks_armed() {
        let ring = EventRing::new(8);
        let sink = ring.sink();
        sink.emit(ev(1, 0));
        ring.clear();
        assert!(ring.is_empty());
        assert_eq!(ring.counts(), EventCounts::default());
        sink.emit(ev(2, 0));
        assert_eq!(ring.len(), 1, "old sink must still reach the cleared ring");
    }

    #[test]
    fn sink_publishes_now() {
        let ring = EventRing::new(2);
        let sink = ring.sink();
        assert_eq!(sink.now(), SimTime(0));
        sink.set_now(SimTime::us(3));
        assert_eq!(ring.sink().now(), SimTime::us(3));
    }
}
