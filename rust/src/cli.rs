//! Minimal command-line parsing (clap is unavailable offline).
//!
//! Grammar: `lmb <command> [--flag=value | --flag] [positional...]`.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut args = Args::default();
        for tok in argv {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    return Err(Error::Config("empty flag '--'".into()));
                }
                match rest.split_once('=') {
                    Some((k, v)) => {
                        args.flags.insert(k.to_string(), v.to_string());
                    }
                    None => {
                        args.flags.insert(rest.to_string(), "true".to_string());
                    }
                }
            } else if args.command.is_empty() {
                args.command = tok;
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// From the process environment.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => crate::config::parse_size(v),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad float for --{name}: '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_positional() {
        let a = parse("fig6 --gen=gen5 --native trace.txt");
        assert_eq!(a.command, "fig6");
        assert_eq!(a.flag("gen"), Some("gen5"));
        assert!(a.has("native"));
        assert_eq!(a.positional, vec!["trace.txt"]);
    }

    #[test]
    fn bare_flag_is_true() {
        let a = parse("run --verbose");
        assert_eq!(a.flag("verbose"), Some("true"));
    }

    #[test]
    fn numeric_flags_with_suffixes() {
        let a = parse("run --span=64G --qd=32 --theta=0.99");
        assert_eq!(a.flag_u64("span", 0).unwrap(), 64 << 30);
        assert_eq!(a.flag_u64("qd", 64).unwrap(), 32);
        assert_eq!(a.flag_u64("missing", 7).unwrap(), 7);
        assert!((a.flag_f64("theta", 0.0).unwrap() - 0.99).abs() < 1e-9);
    }

    #[test]
    fn empty_flag_rejected() {
        assert!(Args::parse(["--".to_string()]).is_err());
    }
}
