//! Scenario engine: replay declarative million-tenant workloads
//! against the real fabric.
//!
//! A *scenario* is data, not code: a small TOML-subset descriptor
//! (committed under `scenarios/` at the repository root) naming a
//! topology, a tenant population, an arrival process, fault injections
//! and hard completion-count floors. The harness loads the descriptor,
//! builds a real [`Cluster`](crate::cluster::Cluster), converts it to
//! the actor-side [`FmService`](crate::lmb::FmService)
//! ([`Cluster::into_service`](crate::cluster::Cluster::into_service)),
//! and drives it tick-by-tick from the deterministic
//! [`Engine`](crate::sim::engine::Engine) — every allocation, free and
//! share executes through the same `FmService` code path production
//! callers use; nothing is mocked.
//!
//! Pipeline:
//!
//! 1. [`descriptor`] — zero-dependency parser for the descriptor text.
//! 2. [`spec`] — schema validation into a typed [`ScenarioSpec`].
//! 3. [`harness`] — the replay: simulated-time arrivals multiplexing a
//!    Zipf-skewed tenant population over the service's lanes, faults
//!    (host crash, host join, expander outage) injected mid-stream.
//! 4. [`report`] — per-scenario and per-tenant latency percentiles,
//!    emitted as `BENCH_scenarios.json` through the bench JSON writer.
//!
//! # Determinism contract
//!
//! One seed, one history. Arrival *times* are fixed by the descriptor
//! (never RNG-sampled), so fault windows hit the same arrival count at
//! every scale; the RNG (a per-scenario [`Pcg64`] stream keyed by
//! seed + name hash) only picks tenants and op kinds. Every iteration
//! that feeds the report is over sorted containers. The result: the
//! same descriptor and seed produce a byte-identical report — the
//! `scenario_suite` integration test enforces this.
//!
//! # Environment hooks
//!
//! * `LMB_SCENARIO_SEED` — overrides every descriptor's seed (decimal
//!   or `0x`-hex, like `LMB_PROP_SEED`). CI pins it so a red scenario
//!   run reproduces locally; a set-but-unparseable value panics.
//! * `LMB_SCENARIO_SCALE` — divides tenant and op counts (clamped to
//!   floors of 64 tenants / 500 ops), so CI replays every committed
//!   scenario in seconds while local runs keep the full 10^5–10^6
//!   tenant populations.
//! * `LMB_FAULT_POINT` — arms one deterministic
//!   [`FaultPoint`](crate::lmb::FaultPoint) (by name: `intake_drop`,
//!   `mid_group_panic`, `expander_nak`, `slow_region`, `crash_between`,
//!   `migrate_abort`)
//!   on every scenario's service, overriding any `[fault_plan]` section.
//!   CI's fault-matrix job iterates this over every point. Completion
//!   *floors* in `[expect]` are suspended under the override (the fault
//!   changes the ok/failed/cancelled mix by design); conservation and
//!   invariant checks still apply in full.
//! * `LMB_FAULT_RATE_PPM` — per-opportunity strike rate for the armed
//!   point, parts-per-million (default 20000). Only read when
//!   `LMB_FAULT_POINT` is set.
//! * `LMB_EVENT_LOG` — a file path: after every harness run the
//!   retained canonical event stream is dumped there as JSONL (one
//!   fixed-key-order object per line — see the crate-level
//!   "Observability plane" section). Byte-identical across runs under
//!   a pinned seed; CI's observability job diffs two dumps to prove it.
//!
//! # Adding a scenario
//!
//! Drop a `.toml` descriptor in `scenarios/` (see that directory's
//! existing files for the schema: root keys for topology and mix, an
//! `[arrival]` table, optional `[[faults]]` entries, an `[expect]`
//! table of completion floors). The committed-suite test and the
//! `scenarios` bench target pick it up automatically — no code change.

pub mod descriptor;
pub mod harness;
pub mod report;
pub mod spec;
pub mod tenant;

pub use descriptor::{Descriptor, Table, Value};
pub use harness::ScenarioHarness;
pub use report::{write_scenarios_json, ScenarioReport};
pub use spec::{Arrival, Expectations, FaultEvent, FaultKind, FaultPlanSpec, ScenarioSpec};
pub use tenant::{AllocRec, TenantBook, TenantLatency};

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
#[allow(unused_imports)] // rustdoc link target
use crate::sim::rng::Pcg64;

/// Seed override for every scenario: the `LMB_SCENARIO_SEED`
/// environment variable when set (decimal, or hex with an `0x` prefix,
/// underscores allowed — the same grammar as `LMB_PROP_SEED`), else
/// `None` (each descriptor's own seed applies). A set-but-unparseable
/// value panics rather than silently replaying a different history
/// than the one CI pinned.
pub fn seed_override() -> Option<u64> {
    match std::env::var("LMB_SCENARIO_SEED") {
        Err(_) => None,
        Ok(v) => match parse_seed(Some(&v)) {
            Some(seed) => Some(seed),
            None => panic!("LMB_SCENARIO_SEED {v:?} is not a decimal or 0x-prefixed hex u64"),
        },
    }
}

/// Parsing behind [`seed_override`], split out so tests never mutate
/// the process environment (`set_var` racing a concurrent `getenv` is
/// UB on glibc under the parallel test harness).
fn parse_seed(var: Option<&str>) -> Option<u64> {
    let v = var?.trim();
    let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(&hex.replace('_', ""), 16),
        None => v.parse::<u64>(),
    };
    parsed.ok()
}

/// Tenant/op divisor: the `LMB_SCENARIO_SCALE` environment variable
/// when set (a positive decimal), else 1 (full scale). Panics on a
/// set-but-unparseable or zero value.
pub fn scale() -> u64 {
    match std::env::var("LMB_SCENARIO_SCALE") {
        Err(_) => 1,
        Ok(v) => match parse_scale(Some(&v)) {
            Some(s) => s,
            None => panic!("LMB_SCENARIO_SCALE {v:?} is not a positive decimal u64"),
        },
    }
}

/// Parsing behind [`scale`] (same no-`set_var` rationale as
/// [`parse_seed`]).
fn parse_scale(var: Option<&str>) -> Option<u64> {
    var?.trim().parse::<u64>().ok().filter(|&s| s > 0)
}

/// Fault-point override for every scenario: `LMB_FAULT_POINT` (a
/// [`FaultPoint`](crate::lmb::FaultPoint) name) plus
/// `LMB_FAULT_RATE_PPM` (default 20000) as a [`FaultPlanSpec`]. CI's
/// fault-matrix job sets these to force each declared fault point
/// through the whole committed suite. Panics on a set-but-invalid
/// value — a typo must not silently run the fault-free suite.
pub fn fault_point_override() -> Option<FaultPlanSpec> {
    let point = match std::env::var("LMB_FAULT_POINT") {
        Err(_) => return None,
        Ok(v) => match parse_fault_point(Some(&v)) {
            Some(p) => p,
            None => panic!("LMB_FAULT_POINT {v:?} is not a known fault point name"),
        },
    };
    let rate_ppm = match std::env::var("LMB_FAULT_RATE_PPM") {
        Err(_) => 20_000,
        Ok(v) => match parse_fault_rate(Some(&v)) {
            Some(r) => r,
            None => panic!("LMB_FAULT_RATE_PPM {v:?} is not in 1..=1_000_000"),
        },
    };
    Some(FaultPlanSpec { point, rate_ppm, crash_budget: 1 })
}

/// Parsing behind [`fault_point_override`] (same no-`set_var` rationale
/// as [`parse_seed`]).
fn parse_fault_point(var: Option<&str>) -> Option<crate::lmb::FaultPoint> {
    crate::lmb::FaultPoint::from_name(var?.trim()).ok()
}

/// Rate parsing behind [`fault_point_override`].
fn parse_fault_rate(var: Option<&str>) -> Option<u32> {
    var?.trim().parse::<u32>().ok().filter(|&r| (1..=1_000_000).contains(&r))
}

/// Event-dump path: the `LMB_EVENT_LOG` environment variable when set
/// (any non-empty path), else `None`. When set, every
/// [`ScenarioHarness`] run finishes by dumping its retained canonical
/// event stream there as JSONL.
pub fn event_log_path() -> Option<PathBuf> {
    match std::env::var("LMB_EVENT_LOG") {
        Err(_) => None,
        Ok(v) => parse_event_log(Some(&v)),
    }
}

/// Parsing behind [`event_log_path`] (same no-`set_var` rationale as
/// [`parse_seed`]).
fn parse_event_log(var: Option<&str>) -> Option<PathBuf> {
    let v = var?.trim();
    if v.is_empty() {
        None
    } else {
        Some(PathBuf::from(v))
    }
}

/// FNV-1a hash of a scenario name: the RNG *stream* id, so two
/// scenarios sharing one pinned seed still draw independent tenant
/// sequences (PCG streams are independent per increment).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The committed scenario directory (`scenarios/` at the repository
/// root, next to the crate).
pub fn committed_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

/// Every committed descriptor, sorted by file name (deterministic
/// replay and report order).
pub fn committed_scenarios() -> Result<Vec<PathBuf>> {
    let dir = committed_dir();
    let mut out = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .map_err(|e| Error::Config(format!("scenario dir {}: {e}", dir.display())))?
    {
        let path = entry?.path();
        if path.extension().is_some_and(|x| x == "toml") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// Load a descriptor and apply the environment hooks: the
/// [`seed_override`] (if any) replaces the descriptor seed, then
/// [`scale`] divides the tenant/op counts.
pub fn load_effective(path: &Path) -> Result<ScenarioSpec> {
    let mut spec = ScenarioSpec::load(path)?;
    if let Some(seed) = seed_override() {
        spec.seed = seed;
    }
    Ok(spec.scaled(scale()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_seed_parsing_mirrors_prop() {
        assert_eq!(parse_seed(None), None);
        assert_eq!(parse_seed(Some("42")), Some(42));
        assert_eq!(parse_seed(Some(" 0xdead_beef ")), Some(0xdead_beef));
        assert_eq!(parse_seed(Some("0Xff")), Some(0xff));
        assert_eq!(parse_seed(Some("junk")), None);
        assert_eq!(parse_seed(Some("-3")), None);
    }

    #[test]
    fn scenario_scale_parsing() {
        assert_eq!(parse_scale(None), None);
        assert_eq!(parse_scale(Some("10")), Some(10));
        assert_eq!(parse_scale(Some(" 1 ")), Some(1));
        assert_eq!(parse_scale(Some("0")), None, "zero would divide everything away");
        assert_eq!(parse_scale(Some("ten")), None);
    }

    #[test]
    fn scenario_fault_point_parsing() {
        use crate::lmb::FaultPoint;
        assert_eq!(parse_fault_point(None), None);
        assert_eq!(parse_fault_point(Some(" expander_nak ")), Some(FaultPoint::ExpanderNak));
        assert_eq!(parse_fault_point(Some("crash_between")), Some(FaultPoint::CrashBetween));
        assert_eq!(parse_fault_point(Some("gremlins")), None);
        assert_eq!(parse_fault_rate(None), None);
        assert_eq!(parse_fault_rate(Some("20000")), Some(20_000));
        assert_eq!(parse_fault_rate(Some("0")), None, "zero rate never strikes");
        assert_eq!(parse_fault_rate(Some("1000001")), None, "over unity");
        assert_eq!(parse_fault_rate(Some("lots")), None);
    }

    #[test]
    fn scenario_event_log_parsing() {
        assert_eq!(parse_event_log(None), None);
        assert_eq!(parse_event_log(Some("")), None, "empty disables the dump");
        assert_eq!(parse_event_log(Some("  ")), None);
        assert_eq!(parse_event_log(Some("/tmp/events.jsonl")), Some("/tmp/events.jsonl".into()));
        assert_eq!(parse_event_log(Some(" out.jsonl ")), Some("out.jsonl".into()));
    }

    #[test]
    fn scenario_fnv_distinguishes_names() {
        assert_ne!(fnv1a("steady_zipf"), fnv1a("burst_storm"));
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325, "FNV-1a offset basis");
        assert_eq!(fnv1a("a"), fnv1a("a"), "pure function");
    }

    #[test]
    fn scenario_committed_directory_exists_and_lists_sorted() {
        let files = committed_scenarios().unwrap();
        assert!(files.len() >= 5, "at least five committed scenarios, got {}", files.len());
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }
}
