//! Scenario descriptors: a zero-dependency TOML-subset parser.
//!
//! Scenarios are **data, not code** — the committed `scenarios/*.toml`
//! files at the repository root are the only inputs the replay harness
//! takes, so adding a workload never means recompiling. The crate is
//! deliberately dependency-free (no crates.io registry in the offline
//! toolchain image), so the subset is hand-rolled here. Supported
//! grammar, one directive per line:
//!
//! ```toml
//! # comment                      (blank lines ignored)
//! key = "string"                 # \" and \\ escapes
//! key = 42                       # unsigned integer; 0x-hex and _ ok
//! key = 0.99                     # float
//! key = true                     # booleans
//! [section]                      # named table (one level)
//! [[events]]                     # array-of-tables: appends an entry
//! ```
//!
//! Everything else — nested tables, inline arrays, dotted keys,
//! datetimes, multi-line strings — is rejected with a line-numbered
//! [`Error::Config`], as are duplicate keys and redefined sections:
//! descriptors are small and hand-written, so a loud parse failure
//! beats a silently-ignored typo. Schema validation (which keys are
//! allowed where) lives in [`super::spec`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    /// Unsigned — the schema has no negative quantities, and `u64`
    /// keeps 64-bit seeds exact (an `i64` would truncate them).
    Int(u64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
        }
    }
}

/// One flat `key = value` table (the root, a `[section]`, or one
/// `[[entry]]` of an array-of-tables).
#[derive(Debug, Clone, Default)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Required string.
    pub fn str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            Some(v) => Err(bad_type(key, "string", v)),
            None => Err(missing(key, "string")),
        }
    }

    /// Required unsigned integer.
    pub fn u64(&self, key: &str) -> Result<u64> {
        match self.get(key) {
            Some(Value::Int(n)) => Ok(*n),
            Some(v) => Err(bad_type(key, "integer", v)),
            None => Err(missing(key, "integer")),
        }
    }

    /// Optional unsigned integer with a default.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(Value::Int(n)) => Ok(*n),
            Some(v) => Err(bad_type(key, "integer", v)),
            None => Ok(default),
        }
    }

    /// Optional float with a default; integers coerce.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(Value::Float(x)) => Ok(*x),
            Some(Value::Int(n)) => Ok(*n as f64),
            Some(v) => Err(bad_type(key, "float", v)),
            None => Ok(default),
        }
    }

    /// Optional string with a default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str> {
        match self.get(key) {
            Some(Value::Str(s)) => Ok(s),
            Some(v) => Err(bad_type(key, "string", v)),
            None => Ok(default),
        }
    }

    /// Reject keys outside `allowed` — a loud failure for typos like
    /// `zipf_thetta` that TOML-as-data would otherwise silently drop.
    pub fn deny_unknown(&self, ctx: &str, allowed: &[&str]) -> Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(Error::Config(format!(
                    "{ctx}: unknown key `{k}` (allowed: {})",
                    allowed.join(", ")
                )));
            }
        }
        Ok(())
    }
}

fn missing(key: &str, want: &str) -> Error {
    Error::Config(format!("missing key `{key}` ({want})"))
}

fn bad_type(key: &str, want: &str, got: &Value) -> Error {
    Error::Config(format!("key `{key}`: expected {want}, got {}", got.type_name()))
}

/// A whole parsed descriptor: root keys, named `[tables]`, and
/// `[[arrays]]` of tables.
#[derive(Debug, Clone, Default)]
pub struct Descriptor {
    pub root: Table,
    tables: BTreeMap<String, Table>,
    arrays: BTreeMap<String, Vec<Table>>,
}

/// Where `key = value` lines currently land during the parse.
enum Cursor {
    Root,
    Table(String),
    Array(String),
}

impl Descriptor {
    /// Named `[table]`, if present.
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    /// Entries of a `[[name]]` array-of-tables (empty if absent).
    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Names of all `[tables]` present.
    pub fn table_names(&self) -> impl Iterator<Item = &str> {
        self.tables.keys().map(String::as_str)
    }

    /// Names of all `[[arrays]]` present.
    pub fn array_names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(String::as_str)
    }

    /// Parse descriptor text. Errors carry 1-based line numbers.
    pub fn parse(text: &str) -> Result<Descriptor> {
        let mut desc = Descriptor::default();
        let mut cursor = Cursor::Root;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|r| r.strip_suffix("]]")) {
                let name = valid_name(lineno, name)?;
                if desc.tables.contains_key(&name) {
                    return Err(at(lineno, format!("`{name}` is already a [table]")));
                }
                desc.arrays.entry(name.clone()).or_default().push(Table::default());
                cursor = Cursor::Array(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
                let name = valid_name(lineno, name)?;
                if desc.arrays.contains_key(&name) {
                    return Err(at(lineno, format!("`{name}` is already an [[array]]")));
                }
                if desc.tables.contains_key(&name) {
                    return Err(at(lineno, format!("section [{name}] redefined")));
                }
                desc.tables.insert(name.clone(), Table::default());
                cursor = Cursor::Table(name);
            } else if let Some((key, rest)) = line.split_once('=') {
                let key = valid_name(lineno, key.trim())?;
                let value = parse_value(lineno, rest.trim())?;
                let table = match &cursor {
                    Cursor::Root => &mut desc.root,
                    Cursor::Table(name) => desc.tables.get_mut(name).expect("cursor table"),
                    Cursor::Array(name) => desc
                        .arrays
                        .get_mut(name)
                        .and_then(|v| v.last_mut())
                        .expect("cursor array entry"),
                };
                if table.entries.insert(key.clone(), value).is_some() {
                    return Err(at(lineno, format!("duplicate key `{key}`")));
                }
            } else {
                return Err(at(
                    lineno,
                    format!("unparseable line {line:?} (expected `key = value` or `[section]`)"),
                ));
            }
        }
        Ok(desc)
    }

    /// Parse a descriptor file; errors are prefixed with the path.
    pub fn load(path: &Path) -> Result<Descriptor> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Descriptor::parse(&text).map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }
}

fn at(lineno: usize, msg: String) -> Error {
    Error::Config(format!("line {lineno}: {msg}"))
}

/// Strip a trailing `#` comment, respecting `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            _ if escaped => escaped = false,
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn valid_name(lineno: usize, name: &str) -> Result<String> {
    let name = name.trim();
    let ok = !name.is_empty()
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-');
    if !ok {
        return Err(at(lineno, format!("bad key/section name {name:?}")));
    }
    Ok(name.to_string())
}

fn parse_value(lineno: usize, raw: &str) -> Result<Value> {
    if raw.is_empty() {
        return Err(at(lineno, "missing value after `=`".into()));
    }
    if let Some(rest) = raw.strip_prefix('"') {
        return parse_string(lineno, rest);
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if raw.starts_with('-') {
        return Err(at(lineno, format!("negative value {raw:?} (schema is unsigned)")));
    }
    let digits = raw.replace('_', "");
    if let Some(hex) = digits.strip_prefix("0x").or_else(|| digits.strip_prefix("0X")) {
        return u64::from_str_radix(hex, 16)
            .map(Value::Int)
            .map_err(|_| at(lineno, format!("bad hex integer {raw:?}")));
    }
    if let Ok(n) = digits.parse::<u64>() {
        return Ok(Value::Int(n));
    }
    if let Ok(x) = digits.parse::<f64>() {
        if x.is_finite() {
            return Ok(Value::Float(x));
        }
    }
    Err(at(lineno, format!("unparseable value {raw:?}")))
}

/// Body of a `"..."` string (opening quote already stripped).
fn parse_string(lineno: usize, rest: &str) -> Result<Value> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '\\' => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => return Err(at(lineno, format!("unsupported escape `\\{other}`"))),
                None => return Err(at(lineno, "dangling escape at end of string".into())),
            },
            '"' => {
                let tail: String = chars.collect();
                if !tail.trim().is_empty() {
                    return Err(at(lineno, format!("trailing garbage after string: {tail:?}")));
                }
                return Ok(Value::Str(out));
            }
            c => out.push(c),
        }
    }
    Err(at(lineno, "unterminated string".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a scenario
name = "steady"        # trailing comment
tenants = 100_000
seed = 0xdead_beef
zipf_theta = 0.99
paper = true

[arrival]
kind = "steady"
gap_ns = 2000

[[faults]]
kind = "crash_host"
slot = 1

[[faults]]
kind = "join_host"
"#;

    #[test]
    fn scenario_descriptor_parses_the_subset() {
        let d = Descriptor::parse(SAMPLE).unwrap();
        assert_eq!(d.root.str("name").unwrap(), "steady");
        assert_eq!(d.root.u64("tenants").unwrap(), 100_000);
        assert_eq!(d.root.u64("seed").unwrap(), 0xdead_beef);
        assert!((d.root.f64_or("zipf_theta", 0.0).unwrap() - 0.99).abs() < 1e-12);
        assert_eq!(d.root.get("paper"), Some(&Value::Bool(true)));
        let arrival = d.table("arrival").unwrap();
        assert_eq!(arrival.str("kind").unwrap(), "steady");
        assert_eq!(arrival.u64("gap_ns").unwrap(), 2000);
        let faults = d.array("faults");
        assert_eq!(faults.len(), 2);
        assert_eq!(faults[0].str("kind").unwrap(), "crash_host");
        assert_eq!(faults[0].u64("slot").unwrap(), 1);
        assert_eq!(faults[1].str("kind").unwrap(), "join_host");
        assert!(d.array("nope").is_empty());
    }

    #[test]
    fn scenario_descriptor_strings_escape_and_guard_hashes() {
        let d = Descriptor::parse(r#"msg = "a \"b\" # not a comment \\" "#).unwrap();
        assert_eq!(d.root.str("msg").unwrap(), r#"a "b" # not a comment \"#);
    }

    #[test]
    fn scenario_descriptor_rejects_malformed_lines() {
        for (bad, why) in [
            ("key value", "no equals"),
            ("key = ", "empty value"),
            ("key = \"unterminated", "unterminated string"),
            ("key = \"x\" junk", "trailing garbage"),
            ("key = \"\\q\"", "bad escape"),
            ("key = -5", "negative"),
            ("key = 1.2.3", "bad float"),
            ("key = 0xzz", "bad hex"),
            ("a = 1\na = 2", "duplicate key"),
            ("[t]\nx = 1\n[t]", "section redefined"),
            ("[t]\n[[t]]", "table vs array clash"),
            ("[[t]]\n[t]", "array vs table clash"),
            ("[bad name]", "bad section name"),
            ("k ey = 1", "bad key name"),
            ("= 1", "empty key"),
            ("[unclosed", "unparseable header"),
        ] {
            let err = Descriptor::parse(bad).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{why}: wrong error kind {err:?}");
            assert!(err.to_string().contains("line "), "{why}: no line number in {err}");
        }
    }

    #[test]
    fn scenario_descriptor_typed_accessors_enforce_types() {
        let d = Descriptor::parse("n = 3\ns = \"x\"").unwrap();
        assert!(d.root.str("n").is_err());
        assert!(d.root.u64("s").is_err());
        assert!(d.root.u64("absent").is_err());
        assert_eq!(d.root.u64_or("absent", 7).unwrap(), 7);
        assert_eq!(d.root.f64_or("n", 0.0).unwrap(), 3.0, "ints coerce to float");
        assert_eq!(d.root.str_or("absent", "dflt").unwrap(), "dflt");
        d.root.deny_unknown("root", &["n", "s"]).unwrap();
        assert!(d.root.deny_unknown("root", &["n"]).is_err());
    }
}
