//! Per-tenant bookkeeping for scenario replays.
//!
//! A scenario multiplexes up to 10^6 tenants, but only tenants that
//! actually complete an allocation materialise state here — the Zipf
//! head. The book tracks which live allocations each tenant owns (so
//! churn frees and shares reference real mmids on real lanes) and the
//! per-tenant latency aggregates behind the report's tenant-level
//! percentiles (full per-tenant histograms would be ~88 MB each; a
//! `(count, sum, max)` triple is enough to rank tenants by mean).

use std::collections::BTreeMap;

use crate::cxl::types::MmId;
use crate::sim::stats::LatencyHistogram;
use crate::sim::time::SimTime;

/// One live allocation a tenant owns: enough to route a later free or
/// share at the home lane with the owning device.
#[derive(Debug, Clone, Copy)]
pub struct AllocRec {
    pub mmid: MmId,
    /// Lane (host slot) the allocation executed on — frees/shares must
    /// route here (cross-host routing fails `NotOwner` by design).
    pub lane: usize,
    /// Index into the scenario's device list of the owning consumer.
    pub dev: usize,
}

/// Per-tenant latency aggregate.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantLatency {
    pub ops: u64,
    pub sum_ns: u128,
    pub max_ns: u64,
}

impl TenantLatency {
    pub fn mean_ns(&self) -> u64 {
        if self.ops == 0 {
            0
        } else {
            (self.sum_ns / self.ops as u128) as u64
        }
    }
}

/// Tenant-indexed scenario state. `BTreeMap` keyed by tenant id keeps
/// every iteration order deterministic — the report's tenant-level
/// percentiles must be byte-identical across runs of the same seed.
#[derive(Debug, Default)]
pub struct TenantBook {
    allocs: BTreeMap<u64, Vec<AllocRec>>,
    latency: BTreeMap<u64, TenantLatency>,
    live: usize,
}

impl TenantBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed allocation for `tenant`.
    pub fn record_alloc(&mut self, tenant: u64, rec: AllocRec) {
        self.allocs.entry(tenant).or_default().push(rec);
        self.live += 1;
    }

    /// Whether `tenant` owns any live allocation.
    pub fn has_alloc(&self, tenant: u64) -> bool {
        self.allocs.get(&tenant).is_some_and(|v| !v.is_empty())
    }

    /// `tenant`'s most recent allocation without consuming it — the
    /// tiering touch path: heat accrues against an extent the tenant
    /// keeps owning.
    pub fn peek_alloc(&self, tenant: u64) -> Option<AllocRec> {
        self.allocs.get(&tenant).and_then(|v| v.last()).copied()
    }

    /// Pop `tenant`'s most recent allocation (LIFO — deterministic and
    /// cache-friendly for hot tenants). `None` if it owns nothing.
    pub fn pop_alloc(&mut self, tenant: u64) -> Option<AllocRec> {
        let recs = self.allocs.get_mut(&tenant)?;
        let rec = recs.pop();
        if recs.is_empty() {
            self.allocs.remove(&tenant);
        }
        if rec.is_some() {
            self.live -= 1;
        }
        rec
    }

    /// Drop every allocation that lived on `lane` (host crash: the
    /// leases are gone; a later free would dangle). Returns how many
    /// were purged.
    pub fn purge_lane(&mut self, lane: usize) -> usize {
        let mut purged = 0;
        self.allocs.retain(|_, recs| {
            let before = recs.len();
            recs.retain(|r| r.lane != lane);
            purged += before - recs.len();
            !recs.is_empty()
        });
        self.live -= purged;
        purged
    }

    /// Live allocations across every tenant.
    pub fn live_allocs(&self) -> usize {
        self.live
    }

    /// Fold one completed-op latency into `tenant`'s aggregate.
    pub fn record_latency(&mut self, tenant: u64, t: SimTime) {
        let agg = self.latency.entry(tenant).or_default();
        agg.ops += 1;
        agg.sum_ns += t.as_ns() as u128;
        agg.max_ns = agg.max_ns.max(t.as_ns());
    }

    /// Tenants that completed at least one op.
    pub fn distinct_tenants(&self) -> u64 {
        self.latency.len() as u64
    }

    /// Distribution of per-tenant *mean* latency, one sample per tenant
    /// in ascending tenant order (deterministic): the histogram behind
    /// the report's tenant-level p50/p99/p999 — "how slow is the
    /// typical tenant's experience", which a global op histogram hides
    /// when one hot tenant dominates the sample count.
    pub fn tenant_mean_histogram(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::new();
        for agg in self.latency.values() {
            h.record(SimTime::ns(agg.mean_ns()));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(lane: usize) -> AllocRec {
        AllocRec { mmid: MmId(7), lane, dev: 0 }
    }

    #[test]
    fn scenario_book_alloc_lifecycle() {
        let mut b = TenantBook::new();
        assert!(!b.has_alloc(3));
        assert!(b.pop_alloc(3).is_none());
        b.record_alloc(3, AllocRec { mmid: MmId(1), lane: 0, dev: 0 });
        b.record_alloc(3, AllocRec { mmid: MmId(2), lane: 1, dev: 1 });
        assert_eq!(b.live_allocs(), 2);
        assert_eq!(b.peek_alloc(3).unwrap().mmid, MmId(2), "peek does not consume");
        assert_eq!(b.live_allocs(), 2);
        let top = b.pop_alloc(3).unwrap();
        assert_eq!(top.mmid, MmId(2), "LIFO pop");
        assert!(b.has_alloc(3));
        assert_eq!(b.pop_alloc(3).unwrap().mmid, MmId(1));
        assert!(!b.has_alloc(3));
        assert_eq!(b.live_allocs(), 0);
    }

    #[test]
    fn scenario_book_purges_a_crashed_lane() {
        let mut b = TenantBook::new();
        b.record_alloc(1, rec(0));
        b.record_alloc(1, rec(1));
        b.record_alloc(2, rec(1));
        assert_eq!(b.purge_lane(1), 2);
        assert_eq!(b.live_allocs(), 1);
        assert!(b.has_alloc(1), "tenant 1 keeps its lane-0 allocation");
        assert!(!b.has_alloc(2), "tenant 2 lost everything with the lane");
        assert_eq!(b.purge_lane(1), 0, "idempotent");
    }

    #[test]
    fn scenario_book_tenant_latency_aggregates() {
        let mut b = TenantBook::new();
        b.record_latency(5, SimTime::us(10));
        b.record_latency(5, SimTime::us(30));
        b.record_latency(9, SimTime::us(100));
        assert_eq!(b.distinct_tenants(), 2);
        let h = b.tenant_mean_histogram();
        assert_eq!(h.count(), 2, "one sample per tenant");
        // tenant 5's mean is 20us, tenant 9's is 100us
        assert!(h.min() <= SimTime::us(20) && h.min() >= SimTime::us(19));
        assert_eq!(h.max(), SimTime::us(100));
    }
}
