//! Scenario reports: per-scenario and per-tenant percentile summaries,
//! serialised as `BENCH_scenarios.json`.
//!
//! Every field is a *simulated* quantity (counts, engine time,
//! histogram quantiles) — no wall clock, no host state — so the same
//! descriptor and seed serialise to byte-identical JSON. That property
//! is load-bearing: the determinism test diffs two whole report files.
//! The array framing comes from the bench JSON writer
//! ([`bench::write_json_rows`]), so the CI validators parse scenario
//! records with the same code path as the perf records.

use std::path::Path;

use crate::sim::stats::LatencyHistogram;
use crate::sim::time::SimTime;
use crate::testing::bench;

/// Everything a scenario replay measured.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    /// Effective seed (after any `LMB_SCENARIO_SEED` override).
    pub seed: u64,
    /// Hosts at build time (faults may change the live count mid-run).
    pub hosts: usize,
    /// Effective tenant population (after any `LMB_SCENARIO_SCALE`).
    pub tenants: u64,
    /// Tenants that completed at least one op (the materialised head).
    pub distinct_tenants: u64,
    pub submitted: u64,
    pub ok: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Of `failed`: capacity exhaustion (FM or module allocator).
    pub failed_capacity: u64,
    /// Of `failed`: expander offline.
    pub failed_expander: u64,
    /// Simulated time at the last event.
    pub sim_duration: SimTime,
    pub op_mean: SimTime,
    pub op_p50: SimTime,
    pub op_p99: SimTime,
    pub op_p999: SimTime,
    pub op_max: SimTime,
    /// Percentiles over per-tenant *mean* latency (one sample per
    /// tenant): the fairness view a hot-tenant-dominated op histogram
    /// hides.
    pub tenant_p50: SimTime,
    pub tenant_p99: SimTime,
    pub tenant_p999: SimTime,
}

impl ScenarioReport {
    /// Submitted ops per simulated second.
    pub fn ops_per_sec(&self) -> f64 {
        let secs = self.sim_duration.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.submitted as f64 / secs
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} ops over {} tenants in {} (ok {} / failed {} / cancelled {}) \
             op p50={} p99={} p999={} | tenant-mean p50={} p99={}",
            self.name,
            self.submitted,
            self.tenants,
            self.sim_duration,
            self.ok,
            self.failed,
            self.cancelled,
            self.op_p50,
            self.op_p99,
            self.op_p999,
            self.tenant_p50,
            self.tenant_p99,
        )
    }

    /// One JSON object. Deterministic: fixed key order, integer
    /// nanoseconds for every latency, one fixed-precision float.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"name\": \"{}\", \"seed\": {}, \"hosts\": {}, \"tenants\": {}, ",
                "\"distinct_tenants\": {}, \"submitted\": {}, \"ok\": {}, \"failed\": {}, ",
                "\"cancelled\": {}, \"failed_capacity\": {}, \"failed_expander\": {}, ",
                "\"sim_duration_ns\": {}, \"ops_per_sec\": {:.1}, ",
                "\"op_mean_ns\": {}, \"op_p50_ns\": {}, \"op_p99_ns\": {}, ",
                "\"op_p999_ns\": {}, \"op_max_ns\": {}, ",
                "\"tenant_p50_ns\": {}, \"tenant_p99_ns\": {}, \"tenant_p999_ns\": {}}}"
            ),
            bench::json_escape(&self.name),
            self.seed,
            self.hosts,
            self.tenants,
            self.distinct_tenants,
            self.submitted,
            self.ok,
            self.failed,
            self.cancelled,
            self.failed_capacity,
            self.failed_expander,
            self.sim_duration.as_ns(),
            self.ops_per_sec(),
            self.op_mean.as_ns(),
            self.op_p50.as_ns(),
            self.op_p99.as_ns(),
            self.op_p999.as_ns(),
            self.op_max.as_ns(),
            self.tenant_p50.as_ns(),
            self.tenant_p99.as_ns(),
            self.tenant_p999.as_ns(),
        )
    }
}

/// Write a suite's reports to `path` as one JSON array (e.g.
/// `BENCH_scenarios.json` at the repo root), via the bench writer's
/// array framing.
pub fn write_scenarios_json(path: &Path, reports: &[ScenarioReport]) -> std::io::Result<()> {
    let rows: Vec<String> = reports.iter().map(ScenarioReport::to_json).collect();
    bench::write_json_rows(path, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScenarioReport {
        let mut ops = LatencyHistogram::new();
        let mut tenants = LatencyHistogram::new();
        for i in 1..=100u64 {
            ops.record(SimTime::us(i));
        }
        tenants.record(SimTime::us(50));
        ScenarioReport {
            name: "steady \"zipf\"".into(),
            seed: 7,
            hosts: 4,
            tenants: 1_000_000,
            distinct_tenants: 812,
            submitted: 100,
            ok: 90,
            failed: 6,
            cancelled: 4,
            failed_capacity: 5,
            failed_expander: 1,
            sim_duration: SimTime::ms(10),
            op_mean: ops.mean(),
            op_p50: ops.p50(),
            op_p99: ops.p99(),
            op_p999: ops.p999(),
            op_max: ops.max(),
            tenant_p50: tenants.p50(),
            tenant_p99: tenants.p99(),
            tenant_p999: tenants.p999(),
        }
    }

    #[test]
    fn scenario_report_json_shape() {
        let j = sample().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"name\": \"steady \\\"zipf\\\"\""), "escaped: {j}");
        assert!(j.contains("\"submitted\": 100"));
        assert!(j.contains("\"failed_expander\": 1"));
        assert!(j.contains("\"sim_duration_ns\": 10000000"));
        // 100 ops over 10 simulated ms = 10000 ops/s
        assert!(j.contains("\"ops_per_sec\": 10000.0"), "{j}");
        assert!(j.contains("\"tenant_p50_ns\":"));
    }

    #[test]
    fn scenario_report_json_is_deterministic() {
        assert_eq!(sample().to_json(), sample().to_json());
    }

    #[test]
    fn scenario_report_file_framing_matches_bench_writer() {
        let path = std::env::temp_dir().join("lmb_scenario_report_test.json");
        write_scenarios_json(&path, &[sample(), sample()]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.starts_with("[\n"));
        assert!(body.trim_end().ends_with(']'));
        assert_eq!(body.matches("\"name\"").count(), 2);
    }

    #[test]
    fn scenario_report_zero_duration_guard() {
        let mut r = sample();
        r.sim_duration = SimTime::ZERO;
        assert_eq!(r.ops_per_sec(), 0.0);
        assert!(r.to_json().contains("\"ops_per_sec\": 0.0"));
    }
}
