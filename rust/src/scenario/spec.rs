//! Typed scenario specifications: descriptor → validated [`ScenarioSpec`].
//!
//! The descriptor layer ([`super::descriptor`]) only knows keys and
//! scalars; this layer knows the schema — which keys exist, their
//! defaults, their legal ranges, and the cross-field rules (a share
//! workload needs ≥ 2 devices, a crash fault needs a slot that exists,
//! a trace arrival needs a readable file). Everything is validated
//! here, before a single host is bound, so a bad descriptor fails with
//! one [`Error::Config`] instead of a panic mid-replay.

use std::path::{Path, PathBuf};

use crate::cxl::fabric::PathKind;
use crate::error::{Error, Result};
use crate::lmb::fault::{FaultPlan, FaultPoint};
use crate::lmb::queue::DEFAULT_LANE_QUOTA;
use crate::pcie::link::PcieGen;
use crate::scenario::descriptor::{Descriptor, Table};
use crate::sim::time::SimTime;
use crate::tier::{TierConfig, TierPolicy};

/// How operations arrive in simulated time. Gaps are **fixed** (not
/// RNG-sampled) so fault windows line up with the same arrival count
/// under every seed — the RNG decides *who* arrives and *what* they do,
/// never *when*.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// One op every `gap`.
    Steady { gap: SimTime },
    /// `burst_ops` ops spaced `gap` apart, then `idle`, repeating.
    Bursts { burst_ops: u64, gap: SimTime, idle: SimTime },
    /// Tenants driven by a recorded IO trace (`lpa % tenants` names the
    /// tenant behind each arrival), one op every `gap`.
    Trace { file: PathBuf, gap: SimTime },
}

/// What a fault event does to the running fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Crash the host behind `slot`: queued lane work cancels, leases
    /// reclaim, tenants re-home onto surviving lanes.
    CrashHost { slot: usize },
    /// Bind a fresh host to the fabric behind a new lane.
    JoinHost,
    /// Take the expander offline: every allocation fails until recovery.
    FailExpander,
    /// Bring the expander back.
    RecoverExpander,
}

/// One scheduled fault injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub kind: FaultKind,
}

/// Declarative knob for the deterministic fault-injection layer: which
/// [`FaultPoint`] to arm on the service, at what per-opportunity rate.
/// The plan's RNG seed is the scenario seed, so a descriptor pins the
/// whole faulty run bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlanSpec {
    pub point: FaultPoint,
    /// Strike probability per opportunity, in parts-per-million.
    pub rate_ppm: u32,
    /// Cap on `crash_between` strikes (ignored by the other points).
    pub crash_budget: u32,
}

impl FaultPlanSpec {
    /// Materialize the armed [`FaultPlan`] under `seed`.
    pub fn plan(&self, seed: u64) -> FaultPlan {
        FaultPlan::new(seed).enable(self.point, self.rate_ppm).with_crash_budget(self.crash_budget)
    }
}

/// Declarative knob for the tiering engine (`[tiering]` in the
/// descriptor): arm a [`crate::tier::TierDaemon`] on the service with
/// these parameters, give the expander a PM tier behind its DRAM, and
/// mix `touch_fraction` data-path accesses into the arrival stream so
/// the heat ledger has something to fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TieringSpec {
    /// Fold-and-migrate cadence in simulated time.
    pub epoch: SimTime,
    /// EWMA decay per epoch (`new = decay·prev + (1-decay)·counts`).
    pub decay: f64,
    /// Migration attempts (including aborts) per epoch.
    pub budget: usize,
    /// Probability an arrival touches one of its tenant's live
    /// allocations (a pure data-path access marker) instead of
    /// submitting alloc/free/share work.
    pub touch_fraction: f64,
    /// CXL persistent-memory capacity behind the DRAM tier, in GiB.
    pub pm_gib: u64,
}

impl TieringSpec {
    /// Materialize the daemon configuration (calibrated latency
    /// policy; the epoch/decay/budget come from the descriptor).
    pub fn config(&self) -> TierConfig {
        TierConfig {
            epoch: self.epoch,
            decay: self.decay,
            budget: self.budget,
            policy: TierPolicy::calibrated(),
        }
    }
}

/// Hard minimums asserted after the replay (completion-count floors;
/// the harness always additionally asserts exact conservation and
/// invariants).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Expectations {
    pub min_ok: u64,
    pub min_failed: u64,
    pub min_cancelled: u64,
}

/// A fully validated scenario, ready for
/// [`ScenarioHarness::run`](crate::scenario::harness::ScenarioHarness::run).
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub hosts: usize,
    /// PCIe consumers attached to every host (tenants rotate over them).
    pub devices: usize,
    pub tenants: u64,
    pub ops: u64,
    pub zipf_theta: f64,
    pub alloc_bytes: u64,
    /// Probability an arrival frees one of its tenant's live
    /// allocations (when it has any).
    pub churn: f64,
    /// Probability an arrival shares one of its tenant's live
    /// allocations to a sibling device (when it has any).
    pub share_fraction: f64,
    pub expander_gib: u64,
    pub host_dram_gib: u64,
    pub lane_quota: usize,
    /// Per-lane intake op cap (backpressure). `0` keeps the default
    /// [`QueueLimits`](crate::lmb::queue::QueueLimits) depth.
    pub lane_depth: usize,
    /// Gap between FM service ticks in simulated time.
    pub service_interval: SimTime,
    /// Fabric path whose modeled latency is added to every completed
    /// op's queueing delay.
    pub path: PathKind,
    pub seed: u64,
    pub arrival: Arrival,
    /// Fault injections, sorted by time.
    pub faults: Vec<FaultEvent>,
    /// Optional deterministic fault-point plan armed on the service.
    pub fault_plan: Option<FaultPlanSpec>,
    /// Optional tiering engine (`[tiering]`): PM tier + hotness daemon.
    pub tiering: Option<TieringSpec>,
    pub expect: Expectations,
}

const ROOT_KEYS: &[&str] = &[
    "name",
    "hosts",
    "devices",
    "tenants",
    "ops",
    "zipf_theta",
    "alloc_bytes",
    "churn",
    "share_fraction",
    "expander_gib",
    "host_dram_gib",
    "lane_quota",
    "lane_depth",
    "service_interval_us",
    "path",
    "seed",
];

impl ScenarioSpec {
    /// Load and validate a descriptor file. Trace paths resolve
    /// relative to the descriptor's directory.
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let desc = Descriptor::load(path)?;
        let base = path.parent().unwrap_or_else(|| Path::new("."));
        Self::from_descriptor(&desc, base)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }

    /// Validate a parsed descriptor into a spec.
    pub fn from_descriptor(desc: &Descriptor, base: &Path) -> Result<ScenarioSpec> {
        desc.root.deny_unknown("root", ROOT_KEYS)?;
        for t in desc.table_names() {
            if t != "arrival" && t != "expect" && t != "fault_plan" && t != "tiering" {
                return Err(Error::Config(format!("unknown section [{t}]")));
            }
        }
        for a in desc.array_names() {
            if a != "faults" {
                return Err(Error::Config(format!("unknown array [[{a}]]")));
            }
        }

        let name = desc.root.str("name")?.to_string();
        if name.is_empty() {
            return Err(Error::Config("scenario name must be non-empty".into()));
        }
        let hosts = desc.root.u64_or("hosts", 2)? as usize;
        if hosts == 0 {
            return Err(Error::Config("hosts must be >= 1".into()));
        }
        let devices = desc.root.u64_or("devices", 1)? as usize;
        if devices == 0 || devices > 32 {
            return Err(Error::Config("devices must be in 1..=32".into()));
        }
        let tenants = desc.root.u64_or("tenants", 100_000)?;
        if tenants == 0 {
            return Err(Error::Config("tenants must be >= 1".into()));
        }
        let ops = desc.root.u64_or("ops", 10_000)?;
        if ops == 0 {
            return Err(Error::Config("ops must be >= 1".into()));
        }
        let zipf_theta = desc.root.f64_or("zipf_theta", 0.99)?;
        // theta == 1.0 passes the sampler's half-open range assert but
        // degenerates (alpha = 1/(1-theta) diverges) — exclude the pole
        if !((0.0..1.0).contains(&zipf_theta) || (zipf_theta > 1.0 && zipf_theta < 2.0)) {
            return Err(Error::Config(format!("zipf_theta {zipf_theta} outside [0,1) ∪ (1,2)")));
        }
        let alloc_bytes = desc.root.u64_or("alloc_bytes", 64 * 1024)?;
        if alloc_bytes == 0 {
            return Err(Error::Config("alloc_bytes must be >= 1".into()));
        }
        let churn = desc.root.f64_or("churn", 0.5)?;
        let share_fraction = desc.root.f64_or("share_fraction", 0.0)?;
        for (key, v) in [("churn", churn), ("share_fraction", share_fraction)] {
            if !(0.0..=1.0).contains(&v) {
                return Err(Error::Config(format!("{key} {v} outside [0,1]")));
            }
        }
        if share_fraction > 0.0 && devices < 2 {
            return Err(Error::Config(
                "share_fraction > 0 needs devices >= 2 (a share must have a distinct target)"
                    .into(),
            ));
        }
        let expander_gib = desc.root.u64_or("expander_gib", 8)?;
        let host_dram_gib = desc.root.u64_or("host_dram_gib", 1)?;
        if expander_gib == 0 || host_dram_gib == 0 {
            return Err(Error::Config("expander_gib / host_dram_gib must be >= 1".into()));
        }
        let lane_quota = desc.root.u64_or("lane_quota", DEFAULT_LANE_QUOTA as u64)? as usize;
        if lane_quota == 0 {
            return Err(Error::Config("lane_quota must be >= 1".into()));
        }
        let lane_depth = desc.root.u64_or("lane_depth", 0)? as usize;
        let service_interval = SimTime::us(desc.root.u64_or("service_interval_us", 64)?);
        if service_interval == SimTime::ZERO {
            return Err(Error::Config("service_interval_us must be >= 1".into()));
        }
        let path = parse_path(desc.root.str_or("path", "host_to_hdm")?)?;
        let seed = desc.root.u64_or("seed", crate::scenario::fnv1a(&name))?;

        let arrival = parse_arrival(desc.table("arrival"), base)?;
        let mut faults = Vec::new();
        for (i, t) in desc.array("faults").iter().enumerate() {
            faults.push(
                parse_fault(t, hosts).map_err(|e| Error::Config(format!("faults[{i}]: {e}")))?,
            );
        }
        faults.sort_by_key(|f| f.at);
        let crashes = faults
            .iter()
            .filter(|f| matches!(f.kind, FaultKind::CrashHost { .. }))
            .count();
        if crashes >= hosts {
            return Err(Error::Config(format!(
                "{crashes} crash faults would kill all {hosts} hosts"
            )));
        }
        let mut crashed = std::collections::HashSet::new();
        for f in &faults {
            if let FaultKind::CrashHost { slot } = f.kind {
                if !crashed.insert(slot) {
                    return Err(Error::Config(format!("slot {slot} crashed twice")));
                }
            }
        }

        let fault_plan = parse_fault_plan(desc.table("fault_plan"))?;
        let tiering = parse_tiering(desc.table("tiering"), expander_gib)?;
        let expect = parse_expect(desc.table("expect"))?;

        Ok(ScenarioSpec {
            name,
            hosts,
            devices,
            tenants,
            ops,
            zipf_theta,
            alloc_bytes,
            churn,
            share_fraction,
            expander_gib,
            host_dram_gib,
            lane_quota,
            lane_depth,
            service_interval,
            path,
            seed,
            arrival,
            faults,
            fault_plan,
            tiering,
            expect,
        })
    }

    /// Divide the tenant and op counts by `scale` (clamped so even an
    /// aggressive CI divisor leaves a meaningful run: ≥ 64 tenants,
    /// ≥ 500 ops). Expectation floors are *not* rescaled — committed
    /// descriptors must choose floors that hold at every scale.
    pub fn scaled(mut self, scale: u64) -> Self {
        let scale = scale.max(1);
        self.tenants = (self.tenants / scale).max(64.min(self.tenants));
        self.ops = (self.ops / scale).max(500.min(self.ops));
        self
    }
}

fn parse_path(s: &str) -> Result<PathKind> {
    match s {
        "onboard_dram" => Ok(PathKind::OnboardDram),
        "host_dram" => Ok(PathKind::HostDram),
        "host_to_hdm" => Ok(PathKind::HostToHdm),
        "cxl_p2p" => Ok(PathKind::CxlP2pToHdm),
        "pcie_gen4" => Ok(PathKind::PcieToHdm(PcieGen::Gen4)),
        "pcie_gen5" => Ok(PathKind::PcieToHdm(PcieGen::Gen5)),
        other => Err(Error::Config(format!(
            "unknown path {other:?} (expected onboard_dram, host_dram, host_to_hdm, \
             cxl_p2p, pcie_gen4 or pcie_gen5)"
        ))),
    }
}

fn parse_arrival(table: Option<&Table>, base: &Path) -> Result<Arrival> {
    let Some(t) = table else {
        return Ok(Arrival::Steady { gap: SimTime::us(1) });
    };
    let gap = SimTime::ns(t.u64_or("gap_ns", 1_000)?);
    if gap == SimTime::ZERO {
        return Err(Error::Config("[arrival] gap_ns must be >= 1".into()));
    }
    match t.str_or("kind", "steady")? {
        "steady" => {
            t.deny_unknown("[arrival]", &["kind", "gap_ns"])?;
            Ok(Arrival::Steady { gap })
        }
        "bursts" => {
            t.deny_unknown("[arrival]", &["kind", "gap_ns", "burst_ops", "idle_ns"])?;
            let burst_ops = t.u64_or("burst_ops", 256)?;
            let idle = SimTime::ns(t.u64_or("idle_ns", 20_000)?);
            if burst_ops == 0 {
                return Err(Error::Config("[arrival] burst_ops must be >= 1".into()));
            }
            Ok(Arrival::Bursts { burst_ops, gap, idle })
        }
        "trace" => {
            t.deny_unknown("[arrival]", &["kind", "gap_ns", "file"])?;
            let file = base.join(t.str("file")?);
            if !file.is_file() {
                return Err(Error::Config(format!(
                    "[arrival] trace file {} not found",
                    file.display()
                )));
            }
            Ok(Arrival::Trace { file, gap })
        }
        other => Err(Error::Config(format!(
            "[arrival] unknown kind {other:?} (expected steady, bursts or trace)"
        ))),
    }
}

fn parse_fault(t: &Table, hosts: usize) -> Result<FaultEvent> {
    let at = SimTime::us(t.u64("at_us")?);
    let kind = match t.str("kind")? {
        "crash_host" => {
            t.deny_unknown("fault", &["kind", "at_us", "slot"])?;
            let slot = t.u64("slot")? as usize;
            if slot >= hosts {
                return Err(Error::Config(format!(
                    "crash_host slot {slot} out of range (hosts = {hosts})"
                )));
            }
            FaultKind::CrashHost { slot }
        }
        "join_host" => {
            t.deny_unknown("fault", &["kind", "at_us"])?;
            FaultKind::JoinHost
        }
        "fail_expander" => {
            t.deny_unknown("fault", &["kind", "at_us"])?;
            FaultKind::FailExpander
        }
        "recover_expander" => {
            t.deny_unknown("fault", &["kind", "at_us"])?;
            FaultKind::RecoverExpander
        }
        other => Err(Error::Config(format!(
            "unknown fault kind {other:?} (expected crash_host, join_host, \
             fail_expander or recover_expander)"
        )))?,
    };
    Ok(FaultEvent { at, kind })
}

fn parse_fault_plan(table: Option<&Table>) -> Result<Option<FaultPlanSpec>> {
    let Some(t) = table else {
        return Ok(None);
    };
    t.deny_unknown("[fault_plan]", &["point", "rate_ppm", "crash_budget"])?;
    let point = FaultPoint::from_name(t.str("point")?)
        .map_err(|e| Error::Config(format!("[fault_plan] {e}")))?;
    let rate_ppm = t.u64_or("rate_ppm", 10_000)?;
    if rate_ppm == 0 || rate_ppm > 1_000_000 {
        return Err(Error::Config(format!(
            "[fault_plan] rate_ppm {rate_ppm} outside 1..=1_000_000"
        )));
    }
    let crash_budget = t.u64_or("crash_budget", 1)? as u32;
    Ok(Some(FaultPlanSpec { point, rate_ppm: rate_ppm as u32, crash_budget }))
}

fn parse_tiering(table: Option<&Table>, expander_gib: u64) -> Result<Option<TieringSpec>> {
    let Some(t) = table else {
        return Ok(None);
    };
    t.deny_unknown("[tiering]", &["epoch_us", "decay", "budget", "touch_fraction", "pm_gib"])?;
    let epoch = SimTime::us(t.u64_or("epoch_us", 100)?);
    if epoch == SimTime::ZERO {
        return Err(Error::Config("[tiering] epoch_us must be >= 1".into()));
    }
    let decay = t.f64_or("decay", 0.5)?;
    // decay = 1.0 would never admit new heat — the daemon would plan
    // from the initial all-zero ledger forever
    if !(0.0..1.0).contains(&decay) {
        return Err(Error::Config(format!("[tiering] decay {decay} outside [0,1)")));
    }
    let budget = t.u64_or("budget", 4)? as usize;
    if budget == 0 {
        return Err(Error::Config("[tiering] budget must be >= 1".into()));
    }
    let touch_fraction = t.f64_or("touch_fraction", 0.5)?;
    if !(0.0..=1.0).contains(&touch_fraction) {
        return Err(Error::Config(format!(
            "[tiering] touch_fraction {touch_fraction} outside [0,1]"
        )));
    }
    // default the PM tier to the DRAM capacity: a symmetric two-tier
    // expander, so the daemon always has somewhere to demote
    let pm_gib = t.u64_or("pm_gib", expander_gib)?;
    if pm_gib == 0 {
        return Err(Error::Config("[tiering] pm_gib must be >= 1 (tiering needs two tiers)".into()));
    }
    Ok(Some(TieringSpec { epoch, decay, budget, touch_fraction, pm_gib }))
}

fn parse_expect(table: Option<&Table>) -> Result<Expectations> {
    let Some(t) = table else {
        return Ok(Expectations::default());
    };
    t.deny_unknown("[expect]", &["min_ok", "min_failed", "min_cancelled"])?;
    Ok(Expectations {
        min_ok: t.u64_or("min_ok", 0)?,
        min_failed: t.u64_or("min_failed", 0)?,
        min_cancelled: t.u64_or("min_cancelled", 0)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(extra: &str) -> Result<ScenarioSpec> {
        let text = format!("name = \"t\"\n{extra}");
        let desc = Descriptor::parse(&text)?;
        ScenarioSpec::from_descriptor(&desc, Path::new("."))
    }

    #[test]
    fn scenario_spec_defaults_are_sane() {
        let s = minimal("").unwrap();
        assert_eq!((s.hosts, s.devices), (2, 1));
        assert_eq!((s.tenants, s.ops), (100_000, 10_000));
        assert_eq!(s.arrival, Arrival::Steady { gap: SimTime::us(1) });
        assert_eq!(s.path, PathKind::HostToHdm);
        assert!(s.faults.is_empty());
        assert_eq!((s.lane_depth, s.fault_plan), (0, None), "no backpressure/fault overrides");
        assert_eq!(s.tiering, None, "tiering stays off unless the descriptor asks");
        assert_eq!(s.expect, Expectations::default());
        assert_eq!(s.seed, crate::scenario::fnv1a("t"), "default seed derives from the name");
    }

    #[test]
    fn scenario_spec_full_descriptor_round_trips() {
        let s = minimal(
            "hosts = 4\ndevices = 2\ntenants = 1_000_000\nops = 60_000\n\
             zipf_theta = 0.9\nalloc_bytes = 65536\nchurn = 0.4\nshare_fraction = 0.1\n\
             expander_gib = 8\nhost_dram_gib = 2\nlane_quota = 32\n\
             service_interval_us = 16\npath = \"cxl_p2p\"\nseed = 7\n\
             [arrival]\nkind = \"bursts\"\nburst_ops = 128\ngap_ns = 200\nidle_ns = 5000\n\
             [expect]\nmin_ok = 100\n\
             [[faults]]\nkind = \"fail_expander\"\nat_us = 900\n\
             [[faults]]\nkind = \"crash_host\"\nslot = 1\nat_us = 300\n",
        )
        .unwrap();
        assert_eq!(s.tenants, 1_000_000);
        assert_eq!(s.path, PathKind::CxlP2pToHdm);
        assert_eq!(
            s.arrival,
            Arrival::Bursts { burst_ops: 128, gap: SimTime::ns(200), idle: SimTime::ns(5000) }
        );
        // faults sorted by time regardless of descriptor order
        assert_eq!(
            s.faults[0],
            FaultEvent { at: SimTime::us(300), kind: FaultKind::CrashHost { slot: 1 } }
        );
        assert_eq!(s.faults[1].kind, FaultKind::FailExpander);
        assert_eq!(s.expect.min_ok, 100);
    }

    #[test]
    fn scenario_spec_rejects_bad_descriptors() {
        for (extra, why) in [
            ("hosts = 0", "zero hosts"),
            ("tenants = 0", "zero tenants"),
            ("ops = 0", "zero ops"),
            ("zipf_theta = 1.0", "theta at the pole"),
            ("zipf_theta = 2.5", "theta too large"),
            ("churn = 1.5", "churn out of range"),
            ("share_fraction = 0.5", "share with one device"),
            ("alloc_bytes = 0", "zero alloc"),
            ("lane_quota = 0", "zero quota"),
            ("service_interval_us = 0", "zero interval"),
            ("path = \"warp\"", "unknown path"),
            ("typo_key = 1", "unknown root key"),
            ("[typo_section]\nx = 1", "unknown section"),
            ("[[typo_array]]\nx = 1", "unknown array"),
            ("[arrival]\nkind = \"fractal\"", "unknown arrival"),
            ("[arrival]\ngap_ns = 0", "zero gap"),
            ("[arrival]\nkind = \"trace\"\nfile = \"no/such/file.trace\"", "missing trace file"),
            ("[[faults]]\nkind = \"crash_host\"\nslot = 9\nat_us = 1", "slot out of range"),
            ("[[faults]]\nkind = \"unplug\"\nat_us = 1", "unknown fault"),
            ("[[faults]]\nkind = \"join_host\"", "fault missing at_us"),
            (
                "[[faults]]\nkind = \"crash_host\"\nslot = 0\nat_us = 1\n\
                 [[faults]]\nkind = \"crash_host\"\nslot = 1\nat_us = 2",
                "crashes kill every host",
            ),
            ("[expect]\nmin_oops = 1", "unknown expect key"),
            ("[fault_plan]\nrate_ppm = 10", "fault plan missing point"),
            ("[fault_plan]\npoint = \"gremlins\"", "unknown fault point"),
            ("[fault_plan]\npoint = \"expander_nak\"\nrate_ppm = 0", "zero rate"),
            ("[fault_plan]\npoint = \"expander_nak\"\nrate_ppm = 2_000_000", "rate over unity"),
            ("[fault_plan]\npoint = \"expander_nak\"\nvolume = 11", "unknown fault plan key"),
            ("[tiering]\nepoch_us = 0", "zero tiering epoch"),
            ("[tiering]\ndecay = 1.0", "decay at the no-fold pole"),
            ("[tiering]\nbudget = 0", "zero migration budget"),
            ("[tiering]\ntouch_fraction = 1.5", "touch fraction out of range"),
            ("[tiering]\npm_gib = 0", "single-tier tiering"),
            ("[tiering]\nwarmth = 3", "unknown tiering key"),
        ] {
            let err = minimal(extra).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{why}: {err:?}");
        }
        // double-crash of one slot (with enough hosts to survive)
        let err = minimal(
            "hosts = 4\n\
             [[faults]]\nkind = \"crash_host\"\nslot = 1\nat_us = 1\n\
             [[faults]]\nkind = \"crash_host\"\nslot = 1\nat_us = 2",
        )
        .unwrap_err();
        assert!(err.to_string().contains("crashed twice"), "{err}");
    }

    #[test]
    fn scenario_spec_fault_plan_round_trips() {
        let s = minimal(
            "lane_depth = 32\nseed = 99\n\
             [fault_plan]\npoint = \"crash_between\"\nrate_ppm = 500\ncrash_budget = 2",
        )
        .unwrap();
        assert_eq!(s.lane_depth, 32);
        let fp = s.fault_plan.unwrap();
        assert_eq!(
            fp,
            FaultPlanSpec { point: FaultPoint::CrashBetween, rate_ppm: 500, crash_budget: 2 }
        );
        // materialized plans are seed-deterministic
        let mut a = fp.plan(s.seed);
        let mut b = fp.plan(s.seed);
        for _ in 0..64 {
            assert_eq!(
                a.strike(FaultPoint::CrashBetween),
                b.strike(FaultPoint::CrashBetween)
            );
        }
        // defaults: rate 10_000 ppm, crash budget 1
        let d = minimal("[fault_plan]\npoint = \"intake_drop\"").unwrap().fault_plan.unwrap();
        assert_eq!((d.rate_ppm, d.crash_budget), (10_000, 1));
    }

    #[test]
    fn scenario_spec_tiering_round_trips() {
        let s = minimal(
            "expander_gib = 2\n\
             [tiering]\nepoch_us = 50\ndecay = 0.875\nbudget = 2\n\
             touch_fraction = 0.25\npm_gib = 4",
        )
        .unwrap();
        let t = s.tiering.unwrap();
        assert_eq!(
            t,
            TieringSpec {
                epoch: SimTime::us(50),
                decay: 0.875,
                budget: 2,
                touch_fraction: 0.25,
                pm_gib: 4,
            }
        );
        let cfg = t.config();
        assert_eq!((cfg.epoch, cfg.budget), (SimTime::us(50), 2));
        assert_eq!(cfg.policy, TierPolicy::calibrated(), "latency scalars come calibrated");

        // defaults: epoch 100us, decay 0.5, budget 4, touch 0.5, and a
        // PM tier mirroring the DRAM capacity
        let d = minimal("expander_gib = 2\n[tiering]\nepoch_us = 100").unwrap().tiering.unwrap();
        assert_eq!((d.decay, d.touch_fraction), (0.5, 0.5));
        assert_eq!((d.budget, d.pm_gib), (4, 2));
    }

    #[test]
    fn scenario_spec_scaling_clamps() {
        let s = minimal("tenants = 1_000_000\nops = 60_000").unwrap();
        let s10 = s.clone().scaled(10);
        assert_eq!((s10.tenants, s10.ops), (100_000, 6_000));
        let huge = s.clone().scaled(1_000_000_000);
        assert_eq!((huge.tenants, huge.ops), (64, 500), "clamped floors");
        let tiny = minimal("tenants = 8\nops = 9").unwrap().scaled(1_000);
        assert_eq!((tiny.tenants, tiny.ops), (8, 9), "never clamps above the spec");
        let s1 = s.scaled(0);
        assert_eq!((s1.tenants, s1.ops), (1_000_000, 60_000), "scale 0 behaves as 1");
    }
}
