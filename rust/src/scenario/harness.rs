//! The scenario replay harness: descriptor in, report out, real fabric
//! in between.
//!
//! The harness is the bridge between the DES side of the crate
//! ([`Engine`], simulated time, seeded RNG) and the control-plane side
//! (the [`FmService`] actor executing against the shared
//! [`FabricRef`]). Nothing is mocked: every op is a real
//! [`Request`] submitted through a real [`SubmitHandle`], scheduled by
//! the service's fair rotating quota, executed by the real allocator
//! under the fabric lock, and reaped from the real completion table —
//! the replay just decides *when* (simulated arrivals, service ticks)
//! and *who* (a Zipf-skewed tenant population multiplexed over the
//! lanes).
//!
//! Event loop invariants:
//!
//! * a `Service` event is pending whenever an op is in flight (arrivals
//!   arm it; services re-arm while the inflight set is non-empty), so
//!   every submission is eventually executed and reaped;
//! * arrival gaps are fixed by the spec — the RNG never touches the
//!   clock, so fault times hit the same arrival count on every seed;
//! * completion latency = queueing delay in simulated time (submit →
//!   reap) + the spec's modeled fabric path latency.
//!
//! After the last event the harness **hard-asserts** the run: exact
//! count conservation (`submitted == ok + failed + cancelled`), the
//! spec's completion floors, an empty inflight set, full service +
//! fabric invariant sweeps, and event-stream reconciliation (every
//! accounted op except a phantom all-lanes-dead arrival is explained
//! by exactly one `Complete` event in the canonical stream). A
//! scenario that completes without panicking has really pushed its ops
//! through the fabric.
//!
//! Every run arms the crate's observability plane: the harness owns an
//! [`EventRing`] shared with the service, the fabric and the queue, so
//! [`ScenarioHarness::events`], [`ScenarioHarness::telemetry`] and
//! [`ScenarioHarness::dump_events`] expose the replay's canonical
//! stream and unified counters after the fact. Setting `LMB_EVENT_LOG`
//! to a path dumps the stream as JSONL automatically after each run.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::Mutex;

use crate::cluster::Cluster;
use crate::cxl::fm::FabricRef;
use crate::cxl::types::{Bdf, GIB};
use crate::error::{Error, Result};
use crate::lmb::queue::{
    Completion, Outcome, PlacementPolicy, QueueLimits, Request, SubmitHandle, Ticket,
};
use crate::lmb::{FmService, LmbHost};
use crate::observe::{EventKind, EventRing, StatsSnapshot};
use crate::scenario::report::ScenarioReport;
use crate::scenario::spec::{Arrival, FaultKind, ScenarioSpec};
use crate::scenario::tenant::{AllocRec, TenantBook};
use crate::sim::engine::Engine;
use crate::sim::rng::Pcg64;
use crate::sim::stats::LatencyHistogram;
use crate::sim::time::SimTime;
use crate::workload::tenants::TenantPopulation;
use crate::workload::trace::Trace;

/// Replay events. Arrivals cascade (each schedules the next until the
/// op budget is spent); services re-arm while work is in flight;
/// faults are scheduled up front at their descriptor times.
#[derive(Debug)]
enum Ev {
    Arrival,
    Service,
    Fault(usize),
}

/// One submitted-but-unreaped op.
#[derive(Debug, Clone, Copy)]
struct Pending {
    ticket: Ticket,
    tenant: u64,
    lane: usize,
    dev: usize,
    submitted: SimTime,
}

/// Drives one [`ScenarioSpec`] against a freshly built fabric.
#[derive(Debug)]
pub struct ScenarioHarness {
    spec: ScenarioSpec,
    /// The canonical event stream for the replay: armed on the service
    /// before the first arrival, cleared at the start of every run so
    /// reruns on one harness are byte-identical under one seed.
    ring: EventRing,
    /// Telemetry captured after the last completed run's hard asserts
    /// (the service is consumed by the replay, so the snapshot is
    /// stashed here for post-run inspection).
    last: Mutex<Option<StatsSnapshot>>,
}

impl ScenarioHarness {
    pub fn new(spec: ScenarioSpec) -> Self {
        // ~5 events per op (submit/schedule/execute/complete + fabric),
        // with headroom for retries and faults; capped so a million-op
        // descriptor cannot balloon the ring.
        let cap = (spec.ops as usize).saturating_mul(8).clamp(1024, 1 << 20);
        ScenarioHarness { spec, ring: EventRing::new(cap), last: Mutex::new(None) }
    }

    /// Load a descriptor (with the environment hooks applied) and
    /// replay it.
    pub fn replay_file(path: &std::path::Path) -> Result<ScenarioReport> {
        ScenarioHarness::new(crate::scenario::load_effective(path)?).run()
    }

    /// The event ring the replay emits into. After [`run`](Self::run)
    /// it retains the (capacity-bounded) tail of the run's canonical
    /// stream; [`EventRing::counts`] carries the exact per-kind totals
    /// regardless of eviction.
    pub fn events(&self) -> &EventRing {
        &self.ring
    }

    /// The unified [`StatsSnapshot`] captured at the end of the last
    /// completed run ([`StatsSnapshot::default`] before any run).
    pub fn telemetry(&self) -> StatsSnapshot {
        self.last.lock().expect("telemetry stash poisoned").unwrap_or_default()
    }

    /// Dump the last run's retained event stream as JSONL to `path`
    /// (the `LMB_EVENT_LOG` hook does this automatically after every
    /// run).
    pub fn dump_events(&self, path: &Path) -> Result<()> {
        self.ring.dump_jsonl(path).map_err(|e| {
            Error::Config(format!("event dump to {} failed: {e}", path.display()))
        })
    }

    /// Build the cluster, convert it to the service, and replay the
    /// scenario to completion. Panics (hard assert) if the run violates
    /// count conservation, the spec's floors, or any invariant.
    pub fn run(&self) -> Result<ScenarioReport> {
        let spec = &self.spec;
        let devices: Vec<Bdf> = (0..spec.devices).map(|d| Bdf::new(d as u8 + 1, 0, 0)).collect();

        let mut builder = Cluster::builder()
            .hosts(spec.hosts)
            .expander_gib(spec.expander_gib)
            .host_dram_gib(spec.host_dram_gib)
            .lane_quota(spec.lane_quota);
        if spec.lane_depth > 0 {
            builder = builder
                .queue_limits(QueueLimits { lane_depth: spec.lane_depth, ..QueueLimits::default() });
        }
        if let Some(t) = spec.tiering {
            // a [tiering] descriptor makes the expander two-tier:
            // `expander_gib` stays the fast (device-DRAM) band, the PM
            // band is the tiering table's own knob
            builder = builder.pm_gib(t.pm_gib);
        }
        let mut cluster = builder.build()?;
        for slot in 0..spec.hosts {
            for dev in &devices {
                cluster.host_mut(slot)?.attach_pcie(*dev);
            }
        }
        let (mut svc, fabric, latency) = cluster.into_service()?;
        // Fresh fabric + service per run, one harness-lifetime ring:
        // clear it so the retained stream and counters describe exactly
        // this replay, then arm the queue + fabric sinks through the
        // service.
        self.ring.clear();
        svc.set_event_ring(self.ring.clone());
        if let Some(t) = spec.tiering {
            svc.set_tiering(t.config());
        }

        // The env override (CI's fault matrix) outranks the descriptor's
        // own [fault_plan]; either way the plan RNG is keyed by the
        // scenario seed, so the faulty run is as reproducible as the
        // clean one.
        let env_plan = crate::scenario::fault_point_override();
        let floors_suspended = env_plan.is_some();
        let effective_plan = env_plan.or(spec.fault_plan);
        let plan_armed = effective_plan.is_some();
        if let Some(fp) = effective_plan {
            svc.set_fault_plan(fp.plan(spec.seed));
        }

        let mut handles: Vec<Option<SubmitHandle>> = Vec::with_capacity(spec.hosts);
        for lane in 0..spec.hosts {
            handles.push(Some(svc.handle(lane)?));
        }
        let reaper = handles[0].clone().expect("lane 0 exists at build time");

        let trace_tenants: Vec<u64> = match &spec.arrival {
            Arrival::Trace { file, .. } => {
                let trace = Trace::load(file)?;
                if trace.is_empty() {
                    return Err(Error::Config(format!("trace {} has no requests", file.display())));
                }
                trace.requests.iter().map(|r| r.lpa % spec.tenants).collect()
            }
            _ => Vec::new(),
        };

        let mut replay = Replay {
            spec,
            devices,
            svc,
            plan_armed,
            floors_suspended,
            fabric,
            path_latency: latency.path_latency(spec.path),
            handles,
            reaper,
            alive: (0..spec.hosts).collect(),
            engine: Engine::new(),
            rng: Pcg64::with_stream(spec.seed, crate::scenario::fnv1a(&spec.name)),
            population: TenantPopulation::new(spec.tenants, spec.zipf_theta),
            trace_tenants,
            emitted: 0,
            inflight: VecDeque::new(),
            service_armed: false,
            book: TenantBook::new(),
            ops_hist: LatencyHistogram::new(),
            submitted: 0,
            ok: 0,
            failed: 0,
            cancelled: 0,
            phantom: 0,
            failed_capacity: 0,
            failed_expander: 0,
        };
        let report = replay.run()?;
        *self.last.lock().expect("telemetry stash poisoned") = Some(replay.svc.telemetry());
        if let Some(path) = crate::scenario::event_log_path() {
            self.dump_events(&path)?;
        }
        Ok(report)
    }
}

/// All mutable replay state, so event handlers are plain `&mut self`
/// methods.
struct Replay<'a> {
    spec: &'a ScenarioSpec,
    devices: Vec<Bdf>,
    svc: FmService,
    /// A deterministic fault plan is armed on the service: lanes may
    /// die *inside* a tick (`crash_between`), so each service event
    /// reconciles the routing tables against service liveness.
    plan_armed: bool,
    /// CI fault-matrix override active: the spec's completion floors
    /// are suspended (the forced fault changes the mix by design);
    /// conservation and invariants still hard-assert.
    floors_suspended: bool,
    fabric: FabricRef,
    path_latency: SimTime,
    /// One endpoint per lane; `None` marks a crashed lane.
    handles: Vec<Option<SubmitHandle>>,
    /// Any handle works for reaping — the completion table is shared.
    reaper: SubmitHandle,
    /// Lanes tenants currently map onto (crashes remove, joins append).
    alive: Vec<usize>,
    engine: Engine<Ev>,
    rng: Pcg64,
    population: TenantPopulation,
    /// Pre-resolved tenant per arrival for trace-driven scenarios.
    trace_tenants: Vec<u64>,
    /// Arrivals emitted so far.
    emitted: u64,
    inflight: VecDeque<Pending>,
    /// Whether a `Service` event is scheduled (the loop invariant).
    service_armed: bool,
    book: TenantBook,
    ops_hist: LatencyHistogram,
    submitted: u64,
    ok: u64,
    failed: u64,
    cancelled: u64,
    /// Arrivals accounted as failed without ever touching the queue
    /// (every lane dead): the one class of op with no `Complete` event,
    /// so the event-stream reconciliation can stay exact.
    phantom: u64,
    failed_capacity: u64,
    failed_expander: u64,
}

impl Replay<'_> {
    fn run(&mut self) -> Result<ScenarioReport> {
        for (i, f) in self.spec.faults.iter().enumerate() {
            self.engine.schedule_at(f.at, Ev::Fault(i));
        }
        self.engine.schedule_at(SimTime::ZERO, Ev::Arrival);

        while let Some((_, ev)) = self.engine.pop() {
            match ev {
                Ev::Arrival => self.on_arrival(),
                Ev::Service => self.on_service(),
                Ev::Fault(i) => self.on_fault(i)?,
            }
        }

        // ---- hard asserts: the run really went through the fabric ----
        let name = &self.spec.name;
        assert!(
            self.inflight.is_empty(),
            "{name}: {} ops still in flight after the event queue drained",
            self.inflight.len()
        );
        assert_eq!(self.svc.tick(), 0, "{name}: service still had schedulable work");
        assert_eq!(
            self.submitted,
            self.ok + self.failed + self.cancelled,
            "{name}: completion counts do not conserve submissions"
        );
        assert_eq!(self.submitted, self.spec.ops, "{name}: arrival budget not fully emitted");
        if !self.floors_suspended {
            let e = &self.spec.expect;
            assert!(
                self.ok >= e.min_ok,
                "{name}: ok {} below the spec floor {}",
                self.ok,
                e.min_ok
            );
            assert!(
                self.failed >= e.min_failed,
                "{name}: failed {} below the spec floor {}",
                self.failed,
                e.min_failed
            );
            assert!(
                self.cancelled >= e.min_cancelled,
                "{name}: cancelled {} below the spec floor {}",
                self.cancelled,
                e.min_cancelled
            );
        }
        self.svc.check_invariants()?;
        self.fabric.check_invariants()?;

        // ---- event-stream reconciliation: every accounted op is ----
        // ---- explained by the canonical stream                   ----
        // The queue posts exactly one completion per admitted ticket and
        // one eager-reject record per refused op, and each emits one
        // `Complete` event; only phantom arrivals (every lane dead)
        // bypass the queue. Per-kind counters survive ring eviction, so
        // this holds at any capacity.
        let ev = self.svc.events().expect("the harness always arms the ring").counts();
        assert_eq!(
            ev.of(EventKind::Complete),
            self.submitted - self.phantom,
            "{name}: Complete events do not explain the accounted ops"
        );
        assert!(
            ev.of(EventKind::Submit) <= ev.of(EventKind::Complete),
            "{name}: more admitted tickets than completion records"
        );

        // ---- tiering reconciliation: every Migrate is explained by ----
        // ---- a terminal Promote/Demote or a counted abort          ----
        if let Some(daemon) = self.svc.tiering() {
            let c = daemon.counters();
            assert_eq!(
                ev.of(EventKind::Migrate),
                ev.of(EventKind::Promote) + ev.of(EventKind::Demote) + c.aborts,
                "{name}: Migrate events unpaired with a terminal Promote/Demote/abort"
            );
            assert_eq!(
                c.promotes,
                ev.of(EventKind::Promote),
                "{name}: daemon promote counter disagrees with the event stream"
            );
            assert_eq!(
                c.demotes,
                ev.of(EventKind::Demote),
                "{name}: daemon demote counter disagrees with the event stream"
            );
        }

        let tenant_means = self.book.tenant_mean_histogram();
        Ok(ScenarioReport {
            name: name.clone(),
            seed: self.spec.seed,
            hosts: self.spec.hosts,
            tenants: self.spec.tenants,
            distinct_tenants: self.book.distinct_tenants(),
            submitted: self.submitted,
            ok: self.ok,
            failed: self.failed,
            cancelled: self.cancelled,
            failed_capacity: self.failed_capacity,
            failed_expander: self.failed_expander,
            sim_duration: self.engine.now(),
            op_mean: self.ops_hist.mean(),
            op_p50: self.ops_hist.p50(),
            op_p99: self.ops_hist.p99(),
            op_p999: self.ops_hist.p999(),
            op_max: self.ops_hist.max(),
            tenant_p50: tenant_means.p50(),
            tenant_p99: tenant_means.p99(),
            tenant_p999: tenant_means.p999(),
        })
    }

    /// Emit one op for one tenant, then schedule the next arrival and
    /// make sure a service tick is armed.
    fn on_arrival(&mut self) {
        if self.alive.is_empty() {
            // every lane is dead (only reachable with a crash-happy
            // fault plan): the op still counts, as a failure, so the
            // arrival budget and conservation stay exact
            self.submitted += 1;
            self.failed += 1;
            self.phantom += 1;
            self.advance_arrivals();
            return;
        }
        let tenant = match &self.spec.arrival {
            Arrival::Trace { .. } => {
                self.trace_tenants[(self.emitted as usize) % self.trace_tenants.len()]
            }
            _ => self.population.sample(&mut self.rng),
        };
        // two draws per arrival regardless of outcome: the op-mix
        // decision never perturbs the tenant sequence
        let share_roll = self.rng.chance(self.spec.share_fraction);
        let churn_roll = self.rng.chance(self.spec.churn);
        // the touch draw exists only when [tiering] is armed, so every
        // descriptor without it keeps its exact two-draw history
        let touch_roll = self.spec.tiering.map(|t| self.rng.chance(t.touch_fraction));

        let (lane, dev, request) = if touch_roll == Some(true) && self.book.has_alloc(tenant) {
            // re-access a live allocation through the data path: the
            // extent's heat counter is what the tiering daemon folds
            let rec = self.book.peek_alloc(tenant).expect("has_alloc checked above");
            (
                rec.lane,
                rec.dev,
                Request::Touch { consumer: self.devices[rec.dev].into(), mmid: rec.mmid },
            )
        } else if share_roll && self.devices.len() > 1 {
            match self.book.pop_alloc(tenant) {
                // share to the next device over; the shared allocation
                // (and its original) stay live to the end of the run
                Some(rec) => {
                    let target = (rec.dev + 1) % self.devices.len();
                    (
                        rec.lane,
                        rec.dev,
                        Request::Share {
                            owner: self.devices[rec.dev].into(),
                            target: self.devices[target].into(),
                            mmid: rec.mmid,
                        },
                    )
                }
                None => self.alloc_op(tenant),
            }
        } else if churn_roll {
            match self.book.pop_alloc(tenant) {
                Some(rec) => (
                    rec.lane,
                    rec.dev,
                    Request::Free { consumer: self.devices[rec.dev].into(), mmid: rec.mmid },
                ),
                None => self.alloc_op(tenant),
            }
        } else {
            self.alloc_op(tenant)
        };

        let handle = self.handles[lane]
            .as_ref()
            .expect("ops only route at live lanes (crashes purge the book and the rotation)");
        // the bounded intake can refuse an op outright: a dead lane
        // rejects eagerly (cancelled), a spent admission budget pushes
        // back (failed) — either way the op is accounted, never lost
        match handle.try_submit_for(Some(tenant), request) {
            Ok(ticket) => {
                self.inflight.push_back(Pending {
                    ticket,
                    tenant,
                    lane,
                    dev,
                    submitted: self.engine.now(),
                });
                self.submitted += 1;
            }
            Err(Error::Cancelled { .. }) => {
                self.submitted += 1;
                self.cancelled += 1;
            }
            Err(Error::QueueFull { .. }) | Err(Error::BudgetExceeded { .. }) => {
                self.submitted += 1;
                self.failed += 1;
            }
            Err(e) => panic!("{}: service queue outlives the replay: {e}", self.spec.name),
        }
        self.advance_arrivals();
    }

    /// Schedule the next arrival (while the op budget lasts) and make
    /// sure a service tick is armed.
    fn advance_arrivals(&mut self) {
        self.emitted += 1;
        if self.emitted < self.spec.ops {
            let gap = match &self.spec.arrival {
                Arrival::Steady { gap } | Arrival::Trace { gap, .. } => *gap,
                Arrival::Bursts { burst_ops, gap, idle } => {
                    if self.emitted % burst_ops == 0 {
                        *idle
                    } else {
                        *gap
                    }
                }
            };
            self.engine.schedule_in(gap, Ev::Arrival);
        }
        if !self.service_armed {
            self.engine.schedule_in(self.spec.service_interval, Ev::Service);
            self.service_armed = true;
        }
    }

    /// The allocation op for `tenant` on its current lane affinity.
    fn alloc_op(&mut self, tenant: u64) -> (usize, usize, Request) {
        let lane = self.alive[(tenant % self.alive.len() as u64) as usize];
        let dev = (tenant % self.devices.len() as u64) as usize;
        (
            lane,
            dev,
            Request::Alloc { consumer: self.devices[dev].into(), size: self.spec.alloc_bytes },
        )
    }

    /// One FM service tick at the simulated now (so queued deadlines
    /// expire on the replay clock), then reap every completion that
    /// landed.
    fn on_service(&mut self) {
        self.service_armed = false;
        self.svc.tick_at(self.engine.now());
        if self.plan_armed {
            self.reconcile_lanes();
        }
        let mut still = VecDeque::with_capacity(self.inflight.len());
        while let Some(p) = self.inflight.pop_front() {
            match self.reaper.take(p.ticket) {
                Some(c) => self.absorb(p, c),
                None => still.push_back(p),
            }
        }
        self.inflight = still;
        if !self.inflight.is_empty() {
            self.engine.schedule_in(self.spec.service_interval, Ev::Service);
            self.service_armed = true;
        }
    }

    /// A `crash_between` strike kills a host *inside* the service tick
    /// (no [`FaultKind::CrashHost`] event fired): fold any lane the
    /// service no longer owns out of the routing tables, exactly as a
    /// scheduled crash would have.
    fn reconcile_lanes(&mut self) {
        let dead: Vec<usize> =
            self.alive.iter().copied().filter(|&l| self.svc.host(l).is_err()).collect();
        for lane in dead {
            self.handles[lane] = None;
            self.alive.retain(|&l| l != lane);
            self.book.purge_lane(lane);
        }
    }

    /// Fold one completion into the counters, the latency aggregates,
    /// and (for allocations) the tenant book.
    fn absorb(&mut self, p: Pending, c: Completion) {
        match c.result {
            Ok(outcome) => {
                self.ok += 1;
                let latency = (self.engine.now() - p.submitted) + self.path_latency;
                self.ops_hist.record(latency);
                self.book.record_latency(p.tenant, latency);
                if let Outcome::Alloc(a) = outcome {
                    self.book.record_alloc(
                        p.tenant,
                        AllocRec { mmid: a.mmid, lane: p.lane, dev: p.dev },
                    );
                }
            }
            Err(Error::Cancelled { .. }) => self.cancelled += 1,
            Err(Error::OutOfCapacity { .. }) | Err(Error::AllocFailed { .. }) => {
                self.failed += 1;
                self.failed_capacity += 1;
            }
            Err(Error::ExpanderFailed(_)) => {
                self.failed += 1;
                self.failed_expander += 1;
            }
            Err(_) => self.failed += 1,
        }
    }

    /// Apply one scheduled fault to the live fabric.
    fn on_fault(&mut self, idx: usize) -> Result<()> {
        match self.spec.faults[idx].kind {
            FaultKind::CrashHost { slot } => {
                // a crash_between strike may have beaten the scheduled
                // crash to this slot; crashing a dead host is a no-op
                if self.svc.host(slot).is_ok() {
                    self.svc.crash_host(slot)?;
                }
                self.handles[slot] = None;
                self.alive.retain(|&l| l != slot);
                // the leases died with the host: drop the book's
                // references so churn never frees a dangling mmid
                self.book.purge_lane(slot);
            }
            FaultKind::JoinHost => {
                let mut host = LmbHost::bind(self.fabric.clone(), self.spec.host_dram_gib * GIB)?;
                host.set_placement_policy(PlacementPolicy::ContentionAware);
                for dev in &self.devices {
                    host.attach_pcie(*dev);
                }
                let lane = self.svc.join_host(host);
                debug_assert_eq!(lane, self.handles.len());
                self.handles.push(Some(self.reaper.retarget(lane).expect("fresh lane is alive")));
                self.alive.push(lane);
            }
            FaultKind::FailExpander => self.fabric.set_expander_failed(true),
            FaultKind::RecoverExpander => self.fabric.set_expander_failed(false),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::descriptor::Descriptor;
    use std::path::Path;

    /// Base topology; `extra` must not repeat these keys (the parser
    /// rejects duplicates). Size knobs go through [`sized`].
    fn spec(extra: &str) -> ScenarioSpec {
        sized("ops = 2000\nexpander_gib = 2\nalloc_bytes = 65536", extra)
    }

    fn sized(size: &str, extra: &str) -> ScenarioSpec {
        let text =
            format!("name = \"inline\"\nhosts = 2\ntenants = 4096\nseed = 11\n{size}\n{extra}");
        let desc = Descriptor::parse(&text).unwrap();
        ScenarioSpec::from_descriptor(&desc, Path::new(".")).unwrap()
    }

    #[test]
    fn scenario_harness_runs_a_steady_mix_through_the_real_service() {
        let report = ScenarioHarness::new(spec("")).run().unwrap();
        assert_eq!(report.submitted, 2000);
        assert_eq!(report.submitted, report.ok + report.failed + report.cancelled);
        assert!(report.ok > 1000, "most ops succeed: {}", report.summary());
        assert!(report.distinct_tenants > 100, "the Zipf head materialised");
        assert!(report.op_p50 >= SimTime::ns(190), "path latency is a floor");
        assert!(report.op_p99 >= report.op_p50);
        assert!(report.sim_duration > SimTime::ZERO);
    }

    #[test]
    fn scenario_harness_is_deterministic_per_seed_and_diverges_across_seeds() {
        let a = ScenarioHarness::new(spec("")).run().unwrap();
        let b = ScenarioHarness::new(spec("")).run().unwrap();
        assert_eq!(a.to_json(), b.to_json(), "same seed, same history");
        let c = ScenarioHarness::new(spec("zipf_theta = 0.5")).run().unwrap();
        assert_ne!(
            a.distinct_tenants,
            c.distinct_tenants,
            "a different mix produces a different history"
        );
    }

    #[test]
    fn scenario_harness_crash_and_expander_faults_show_up_in_counts() {
        let report = ScenarioHarness::new(spec(
            "lane_quota = 32\n\
             [[faults]]\nkind = \"crash_host\"\nslot = 1\nat_us = 300\n\
             [[faults]]\nkind = \"fail_expander\"\nat_us = 600\n\
             [[faults]]\nkind = \"recover_expander\"\nat_us = 900\n\
             [[faults]]\nkind = \"join_host\"\nat_us = 1200\n",
        ))
        .run()
        .unwrap();
        assert!(report.cancelled >= 1, "crash mid-stream cancels queued lane work");
        assert!(report.failed_expander >= 1, "allocs during the outage fail");
        assert!(report.ok > 500, "the fabric recovers: {}", report.summary());
    }

    #[test]
    fn scenario_harness_share_fanout_exercises_cross_device_grants() {
        let report = ScenarioHarness::new(spec("devices = 3\nshare_fraction = 0.3\nchurn = 0.2"))
            .run()
            .unwrap();
        assert!(report.ok > 1000, "{}", report.summary());
    }

    #[test]
    fn scenario_harness_backpressure_rejects_but_conserves() {
        // 4-deep lanes, arrivals every 100 ns, a service tick every
        // 64 us: the intake must refuse most of each burst
        let report = ScenarioHarness::new(spec(
            "lane_depth = 4\n[arrival]\nkind = \"steady\"\ngap_ns = 100",
        ))
        .run()
        .unwrap();
        assert!(report.failed > 100, "overload pushed back: {}", report.summary());
        assert!(report.ok >= 8, "admitted work still completed");
        assert_eq!(report.submitted, report.ok + report.failed + report.cancelled);
    }

    #[test]
    fn scenario_harness_descriptor_fault_plan_is_deterministic() {
        let faulty = || {
            ScenarioHarness::new(spec(
                "[fault_plan]\npoint = \"expander_nak\"\nrate_ppm = 200_000",
            ))
            .run()
            .unwrap()
        };
        let a = faulty();
        let b = faulty();
        assert_eq!(a.to_json(), b.to_json(), "one seed, one faulty history");
        assert_eq!(a.submitted, a.ok + a.failed + a.cancelled);
        assert!(a.ok > 1000, "transient NAKs are healed by the retry layer: {}", a.summary());
    }

    #[test]
    fn scenario_harness_crash_between_plan_survives_to_a_conserved_report() {
        let report = ScenarioHarness::new(spec(
            "[fault_plan]\npoint = \"crash_between\"\nrate_ppm = 5_000\ncrash_budget = 1",
        ))
        .run()
        .unwrap();
        assert_eq!(report.submitted, report.ok + report.failed + report.cancelled);
        assert!(report.ok > 0, "{}", report.summary());
    }

    #[test]
    fn scenario_harness_event_stream_and_telemetry_cover_the_run() {
        let h = ScenarioHarness::new(spec(""));
        let report = h.run().unwrap();

        // every accounted op has a Complete record (no phantom arrivals
        // in a crash-free run), and the tail retained in the ring is
        // the run's stream, tenants attached
        let counts = h.events().counts();
        assert_eq!(counts.of(EventKind::Complete), report.submitted);
        assert!(counts.of(EventKind::Alloc) >= 1, "fabric allocations were observed");
        assert!(counts.of(EventKind::Schedule) >= 1, "queue scheduling was observed");
        let tenanted = h
            .events()
            .snapshot()
            .iter()
            .filter(|e| matches!(e, crate::observe::Event::Submit { tenant: Some(_), .. }))
            .count();
        assert!(tenanted > 0, "submissions carry the replay's tenant attribution");

        // the stashed snapshot is the end-of-run view of the same ring
        let snap = h.telemetry();
        assert_eq!(snap.events.emitted, counts.emitted);
        assert_eq!(snap.events.of(EventKind::Complete), report.submitted);

        // one seed, one stream: a rerun on the same harness reproduces
        // the retained JSONL byte for byte
        let first = h.events().to_jsonl();
        assert!(!first.is_empty());
        h.run().unwrap();
        assert_eq!(h.events().to_jsonl(), first, "replay is byte-identical per seed");
    }

    #[test]
    fn scenario_harness_tiering_replay_migrates_and_reconciles() {
        // 1 GiB fast band (4 extents) + 1 GiB PM band, extent-sized
        // allocs, Zipf-skewed touches: the daemon must find hot
        // PM-resident extents and promote them
        let h = ScenarioHarness::new(sized(
            "ops = 3000\nexpander_gib = 1\nalloc_bytes = 268435456",
            "churn = 0.3\n[tiering]\nepoch_us = 50\ntouch_fraction = 0.6",
        ));
        let report = h.run().unwrap();
        assert_eq!(report.submitted, report.ok + report.failed + report.cancelled);
        let counts = h.events().counts();
        assert!(counts.of(EventKind::Migrate) >= 1, "the daemon really moved extents");
        assert_eq!(
            counts.of(EventKind::Migrate),
            counts.of(EventKind::Promote) + counts.of(EventKind::Demote),
            "no aborts without a fault plan"
        );
        // one seed, one stream — with the daemon in the loop too
        let first = h.events().to_jsonl();
        h.run().unwrap();
        assert_eq!(h.events().to_jsonl(), first, "tiered replay is byte-identical per seed");
    }

    #[test]
    fn scenario_harness_capacity_exhaustion_fails_loudly_not_silently() {
        // 1 GiB pool, 8 MiB allocs, low churn: the pool must exhaust
        let report = ScenarioHarness::new(sized(
            "ops = 1500\nexpander_gib = 1\nalloc_bytes = 8388608",
            "churn = 0.1",
        ))
        .run()
        .unwrap();
        assert!(report.failed_capacity > 100, "{}", report.summary());
        assert!(report.ok >= 128, "the pool's worth of allocs succeeded first");
    }
}
