//! DMA engine: endpoint-initiated data movement through the IOMMU and
//! host bridge, with functional data transfer into the expander when the
//! target resolves to an HDM window.
//!
//! This is the mechanism by which an SSD reaches its LMB-resident L2P
//! table: the controller issues MemRd/MemWr TLPs against the bus address
//! the LMB module returned from `lmb_pcie_alloc` (§3.3, Figure 5).

use crate::cxl::types::{Bdf, BusAddr};
use crate::sim::time::SimTime;

/// Outcome of one DMA transaction (latency + bytes moved).
#[derive(Debug, Clone, Copy)]
pub struct DmaResult {
    pub latency: SimTime,
    pub bytes: u64,
}

/// A descriptor the device hands to its DMA engine.
#[derive(Debug, Clone, Copy)]
pub struct DmaDescriptor {
    pub device: Bdf,
    pub bus_addr: BusAddr,
    pub len: u32,
    pub write: bool,
}

impl DmaDescriptor {
    pub fn read(device: Bdf, bus_addr: BusAddr, len: u32) -> Self {
        DmaDescriptor { device, bus_addr, len, write: false }
    }

    pub fn write(device: Bdf, bus_addr: BusAddr, len: u32) -> Self {
        DmaDescriptor { device, bus_addr, len, write: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_builders() {
        let d = DmaDescriptor::read(Bdf::new(1, 0, 0), BusAddr(0x1000), 64);
        assert!(!d.write);
        let d = DmaDescriptor::write(Bdf::new(1, 0, 0), BusAddr(0x1000), 64);
        assert!(d.write);
    }
}
