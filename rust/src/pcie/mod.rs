//! PCIe substrate: links, TLPs, the root complex bridge that converts
//! device TLPs into CXL.mem requests (§3.2 "Data path"), DMA, and the
//! IOMMU that isolates PCIe devices (§3.3).

pub mod dma;
pub mod iommu;
pub mod link;
pub mod root_complex;
pub mod tlp;

pub use iommu::Iommu;
pub use link::PcieGen;
pub use root_complex::RootComplex;
pub use tlp::{Tlp, TlpKind};
