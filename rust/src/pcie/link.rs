//! PCIe link model: generation, lane count, effective bandwidth, and
//! TLP serialization cost.
//!
//! The paper evaluates on Gen4 x4 and Gen5 x4 U.2 SSDs (Table 3). Lane
//! rates: Gen4 = 16 GT/s, Gen5 = 32 GT/s, 128b/130b encoding; we apply a
//! protocol-efficiency factor (~87%) covering TLP/DLLP headers and flow
//! control, which lands on the usable bandwidths the Table 3 sequential
//! numbers imply (Gen4 x4 ≈ 6.9 GB/s, Gen5 x4 ≈ 13.9 GB/s usable).

use crate::sim::time::SimTime;

/// PCIe generation (only the two the paper evaluates).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PcieGen {
    Gen4,
    Gen5,
}

impl PcieGen {
    /// Per-lane raw rate in GT/s.
    pub fn gts(self) -> f64 {
        match self {
            PcieGen::Gen4 => 16.0,
            PcieGen::Gen5 => 32.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PcieGen::Gen4 => "Gen4",
            PcieGen::Gen5 => "Gen5",
        }
    }
}

/// A PCIe link (endpoint ↔ root complex).
#[derive(Debug, Clone, Copy)]
pub struct PcieLink {
    pub gen: PcieGen,
    pub lanes: u8,
    /// Fraction of raw bandwidth usable as payload (headers, DLLP, FC).
    pub efficiency: f64,
}

impl PcieLink {
    pub fn new(gen: PcieGen, lanes: u8) -> Self {
        PcieLink { gen, lanes, efficiency: 0.92 }
    }

    /// Usable payload bandwidth in bytes/sec (one direction).
    pub fn bandwidth_bps(&self) -> u64 {
        // 128b/130b: raw GT/s ≈ raw Gbit/s * (128/130) → bytes/s
        let raw = self.gen.gts() * 1e9 * (128.0 / 130.0) / 8.0;
        (raw * self.lanes as f64 * self.efficiency) as u64
    }

    /// Serialization time of `bytes` of payload.
    pub fn serialize(&self, bytes: u64) -> SimTime {
        let bps = self.bandwidth_bps();
        SimTime::ns((bytes as u128 * 1_000_000_000u128 / bps as u128) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen4_x4_usable_bandwidth_matches_table3() {
        let l = PcieLink::new(PcieGen::Gen4, 4);
        let gbps = l.bandwidth_bps() as f64 / 1e9;
        // Table 3 Gen4 seq read = 7.2 GB/s (device-limited, close to link)
        assert!((6.5..7.3).contains(&gbps), "gen4 x4 usable = {gbps} GB/s");
    }

    #[test]
    fn gen5_x4_usable_bandwidth_matches_table3() {
        let l = PcieLink::new(PcieGen::Gen5, 4);
        let gbps = l.bandwidth_bps() as f64 / 1e9;
        // Table 3 Gen5 seq read = 14 GB/s
        assert!((13.0..14.5).contains(&gbps), "gen5 x4 usable = {gbps} GB/s");
    }

    #[test]
    fn serialization_4k() {
        let l = PcieLink::new(PcieGen::Gen5, 4);
        let t = l.serialize(4096);
        // 4 KiB over ~13.7 GB/s ≈ 300 ns
        assert!((250..400).contains(&t.as_ns()), "t={t}");
    }

    #[test]
    fn gen5_twice_gen4() {
        let g4 = PcieLink::new(PcieGen::Gen4, 4).bandwidth_bps();
        let g5 = PcieLink::new(PcieGen::Gen5, 4).bandwidth_bps();
        let rel = (g5 as f64 - 2.0 * g4 as f64).abs() / g5 as f64;
        assert!(rel < 1e-9, "g5 {g5} vs 2*g4 {}", 2 * g4);
    }
}
