//! Root complex: the host-side bridge of the LMB-PCIe data path (§3.2).
//!
//! A PCIe device cannot speak CXL. Its TLPs target bus addresses that the
//! IOMMU translates to HPAs; when an HPA resolves to an HDM window, the
//! root complex converts the access into a CXL.mem `MemRd`/`MemWr` with
//! the *uncached* attribute (PCIe devices do not participate in CXL
//! coherency) and forwards it into the fabric. Accesses to plain host
//! DRAM stay local.
//!
//! The functional path moves real bytes: DMA writes land in the expander
//! backing store, DMA reads return them.

use crate::cxl::expander::Expander;
use crate::cxl::packet::{CxlMemReq, MemAddr};
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{Requester, Spid};
use crate::error::Result;
use crate::host::AddressSpace;
use crate::pcie::dma::{DmaDescriptor, DmaResult};
use crate::pcie::iommu::Iommu;
use crate::pcie::link::PcieLink;
use crate::pcie::tlp::Tlp;
use crate::sim::time::SimTime;

/// Root-complex configuration: the bridging overhead the LMB-PCIe path
/// pays on top of the raw PCIe and CXL hops.
#[derive(Debug, Clone, Copy)]
pub struct RootComplexConfig {
    /// TLP → CXL.mem conversion cost (see `cxl::fabric` derivation).
    pub tlp_conversion: SimTime,
    /// Host DRAM access latency (for non-HDM targets).
    pub host_dram: SimTime,
    /// The host root port's SPID on the fabric.
    pub host_spid: Spid,
}

impl Default for RootComplexConfig {
    fn default() -> Self {
        RootComplexConfig {
            tlp_conversion: SimTime::ns(220),
            host_dram: SimTime::ns(100),
            host_spid: Spid(0),
        }
    }
}

/// The root complex ties IOMMU, host address space, switch and expander
/// together for PCIe-originated traffic.
#[derive(Debug)]
pub struct RootComplex {
    pub cfg: RootComplexConfig,
}

impl RootComplex {
    pub fn new(cfg: RootComplexConfig) -> Self {
        RootComplex { cfg }
    }

    /// Service a device DMA transaction end-to-end:
    /// IOMMU translate → address-space resolve → (host DRAM | TLP→CXL.mem
    /// conversion + fabric + HDM media). Returns total latency.
    ///
    /// `data`: for writes, the bytes to store; for reads, the buffer to
    /// fill (lengths must equal `desc.len`).
    pub fn dma(
        &self,
        desc: DmaDescriptor,
        link: &PcieLink,
        iommu: &mut Iommu,
        space: &AddressSpace,
        switch: &PbrSwitch,
        expander: &mut Expander,
        data: &mut [u8],
    ) -> Result<DmaResult> {
        assert_eq!(data.len(), desc.len as usize, "buffer/len mismatch");
        let hpa = iommu.translate(desc.device, desc.bus_addr, desc.len as u64, desc.write)?;
        // PCIe wire cost: payload (+ header overhead) serialization.
        let tlp = if desc.write {
            Tlp::mem_write(desc.device, desc.bus_addr, desc.len)
        } else {
            Tlp::mem_read(desc.device, desc.bus_addr, desc.len)
        };
        let wire_bytes = desc.len as u64 + tlp.header_bytes() as u64;
        let mut latency = link.serialize(wire_bytes);

        match space.resolve(hpa)? {
            crate::host::Target::HostDram { .. } => {
                latency += self.cfg.host_dram;
                // Host DRAM is modeled timing-only; LMB data lives in HDM.
            }
            crate::host::Target::Hdm { dpa } => {
                latency += self.cfg.tlp_conversion;
                let req = if desc.write {
                    CxlMemReq::write(
                        MemAddr::Hpa(hpa),
                        desc.len,
                        Requester::Host(self.cfg.host_spid),
                    )
                    .uncached()
                } else {
                    CxlMemReq::read(
                        MemAddr::Hpa(hpa),
                        desc.len,
                        Requester::Host(self.cfg.host_spid),
                    )
                    .uncached()
                };
                latency += switch.route_to_gfd(&req)?;
                latency += expander.access(&req)?;
                if desc.write {
                    expander.write_dpa(dpa, data)?;
                } else {
                    expander.read_dpa(dpa, data)?;
                }
            }
        }
        Ok(DmaResult { latency, bytes: desc.len as u64 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::ExpanderConfig;
    use crate::cxl::types::{Bdf, Dpa, Hpa, Range, GIB, PAGE_SIZE};
    use crate::host::AddressSpace;
    use crate::pcie::iommu::IommuPerm;
    use crate::pcie::link::PcieGen;

    struct Rig {
        rc: RootComplex,
        link: PcieLink,
        iommu: Iommu,
        space: AddressSpace,
        switch: PbrSwitch,
        expander: Expander,
        dev: Bdf,
        bus: crate::cxl::types::BusAddr,
    }

    fn rig() -> Rig {
        let mut switch = PbrSwitch::new(8);
        let (host_spid, _) = switch.bind_host().unwrap();
        switch.attach_gfd().unwrap();
        let mut expander =
            Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() });
        // HDM window at HPA 4 GiB covering the whole expander
        let hdm_base = 4 * GIB;
        expander.add_decoder(Range::new(hdm_base, GIB), Dpa(0)).unwrap();
        let mut space = AddressSpace::new(2 * GIB); // 2 GiB host DRAM
        space.add_hdm_window(Range::new(hdm_base, GIB), Dpa(0)).unwrap();
        let mut iommu = Iommu::new();
        let dev = Bdf::new(1, 0, 0);
        iommu.attach(dev);
        let bus = iommu
            .map(dev, Hpa(hdm_base + 0x10000), 16 * PAGE_SIZE, IommuPerm::ReadWrite)
            .unwrap();
        let rc = RootComplex::new(RootComplexConfig { host_spid, ..Default::default() });
        Rig { rc, link: PcieLink::new(PcieGen::Gen5, 4), iommu, space, switch, expander, dev, bus }
    }

    #[test]
    fn dma_write_then_read_roundtrips_through_hdm() {
        let mut r = rig();
        let mut wbuf = vec![0x5au8; 4096];
        let res = r
            .rc
            .dma(
                DmaDescriptor::write(r.dev, r.bus, 4096),
                &r.link,
                &mut r.iommu,
                &r.space,
                &r.switch,
                &mut r.expander,
                &mut wbuf,
            )
            .unwrap();
        assert!(res.latency > SimTime::ns(400), "write latency = {}", res.latency);
        let mut rbuf = vec![0u8; 4096];
        r.rc.dma(
            DmaDescriptor::read(r.dev, r.bus, 4096),
            &r.link,
            &mut r.iommu,
            &r.space,
            &r.switch,
            &mut r.expander,
            &mut rbuf,
        )
        .unwrap();
        assert_eq!(rbuf, wbuf);
    }

    #[test]
    fn small_access_latency_near_fig2_constant() {
        // A 64 B access over the LMB-PCIe Gen5 path should be close to
        // the paper's 1190 ns injection constant (plus a few ns of wire).
        let mut r = rig();
        let mut buf = vec![0u8; 64];
        let res = r
            .rc
            .dma(
                DmaDescriptor::read(r.dev, r.bus, 64),
                &r.link,
                &mut r.iommu,
                &r.space,
                &r.switch,
                &mut r.expander,
                &mut buf,
            )
            .unwrap();
        let ns = res.latency.as_ns();
        // conversion(220) + crossing(120) + media(70) + wire(~6) = ~416;
        // the remaining 780-ns "PCIe dev→host" leg is charged by the SSD
        // controller model as the device-side request path — asserted in
        // the fabric tests. Here we check the bridge-side sum.
        assert!((400..450).contains(&ns), "bridge-side latency = {ns} ns");
    }

    #[test]
    fn unmapped_dma_faults_without_touching_hdm() {
        let mut r = rig();
        let mut buf = vec![0u8; 64];
        let res = r.rc.dma(
            DmaDescriptor::read(r.dev, crate::cxl::types::BusAddr(0xbad0_0000), 64),
            &r.link,
            &mut r.iommu,
            &r.space,
            &r.switch,
            &mut r.expander,
            &mut buf,
        );
        assert!(res.is_err());
        assert_eq!(r.expander.served_ops, 0);
    }
}
