//! IOMMU: per-device DMA isolation (§3.3).
//!
//! "For PCIe devices, it is common to use IOMMU to isolate the range of
//! memory that can be accessed by the device. … When memory is requested
//! by a PCIe device, the kernel module creates the IOMMU page tables for
//! the allocated memory."
//!
//! Each device (BDF) gets a domain holding IOVA→HPA mappings at 4 KiB
//! granularity, stored as a range map (contiguous multi-page mappings are
//! one entry). Translation faults are first-class errors — the isolation
//! property the paper's access-control section relies on.

use std::collections::{BTreeMap, HashMap};

use crate::cxl::types::{Bdf, BusAddr, Hpa, PAGE_SIZE};
use crate::error::{Error, Result};

/// Mapping permissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IommuPerm {
    Read,
    ReadWrite,
}

#[derive(Debug, Clone, Copy)]
struct Mapping {
    iova: u64,
    hpa: u64,
    len: u64,
    perm: IommuPerm,
}

/// Per-device translation domain.
#[derive(Debug, Default)]
pub struct Domain {
    /// iova base → mapping (ranges are non-overlapping).
    maps: BTreeMap<u64, Mapping>,
    /// simple bump allocator for fresh IOVA space
    next_iova: u64,
}

impl Domain {
    fn new() -> Self {
        // Start device address space at 4 GiB to keep low addresses
        // obviously invalid (catches zero-initialised handles).
        Domain { maps: BTreeMap::new(), next_iova: 1 << 32 }
    }

    fn find(&self, iova: u64, len: u64) -> Option<&Mapping> {
        self.maps
            .range(..=iova)
            .next_back()
            .map(|(_, m)| m)
            .filter(|m| iova >= m.iova && iova + len <= m.iova + m.len)
    }

    fn overlaps(&self, iova: u64, len: u64) -> bool {
        if let Some((_, m)) = self.maps.range(..iova + len).next_back() {
            if m.iova + m.len > iova {
                return true;
            }
        }
        false
    }
}

/// The system IOMMU: a map of BDF → domain.
#[derive(Debug, Default)]
pub struct Iommu {
    domains: HashMap<Bdf, Domain>,
    /// Translation-fault counter (observability; §3.3 isolation events).
    pub faults: u64,
}

impl Iommu {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create (or get) the domain for a device.
    pub fn attach(&mut self, bdf: Bdf) {
        self.domains.entry(bdf).or_insert_with(Domain::new);
    }

    /// Tear down a device's domain entirely.
    pub fn detach(&mut self, bdf: Bdf) {
        self.domains.remove(&bdf);
    }

    pub fn is_attached(&self, bdf: Bdf) -> bool {
        self.domains.contains_key(&bdf)
    }

    /// Map `len` bytes of HPA into the device's IOVA space; returns the
    /// allocated bus address. `len` and `hpa` must be page-aligned.
    pub fn map(&mut self, bdf: Bdf, hpa: Hpa, len: u64, perm: IommuPerm) -> Result<BusAddr> {
        if !hpa.is_aligned(PAGE_SIZE) || len == 0 || len % PAGE_SIZE != 0 {
            return Err(Error::Config(format!(
                "iommu map must be page-aligned (hpa={hpa:?} len={len:#x})"
            )));
        }
        let dom = self
            .domains
            .get_mut(&bdf)
            .ok_or_else(|| Error::Device(format!("device {bdf} not attached to IOMMU")))?;
        let iova = dom.next_iova;
        debug_assert!(!dom.overlaps(iova, len));
        dom.next_iova += len.next_multiple_of(PAGE_SIZE) + PAGE_SIZE; // guard page
        dom.maps.insert(iova, Mapping { iova, hpa: hpa.0, len, perm });
        Ok(BusAddr(iova))
    }

    /// Map at a *fixed* IOVA (used when sharing an existing region into
    /// another device at a stable address).
    pub fn map_fixed(
        &mut self,
        bdf: Bdf,
        iova: BusAddr,
        hpa: Hpa,
        len: u64,
        perm: IommuPerm,
    ) -> Result<()> {
        let dom = self
            .domains
            .get_mut(&bdf)
            .ok_or_else(|| Error::Device(format!("device {bdf} not attached to IOMMU")))?;
        if dom.overlaps(iova.0, len) {
            return Err(Error::Config(format!("iova {iova:?} already mapped")));
        }
        dom.maps.insert(iova.0, Mapping { iova: iova.0, hpa: hpa.0, len, perm });
        Ok(())
    }

    /// Remove the mapping starting exactly at `iova`.
    pub fn unmap(&mut self, bdf: Bdf, iova: BusAddr) -> Result<()> {
        let dom = self
            .domains
            .get_mut(&bdf)
            .ok_or_else(|| Error::Device(format!("device {bdf} not attached to IOMMU")))?;
        dom.maps
            .remove(&iova.0)
            .map(|_| ())
            .ok_or_else(|| Error::Config(format!("no mapping at {iova:?}")))
    }

    /// Translate a device access; returns the HPA or an IOMMU fault.
    pub fn translate(&mut self, bdf: Bdf, iova: BusAddr, len: u64, write: bool) -> Result<Hpa> {
        let fault = |s: &str, faults: &mut u64| {
            *faults += 1;
            Err(Error::IommuFault {
                bdf: bdf.to_string(),
                hpa: Hpa(iova.0),
                reason: s.to_string(),
            })
        };
        let Some(dom) = self.domains.get(&bdf) else {
            return fault("no domain", &mut self.faults);
        };
        match dom.find(iova.0, len.max(1)) {
            Some(m) => {
                if write && m.perm != IommuPerm::ReadWrite {
                    return fault("write to read-only mapping", &mut self.faults);
                }
                Ok(Hpa(m.hpa + (iova.0 - m.iova)))
            }
            None => fault("unmapped iova", &mut self.faults),
        }
    }

    /// Number of live mappings for a device.
    pub fn mapping_count(&self, bdf: Bdf) -> usize {
        self.domains.get(&bdf).map_or(0, |d| d.maps.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Bdf {
        Bdf::new(2, 0, 0)
    }

    fn iommu_with_dev() -> Iommu {
        let mut i = Iommu::new();
        i.attach(dev());
        i
    }

    #[test]
    fn map_translate_roundtrip_with_offset() {
        let mut i = iommu_with_dev();
        let iova = i.map(dev(), Hpa(0x10_0000), 2 * PAGE_SIZE, IommuPerm::ReadWrite).unwrap();
        let hpa = i.translate(dev(), BusAddr(iova.0 + 0x1234), 8, true).unwrap();
        assert_eq!(hpa, Hpa(0x10_1234));
    }

    #[test]
    fn unmapped_access_faults_and_counts() {
        let mut i = iommu_with_dev();
        assert!(matches!(
            i.translate(dev(), BusAddr(0xdead_b000), 8, false),
            Err(Error::IommuFault { .. })
        ));
        assert_eq!(i.faults, 1);
    }

    #[test]
    fn cross_boundary_access_faults() {
        let mut i = iommu_with_dev();
        let iova = i.map(dev(), Hpa(0x10_0000), PAGE_SIZE, IommuPerm::ReadWrite).unwrap();
        // last byte ok, crossing the end faults
        assert!(i.translate(dev(), BusAddr(iova.0 + PAGE_SIZE - 1), 1, false).is_ok());
        assert!(i.translate(dev(), BusAddr(iova.0 + PAGE_SIZE - 1), 2, false).is_err());
    }

    #[test]
    fn write_permission_enforced() {
        let mut i = iommu_with_dev();
        let iova = i.map(dev(), Hpa(0x20_0000), PAGE_SIZE, IommuPerm::Read).unwrap();
        assert!(i.translate(dev(), iova, 8, false).is_ok());
        assert!(i.translate(dev(), iova, 8, true).is_err());
    }

    #[test]
    fn unmap_revokes() {
        let mut i = iommu_with_dev();
        let iova = i.map(dev(), Hpa(0x30_0000), PAGE_SIZE, IommuPerm::ReadWrite).unwrap();
        i.unmap(dev(), iova).unwrap();
        assert!(i.translate(dev(), iova, 8, false).is_err());
        assert_eq!(i.mapping_count(dev()), 0);
    }

    #[test]
    fn domains_are_isolated() {
        let mut i = iommu_with_dev();
        let other = Bdf::new(3, 0, 0);
        i.attach(other);
        let iova = i.map(dev(), Hpa(0x40_0000), PAGE_SIZE, IommuPerm::ReadWrite).unwrap();
        // same IOVA in the other device's domain must fault
        assert!(i.translate(other, iova, 8, false).is_err());
    }

    #[test]
    fn unaligned_map_rejected() {
        let mut i = iommu_with_dev();
        assert!(i.map(dev(), Hpa(0x123), PAGE_SIZE, IommuPerm::ReadWrite).is_err());
        assert!(i.map(dev(), Hpa(0x1000), 100, IommuPerm::ReadWrite).is_err());
    }

    #[test]
    fn map_fixed_rejects_overlap() {
        let mut i = iommu_with_dev();
        i.map_fixed(dev(), BusAddr(0x5000_0000), Hpa(0x50_0000), PAGE_SIZE, IommuPerm::Read)
            .unwrap();
        assert!(i
            .map_fixed(dev(), BusAddr(0x5000_0000), Hpa(0x60_0000), PAGE_SIZE, IommuPerm::Read)
            .is_err());
    }

    #[test]
    fn detach_removes_domain() {
        let mut i = iommu_with_dev();
        let iova = i.map(dev(), Hpa(0x10_0000), PAGE_SIZE, IommuPerm::ReadWrite).unwrap();
        i.detach(dev());
        assert!(!i.is_attached(dev()));
        assert!(i.translate(dev(), iova, 8, false).is_err());
    }
}
