//! Transaction Layer Packets.
//!
//! Only the subset the LMB data path needs: memory reads/writes issued
//! by endpoints (DMA toward host memory or HDM windows) and completions.
//! §3.2: "The PCIe TLP is converted by the CPU into MemRd/MemWr commands
//! in the CXL.mem protocol."

use crate::cxl::types::{Bdf, BusAddr};

/// TLP kinds we model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TlpKind {
    /// Memory read request (non-posted).
    MemRd,
    /// Memory write request (posted).
    MemWr,
    /// Completion with data (for MemRd).
    CplD,
    /// Completion without data (errors, zero-length).
    Cpl,
}

/// Maximum payload size we model per TLP (bytes). Typical data-center
/// configurations run MPS=256; larger transfers split.
pub const MAX_PAYLOAD: u32 = 256;

/// A transaction-layer packet.
#[derive(Debug, Clone, Copy)]
pub struct Tlp {
    pub kind: TlpKind,
    pub requester: Bdf,
    /// Device-visible address (an IOVA — translated by the IOMMU).
    pub addr: BusAddr,
    pub len: u32,
}

impl Tlp {
    pub fn mem_read(requester: Bdf, addr: BusAddr, len: u32) -> Self {
        Tlp { kind: TlpKind::MemRd, requester, addr, len }
    }

    pub fn mem_write(requester: Bdf, addr: BusAddr, len: u32) -> Self {
        Tlp { kind: TlpKind::MemWr, requester, addr, len }
    }

    pub fn is_write(&self) -> bool {
        self.kind == TlpKind::MemWr
    }

    /// Number of TLPs after MPS splitting.
    pub fn segments(&self) -> u32 {
        self.len.div_ceil(MAX_PAYLOAD).max(1)
    }

    /// Header overhead in bytes for this TLP train (3DW/4DW header + LCRC
    /// per segment ≈ 24 B each).
    pub fn header_bytes(&self) -> u32 {
        self.segments() * 24
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bdf() -> Bdf {
        Bdf::new(1, 0, 0)
    }

    #[test]
    fn splitting_by_mps() {
        assert_eq!(Tlp::mem_write(bdf(), BusAddr(0), 64).segments(), 1);
        assert_eq!(Tlp::mem_write(bdf(), BusAddr(0), 256).segments(), 1);
        assert_eq!(Tlp::mem_write(bdf(), BusAddr(0), 257).segments(), 2);
        assert_eq!(Tlp::mem_write(bdf(), BusAddr(0), 4096).segments(), 16);
    }

    #[test]
    fn zero_length_still_one_segment() {
        assert_eq!(Tlp::mem_read(bdf(), BusAddr(0), 0).segments(), 1);
    }

    #[test]
    fn header_overhead_scales_with_segments() {
        let t = Tlp::mem_write(bdf(), BusAddr(0), 4096);
        assert_eq!(t.header_bytes(), 16 * 24);
    }
}
