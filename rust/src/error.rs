//! Crate-wide error type.
//!
//! Every fallible public API returns [`Result`]. Control-plane failures
//! (allocation, access control, fabric management) are first-class — the
//! paper's §1 "LMB challenges" calls out allocation failure, isolation
//! violations and expander failure as the hard cases, so they get
//! dedicated variants rather than a stringly-typed catch-all.

use crate::cxl::types::{Dpid, Hpa, MmId, Spid};

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the LMB stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// The fabric manager could not satisfy a capacity request.
    #[error("expander out of capacity: requested {requested} B, available {available} B")]
    OutOfCapacity { requested: u64, available: u64 },

    /// The LMB module could not satisfy an allocation (distinct from FM
    /// capacity: the module-level allocator may be fragmented).
    #[error("lmb allocation failed: requested {requested} B ({reason})")]
    AllocFailed { requested: u64, reason: String },

    /// Unknown memory id passed to free/share.
    #[error("unknown memory id {0:?}")]
    UnknownMmId(MmId),

    /// The caller does not own the memory id.
    #[error("memory id {mmid:?} is not owned by the calling device")]
    NotOwner { mmid: MmId },

    /// IOMMU rejected a device access (PCIe-side isolation, §3.3).
    #[error("iommu fault: device {bdf} access to {hpa:?} denied ({reason})")]
    IommuFault { bdf: String, hpa: Hpa, reason: String },

    /// SAT rejected a CXL device access (CXL-side isolation, §3.3).
    #[error("SAT violation: SPID {spid:?} has no grant for DPID {dpid:?}")]
    SatViolation { spid: Spid, dpid: Dpid },

    /// Address did not decode to any HDM window / DMP.
    #[error("address decode failed: {0}")]
    DecodeFault(String),

    /// The expander (or a DMP) is failed / offline (§1 single point of failure).
    #[error("expander unavailable: {0}")]
    ExpanderFailed(String),

    /// Fabric management protocol error (bad bind, duplicate SPID, ...).
    #[error("fabric manager: {0}")]
    FabricManager(String),

    /// Device-side protocol error (NVMe/controller misuse).
    #[error("device: {0}")]
    Device(String),

    /// Workload / configuration validation error.
    #[error("config: {0}")]
    Config(String),

    /// PJRT runtime error (artifact loading, compilation, execution).
    #[error("runtime: {0}")]
    Runtime(String),

    /// I/O error (artifact files, traces).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
