//! Crate-wide error type.
//!
//! Every fallible public API returns [`Result`]. Control-plane failures
//! (allocation, access control, fabric management) are first-class — the
//! paper's §1 "LMB challenges" calls out allocation failure, isolation
//! violations and expander failure as the hard cases, so they get
//! dedicated variants rather than a stringly-typed catch-all.
//!
//! `Display`/`Error` are hand-implemented so the crate builds with zero
//! dependencies (the offline toolchain image carries no crates.io
//! registry; `thiserror` would be its only use).

use std::fmt;

use crate::cxl::types::{Dpid, Hpa, MmId, Spid};

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the LMB stack.
#[derive(Debug)]
pub enum Error {
    /// The fabric manager could not satisfy a capacity request.
    OutOfCapacity { requested: u64, available: u64 },

    /// The LMB module could not satisfy an allocation (distinct from FM
    /// capacity: the module-level allocator may be fragmented).
    AllocFailed { requested: u64, reason: String },

    /// Unknown memory id passed to free/share.
    UnknownMmId(MmId),

    /// A [`Placement`](crate::lmb::allocator::Placement) referenced an
    /// extent the sub-allocator no longer tracks (stale handle after the
    /// extent was released to the FM).
    StalePlacement { extent: u64 },

    /// The caller does not own the memory id.
    NotOwner { mmid: MmId },

    /// A queued submission was cancelled before it was scheduled (its
    /// host crashed and the lane was drained — see
    /// [`AllocQueue::cancel_lane`](crate::lmb::queue::AllocQueue::cancel_lane)).
    Cancelled { ticket: u64 },

    /// The shared fabric lock is poisoned: another thread panicked
    /// while holding it, so the `FabricManager` state may be
    /// mid-mutation. Surfaced by every fallible
    /// [`FabricRef`](crate::cxl::fm::FabricRef) operation after the
    /// panic; `FabricRef::check_invariants` deliberately bypasses the
    /// poison flag so the actual state can still be audited.
    FabricPoisoned,

    /// IOMMU rejected a device access (PCIe-side isolation, §3.3).
    IommuFault { bdf: String, hpa: Hpa, reason: String },

    /// SAT rejected a CXL device access (CXL-side isolation, §3.3).
    SatViolation { spid: Spid, dpid: Dpid },

    /// Address did not decode to any HDM window / DMP.
    DecodeFault(String),

    /// The expander (or a DMP) is failed / offline (§1 single point of failure).
    ExpanderFailed(String),

    /// Fabric management protocol error (bad bind, duplicate SPID, ...).
    FabricManager(String),

    /// Device-side protocol error (NVMe/controller misuse).
    Device(String),

    /// Workload / configuration validation error.
    Config(String),

    /// PJRT runtime error (artifact loading, compilation, execution).
    Runtime(String),

    /// I/O error (artifact files, traces).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::OutOfCapacity { requested, available } => write!(
                f,
                "expander out of capacity: requested {requested} B, available {available} B"
            ),
            Error::AllocFailed { requested, reason } => {
                write!(f, "lmb allocation failed: requested {requested} B ({reason})")
            }
            Error::UnknownMmId(mmid) => write!(f, "unknown memory id {mmid:?}"),
            Error::StalePlacement { extent } => {
                write!(f, "stale placement: extent {extent} is no longer leased")
            }
            Error::NotOwner { mmid } => {
                write!(f, "memory id {mmid:?} is not owned by the calling device")
            }
            Error::Cancelled { ticket } => {
                write!(f, "queued submission {ticket} cancelled before scheduling")
            }
            Error::FabricPoisoned => {
                write!(f, "fabric lock poisoned: a thread panicked while holding it")
            }
            Error::IommuFault { bdf, hpa, reason } => {
                write!(f, "iommu fault: device {bdf} access to {hpa:?} denied ({reason})")
            }
            Error::SatViolation { spid, dpid } => {
                write!(f, "SAT violation: SPID {spid:?} has no grant for DPID {dpid:?}")
            }
            Error::DecodeFault(s) => write!(f, "address decode failed: {s}"),
            Error::ExpanderFailed(s) => write!(f, "expander unavailable: {s}"),
            Error::FabricManager(s) => write!(f, "fabric manager: {s}"),
            Error::Device(s) => write!(f, "device: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_vocabulary() {
        let e = Error::OutOfCapacity { requested: 4096, available: 0 };
        assert_eq!(
            e.to_string(),
            "expander out of capacity: requested 4096 B, available 0 B"
        );
        let e = Error::NotOwner { mmid: MmId(7) };
        assert!(e.to_string().contains("not owned"));
        let e = Error::SatViolation { spid: Spid(3), dpid: Dpid(1) };
        assert!(e.to_string().starts_with("SAT violation"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
