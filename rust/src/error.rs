//! Crate-wide error type.
//!
//! Every fallible public API returns [`Result`]. Control-plane failures
//! (allocation, access control, fabric management) are first-class — the
//! paper's §1 "LMB challenges" calls out allocation failure, isolation
//! violations and expander failure as the hard cases, so they get
//! dedicated variants rather than a stringly-typed catch-all.
//!
//! `Display`/`Error` are hand-implemented so the crate builds with zero
//! dependencies (the offline toolchain image carries no crates.io
//! registry; `thiserror` would be its only use).

use std::fmt;

use crate::cxl::types::{Dpid, Hpa, MmId, Spid};

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the LMB stack.
#[derive(Debug)]
pub enum Error {
    /// The fabric manager could not satisfy a capacity request.
    OutOfCapacity { requested: u64, available: u64 },

    /// The LMB module could not satisfy an allocation (distinct from FM
    /// capacity: the module-level allocator may be fragmented).
    AllocFailed { requested: u64, reason: String },

    /// Unknown memory id passed to free/share.
    UnknownMmId(MmId),

    /// A [`Placement`](crate::lmb::allocator::Placement) referenced an
    /// extent the sub-allocator no longer tracks (stale handle after the
    /// extent was released to the FM).
    StalePlacement { extent: u64 },

    /// The caller does not own the memory id.
    NotOwner { mmid: MmId },

    /// A queued submission was cancelled before it was scheduled (its
    /// host crashed and the lane was drained — see
    /// [`AllocQueue::cancel_lane`](crate::lmb::queue::AllocQueue::cancel_lane)).
    /// A submit rejected eagerly because the target lane is already
    /// dead carries [`NO_TICKET`](crate::lmb::queue::NO_TICKET).
    Cancelled { ticket: u64 },

    /// A lane's bounded intake is at its op-depth limit (backpressure).
    /// Transient: the queue drains as the service ticks, so a bounded
    /// retry or a blocking submit is the right response.
    QueueFull { lane: usize, depth: usize },

    /// A submission would push the lane past its byte budget. Permanent
    /// for this request: retrying without freeing or shrinking cannot
    /// succeed, and blocking submits refuse to wait on it.
    BudgetExceeded { lane: usize, queued_bytes: u64, limit_bytes: u64 },

    /// A queued submission's deadline passed before it executed (or a
    /// `wait_timeout` elapsed). Terminal for the ticket when posted by
    /// the service; retryable by re-submitting with a later deadline.
    TimedOut { ticket: u64 },

    /// The service loop that owned the queue has exited: the intake
    /// channel is closed and pending completions will never be posted.
    /// Surfaced instead of blocking forever in `wait`/`submit`.
    ServiceGone,

    /// The shared fabric lock is poisoned: another thread panicked
    /// while holding it, so the `FabricManager` state may be
    /// mid-mutation. Surfaced by every fallible
    /// [`FabricRef`](crate::cxl::fm::FabricRef) operation after the
    /// panic; `FabricRef::check_invariants` deliberately bypasses the
    /// poison flag so the actual state can still be audited.
    FabricPoisoned,

    /// IOMMU rejected a device access (PCIe-side isolation, §3.3).
    IommuFault { bdf: String, hpa: Hpa, reason: String },

    /// SAT rejected a CXL device access (CXL-side isolation, §3.3).
    SatViolation { spid: Spid, dpid: Dpid },

    /// Address did not decode to any HDM window / DMP.
    DecodeFault(String),

    /// The expander (or a DMP) is failed / offline (§1 single point of failure).
    ExpanderFailed(String),

    /// Fabric management protocol error (bad bind, duplicate SPID, ...).
    FabricManager(String),

    /// Device-side protocol error (NVMe/controller misuse).
    Device(String),

    /// Workload / configuration validation error.
    Config(String),

    /// PJRT runtime error (artifact loading, compilation, execution).
    Runtime(String),

    /// I/O error (artifact files, traces).
    Io(std::io::Error),
}

impl Error {
    /// Transient-vs-permanent taxonomy for the retry layer.
    ///
    /// Transient errors name conditions that can clear on their own —
    /// the expander coming back from an outage, a quarantined region
    /// being routed around, a poisoned fabric lock recovered by
    /// `into_inner`, a bounded intake draining — so `FmService` retries
    /// them with bounded deterministic backoff before surfacing
    /// failure. Everything else is permanent for the request that hit
    /// it: retrying the identical submission cannot succeed.
    ///
    /// The match is deliberately exhaustive (no `_` arm): adding an
    /// `Error` variant without classifying it is a compile error, and
    /// the taxonomy meta-test in this module pins each arm's value.
    pub fn is_transient(&self) -> bool {
        match self {
            // Clears when the device recovers or placement reroutes.
            Error::ExpanderFailed(_) => true,
            // A poisoned lock is recovered on the next `locked()` pass.
            Error::FabricPoisoned => true,
            // Backpressure: the lane drains as the service ticks.
            Error::QueueFull { .. } => true,

            // Capacity/allocator outcomes: stable until a free lands,
            // which no blind retry performs.
            Error::OutOfCapacity { .. } => false,
            Error::AllocFailed { .. } => false,
            // Protocol misuse and stale handles never self-heal.
            Error::UnknownMmId(_) => false,
            Error::StalePlacement { .. } => false,
            Error::NotOwner { .. } => false,
            // Terminal ticket states.
            Error::Cancelled { .. } => false,
            Error::TimedOut { .. } => false,
            Error::ServiceGone => false,
            // Budgets are a property of the request, not the moment.
            Error::BudgetExceeded { .. } => false,
            // Access-control denials are policy, not weather.
            Error::IommuFault { .. } => false,
            Error::SatViolation { .. } => false,
            Error::DecodeFault(_) => false,
            Error::FabricManager(_) => false,
            Error::Device(_) => false,
            Error::Config(_) => false,
            Error::Runtime(_) => false,
            Error::Io(_) => false,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::OutOfCapacity { requested, available } => write!(
                f,
                "expander out of capacity: requested {requested} B, available {available} B"
            ),
            Error::AllocFailed { requested, reason } => {
                write!(f, "lmb allocation failed: requested {requested} B ({reason})")
            }
            Error::UnknownMmId(mmid) => write!(f, "unknown memory id {mmid:?}"),
            Error::StalePlacement { extent } => {
                write!(f, "stale placement: extent {extent} is no longer leased")
            }
            Error::NotOwner { mmid } => {
                write!(f, "memory id {mmid:?} is not owned by the calling device")
            }
            Error::Cancelled { ticket } => {
                write!(f, "queued submission {ticket} cancelled before scheduling")
            }
            Error::QueueFull { lane, depth } => {
                write!(f, "lane {lane} intake full at depth {depth} (backpressure)")
            }
            Error::BudgetExceeded { lane, queued_bytes, limit_bytes } => write!(
                f,
                "lane {lane} byte budget exceeded: {queued_bytes} B queued against a \
                 {limit_bytes} B limit"
            ),
            Error::TimedOut { ticket } => {
                write!(f, "submission {ticket} deadline passed before completion")
            }
            Error::ServiceGone => {
                write!(f, "service loop exited: intake closed, completions will never post")
            }
            Error::FabricPoisoned => {
                write!(f, "fabric lock poisoned: a thread panicked while holding it")
            }
            Error::IommuFault { bdf, hpa, reason } => {
                write!(f, "iommu fault: device {bdf} access to {hpa:?} denied ({reason})")
            }
            Error::SatViolation { spid, dpid } => {
                write!(f, "SAT violation: SPID {spid:?} has no grant for DPID {dpid:?}")
            }
            Error::DecodeFault(s) => write!(f, "address decode failed: {s}"),
            Error::ExpanderFailed(s) => write!(f, "expander unavailable: {s}"),
            Error::FabricManager(s) => write!(f, "fabric manager: {s}"),
            Error::Device(s) => write!(f, "device: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_vocabulary() {
        let e = Error::OutOfCapacity { requested: 4096, available: 0 };
        assert_eq!(
            e.to_string(),
            "expander out of capacity: requested 4096 B, available 0 B"
        );
        let e = Error::NotOwner { mmid: MmId(7) };
        assert!(e.to_string().contains("not owned"));
        let e = Error::SatViolation { spid: Spid(3), dpid: Dpid(1) };
        assert!(e.to_string().starts_with("SAT violation"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }

    /// One representative value per variant. Kept next to the taxonomy
    /// so that growing `Error` forces both lists (and `is_transient`'s
    /// exhaustive match) to grow in the same diff.
    fn every_variant() -> Vec<Error> {
        vec![
            Error::OutOfCapacity { requested: 1, available: 0 },
            Error::AllocFailed { requested: 1, reason: "frag".into() },
            Error::UnknownMmId(MmId(1)),
            Error::StalePlacement { extent: 1 },
            Error::NotOwner { mmid: MmId(1) },
            Error::Cancelled { ticket: 1 },
            Error::QueueFull { lane: 0, depth: 1 },
            Error::BudgetExceeded { lane: 0, queued_bytes: 2, limit_bytes: 1 },
            Error::TimedOut { ticket: 1 },
            Error::ServiceGone,
            Error::FabricPoisoned,
            Error::IommuFault { bdf: "0:0.0".into(), hpa: Hpa(0), reason: "no map".into() },
            Error::SatViolation { spid: Spid(1), dpid: Dpid(1) },
            Error::DecodeFault("x".into()),
            Error::ExpanderFailed("x".into()),
            Error::FabricManager("x".into()),
            Error::Device("x".into()),
            Error::Config("x".into()),
            Error::Runtime("x".into()),
            Error::Io(std::io::Error::other("x")),
        ]
    }

    /// The oracle: a second exhaustive match, written as the *intended*
    /// classification. `is_transient` drifting from it (or a new
    /// variant missing from `every_variant`) fails here; a new variant
    /// missing from either match refuses to compile.
    fn expected_transient(e: &Error) -> bool {
        match e {
            Error::ExpanderFailed(_) | Error::FabricPoisoned | Error::QueueFull { .. } => true,
            Error::OutOfCapacity { .. }
            | Error::AllocFailed { .. }
            | Error::UnknownMmId(_)
            | Error::StalePlacement { .. }
            | Error::NotOwner { .. }
            | Error::Cancelled { .. }
            | Error::TimedOut { .. }
            | Error::ServiceGone
            | Error::BudgetExceeded { .. }
            | Error::IommuFault { .. }
            | Error::SatViolation { .. }
            | Error::DecodeFault(_)
            | Error::FabricManager(_)
            | Error::Device(_)
            | Error::Config(_)
            | Error::Runtime(_)
            | Error::Io(_) => false,
        }
    }

    #[test]
    fn every_error_variant_is_classified() {
        let all = every_variant();
        // Debug names double as a uniqueness check that the sample set
        // really covers distinct variants (not one variant twice).
        let mut names: Vec<String> = all
            .iter()
            .map(|e| {
                let d = format!("{e:?}");
                d.split(|c: char| c == ' ' || c == '(' || c == '{')
                    .next()
                    .unwrap_or_default()
                    .to_string()
            })
            .collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate variant in every_variant()");

        for e in &all {
            assert_eq!(
                e.is_transient(),
                expected_transient(e),
                "taxonomy drift for {e:?}"
            );
        }
        // Spot-pin the load-bearing members of each class.
        assert!(Error::ExpanderFailed("outage".into()).is_transient());
        assert!(Error::QueueFull { lane: 3, depth: 64 }.is_transient());
        assert!(!Error::BudgetExceeded { lane: 0, queued_bytes: 9, limit_bytes: 8 }.is_transient());
        assert!(!Error::ServiceGone.is_transient());
        assert!(!Error::TimedOut { ticket: 7 }.is_transient());
    }

    #[test]
    fn new_variant_displays_are_actionable() {
        let e = Error::QueueFull { lane: 2, depth: 128 };
        assert!(e.to_string().contains("backpressure"), "{e}");
        let e = Error::BudgetExceeded { lane: 1, queued_bytes: 4096, limit_bytes: 1024 };
        assert!(e.to_string().contains("byte budget"), "{e}");
        let e = Error::TimedOut { ticket: 42 };
        assert!(e.to_string().contains("deadline"), "{e}");
        assert!(Error::ServiceGone.to_string().contains("intake closed"));
    }
}
