//! # LMB — CXL-Linked Memory Buffer for PCIe devices
//!
//! Full-system reproduction of *"LMB: Augmenting PCIe Devices with
//! CXL-Linked Memory Buffer"* (DapuStor, CS.AR 2024).
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the LMB system itself: a CXL fabric model
//!   (PBR switch, GFD memory expander, fabric manager), a PCIe substrate
//!   (TLP bridge, IOMMU, DMA), the LMB kernel-module analogue with the
//!   paper's Table 2 API, a calibrated discrete-event SSD model
//!   (NAND, FTL variants, controller pipeline), a FIO-like workload
//!   engine, and the coordinator that drives end-to-end experiments.
//! * **Layer 2 (JAX, build time)** — the simulator's batched data plane
//!   (`python/compile/model.py`): per-IO service-demand composition and a
//!   max-plus pipeline scan, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (Pallas, build time)** — the data-plane hot-spot kernels
//!   (`python/compile/kernels/`), verified against pure-jnp oracles.
//!
//! Python never runs at simulation time: [`runtime`] loads the AOT HLO
//! via the PJRT C API (`xla` crate) and executes it from the hot path.
//!
//! ## Fabric ownership model
//!
//! Since the shared-fabric split (multi-host sharding), no host owns
//! the fabric. The switch, expander, lease table and fabric-global mmid
//! namespace live in the [`cxl::fm::FabricManager`], which sits behind
//! [`cxl::fm::FabricRef`] — a cheap-clone, **`Send + Sync`** handle
//! over `Arc<Mutex<_>>`. Each [`lmb::LmbHost`] holds one clone plus the
//! state that really is per-host: its IOMMU, host physical address
//! space (HDM windows in a host-disjoint HPA region), and the loaded
//! [`lmb::LmbModule`]. Leases are keyed by `HostId` and mmids never
//! collide across hosts, so no handle-holder can free or share memory
//! it does not own — and there is deliberately no public path to
//! `&mut FabricManager` that could bypass those checks.
//! [`cluster::Cluster`] composes the pieces: one fabric, N hosts,
//! routed per-host alloc/free/share, crash containment
//! ([`cluster::Cluster::crash_host`]) and cluster-wide expander
//! failover ([`lmb::failure::FailureDomain::fail_cluster`]).
//!
//! **Threading model.** Fabric access is *scoped*: readers call
//! `with_fm(|fm| ..)` (on `FabricRef`, `LmbHost`, `System`, `Cluster`);
//! the crate-internal mutator is `with_fm_mut`. No lock guard type
//! ever escapes `cxl::fm` — there is no `lock()`/`get()` returning a
//! guard, so callers cannot hold the fabric across unrelated work, and
//! the batched data path is the closure-scoped
//! [`lmb::LmbHost::with_io_session`]. The rules:
//!
//! * **Lock ordering** — the fabric mutex is the *innermost* lock in
//!   the crate. Queue completion tables never hold it, and a fabric
//!   scope must never call back into `FabricRef`/queue APIs (the mutex
//!   is not reentrant; a re-entry deadlocks).
//! * **Who may block** — only [`lmb::SubmitHandle::wait`] and the
//!   [`lmb::FmService::run`] loop park a thread. Everything else
//!   (submit, poll, take, every `with_fm` scope) is non-blocking
//!   beyond the short critical section.
//! * **Poisoning** — a panic inside a fabric scope poisons the lock;
//!   subsequent fallible calls surface
//!   [`error::Error::FabricPoisoned`] instead of deadlocking or
//!   aborting, while `check_invariants` and the observability reads
//!   deliberately bypass the poison flag so post-panic state can be
//!   audited (and crash reclaim still runs).
//!
//! ## Hot-path indexing
//!
//! The per-access lookups are all sublinear, mirroring how real CXL
//! hardware decodes with fixed registers rather than table walks:
//!
//! * the expander keeps its HDM decoder and DMP tables **sorted and
//!   disjoint**, so `decode_hpa`/DMP resolution are binary searches,
//!   fronted by a **one-entry last-hit translation cache** (a
//!   device-TLB analogue, hit/miss counters on
//!   [`cxl::expander::Expander::tlb_stats`]);
//! * the SAT keeps each SPID's grant list **sorted by window base**, so
//!   the per-P2P-op [`cxl::sat::SatTable::check`] is a binary search;
//! * the FM carries running `free_bytes` / per-host `leased_bytes`
//!   counters (O(1) `available`/`leased_to`), and the module
//!   sub-allocator caches each extent's **largest free run** so
//!   placement skips extents that cannot fit without probing their
//!   free lists;
//! * the batched host data path ([`lmb::LmbHost::with_io_session`])
//!   resolves an allocation once and streams N ops under a single
//!   scoped fabric lock.
//!
//! The old linear scans survive as executable oracles in
//! [`testing::oracle`]; property tests assert behavioural equivalence
//! and `benches/perf_hotpath.rs` measures the win (>= 5x at pool scale,
//! asserted) and dumps `BENCH_hotpath.json` for PR-over-PR tracking.
//!
//! ## Queued allocation
//!
//! Allocation is an MPSC submission/completion protocol over
//! [`lmb::queue::AllocQueue`]: `submit` enqueues an alloc/free/share
//! [`lmb::queue::Request`] on a per-host lane and returns a
//! [`lmb::queue::Ticket`] — from the owning thread directly, or from
//! any driver thread through a cloneable
//! [`lmb::SubmitHandle`] (`submit_handle()` on `LmbHost`, `System`,
//! `Cluster`; `handle()` on [`lmb::FmService`]). Deterministic
//! tick-driven scheduling (`tick_queue`/`drain_queue`, or the
//! [`lmb::FmService::run`] actor loop that owns the execute side) pops
//! a rotating per-lane quota — fair across hosts, no RNG or clock, so
//! for a fixed arrival order tests replay from seeded request streams
//! — and executes each host's group under a **single fabric lock
//! acquisition** ([`lmb::LmbHost::execute_requests`]). Completions
//! land in a table shared with every handle: `poll`/`take` from any
//! thread, or block on [`lmb::SubmitHandle::wait`] (never from the
//! thread driving the queue). The synchronous `alloc`/`free`/`share`
//! are one-shot submit + drain over the same queue, so there is
//! exactly one allocation code path whether callers are synchronous,
//! queued, or threaded. Placement is contention-aware by default: the
//! FM splits the DPA space into regions and prices every candidate
//! carve point with the coordinator's M/M/1 cost model
//! ([`coordinator::contention::placement_cost`]), spreading extents
//! across regions and falling back to first-fit on ties
//! ([`cxl::fm::PlacementPolicy`]). A crashed host's
//! queued-but-unscheduled submissions are cancelled
//! ([`error::Error::Cancelled`]) before its leases are reclaimed, and
//! cancellation is terminal: `poll` keeps answering `Cancelled` even
//! after the completion is taken.
//!
//! ## Scenario engine
//!
//! [`scenario`] replays declarative million-tenant workloads against
//! the real fabric. A scenario is **data, not code**: a TOML-subset
//! descriptor committed under `scenarios/` at the repository root
//! (parsed by the zero-dependency [`scenario::Descriptor`], validated
//! into a [`scenario::ScenarioSpec`]) naming a topology, a Zipf tenant
//! population, an arrival process (steady, fio-style bursts, or a
//! recorded trace), fault injections (host crash/join, expander
//! outage) and hard completion-count floors. The
//! [`scenario::ScenarioHarness`] builds a [`cluster::Cluster`],
//! converts it to the [`lmb::FmService`] actor
//! ([`cluster::Cluster::into_service`]), and drives it from the
//! deterministic [`sim::engine::Engine`]: simulated-time arrivals
//! multiplex up to 10^6 tenants over the service's lanes through real
//! [`lmb::SubmitHandle`]s — nothing is mocked. Arrival gaps are fixed
//! by the descriptor (the seeded RNG only picks tenants and op kinds,
//! never times), so one seed yields one history and fault windows land
//! at every scale; the same descriptor and seed serialise to a
//! byte-identical [`scenario::ScenarioReport`] (`BENCH_scenarios.json`,
//! with per-op *and* per-tenant-mean p50/p99/p999). `LMB_SCENARIO_SEED`
//! pins the seed across the suite and `LMB_SCENARIO_SCALE` divides the
//! tenant/op counts for CI. Adding a scenario is dropping a descriptor
//! in `scenarios/` — the suite test and the `scenarios` bench pick it
//! up automatically.
//!
//! ## Quick start
//!
//! The control plane is the unified, consumer-generic API on
//! [`lmb::LmbHost`](crate::lmb::LmbHost) (forwarded by [`system::System`]);
//! the paper's Table-2-named methods remain as deprecated shims.
//!
//! ```no_run
//! use lmb::prelude::*;
//!
//! // Build a host + CXL fabric with one memory expander.
//! let mut system = System::builder().expander_gib(4).build().unwrap();
//! // Attach a PCIe SSD and give an L2P segment an LMB allocation.
//! let ssd = system.attach_pcie_ssd(SsdSpec::gen5());
//! let dev = system.consumer(ssd).unwrap();
//! let alloc = system.alloc(dev, 64 << 20).unwrap();
//! assert!(alloc.size >= 64 << 20);
//! assert!(alloc.bus_addr.is_some(), "device-visible via the IOMMU");
//! system.free(dev, alloc.mmid).unwrap();
//! ```

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cxl;
pub mod error;
pub mod gpu;
pub mod host;
pub mod lmb;
pub mod pcie;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod ssd;
pub mod system;
pub mod testing;
pub mod workload;

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::cluster::{Cluster, ClusterBuilder};
    pub use crate::coordinator::{Coordinator, ExperimentReport, SchemeRow};
    pub use crate::cxl::expander::ExpanderConfig;
    pub use crate::cxl::fabric::{Fabric, PathKind};
    pub use crate::cxl::fm::{FabricManager, FabricRef, HostId};
    pub use crate::cxl::types::*;
    pub use crate::error::{Error, Result};
    pub use crate::lmb::queue::{
        AllocQueue, Completion, Outcome, PlacementPolicy, QueueStats, QueueStatus, Request,
        SubmitHandle, Ticket,
    };
    pub use crate::lmb::{
        Consumer, FmService, IoSession, LmbAlloc, LmbHost, LmbModule, LmbRegion,
    };
    pub use crate::scenario::{ScenarioHarness, ScenarioReport, ScenarioSpec};
    pub use crate::sim::stats::{LatencyHistogram, Throughput};
    pub use crate::sim::time::SimTime;
    pub use crate::ssd::spec::SsdSpec;
    pub use crate::ssd::IndexPlacement;
    pub use crate::system::{System, SystemBuilder};
    pub use crate::workload::{FioJob, IoEngine, IoPattern};
}
