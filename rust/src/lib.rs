//! # LMB — CXL-Linked Memory Buffer for PCIe devices
//!
//! Full-system reproduction of *"LMB: Augmenting PCIe Devices with
//! CXL-Linked Memory Buffer"* (DapuStor, CS.AR 2024).
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the LMB system itself: a CXL fabric model
//!   (PBR switch, GFD memory expander, fabric manager), a PCIe substrate
//!   (TLP bridge, IOMMU, DMA), the LMB kernel-module analogue with the
//!   paper's Table 2 API, a calibrated discrete-event SSD model
//!   (NAND, FTL variants, controller pipeline), a FIO-like workload
//!   engine, and the coordinator that drives end-to-end experiments.
//! * **Layer 2 (JAX, build time)** — the simulator's batched data plane
//!   (`python/compile/model.py`): per-IO service-demand composition and a
//!   max-plus pipeline scan, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (Pallas, build time)** — the data-plane hot-spot kernels
//!   (`python/compile/kernels/`), verified against pure-jnp oracles.
//!
//! Python never runs at simulation time: [`runtime`] loads the AOT HLO
//! via the PJRT C API (`xla` crate) and executes it from the hot path.
//!
//! ## Fabric ownership model
//!
//! Since the shared-fabric split (multi-host sharding), no host owns
//! the fabric. The switch, expander, lease table and fabric-global mmid
//! namespace live in the [`cxl::fm::FabricManager`], which sits behind
//! [`cxl::fm::FabricRef`] — a cheap-clone, **`Send + Sync`** handle.
//! Each [`lmb::LmbHost`] holds one clone plus the state that really is
//! per-host: its IOMMU, host physical address space (HDM windows in a
//! host-disjoint HPA region), and the loaded [`lmb::LmbModule`]. Leases
//! are keyed by `HostId` and mmids never collide across hosts, so no
//! handle-holder can free or share memory it does not own — and there
//! is deliberately no public path to mutate the fabric directly, which
//! could bypass those checks. [`cluster::Cluster`] composes the pieces:
//! one fabric, N hosts, routed per-host alloc/free/share, crash
//! containment ([`cluster::Cluster::crash_host`]) and cluster-wide
//! expander failover ([`lmb::failure::FailureDomain::fail_cluster`]).
//!
//! **Sharded lock hierarchy.** The fabric is not one mutex: mutable
//! state is sharded along the placement-region boundaries the
//! contention-aware policy already spreads leases across, so
//! disjoint-region allocation traffic never serialises. Every
//! `FabricManager` method takes `&self`; internally the locks are, in
//! strict acquisition order,
//!
//! 1. **seal** — a scope mutex held only by `with_fm(|fm| ..)`; its
//!    poison bit is the fabric-wide "a scoped caller panicked" seal,
//!    and the lock-free `seal_check` at the alloc/free/share entry
//!    points is what turns it into [`error::Error::FabricPoisoned`];
//! 2. **control plane** — one mutex over the lease table, per-host
//!    accounting and placement bookkeeping (taken only by lease-grant /
//!    release / crash-reclaim paths, never by warm-extent alloc/free);
//! 3. **region shards** — one mutex per placement region over that
//!    region's sub-allocator free lists and load counters; multi-region
//!    ops (extent placement scans, spanning releases, crash reclaim)
//!    take shards in **ascending region index** (ordered two-phase
//!    locking, so concurrent cross-region ops cannot deadlock);
//! 4. **expander** — an `RwLock` over the decoder/DMP/SAT tables and
//!    backing store, *innermost*: `decode_hpa`, DMP resolution and SAT
//!    checks take the read side and never contend with allocation,
//!    which only takes the write side to program or tear down decoders.
//!
//! No lock guard type escapes `cxl::fm`, so callers cannot hold fabric
//! locks across unrelated work; the batched data path is the
//! closure-scoped [`lmb::LmbHost::with_io_session`]. The rules:
//!
//! * **Who may block** — only [`lmb::SubmitHandle::wait`] and the
//!   [`lmb::FmService::run`] loop park a thread. Everything else
//!   (submit, poll, take, every `with_fm` scope, every sharded FM call)
//!   is non-blocking beyond short per-shard critical sections.
//! * **Poisoning** — a panic inside a `with_fm` scope poisons the seal:
//!   every subsequent fallible call on any host surfaces
//!   [`error::Error::FabricPoisoned`]. A panic while holding one
//!   *region* shard quarantines only that shard — its waiters get the
//!   typed error, disjoint regions keep allocating, and placement
//!   routes new leases around it. `check_invariants` and the
//!   observability reads deliberately bypass both poison layers so
//!   post-panic state can be audited (and crash reclaim still runs).
//! * **Contention observability** — per-layer acquisition/contention
//!   counters ([`cxl::fm::LockStats`]) land in the unified
//!   [`observe::StatsSnapshot`] via `telemetry()` on the owning
//!   service/cluster; the scaling bench
//!   (`benches/concurrency_scaling.rs`) asserts the warm alloc/free
//!   path stays region-lock-free, and `examples/threaded_drivers.rs`
//!   prints the counters live.
//! * **Parallel execution** — with the shards in place,
//!   [`lmb::FmService::run`] fans disjoint hosts' scheduled groups out
//!   to a worker pool (lane *i* pinned to worker *i* mod *W*, so
//!   per-lane FIFO order is preserved); `with_workers(1)` recovers the
//!   serial actor loop, and `BENCH_concurrency.json` tracks the ≥2x
//!   ops/s the pool buys at 4 driver threads.
//!
//! ## Hot-path indexing
//!
//! The per-access lookups are all sublinear, mirroring how real CXL
//! hardware decodes with fixed registers rather than table walks:
//!
//! * the expander keeps its HDM decoder and DMP tables **sorted and
//!   disjoint**, so `decode_hpa`/DMP resolution are binary searches,
//!   fronted by a **one-entry last-hit translation cache** (a
//!   device-TLB analogue; hit/miss counters surface as `tlb_hits` /
//!   `tlb_misses` in the unified [`observe::StatsSnapshot`]);
//! * the SAT keeps each SPID's grant list **sorted by window base**, so
//!   the per-P2P-op [`cxl::sat::SatTable::check`] is a binary search;
//! * the FM carries running `free_bytes` / per-host `leased_bytes`
//!   counters (O(1) `available`/`leased_to`), and the module
//!   sub-allocator caches each extent's **largest free run** so
//!   placement skips extents that cannot fit without probing their
//!   free lists;
//! * the batched host data path ([`lmb::LmbHost::with_io_session`])
//!   resolves an allocation once and streams N ops under a single
//!   scoped fabric lock.
//!
//! The old linear scans survive as executable oracles in
//! [`testing::oracle`]; property tests assert behavioural equivalence
//! and `benches/perf_hotpath.rs` measures the win (>= 5x at pool scale,
//! asserted) and dumps `BENCH_hotpath.json` for PR-over-PR tracking.
//!
//! ## Queued allocation
//!
//! Allocation is an MPSC submission/completion protocol over
//! [`lmb::queue::AllocQueue`]: `submit` enqueues an alloc/free/share
//! [`lmb::queue::Request`] on a per-host lane and returns a
//! [`lmb::queue::Ticket`] — from the owning thread directly, or from
//! any driver thread through a cloneable
//! [`lmb::SubmitHandle`] (`submit_handle()` on `LmbHost`, `System`,
//! `Cluster`; `handle()` on [`lmb::FmService`]). Deterministic
//! tick-driven scheduling (`tick_queue`/`drain_queue`, or the
//! [`lmb::FmService::run`] loop that owns the execute side and fans
//! lane groups out to its worker pool) pops a rotating per-lane quota —
//! fair across hosts, no RNG or clock, so for a fixed arrival order
//! tests replay from seeded request streams — and executes each host's
//! group against the sharded fabric, each request taking only the
//! region locks it touches ([`lmb::LmbHost::execute_requests`]), so
//! disjoint hosts' groups execute concurrently. Completions
//! land in a table shared with every handle: `poll`/`take` from any
//! thread, or block on [`lmb::SubmitHandle::wait`] (never from the
//! thread driving the queue). The synchronous `alloc`/`free`/`share`
//! are one-shot submit + drain over the same queue, so there is
//! exactly one allocation code path whether callers are synchronous,
//! queued, or threaded. Placement is contention-aware by default: the
//! FM splits the DPA space into regions and prices every candidate
//! carve point with the coordinator's M/M/1 cost model
//! ([`coordinator::contention::placement_cost`]), spreading extents
//! across regions and falling back to first-fit on ties
//! ([`cxl::fm::PlacementPolicy`]). A crashed host's
//! queued-but-unscheduled submissions are cancelled
//! ([`error::Error::Cancelled`]) before its leases are reclaimed, and
//! cancellation is terminal: `poll` keeps answering `Cancelled` even
//! after the completion is taken.
//!
//! ## Robustness model
//!
//! The submission plane is **bounded and fault-tolerant by
//! construction** — misbehaving tenants, overdue work and injected
//! faults surface as typed errors or terminal completions, never as
//! unbounded queues or hangs:
//!
//! * **Backpressure** — every lane's intake is bounded by
//!   [`lmb::queue::QueueLimits`] (op-count *and* byte budgets, charged
//!   at submit while work is queued, released when it is scheduled or
//!   resolved). [`lmb::SubmitHandle::try_submit`] never blocks: a full
//!   lane is [`error::Error::QueueFull`], an oversized or over-budget
//!   request is [`error::Error::BudgetExceeded`]. The blocking
//!   [`lmb::SubmitHandle::submit`] parks until admission instead. The
//!   queue *owner* (the thread that drains it) is exempt from blocking
//!   admission — blocking there would deadlock — so `Cluster::submit`
//!   uses the non-blocking path. The payoff is the flooding-tenant
//!   bound gated in CI (`benches/qos_isolation.rs`, `BENCH_qos.json`):
//!   a victim lane's p99 stays within 3x its quiet baseline while a
//!   neighbour floods its own lane.
//! * **Deadlines** — submissions may carry a
//!   [`sim::time::SimTime`] deadline
//!   ([`lmb::SubmitHandle::try_submit_with_deadline`]); the service
//!   expires overdue tickets at the top of every
//!   [`lmb::FmService::tick_at`] with the terminal
//!   [`error::Error::TimedOut`] before scheduling new work, and
//!   [`lmb::SubmitHandle::wait_timeout`] bounds the waiter's side.
//! * **Transient vs permanent** — [`error::Error::is_transient`] is
//!   the crate-wide taxonomy: expander outages, fabric poisoning and
//!   full queues are worth retrying; everything else is permanent.
//!   [`lmb::FmService`] retries transient group failures under a
//!   bounded, deterministic [`lmb::RetryPolicy`] (exponential backoff
//!   expressed as yield counts — no clocks), then surfaces the typed
//!   error. `telemetry().retries` counts the heals.
//! * **Liveness of the contract** — [`lmb::SubmitHandle::wait`] on a
//!   ticket whose service has been dropped returns
//!   [`error::Error::ServiceGone`] instead of parking forever, and
//!   retargeting a handle onto a crashed lane is an eager
//!   [`error::Error::Cancelled`].
//! * **Deterministic fault injection** — [`lmb::FaultPlan`] arms any
//!   of the five declared [`lmb::FaultPoint`]s (`intake_drop`,
//!   `mid_group_panic`, `expander_nak`, `slow_region`,
//!   `crash_between`) at a per-million strike rate. Strikes are a pure
//!   function of (seed, fault point, opportunity index) — no RNG
//!   state, no clocks — so a faulty history replays bit-for-bit
//!   (`tests/fault_matrix.rs` proves it per point). Scenarios arm
//!   plans declaratively (`[fault_plan]` in the descriptor, or the
//!   `LMB_FAULT_POINT`/`LMB_FAULT_RATE_PPM` env override CI sweeps in
//!   its fault-matrix job).
//!
//! ## Scenario engine
//!
//! [`scenario`] replays declarative million-tenant workloads against
//! the real fabric. A scenario is **data, not code**: a TOML-subset
//! descriptor committed under `scenarios/` at the repository root
//! (parsed by the zero-dependency [`scenario::Descriptor`], validated
//! into a [`scenario::ScenarioSpec`]) naming a topology, a Zipf tenant
//! population, an arrival process (steady, fio-style bursts, or a
//! recorded trace), fault injections (host crash/join, expander
//! outage) and hard completion-count floors. The
//! [`scenario::ScenarioHarness`] builds a [`cluster::Cluster`],
//! converts it to the [`lmb::FmService`] actor
//! ([`cluster::Cluster::into_service`]), and drives it from the
//! deterministic [`sim::engine::Engine`]: simulated-time arrivals
//! multiplex up to 10^6 tenants over the service's lanes through real
//! [`lmb::SubmitHandle`]s — nothing is mocked. Arrival gaps are fixed
//! by the descriptor (the seeded RNG only picks tenants and op kinds,
//! never times), so one seed yields one history and fault windows land
//! at every scale; the same descriptor and seed serialise to a
//! byte-identical [`scenario::ScenarioReport`] (`BENCH_scenarios.json`,
//! with per-op *and* per-tenant-mean p50/p99/p999). `LMB_SCENARIO_SEED`
//! pins the seed across the suite and `LMB_SCENARIO_SCALE` divides the
//! tenant/op counts for CI. Adding a scenario is dropping a descriptor
//! in `scenarios/` — the suite test and the `scenarios` bench pick it
//! up automatically.
//!
//! ## Observability plane
//!
//! [`observe`] is the one place diagnostics live — a canonical,
//! structured event stream plus one telemetry snapshot. The scattered
//! per-subsystem accessors (`stats`, `retries_performed`,
//! `fault_strikes*`, `lock_stats`, `tlb_stats`) finished their
//! deprecation cycle and are **gone**; `tests/api_surface.rs` pins
//! their absence. Standalone-fabric drivers without a service sample
//! the fabric slice via [`cxl::fm::FabricRef::telemetry`]:
//!
//! * **Event taxonomy** — a typed [`observe::Event`] per lifecycle
//!   transition: `submit`/`schedule`/`execute`/`complete`/`timeout`/
//!   `retry`/`fault` on the submission plane,
//!   `alloc`/`free`/`share`/`quarantine`/`failover` on the fabric,
//!   `promote`/`demote`/`migrate` from the tiering engine, and
//!   `crash`/`join` on the cluster. Every event carries its
//!   [`sim::time::SimTime`] tick, lane, and (where meaningful) ticket,
//!   mmid, tenant and outcome.
//! * **Ring semantics** — [`observe::EventRing`] is a fixed-capacity
//!   drop-oldest buffer with an exact dropped-count watermark; the
//!   cheap-clone [`observe::EventSink`] handles let FmService workers,
//!   fabric shards and the scenario harness emit without sharing any
//!   fabric lock (emission happens strictly outside the counted
//!   critical sections). Arm one via `set_event_ring` on
//!   [`lmb::FmService`] / [`cluster::Cluster`], or implicitly through
//!   [`scenario::ScenarioHarness`].
//! * **JSONL dump** — `dump_events(path)` (or
//!   [`observe::EventRing::to_jsonl`]) serialises the stream one
//!   fixed-key-order JSON object per line; setting
//!   `LMB_EVENT_LOG=<path>` makes every scenario replay dump its
//!   stream automatically. Under a pinned seed the dump is
//!   byte-identical across runs (`tests/observability.rs` proves it
//!   against the committed `faulty_nak_retry` scenario).
//! * **One snapshot** — `telemetry()` on [`lmb::FmService`],
//!   [`cluster::Cluster`] and [`scenario::ScenarioHarness`] returns the
//!   unified [`observe::StatsSnapshot`]: queue counters, lock stats,
//!   TLB hit/miss, retries, per-point fault strikes and per-kind event
//!   counts in one coherent read.
//!
//! ## Tiering engine
//!
//! [`tier`] closes the loop between observation and placement: the
//! expander models **two media tiers** behind one DPA space (device
//! DRAM below [`cxl::expander::Expander::tier_boundary`], CXL
//! persistent memory above it, priced by the calibrated
//! `HDM_MEDIA_LATENCY` / `PM_MEDIA_LATENCY` scalars), and a
//! hotness-driven daemon migrates extents between them live:
//!
//! * **Heat ledger** — every data-path access ([`lmb::IoSession`]
//!   reads/writes, [`cxl::fm::FabricRef::read_dpa`]/`write_dpa`, the
//!   queued `Request::Touch` marker) bumps one per-extent atomic
//!   counter — no new fabric-wide lock on the hot path. At each epoch
//!   the [`lmb::FmService`] tick folds the counters into the
//!   [`tier::TierDaemon`]'s EWMA ledger
//!   (`new_hot = decay·prev + (1-decay)·counts`, mirroring the Pallas
//!   hotness kernel in `python/compile/kernels/hotness.py`).
//! * **Policy** — [`tier::TierPolicy`] ranks extents by folded heat
//!   and computes a promotion/demotion plan against the DRAM slot
//!   budget; demotions are capped at the promotion count, so a cold
//!   pool never churns.
//! * **Live migration** — `migrate_extent` copies an extent under the
//!   fabric's seal/fence (readers drain at the seal; decoders, SAT
//!   grants and the translation map re-target atomically under the
//!   expander write lock), with rollback on a mid-copy abort
//!   ([`lmb::FaultPoint::MigrateAbort`]). Modules keep their original
//!   **virtual** DPAs forever; the fabric translates through a
//!   forward map — the innermost lock in the hierarchy, taken only
//!   for point lookups, never while acquiring another lock.
//! * **Accountability** — every migration emits `Migrate` plus a
//!   terminal `Promote`/`Demote` (or `Fault` on abort) into the event
//!   ring; `benches/ablation_tiering.rs` gates the win (tiered beats
//!   static placement on a Zipf-skewed heat distribution,
//!   `BENCH_tiering.json`) and `scenarios/zipf_tiering.toml` replays
//!   the whole engine deterministically under fault injection.
//!
//! ## Quick start
//!
//! The control plane is the unified, consumer-generic API on
//! [`lmb::LmbHost`](crate::lmb::LmbHost) (forwarded by [`system::System`]);
//! the paper's Table-2-named shims have been removed after their
//! deprecation cycle — `alloc`/`free`/`share` with a typed
//! [`lmb::Consumer`] are the one surface.
//!
//! ```no_run
//! use lmb::prelude::*;
//!
//! // Build a host + CXL fabric with one memory expander.
//! let mut system = System::builder().expander_gib(4).build().unwrap();
//! // Attach a PCIe SSD and give an L2P segment an LMB allocation.
//! let ssd = system.attach_pcie_ssd(SsdSpec::gen5());
//! let dev = system.consumer(ssd).unwrap();
//! let alloc = system.alloc(dev, 64 << 20).unwrap();
//! assert!(alloc.size >= 64 << 20);
//! assert!(alloc.bus_addr.is_some(), "device-visible via the IOMMU");
//! system.free(dev, alloc.mmid).unwrap();
//! ```

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cxl;
pub mod error;
pub mod gpu;
pub mod host;
pub mod lmb;
pub mod observe;
pub mod pcie;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod ssd;
pub mod system;
pub mod testing;
pub mod tier;
pub mod workload;

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::cluster::{Cluster, ClusterBuilder};
    pub use crate::coordinator::{Coordinator, ExperimentReport, SchemeRow};
    pub use crate::cxl::expander::{ExpanderConfig, MediaTier};
    pub use crate::cxl::fabric::{Fabric, PathKind};
    pub use crate::cxl::fm::{FabricManager, FabricRef, HostId, LockStats};
    pub use crate::cxl::types::*;
    pub use crate::error::{Error, Result};
    pub use crate::lmb::queue::{
        AllocQueue, Completion, Outcome, PlacementPolicy, QueueLimits, QueueStats, QueueStatus,
        Request, SubmitHandle, Ticket, NO_TICKET,
    };
    pub use crate::lmb::{
        Consumer, FaultPlan, FaultPoint, FmService, IoSession, LmbAlloc, LmbHost, LmbModule,
        LmbRegion, RetryPolicy,
    };
    pub use crate::observe::{
        Event, EventCounts, EventKind, EventOutcome, EventRing, EventSink, StatsSnapshot,
    };
    pub use crate::scenario::{FaultPlanSpec, ScenarioHarness, ScenarioReport, ScenarioSpec};
    pub use crate::sim::stats::{LatencyHistogram, Throughput};
    pub use crate::sim::time::SimTime;
    pub use crate::ssd::spec::SsdSpec;
    pub use crate::ssd::IndexPlacement;
    pub use crate::system::{System, SystemBuilder};
    pub use crate::tier::{MigrateOutcome, TierConfig, TierDaemon, TierPolicy};
    pub use crate::workload::{FioJob, IoEngine, IoPattern};
}
