//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness =
//! false`): warm-up + timed repetitions, reporting min/mean/p50 wall
//! time per iteration and derived throughput.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
}

impl Measurement {
    /// Iterations/sec implied by the mean.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: samples[samples.len() / 2],
    }
}

/// Pretty-print a measurement with an optional items-per-iteration count
/// (to derive items/sec).
pub fn report(m: &Measurement, items_per_iter: Option<u64>) {
    let human = |ns: f64| -> String {
        if ns >= 1e9 {
            format!("{:.3}s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.3}ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.3}us", ns / 1e3)
        } else {
            format!("{ns:.0}ns")
        }
    };
    match items_per_iter {
        Some(n) => println!(
            "{:<44} {:>10}/iter (min {:>10})  {:>12.2} Mitems/s",
            m.name,
            human(m.mean_ns),
            human(m.min_ns),
            n as f64 / m.mean_ns * 1e3,
        ),
        None => println!(
            "{:<44} {:>10}/iter (min {:>10}, p50 {:>10})",
            m.name,
            human(m.mean_ns),
            human(m.min_ns),
            human(m.p50_ns)
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let m = measure("spin", 1, 8, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.mean_ns);
        assert_eq!(m.iters, 8);
        assert!(acc > 0);
    }

    #[test]
    fn per_sec_inverse_of_mean() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6,
            min_ns: 1e6,
            p50_ns: 1e6,
        };
        assert!((m.per_sec() - 1000.0).abs() < 1e-9);
    }
}
