//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness =
//! false`): warm-up + timed repetitions, reporting min/mean/p50 wall
//! time per iteration and derived throughput. Results can be dumped as
//! machine-readable JSON ([`write_json`]) so the perf trajectory is
//! tracked PR-over-PR, and iteration counts honour the
//! `LMB_BENCH_ITERS` override ([`iters`]) so CI can smoke-run the
//! benches cheaply.

use std::path::Path;
use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
}

impl Measurement {
    /// Iterations/sec implied by the mean.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Measured-iteration count: `default`, unless the `LMB_BENCH_ITERS`
/// environment variable overrides it (CI smoke runs set a small value
/// so every bench target stays exercisable on each PR).
pub fn iters(default: u32) -> u32 {
    iters_from(std::env::var("LMB_BENCH_ITERS").ok().as_deref(), default)
}

/// Parsing behind [`iters`], split out so tests never have to mutate
/// the process environment (a data race under the parallel test
/// harness: `set_var` racing any concurrent `getenv` is UB on glibc).
fn iters_from(var: Option<&str>, default: u32) -> u32 {
    var.and_then(|v| v.parse::<u32>().ok()).filter(|&n| n > 0).unwrap_or(default)
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: samples[samples.len() / 2],
    }
}

/// Pretty-print a measurement with an optional items-per-iteration count
/// (to derive items/sec).
pub fn report(m: &Measurement, items_per_iter: Option<u64>) {
    let human = |ns: f64| -> String {
        if ns >= 1e9 {
            format!("{:.3}s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.3}ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.3}us", ns / 1e3)
        } else {
            format!("{ns:.0}ns")
        }
    };
    match items_per_iter {
        Some(n) => println!(
            "{:<44} {:>10}/iter (min {:>10})  {:>12.2} Mitems/s",
            m.name,
            human(m.mean_ns),
            human(m.min_ns),
            n as f64 / m.mean_ns * 1e3,
        ),
        None => println!(
            "{:<44} {:>10}/iter (min {:>10}, p50 {:>10})",
            m.name,
            human(m.mean_ns),
            human(m.min_ns),
            human(m.p50_ns)
        ),
    }
}

/// Escape a string for embedding in a JSON literal (shared with the
/// scenario report writer, which emits its own record shape).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialise one measurement (plus its per-iteration item count, if
/// meaningful) as a JSON object.
pub fn to_json(m: &Measurement, items_per_iter: Option<u64>) -> String {
    let items = match items_per_iter {
        Some(n) => n.to_string(),
        None => "null".to_string(),
    };
    let items_per_sec = match items_per_iter {
        Some(n) => format!("{:.1}", n as f64 / m.mean_ns * 1e9),
        None => "null".to_string(),
    };
    format!(
        concat!(
            "{{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.3}, \"min_ns\": {:.3}, ",
            "\"p50_ns\": {:.3}, \"items_per_iter\": {items}, \"items_per_sec\": {items_per_sec}}}"
        ),
        json_escape(&m.name),
        m.iters,
        m.mean_ns,
        m.min_ns,
        m.p50_ns,
    )
}

/// Write a bench run's measurements to `path` as a JSON array (e.g.
/// `BENCH_hotpath.json` at the repo root — the machine-readable record
/// the CI smoke step parses and the perf trajectory is tracked by).
pub fn write_json(path: &Path, rows: &[(Measurement, Option<u64>)]) -> std::io::Result<()> {
    let rows: Vec<String> = rows.iter().map(|(m, items)| to_json(m, *items)).collect();
    write_json_rows(path, &rows)
}

/// Low-level JSON-array writer behind [`write_json`]: each row is one
/// pre-serialised JSON object. Lets other record shapes (the scenario
/// engine's `BENCH_scenarios.json`) share the exact array framing the
/// CI validators parse.
pub fn write_json_rows(path: &Path, rows: &[String]) -> std::io::Result<()> {
    let mut body = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        body.push_str("  ");
        body.push_str(row);
        if i + 1 < rows.len() {
            body.push(',');
        }
        body.push('\n');
    }
    body.push_str("]\n");
    std::fs::write(path, body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let m = measure("spin", 1, 8, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.mean_ns);
        assert_eq!(m.iters, 8);
        assert!(acc > 0);
    }

    #[test]
    fn per_sec_inverse_of_mean() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6,
            min_ns: 1e6,
            p50_ns: 1e6,
        };
        assert!((m.per_sec() - 1000.0).abs() < 1e-9);
    }

    fn sample() -> Measurement {
        Measurement {
            name: "dec \"fast\"".into(),
            iters: 4,
            mean_ns: 250.0,
            min_ns: 100.0,
            p50_ns: 200.0,
        }
    }

    #[test]
    fn json_record_shape_and_escaping() {
        let j = to_json(&sample(), Some(1000));
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"name\": \"dec \\\"fast\\\"\""), "quotes escaped: {j}");
        assert!(j.contains("\"mean_ns\": 250.000"));
        assert!(j.contains("\"items_per_iter\": 1000"));
        assert!(j.contains("\"items_per_sec\": 4000000000.0"));
        let j = to_json(&sample(), None);
        assert!(j.contains("\"items_per_iter\": null"));
        assert!(j.contains("\"items_per_sec\": null"));
    }

    #[test]
    fn json_file_round_trip() {
        let path = std::env::temp_dir().join("lmb_bench_json_test.json");
        write_json(&path, &[(sample(), Some(8)), (sample(), None)]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.starts_with("[\n"));
        assert!(body.trim_end().ends_with(']'));
        assert_eq!(body.matches("\"name\"").count(), 2);
        assert_eq!(body.matches(',').count(), 13, "one record separator + field commas");
    }

    #[test]
    fn iters_override_parsing() {
        assert_eq!(iters_from(None, 200), 200);
        assert_eq!(iters_from(Some("7"), 200), 7);
        assert_eq!(iters_from(Some("0"), 200), 200, "zero falls back to the default");
        assert_eq!(iters_from(Some("junk"), 200), 200);
        assert_eq!(iters_from(Some(""), 200), 200);
    }
}
