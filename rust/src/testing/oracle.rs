//! Linear-scan reference oracles for the indexed hot paths.
//!
//! These are the *old* per-access implementations — unsorted tables
//! walked front to back — preserved verbatim as executable
//! specifications. The property tests in `tests/prop_invariants.rs`
//! drive them in lockstep with the indexed fast paths (sorted decoder
//! table + TLB, binary-searched SAT, `largest_free`-skipping
//! sub-allocator) and assert behavioural equivalence; the benches in
//! `benches/perf_hotpath.rs` and `benches/ablation_allocator.rs` time
//! them against the fast paths so the speedup is measured, not
//! asserted.

use std::collections::HashMap;

use crate::cxl::sat::SatPerm;
use crate::cxl::types::{align_up, Dpa, Hpa, Range, Spid, PAGE_SIZE};

/// The old `Expander` decoder table: an unsorted `Vec` scanned per
/// translation.
#[derive(Debug, Default)]
pub struct LinearDecoders {
    entries: Vec<(Range, u64)>,
}

impl LinearDecoders {
    pub fn new() -> Self {
        Self::default()
    }

    /// Program a window; `false` if it overlaps an existing one.
    pub fn add(&mut self, hpa_window: Range, dpa_base: u64) -> bool {
        if self.entries.iter().any(|(w, _)| w.overlaps(&hpa_window)) {
            return false;
        }
        self.entries.push((hpa_window, dpa_base));
        true
    }

    /// Remove the window starting at `hpa_base`; `false` if absent.
    pub fn remove(&mut self, hpa_base: u64) -> bool {
        let before = self.entries.len();
        self.entries.retain(|(w, _)| w.base != hpa_base);
        self.entries.len() != before
    }

    /// Translate by scanning every window (the old `decode_hpa`).
    pub fn decode(&self, hpa: Hpa) -> Option<Dpa> {
        self.entries
            .iter()
            .find(|(w, _)| w.contains(hpa.0))
            .map(|(w, dpa)| Dpa(dpa + (hpa.0 - w.base)))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The old `SatTable`: per-SPID grant lists in insertion order, scanned
/// front to back on every check (the same structure the real table used
/// before the sorted/binary-search rewrite, so bench comparisons are
/// apples to apples).
#[derive(Debug, Default)]
pub struct LinearSat {
    grants: HashMap<Spid, Vec<(Range, SatPerm)>>,
    entries: usize,
}

impl LinearSat {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grant a window; `false` if it overlaps a same-SPID grant.
    pub fn grant(&mut self, spid: Spid, range: Range, perm: SatPerm) -> bool {
        let list = self.grants.entry(spid).or_default();
        if list.iter().any(|(r, _)| r.overlaps(&range)) {
            return false;
        }
        list.push((range, perm));
        self.entries += 1;
        true
    }

    /// Revoke the exact `(spid, range)` grant; `false` if absent.
    pub fn revoke(&mut self, spid: Spid, range: Range) -> bool {
        let Some(list) = self.grants.get_mut(&spid) else {
            return false;
        };
        let before = list.len();
        list.retain(|(r, _)| *r != range);
        let removed = before - list.len();
        self.entries -= removed;
        removed > 0
    }

    /// Revoke every grant (any SPID) overlapping `range`; returns the
    /// number removed (mirrors `SatTable::revoke_overlapping`).
    pub fn revoke_overlapping(&mut self, range: Range) -> usize {
        let mut removed = 0;
        for list in self.grants.values_mut() {
            let before = list.len();
            list.retain(|(r, _)| !r.overlaps(&range));
            removed += before - list.len();
        }
        self.entries -= removed;
        removed
    }

    /// The old linear `check`: walk the requester's grant list.
    pub fn check(&self, spid: Spid, dpa: Dpa, len: u64, write: bool) -> bool {
        let Some(list) = self.grants.get(&spid) else {
            return false;
        };
        list.iter().any(|(r, p)| {
            r.contains_span(dpa.0, len.max(1)) && (!write || *p == SatPerm::ReadWrite)
        })
    }

    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }
}

/// A placement handed out by [`LinearSubAllocator`]; field-for-field
/// comparable with `lmb::allocator::Placement`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinearPlacement {
    /// Adoption-order id (mirrors `ExtentId.0`).
    pub extent: u64,
    pub offset: u64,
    pub len: u64,
    pub dpa: Dpa,
    pub hpa: Hpa,
}

#[derive(Debug)]
struct LinearExtent {
    id: u64,
    dpa_base: u64,
    hpa_base: u64,
    len: u64,
    /// Sorted, coalesced free list (identical policy to the fast path).
    free: Vec<Range>,
    used: u64,
}

/// The old `SubAllocator`: first-fit in adoption order, probing every
/// extent's free list with no `largest_free` skip.
#[derive(Debug, Default)]
pub struct LinearSubAllocator {
    extents: Vec<LinearExtent>,
    next_id: u64,
}

impl LinearSubAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt an extent of `len` bytes at `dpa_base`, mapped at
    /// `hpa_base`; returns its stable adoption-order id.
    pub fn adopt(&mut self, dpa_base: u64, hpa_base: u64, len: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.extents.push(LinearExtent {
            id,
            dpa_base,
            hpa_base,
            len,
            free: vec![Range::new(0, len)],
            used: 0,
        });
        id
    }

    /// First-fit placement, probing every extent's free list.
    pub fn alloc(&mut self, size: u64) -> Option<LinearPlacement> {
        let len = align_up(size.max(1), PAGE_SIZE);
        for st in self.extents.iter_mut() {
            let Some(pos) = st.free.iter().position(|r| r.len >= len) else {
                continue;
            };
            let r = st.free[pos];
            if r.len == len {
                st.free.remove(pos);
            } else {
                st.free[pos] = Range::new(r.base + len, r.len - len);
            }
            st.used += len;
            return Some(LinearPlacement {
                extent: st.id,
                offset: r.base,
                len,
                dpa: Dpa(st.dpa_base + r.base),
                hpa: Hpa(st.hpa_base + r.base),
            });
        }
        None
    }

    /// Free a placement; `Some(true)` when the extent drained fully,
    /// `None` on a stale extent id.
    pub fn free(&mut self, p: LinearPlacement) -> Option<bool> {
        let st = self.extents.iter_mut().find(|s| s.id == p.extent)?;
        let mut r = Range::new(p.offset, p.len);
        let idx = st.free.partition_point(|f| f.base < r.base);
        if idx < st.free.len() && r.end() == st.free[idx].base {
            r = Range::new(r.base, r.len + st.free[idx].len);
            st.free.remove(idx);
        }
        if idx > 0 && st.free[idx - 1].end() == r.base {
            let prev = st.free[idx - 1];
            st.free[idx - 1] = Range::new(prev.base, prev.len + r.len);
        } else {
            st.free.insert(idx, r);
        }
        st.used -= p.len;
        Some(st.used == 0)
    }

    /// Drop a drained extent; `false` if the id is unknown.
    pub fn remove_extent(&mut self, id: u64) -> bool {
        let before = self.extents.len();
        self.extents.retain(|s| s.id != id);
        self.extents.len() != before
    }

    pub fn extent_count(&self) -> usize {
        self.extents.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decoders_translate_and_reject_overlap() {
        let mut d = LinearDecoders::new();
        assert!(d.add(Range::new(0x1000, 0x1000), 0));
        assert!(!d.add(Range::new(0x1800, 0x1000), 0x10_0000), "overlap rejected");
        assert_eq!(d.decode(Hpa(0x1040)), Some(Dpa(0x40)));
        assert_eq!(d.decode(Hpa(0x3000)), None);
        assert!(d.remove(0x1000));
        assert!(!d.remove(0x1000), "already gone");
        assert!(d.is_empty());
    }

    #[test]
    fn linear_sat_checks_like_the_old_table() {
        let mut s = LinearSat::new();
        assert!(s.grant(Spid(1), Range::new(0, 0x1000), SatPerm::ReadOnly));
        assert!(!s.grant(Spid(1), Range::new(0x800, 0x1000), SatPerm::ReadWrite));
        assert!(s.grant(Spid(2), Range::new(0x800, 0x1000), SatPerm::ReadWrite));
        assert!(s.check(Spid(1), Dpa(0), 64, false));
        assert!(!s.check(Spid(1), Dpa(0), 64, true), "read-only");
        assert!(s.check(Spid(2), Dpa(0x800), 64, true));
        assert_eq!(s.revoke_overlapping(Range::new(0, 0x2000)), 2);
        assert!(s.is_empty());
    }

    #[test]
    fn linear_suballocator_first_fit_round_trip() {
        let mut a = LinearSubAllocator::new();
        let id = a.adopt(0, 1 << 32, 4 * PAGE_SIZE);
        let p = a.alloc(PAGE_SIZE + 1).unwrap();
        assert_eq!(p.extent, id);
        assert_eq!(p.len, 2 * PAGE_SIZE);
        assert_eq!(p.hpa, Hpa(1 << 32));
        let q = a.alloc(PAGE_SIZE).unwrap();
        assert_eq!(q.offset, 2 * PAGE_SIZE);
        assert!(!a.free(p).unwrap(), "q still live");
        assert!(a.free(q).unwrap(), "now drained");
        assert!(a.remove_extent(id));
        assert!(a.free(q).is_none(), "stale extent id reported");
        assert_eq!(a.extent_count(), 0);
    }
}
