//! Test support: a miniature property-based testing framework.
//!
//! `proptest` is unavailable in this offline build environment, so this
//! module provides the subset we need: random case generation from a
//! seeded [`Pcg64`](crate::sim::rng::Pcg64), failure reporting with the
//! reproducing seed, and greedy shrinking for the common carriers
//! (integers, vectors, tuples).
//!
//! Usage:
//! ```no_run
//! use lmb::testing::prop::{check, Shrink};
//! check("add is commutative", 256, |rng| {
//!     (rng.next_below(1000), rng.next_below(1000))
//! }, |&(a, b)| a + b == b + a);
//! ```

pub mod bench;
pub mod oracle;
pub mod prop;

use crate::cxl::fm::FabricRef;

/// Region-poison fault injection: panic a throwaway thread while it
/// holds `region`'s shard lock — exactly the state an unwound
/// allocation path leaves behind. The sharded-poison tests use this to
/// prove one poisoned region quarantines itself without sealing the
/// fabric or deadlocking disjoint regions. Panics (in the calling
/// thread) if `region` is out of range.
pub fn poison_region(fabric: &FabricRef, region: usize) {
    std::thread::scope(|s| {
        let poisoner = s.spawn(|| fabric.poison_region_for_test(region)).join();
        assert!(poisoner.is_err(), "poisoning thread must panic");
    });
}
