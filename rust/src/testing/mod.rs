//! Test support: a miniature property-based testing framework.
//!
//! `proptest` is unavailable in this offline build environment, so this
//! module provides the subset we need: random case generation from a
//! seeded [`Pcg64`](crate::sim::rng::Pcg64), failure reporting with the
//! reproducing seed, and greedy shrinking for the common carriers
//! (integers, vectors, tuples).
//!
//! Usage:
//! ```no_run
//! use lmb::testing::prop::{check, Shrink};
//! check("add is commutative", 256, |rng| {
//!     (rng.next_below(1000), rng.next_below(1000))
//! }, |&(a, b)| a + b == b + a);
//! ```

pub mod bench;
pub mod oracle;
pub mod prop;
