//! Mini property-testing engine: generate → check → shrink.
//!
//! Each run derives its cases from a fixed base seed plus the case index,
//! so failures print a standalone reproduction seed. Shrinking is greedy:
//! the failing value is asked for simpler candidates ([`Shrink`]); the
//! first candidate that still fails replaces it, until a fixpoint.

use crate::sim::rng::Pcg64;

/// Types that can propose structurally smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate simplifications, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

macro_rules! impl_shrink_uint {
    ($($t:ty),*) => {$(
        impl Shrink for $t {
            fn shrink(&self) -> Vec<Self> {
                let mut out = Vec::new();
                if *self != 0 {
                    out.push(0);
                    out.push(*self / 2);
                    out.push(*self - 1);
                }
                out.dedup();
                out
            }
        }
    )*};
}
impl_shrink_uint!(u8, u16, u32, u64, usize);

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // remove halves, then single elements, then shrink one element
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for cand in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink, D: Shrink> Shrink for (A, B, C, D) {
    fn shrink(&self) -> Vec<Self> {
        let (a, b, c, d) = self;
        let mut out: Vec<Self> =
            a.shrink().into_iter().map(|x| (x, b.clone(), c.clone(), d.clone())).collect();
        out.extend(b.shrink().into_iter().map(|x| (a.clone(), x, c.clone(), d.clone())));
        out.extend(c.shrink().into_iter().map(|x| (a.clone(), b.clone(), x, d.clone())));
        out.extend(d.shrink().into_iter().map(|x| (a.clone(), b.clone(), c.clone(), x)));
        out
    }
}

const BASE_SEED: u64 = 0x1_5eed_cafe;
const MAX_SHRINK_STEPS: usize = 2000;

/// Base seed for [`check`]: the `LMB_PROP_SEED` environment variable
/// when set (decimal, or hex with an `0x` prefix — the same form the
/// failure message prints), else [`BASE_SEED`]. CI pins the variable so
/// a red property run reproduces locally with the identical cases; a
/// set-but-unparseable value panics rather than silently voiding that
/// contract by falling back to the default seed.
pub fn base_seed() -> u64 {
    match std::env::var("LMB_PROP_SEED") {
        Err(_) => BASE_SEED,
        Ok(v) => match parse_seed(Some(&v)) {
            Some(seed) => seed,
            None => panic!("LMB_PROP_SEED {v:?} is not a decimal or 0x-prefixed hex u64"),
        },
    }
}

/// Parsing behind [`base_seed`], split out so tests never mutate the
/// process environment (a data race under the parallel test harness).
fn parse_seed(var: Option<&str>) -> Option<u64> {
    let v = var?.trim();
    let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(&hex.replace('_', ""), 16),
        None => v.parse::<u64>(),
    };
    parsed.ok()
}

/// Run `cases` random checks of `prop` over values drawn by `gen`.
///
/// Panics with the shrunk counterexample and reproduction seed on
/// failure. The property returns `true` for pass.
pub fn check<T, G, P>(name: &str, cases: u32, gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> bool,
{
    check_seeded(name, base_seed(), cases, gen, prop)
}

/// [`check`] with an explicit base seed (printed seeds reproduce 1 case).
pub fn check_seeded<T, G, P>(name: &str, base_seed: u64, cases: u32, gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> bool,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::new(seed);
        let value = gen(&mut rng);
        if prop(&value) {
            continue;
        }
        // shrink
        let mut failing = value;
        let mut steps = 0;
        'outer: loop {
            for cand in failing.shrink() {
                steps += 1;
                if steps > MAX_SHRINK_STEPS {
                    break 'outer;
                }
                if !prop(&cand) {
                    failing = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed (case {case}, seed {seed:#x});\n  \
             shrunk counterexample: {failing:?}"
        );
    }
}

/// Draw a vector with length in `[0, max_len]` using `f` per element.
pub fn vec_of<T>(rng: &mut Pcg64, max_len: usize, mut f: impl FnMut(&mut Pcg64) -> T) -> Vec<T> {
    let len = rng.next_below(max_len as u64 + 1) as usize;
    (0..len).map(|_| f(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("u64 halving", 128, |r| r.next_below(1 << 40), |&x| x / 2 <= x);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check("all < 100", 256, |r| r.next_below(1 << 20), |&x| x < 100);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land exactly on the boundary value 100
        assert!(msg.contains("counterexample: 100"), "got: {msg}");
    }

    #[test]
    fn vec_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(
                "no vec sums past 1000",
                256,
                |r| vec_of(r, 20, |r| r.next_below(500)),
                |v: &Vec<u64>| v.iter().sum::<u64>() <= 1000,
            );
        });
        assert!(result.is_err(), "property should fail");
    }

    #[test]
    fn seed_override_parsing() {
        assert_eq!(parse_seed(None), None);
        assert_eq!(parse_seed(Some("12345")), Some(12345));
        assert_eq!(parse_seed(Some("0x15eedcafe")), Some(0x1_5eed_cafe));
        assert_eq!(parse_seed(Some("0x1_5eed_cafe")), Some(0x1_5eed_cafe), "underscores ok");
        assert_eq!(parse_seed(Some(" 0XFF ")), Some(0xff), "whitespace + upper-case prefix");
        assert_eq!(parse_seed(Some("junk")), None);
        assert_eq!(parse_seed(Some("")), None);
    }

    #[test]
    fn deterministic_given_seed() {
        // same seed → same draws → same result (no panic twice in a row)
        for _ in 0..2 {
            check_seeded("det", 7, 32, |r| r.next_below(10), |&x| x < 10);
        }
    }
}
