//! Multi-host cluster (§3.1–3.2 scalability): one CXL expander
//! supplements the onboard DRAM of PCIe devices across *multiple
//! hosts*, with the FM arbitrating leases.
//!
//! The [`Cluster`] builds one fabric (switch + expander behind a
//! [`FabricRef`]), binds N [`LmbHost`]s to it, and routes per-host
//! alloc/free/share. Two properties the paper's architecture implies
//! are enforced here and testable:
//!
//! * **Cross-host isolation** — mmids come from the fabric-global
//!   namespace, so a handle minted on host A can never alias host B's
//!   memory; routing an operation at the wrong host fails with
//!   [`Error::NotOwner`] instead of silently touching foreign state.
//! * **Crash containment** — [`Cluster::crash_host`] reclaims the
//!   victim's leases through [`FabricManager::release_host`] (including
//!   stale SAT grants and HDM decoders) without perturbing sibling
//!   hosts' extents; stable `ExtentId`s keep every surviving placement
//!   valid.
//!
//! Cluster-wide expander failure/recovery is driven by
//! [`FailureDomain::fail_cluster`](crate::lmb::failure::FailureDomain::fail_cluster).
//!
//! ```
//! use lmb::cluster::Cluster;
//! use lmb::cxl::types::{Bdf, EXTENT_SIZE, GIB};
//!
//! // 1 GiB expander (4 extents), two hosts
//! let mut cluster = Cluster::builder()
//!     .hosts(2)
//!     .expander_gib(1)
//!     .host_dram_gib(1)
//!     .build()
//!     .unwrap();
//! let dev = Bdf::new(1, 0, 0);
//! cluster.host_mut(0).unwrap().attach_pcie(dev);
//! cluster.host_mut(1).unwrap().attach_pcie(dev);
//!
//! let a = cluster.alloc(0, dev, EXTENT_SIZE).unwrap();
//! let _b = cluster.alloc(1, dev, EXTENT_SIZE).unwrap();
//! assert_eq!(cluster.leased_to(0).unwrap(), EXTENT_SIZE);
//!
//! // host 1 cannot free host 0's memory
//! assert!(cluster.free(1, dev, a.mmid).is_err());
//!
//! // a crash returns host 0's capacity to the shared pool
//! cluster.crash_host(0).unwrap();
//! assert_eq!(cluster.available(), GIB - EXTENT_SIZE);
//! ```

use std::collections::HashSet;

use crate::cxl::expander::{Expander, ExpanderConfig};
use crate::cxl::fabric::{Fabric, FabricConfig};
use crate::cxl::fm::{FabricManager, FabricRef};
use crate::cxl::switch::PbrSwitch;
use crate::cxl::types::{gib_to_bytes, MmId, Spid, GIB};
use crate::error::{Error, Result};
use crate::lmb::queue::{
    AllocQueue, Completion, Outcome, PlacementPolicy, QueueLimits, QueueStatus, Request, Scheduled,
    SubmitHandle, Ticket, DEFAULT_LANE_QUOTA,
};
use crate::lmb::{Consumer, FmService, LmbAlloc, LmbHost};
use crate::observe::{Event, EventRing, StatsSnapshot};

/// N LMB hosts arbitrating one switch + expander through a shared
/// [`FabricRef`]. Hosts are addressed by their slot index (stable
/// across crashes: a crashed slot stays empty, later joins append).
///
/// The cluster carries the fleet-wide [`AllocQueue`]: submissions are
/// routed per slot ([`Cluster::submit`]), scheduled fairly across hosts
/// (rotating per-lane quota, [`Cluster::tick_queue`]), executed under
/// one fabric lock per slot group, and reaped via
/// [`Cluster::take_completion`]. The synchronous routed surface
/// ([`Cluster::alloc`] / [`Cluster::free`] / [`Cluster::share`]) is a
/// one-shot submit + drain over that same queue. A host crash cancels
/// its queued-but-unscheduled submissions
/// ([`AllocQueue::cancel_lane`]) before its leases are reclaimed.
#[derive(Debug)]
pub struct Cluster {
    fabric: FabricRef,
    /// Latency model for the shared fabric (one per cluster).
    latency: Fabric,
    slots: Vec<Option<LmbHost>>,
    host_dram: u64,
    /// Cluster-wide allocation queue (one lane per slot).
    queue: AllocQueue,
    /// Per-lane requests serviced per scheduling tick.
    lane_quota: usize,
    /// Placement policy installed on every joining host.
    policy: PlacementPolicy,
    /// Observability ring, if armed ([`Cluster::set_event_ring`]).
    events: Option<EventRing>,
}

/// Builder for [`Cluster`].
#[derive(Debug)]
pub struct ClusterBuilder {
    expander: ExpanderConfig,
    fabric: FabricConfig,
    switch_ports: u8,
    host_dram: u64,
    hosts: usize,
    lane_quota: usize,
    policy: PlacementPolicy,
    limits: QueueLimits,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        ClusterBuilder {
            expander: ExpanderConfig::default(),
            fabric: FabricConfig::default(),
            switch_ports: 32,
            host_dram: 16 * GIB,
            hosts: 2,
            lane_quota: DEFAULT_LANE_QUOTA,
            policy: PlacementPolicy::ContentionAware,
            limits: QueueLimits::default(),
        }
    }
}

impl ClusterBuilder {
    /// Number of hosts bound at build time (more can join later).
    pub fn hosts(mut self, n: usize) -> Self {
        self.hosts = n;
        self
    }

    /// Expander DRAM capacity in GiB (checked, like
    /// [`SystemBuilder`](crate::system::SystemBuilder)).
    pub fn expander_gib(mut self, gib: u64) -> Self {
        self.expander.dram_capacity = gib_to_bytes(gib);
        self
    }

    /// Add a PM partition of `gib` GiB.
    pub fn pm_gib(mut self, gib: u64) -> Self {
        self.expander.pm_capacity = gib_to_bytes(gib);
        self
    }

    /// Per-host DRAM size in GiB.
    pub fn host_dram_gib(mut self, gib: u64) -> Self {
        self.host_dram = gib_to_bytes(gib);
        self
    }

    /// Switch edge-port budget (hosts + devices + GFD).
    pub fn switch_ports(mut self, ports: u8) -> Self {
        self.switch_ports = ports;
        self
    }

    /// Override fabric latency constants.
    pub fn fabric_config(mut self, cfg: FabricConfig) -> Self {
        self.fabric = cfg;
        self
    }

    /// Extent-placement policy installed on every host (default:
    /// contention-aware; first-fit is the ablation baseline).
    pub fn placement_policy(mut self, policy: PlacementPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Per-host requests serviced per queue tick (fairness quantum).
    pub fn lane_quota(mut self, quota: usize) -> Self {
        self.lane_quota = quota.max(1);
        self
    }

    /// Per-lane admission budgets for the cluster queue (and any
    /// [`FmService`] built from it): op-depth and queued-byte caps
    /// enforced at submit time (backpressure).
    pub fn queue_limits(mut self, limits: QueueLimits) -> Self {
        self.limits = limits;
        self
    }

    pub fn build(self) -> Result<Cluster> {
        let fabric = FabricRef::new(FabricManager::new(
            PbrSwitch::new(self.switch_ports),
            Expander::new(self.expander),
        ));
        let mut queue = AllocQueue::new();
        queue.set_limits(self.limits);
        let mut cluster = Cluster {
            fabric,
            latency: Fabric::new(self.fabric),
            slots: Vec::new(),
            host_dram: self.host_dram,
            queue,
            lane_quota: self.lane_quota,
            policy: self.policy,
            events: None,
        };
        for _ in 0..self.hosts {
            cluster.join_host()?;
        }
        Ok(cluster)
    }
}

impl Cluster {
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    /// The shared fabric handle (clone it to bind hosts out-of-band or
    /// to drive failure injection).
    pub fn fabric_ref(&self) -> &FabricRef {
        &self.fabric
    }

    /// Scoped read-only view of the shared FM: the closure runs with
    /// the fabric locked; no guard type escapes (see
    /// [`FabricRef::with_fm`]).
    pub fn with_fm<R>(&self, f: impl FnOnce(&FabricManager) -> R) -> Result<R> {
        self.fabric.with_fm(f)
    }

    /// The cluster's fabric latency model.
    pub fn latency(&self) -> &Fabric {
        &self.latency
    }

    // ---- observability plane ----

    /// Arm a structured-event ring on the cluster: the queue and the
    /// shared fabric emit into cheap-clone sinks of `ring` from here on.
    /// The fabric's sink is set-once — the first ring armed on a fabric
    /// wins; re-arming swaps only the cluster/queue side.
    pub fn set_event_ring(&mut self, ring: EventRing) {
        self.queue.set_event_sink(ring.sink());
        self.fabric.set_event_sink(ring.sink());
        self.events = Some(ring);
    }

    /// The armed event ring, if any.
    pub fn events(&self) -> Option<&EventRing> {
        self.events.as_ref()
    }

    /// One unified telemetry snapshot: queue counters, fabric lock
    /// stats, expander TLB counters, and (if a ring is armed) per-kind
    /// event counts. The cluster path has no retry loop or fault plan,
    /// so those fields read zero here — [`FmService::telemetry`] is
    /// the fault-aware sibling.
    pub fn telemetry(&self) -> StatsSnapshot {
        let (lock, tlb_hits, tlb_misses) = self.fabric.telemetry_counters();
        StatsSnapshot {
            queue: self.queue.stats(),
            lock,
            tlb_hits,
            tlb_misses,
            events: self.events.as_ref().map(EventRing::counts).unwrap_or_default(),
            ..StatsSnapshot::default()
        }
    }

    /// Bind one more host to the shared fabric; returns its slot index.
    pub fn join_host(&mut self) -> Result<usize> {
        let mut host = LmbHost::bind(self.fabric.clone(), self.host_dram)?;
        host.set_placement_policy(self.policy);
        self.slots.push(Some(host));
        let lane = self.slots.len() - 1;
        if let Some(ring) = &self.events {
            let sink = ring.sink();
            sink.emit(Event::Join { tick: sink.now(), lane });
        }
        Ok(lane)
    }

    /// Number of slots ever created (crashed ones included).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Number of currently bound hosts.
    pub fn alive_hosts(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// The host in `slot`, if it is alive.
    pub fn host(&self, slot: usize) -> Result<&LmbHost> {
        self.slots
            .get(slot)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| Error::FabricManager(format!("no live host in slot {slot}")))
    }

    pub fn host_mut(&mut self, slot: usize) -> Result<&mut LmbHost> {
        self.slots
            .get_mut(slot)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| Error::FabricManager(format!("no live host in slot {slot}")))
    }

    /// Iterate the live hosts as `(slot, host)`.
    pub fn hosts(&self) -> impl Iterator<Item = (usize, &LmbHost)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|h| (i, h)))
    }

    /// Bind a CXL device through `slot`'s host (P2P consumers are
    /// fabric-wide; the slot just names who programs the grant).
    pub fn attach_cxl_device(&mut self, slot: usize) -> Result<Spid> {
        self.host_mut(slot)?.attach_cxl_device()
    }

    // ---- routed per-host LMB surface (one-shot over the queue) ----

    /// Allocate on `slot`'s host for `consumer`.
    pub fn alloc(
        &mut self,
        slot: usize,
        consumer: impl Into<Consumer>,
        size: u64,
    ) -> Result<LmbAlloc> {
        let consumer = consumer.into();
        let outcome = self.submit_and_wait(slot, Request::Alloc { consumer, size })?;
        outcome.into_alloc()
    }

    /// All-or-nothing batch allocation on `slot`'s host. Everything
    /// already queued cluster-wide is drained first, so the batch never
    /// jumps ahead of pending submissions; the batch itself then runs
    /// through the host's own queue path ([`LmbHost::alloc_many`]),
    /// which rolls a partial batch back before any sibling lane can
    /// observe — or fail against — its transient claims.
    pub fn alloc_many(
        &mut self,
        slot: usize,
        consumer: impl Into<Consumer>,
        sizes: &[u64],
    ) -> Result<Vec<LmbAlloc>> {
        self.drain_queue();
        self.host_mut(slot)?.alloc_many(consumer, sizes)
    }

    /// Free `mmid` through `slot`'s host. Cross-host isolation: if the
    /// mmid belongs to a *different* host this fails with
    /// [`Error::NotOwner`] — fabric-global mmids guarantee a foreign
    /// handle can never alias a local allocation.
    pub fn free(&mut self, slot: usize, consumer: impl Into<Consumer>, mmid: MmId) -> Result<()> {
        let consumer = consumer.into();
        match self.submit_and_wait(slot, Request::Free { consumer, mmid })? {
            Outcome::Freed => Ok(()),
            other => unreachable!("free submission yielded {other:?}"),
        }
    }

    /// Owner-authorised share through `slot`'s host, with the same
    /// cross-host isolation rule as [`Cluster::free`].
    pub fn share(
        &mut self,
        slot: usize,
        owner: impl Into<Consumer>,
        target: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        let owner = owner.into();
        let target = target.into();
        let outcome = self.submit_and_wait(slot, Request::Share { owner, target, mmid })?;
        outcome.into_alloc()
    }

    // ---- cluster-wide queued allocation ----

    /// Enqueue a request on `slot`'s lane of the cluster queue; errors
    /// immediately if the slot has no live host, or with
    /// [`Error::QueueFull`] / [`Error::BudgetExceeded`] when the lane's
    /// admission budget ([`ClusterBuilder::queue_limits`]) is spent —
    /// the owner never blocks on its own backlog. Nothing executes
    /// until [`Cluster::tick_queue`] / [`Cluster::drain_queue`] (or a
    /// synchronous routed call, whose one-shot drain services the whole
    /// queue).
    pub fn submit(&mut self, slot: usize, request: Request) -> Result<Ticket> {
        self.host(slot)?; // reject routing at a dead/unknown slot
        self.queue.try_submit(slot, request)
    }

    /// Where a submission is in its lifecycle.
    pub fn poll_submission(&self, ticket: Ticket) -> QueueStatus {
        self.queue.poll(ticket)
    }

    /// Claim a serviced submission's completion (tickets are
    /// single-use).
    pub fn take_completion(&mut self, ticket: Ticket) -> Option<Completion> {
        self.queue.take(ticket)
    }

    /// A cloneable, `Send` submission endpoint onto `slot`'s lane of
    /// the cluster queue: per-device driver threads submit (and
    /// `poll`/`take`/`wait`) from their own contexts while the cluster
    /// owner keeps ticking ([`Cluster::tick_queue`] pumps the intake
    /// channel every tick). Errors if the slot has no live host — but
    /// note a handle outliving its host is safe: submissions landing on
    /// a crashed slot complete with [`Error::Cancelled`].
    pub fn submit_handle(&self, slot: usize) -> Result<SubmitHandle> {
        self.host(slot)?;
        self.queue.handle(slot)
    }

    /// The cluster-wide allocation queue (stats / pending inspection).
    pub fn queue(&self) -> &AllocQueue {
        &self.queue
    }

    /// One deterministic scheduling tick: pop up to the per-lane quota
    /// from every live slot (lanes visited in rotating order, so no
    /// host starves), execute each slot's group under a single fabric
    /// lock, and post completions. Returns how many requests were
    /// serviced.
    pub fn tick_queue(&mut self) -> usize {
        let mut rest = self.queue.schedule(self.lane_quota);
        let total = rest.len();
        while !rest.is_empty() {
            let lane = rest[0].lane;
            let cut = rest.iter().position(|s| s.lane != lane).unwrap_or(rest.len());
            let tail = rest.split_off(cut);
            let group = std::mem::replace(&mut rest, tail);
            self.execute_group(lane, group);
        }
        total
    }

    /// Tick until the cluster queue is idle; returns how many
    /// submissions were serviced.
    pub fn drain_queue(&mut self) -> usize {
        let mut total = 0;
        loop {
            let n = self.tick_queue();
            if n == 0 {
                return total;
            }
            total += n;
        }
    }

    /// Execute one slot's scheduled group. Requests that reference a
    /// sibling host's mmid complete with [`Error::NotOwner`] (the
    /// router's cross-host isolation rule) without touching the fabric;
    /// the rest run under the host's single-lock execution path.
    fn execute_group(&mut self, lane: usize, group: Vec<Scheduled>) {
        if self.host(lane).is_err() {
            // the host vanished between scheduling and execution
            // (defensive: crash_host cancels the lane first)
            for s in group {
                self.queue.complete(Completion {
                    ticket: s.ticket,
                    lane,
                    tenant: s.tenant,
                    result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                });
            }
            return;
        }
        let mut runnable = Vec::with_capacity(group.len());
        for s in group {
            if let Some(mmid) = s.request.target_mmid() {
                if self.check_home(lane, mmid).is_err() {
                    self.queue.complete(Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::NotOwner { mmid }),
                    });
                    continue;
                }
            }
            runnable.push(s);
        }
        if runnable.is_empty() {
            return;
        }
        let host = self
            .slots
            .get_mut(lane)
            .and_then(|s| s.as_mut())
            .expect("host liveness checked above");
        let completions = host.execute_requests(runnable);
        for c in completions {
            self.queue.complete(c);
        }
    }

    /// One-shot path for the synchronous routed surface: submit, drain,
    /// claim.
    fn submit_and_wait(&mut self, slot: usize, request: Request) -> Result<Outcome> {
        let ticket = self.submit(slot, request)?;
        self.drain_queue();
        match self.queue.take(ticket) {
            Some(c) => c.result,
            None => Err(Error::FabricManager("cluster queue lost a completion".into())),
        }
    }

    /// Reject an operation routed at `slot` for an mmid that lives on a
    /// sibling host. (An mmid unknown everywhere falls through to the
    /// module's own `UnknownMmId` error.)
    fn check_home(&self, slot: usize, mmid: MmId) -> Result<()> {
        if self.host(slot)?.get(mmid).is_none() && self.owner_slot_of(mmid).is_some() {
            return Err(Error::NotOwner { mmid });
        }
        Ok(())
    }

    /// Which slot's host holds `mmid`, if any.
    pub fn owner_slot_of(&self, mmid: MmId) -> Option<usize> {
        self.hosts().find(|(_, h)| h.get(mmid).is_some()).map(|(i, _)| i)
    }

    // ---- capacity / accounting ----

    /// Unleased capacity in the shared pool.
    pub fn available(&self) -> u64 {
        self.fabric.available()
    }

    /// Bytes the FM has leased to `slot`'s host.
    pub fn leased_to(&self, slot: usize) -> Result<u64> {
        Ok(self.fabric.leased_to(self.host(slot)?.host()))
    }

    // ---- failure domain ----

    /// Crash `slot`'s host: its queued-but-unscheduled submissions are
    /// cancelled (each completes with [`Error::Cancelled`], so no
    /// ticket dangles and nothing executes against reclaimed memory),
    /// its module state vanishes, and the FM reclaims every lease
    /// (revoking stale SAT grants and HDM decoders with them). Siblings
    /// keep their extents, placements, grants and queued submissions.
    pub fn crash_host(&mut self, slot: usize) -> Result<()> {
        let host = self
            .slots
            .get_mut(slot)
            .ok_or_else(|| Error::FabricManager(format!("no slot {slot}")))?
            .take()
            .ok_or_else(|| Error::FabricManager(format!("host in slot {slot} already gone")))?;
        self.queue.cancel_lane(slot);
        self.fabric.release_host(host.host());
        if let Some(ring) = &self.events {
            let sink = ring.sink();
            sink.emit(Event::Crash { tick: sink.now(), lane: slot });
        }
        Ok(())
    }

    /// Fabric + every live host's module invariants, plus the
    /// cluster-level ones: fabric-global mmid uniqueness and exact
    /// lease accounting across hosts.
    pub fn check_invariants(&self) -> Result<()> {
        self.fabric.check_invariants()?;
        let mut seen: HashSet<MmId> = HashSet::new();
        let mut leased_sum = 0;
        for (slot, host) in self.hosts() {
            host.module().check_invariants()?;
            for mmid in host.module().mmids() {
                if !seen.insert(mmid) {
                    return Err(Error::FabricManager(format!(
                        "mmid {mmid:?} appears on two hosts (slot {slot})"
                    )));
                }
            }
            let fm_view = self.fabric.leased_to(host.host());
            let module_view = host.module().leased();
            if fm_view != module_view {
                return Err(Error::FabricManager(format!(
                    "slot {slot}: FM says {fm_view} B leased, module says {module_view} B"
                )));
            }
            leased_sum += fm_view;
        }
        // poison-tolerant like every other read in this sweep: the
        // audit must keep working after a panic poisoned the lock
        let capacity = self.fabric.capacity();
        if self.fabric.available() + leased_sum != capacity {
            return Err(Error::FabricManager(format!(
                "cluster capacity leak: free {} + leased {} != {}",
                self.fabric.available(),
                leased_sum,
                capacity
            )));
        }
        Ok(())
    }

    /// Convert a fully-built cluster into the actor-side triple the
    /// scenario engine drives: the [`FmService`] owning the hosts (lane
    /// `i` = slot `i`, same lane quota), a [`FabricRef`] clone for
    /// failure injection and invariant sweeps, and the cluster's
    /// latency model. The builder stays the one place topology is
    /// configured; the service becomes the one place requests execute.
    ///
    /// Refuses if any slot has crashed (lane numbering would silently
    /// shift) or the cluster queue still holds undrained submissions
    /// (their tickets would be stranded — the service has its own
    /// queue).
    pub fn into_service(mut self) -> Result<(FmService, FabricRef, Fabric)> {
        self.queue.pump();
        if self.queue.pending() > 0 || self.queue.ready() > 0 {
            return Err(Error::FabricManager(
                "drain the cluster queue before converting to a service".into(),
            ));
        }
        let mut hosts = Vec::with_capacity(self.slots.len());
        for (slot, h) in self.slots.drain(..).enumerate() {
            match h {
                Some(h) => hosts.push(h),
                None => {
                    return Err(Error::FabricManager(format!(
                        "slot {slot} has crashed; rebuild the cluster before converting"
                    )))
                }
            }
        }
        let mut svc = FmService::new(hosts)
            .with_lane_quota(self.lane_quota)
            .with_limits(self.queue.limits());
        if let Some(ring) = self.events.take() {
            svc.set_event_ring(ring);
        }
        Ok((svc, self.fabric.clone(), self.latency.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::{Bdf, EXTENT_SIZE, PAGE_SIZE};

    fn two_hosts() -> (Cluster, Bdf) {
        let b = Cluster::builder().hosts(2).expander_gib(1).host_dram_gib(1);
        (b.build().unwrap(), Bdf::new(1, 0, 0))
    }

    #[test]
    fn builder_binds_n_hosts_to_one_fabric() {
        let (cluster, _) = two_hosts();
        assert_eq!(cluster.alive_hosts(), 2);
        let ids: Vec<_> = cluster.hosts().map(|(_, h)| h.host()).collect();
        assert_ne!(ids[0], ids[1]);
        assert_eq!(cluster.available(), GIB);
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn routed_ops_and_owner_lookup() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        let a = cluster.alloc(0, dev, PAGE_SIZE).unwrap();
        let b = cluster.alloc(1, dev, PAGE_SIZE).unwrap();
        assert_eq!(cluster.owner_slot_of(a.mmid), Some(0));
        assert_eq!(cluster.owner_slot_of(b.mmid), Some(1));
        assert_eq!(cluster.owner_slot_of(MmId(0xdead)), None);
        cluster.free(0, dev, a.mmid).unwrap();
        cluster.free(1, dev, b.mmid).unwrap();
        assert_eq!(cluster.available(), GIB);
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn cross_host_free_and_share_rejected_as_not_owner() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        let a = cluster.alloc(0, dev, PAGE_SIZE).unwrap();
        assert!(matches!(cluster.free(1, dev, a.mmid), Err(Error::NotOwner { .. })));
        assert!(matches!(cluster.share(1, dev, dev, a.mmid), Err(Error::NotOwner { .. })));
        // a genuinely unknown mmid is still UnknownMmId
        assert!(matches!(cluster.free(1, dev, MmId(0xdead)), Err(Error::UnknownMmId(_))));
        // the owner path still works
        cluster.free(0, dev, a.mmid).unwrap();
    }

    #[test]
    fn queued_submissions_route_and_complete_per_slot() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        let req = Request::Alloc { consumer: dev.into(), size: PAGE_SIZE };
        let t0 = cluster.submit(0, req).unwrap();
        let t1 = cluster.submit(1, req).unwrap();
        assert_eq!(cluster.poll_submission(t0), QueueStatus::Queued);
        assert_eq!(cluster.queue().pending(), 2);
        assert_eq!(cluster.drain_queue(), 2);
        let a0 = cluster.take_completion(t0).unwrap().into_alloc().unwrap();
        let a1 = cluster.take_completion(t1).unwrap().into_alloc().unwrap();
        assert_eq!(cluster.owner_slot_of(a0.mmid), Some(0));
        assert_eq!(cluster.owner_slot_of(a1.mmid), Some(1));
        assert_eq!(cluster.leased_to(0).unwrap(), EXTENT_SIZE);
        assert_eq!(cluster.leased_to(1).unwrap(), EXTENT_SIZE);
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn queued_cross_host_ops_complete_with_not_owner() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        let a = cluster.alloc(0, dev, PAGE_SIZE).unwrap();
        // a queued free routed at the wrong slot completes NotOwner
        let req = Request::Free { consumer: dev.into(), mmid: a.mmid };
        let t = cluster.submit(1, req).unwrap();
        cluster.drain_queue();
        let c = cluster.take_completion(t).unwrap();
        assert!(matches!(c.result, Err(Error::NotOwner { .. })), "got {:?}", c.result);
        // the allocation is untouched and the owner path still works
        assert_eq!(cluster.owner_slot_of(a.mmid), Some(0));
        cluster.free(0, dev, a.mmid).unwrap();
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn queue_is_fair_across_hosts_under_flood() {
        // slot 0 floods 3 extents' worth; slot 1 asks for one. With a
        // 1 GiB pool (4 extents) and per-lane quota 1, fair rotation
        // guarantees slot 1's single request is serviced long before
        // the flood can drain the pool.
        let dev = Bdf::new(1, 0, 0);
        let mut c = Cluster::builder()
            .hosts(2)
            .expander_gib(1)
            .host_dram_gib(1)
            .lane_quota(1)
            .build()
            .unwrap();
        c.host_mut(0).unwrap().attach_pcie(dev);
        c.host_mut(1).unwrap().attach_pcie(dev);
        let req = Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE };
        let flood: Vec<_> = (0..4).map(|_| c.submit(0, req).unwrap()).collect();
        let light = c.submit(1, req).unwrap();
        c.drain_queue();
        assert!(
            c.take_completion(light).unwrap().result.is_ok(),
            "fair scheduling served the light host before the flood drained the pool"
        );
        let mut flood_ok = 0;
        for t in flood {
            if c.take_completion(t).unwrap().result.is_ok() {
                flood_ok += 1;
            }
        }
        assert_eq!(flood_ok, 3, "the flood got the remaining extents");
        c.check_invariants().unwrap();
    }

    #[test]
    fn crash_host_is_contained_and_rejoinable() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        cluster.alloc(0, dev, EXTENT_SIZE).unwrap();
        let survivor = cluster.alloc(1, dev, PAGE_SIZE).unwrap();
        cluster.host_mut(1).unwrap().write(survivor.mmid, 0, b"sibling").unwrap();

        cluster.crash_host(0).unwrap();
        assert!(cluster.host(0).is_err());
        assert!(cluster.crash_host(0).is_err(), "double crash rejected");
        assert_eq!(cluster.alive_hosts(), 1);
        assert_eq!(cluster.available(), GIB - EXTENT_SIZE, "victim's extent reclaimed");

        // the sibling's placement is untouched
        let mut buf = [0u8; 7];
        cluster.host(1).unwrap().read(survivor.mmid, 0, &mut buf).unwrap();
        assert_eq!(&buf, b"sibling");
        cluster.check_invariants().unwrap();

        // a replacement host joins the same pool
        let slot = cluster.join_host().unwrap();
        assert_eq!(slot, 2);
        cluster.host_mut(slot).unwrap().attach_pcie(dev);
        cluster.alloc(slot, dev, PAGE_SIZE).unwrap();
        cluster.check_invariants().unwrap();
    }

    #[test]
    fn into_service_hands_hosts_to_the_actor_side() {
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.host_mut(1).unwrap().attach_pcie(dev);
        let (mut svc, fabric, latency) = cluster.into_service().unwrap();
        assert_eq!(svc.lanes(), 2);
        assert!(latency.path_latency(crate::cxl::fabric::PathKind::HostToHdm).as_ns() > 0);
        let h = svc.handle(1).unwrap();
        let t = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h.take(t).unwrap().into_alloc().unwrap();
        assert_eq!(fabric.lease_count(), 1);
        svc.check_invariants().unwrap();
    }

    #[test]
    fn into_service_refuses_crashed_or_undrained_clusters() {
        let (mut cluster, _) = two_hosts();
        cluster.crash_host(0).unwrap();
        assert!(cluster.into_service().is_err());
        let (mut cluster, dev) = two_hosts();
        cluster.host_mut(0).unwrap().attach_pcie(dev);
        cluster.submit(0, Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert!(cluster.into_service().is_err(), "undrained submissions would strand tickets");
    }
}
