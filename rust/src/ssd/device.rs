//! Event-driven functional SSD device — the "white-box firmware".
//!
//! Executes a real request stream through the discrete-event engine:
//! closed-loop QD admission, an index stage with `W` lookup slots whose
//! memory accesses go to the configured placement (and, for DFTL, an
//! *actual* CLOCK CMT deciding hit/miss per LPA), a die-accurate media
//! stage, and a serializing host link. The L2P table is updated
//! functionally along the way.
//!
//! Role in the architecture: the microscopic cross-check of the batched
//! analytic data plane. `rust/tests/des_crosscheck.rs` asserts that at
//! small scale the event-driven device reproduces the same scheme
//! ordering and (for media-bound cells) the same throughput as the
//! batch model the XLA path executes.

use crate::cxl::fabric::Fabric;
use crate::sim::engine::Engine;
use crate::sim::rng::Pcg64;
use crate::sim::stats::{LatencyHistogram, Throughput};
use crate::sim::time::SimTime;
use crate::ssd::controller::Controller;
use crate::ssd::ftl::dftl::CmtCache;
use crate::ssd::ftl::l2p::L2pTable;
use crate::ssd::spec::SsdSpec;
use crate::ssd::IndexPlacement;
use crate::workload::fio::{FioJob, IoRequest};

/// Pipeline events for one IO (payload = IO index into the trace).
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Try to admit the next IO (closed loop).
    Admit,
    /// Index lookup finished for IO i.
    IndexDone(usize),
    /// Media service finished for IO i.
    MediaDone(usize),
    /// Link transfer finished for IO i (completion).
    LinkDone(usize),
}

/// Result of one device run.
#[derive(Debug)]
pub struct DeviceRun {
    pub completed: u64,
    pub span: SimTime,
    pub latency: LatencyHistogram,
    pub throughput: Throughput,
    /// Observed DFTL CMT hit ratio (1.0 for non-DFTL placements).
    pub cmt_hit_ratio: f64,
    /// Events dispatched by the engine (observability).
    pub events: u64,
}

impl DeviceRun {
    pub fn kiops(&self) -> f64 {
        self.throughput.kiops()
    }
}

/// The event-driven device.
pub struct SsdDevice {
    ctl: Controller,
    /// Free-at times for the W index slots.
    index_slots: Vec<SimTime>,
    /// Free-at times per die.
    dies: Vec<SimTime>,
    /// Host link free-at.
    link_free: SimTime,
    l2p: L2pTable,
    cmt: CmtCache,
    rng: Pcg64,
    /// Write-calendar slot service (set per write job in [`Self::run`]).
    write_service: Option<SimTime>,
}

impl SsdDevice {
    pub fn new(spec: SsdSpec, placement: IndexPlacement, fabric: Fabric, span_pages: u64) -> Self {
        let entries_per_tpage = spec.nand.page_bytes as u64 / 4;
        // CMT sized to hold the calibrated hit ratio's working share:
        // 64 translation pages ≈ 1 MiB of CMT (see spec calibration).
        let cmt = CmtCache::new(64, entries_per_tpage);
        let w = spec.pipeline.index_width as usize;
        let dies = spec.nand.dies() as usize;
        let ctl = Controller::new(spec, placement, fabric);
        SsdDevice {
            ctl,
            index_slots: vec![SimTime::ZERO; w],
            dies: vec![SimTime::ZERO; dies],
            link_free: SimTime::ZERO,
            l2p: L2pTable::new(span_pages),
            cmt,
            rng: Pcg64::with_stream(0xde5, 0x55d),
            write_service: None,
        }
    }

    pub fn controller(&self) -> &Controller {
        &self.ctl
    }

    /// Index service for one concrete request: for DFTL the CMT decides
    /// hit/miss from the real LPA; other placements use the scheme's
    /// fixed access chain (reads only — updates are posted).
    fn index_service(&mut self, req: IoRequest) -> SimTime {
        let spec = &self.ctl.spec;
        let f = SimTime::ns(spec.pipeline.firmware_ns as u64);
        match self.ctl.placement {
            IndexPlacement::Dftl => {
                let hit = self.cmt.access(req.lpa);
                let dram = self.ctl.fabric.cfg.onboard_dram;
                if hit {
                    f + dram
                } else {
                    let ops = if req.is_write {
                        spec.pipeline.dftl_flash_ops_write
                    } else {
                        spec.pipeline.dftl_flash_ops_read
                    };
                    f + dram
                        + SimTime::ns(
                            (ops * self.ctl.fabric.cfg.flash_read.as_ns() as f64) as u64,
                        )
                }
            }
            _ if req.is_write => f,
            _ => f + self.ctl.index_access() * spec.pipeline.index_accesses as u64,
        }
    }

    fn media_service(&mut self, req: IoRequest) -> SimTime {
        let spec = &self.ctl.spec;
        if req.is_write {
            // calendar slot sized for sustained (post-WA) drain; the
            // perceived ack is within ~t_buf at sub-saturation depths
            self.write_service.unwrap_or(spec.write_buffer_latency)
        } else {
            // tR with ±10% sense-time jitter, as the batch model uses
            let jit = 0.9 + 0.2 * self.rng.next_f64();
            SimTime::ns((spec.nand.t_read.as_ns() as f64 * jit) as u64)
        }
    }

    /// Acquire the earliest-free resource from a calendar, starting no
    /// earlier than `now`; returns the service completion time.
    fn acquire(cal: &mut [SimTime], now: SimTime, service: SimTime) -> SimTime {
        let (idx, _) = cal
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| **t)
            .expect("non-empty calendar");
        let start = cal[idx].max(now);
        let done = start + service;
        cal[idx] = done;
        done
    }

    /// Run a job's request stream through the device, closed-loop at the
    /// job's outstanding depth. Functionally maintains the L2P table.
    pub fn run(&mut self, job: &FioJob) -> crate::Result<DeviceRun> {
        job.validate()?;
        let requests: Vec<IoRequest> = job.generate().collect();
        let total = requests.len();
        let qd = job.outstanding() as usize;
        let xfer = self.ctl.spec.link().serialize(job.block_size as u64);

        // Writes are buffered, but the buffer drains at the sustained
        // program rate after write amplification (GC) and the
        // controller's small-block commit cap — size a write calendar so
        // its capacity equals the analytic media bound.
        if job.pattern.is_write() {
            let caps = self.ctl.stage_caps(job.pattern, job.block_size);
            let cap = caps.media_iops.min(caps.write_path_iops.unwrap_or(f64::MAX));
            let t_buf = self.ctl.spec.write_buffer_latency.as_secs_f64();
            let slots = (cap * t_buf).ceil().max(1.0) as usize;
            let service = SimTime::ns((slots as f64 / cap * 1e9) as u64);
            self.dies = vec![SimTime::ZERO; slots];
            self.write_service = Some(service);
        }

        let mut engine: Engine<Ev> = Engine::new();
        let mut submitted = 0usize;
        let mut inflight = 0usize;
        let mut completed = 0u64;
        let mut start_times = vec![SimTime::ZERO; total];
        let mut hist = LatencyHistogram::new();
        let mut tput = Throughput::new();

        for _ in 0..qd.min(total) {
            engine.schedule_at(SimTime::ZERO, Ev::Admit);
        }

        // reborrow for the dispatch closure (self is used again after)
        let this = &mut *self;
        engine.run_until(SimTime::MAX, |eng, now, ev| match ev {
            Ev::Admit => {
                if submitted >= total {
                    return;
                }
                let i = submitted;
                submitted += 1;
                inflight += 1;
                start_times[i] = now;
                let req = requests[i];
                let service = this.index_service(req);
                let done = Self::acquire(&mut this.index_slots, now, service);
                eng.schedule_at(done, Ev::IndexDone(i));
            }
            Ev::IndexDone(i) => {
                let req = requests[i];
                // functional L2P maintenance
                if req.is_write {
                    let ppa = (this.l2p.updates % u32::MAX as u64) as u32;
                    this.l2p.update(req.lpa, ppa);
                } else {
                    let _ = this.l2p.lookup(req.lpa);
                }
                let service = this.media_service(req);
                let done = Self::acquire(&mut this.dies, now, service);
                eng.schedule_at(done, Ev::MediaDone(i));
            }
            Ev::MediaDone(i) => {
                let start = this.link_free.max(now);
                this.link_free = start + xfer;
                eng.schedule_at(this.link_free, Ev::LinkDone(i));
            }
            Ev::LinkDone(i) => {
                completed += 1;
                inflight -= 1;
                hist.record(now - start_times[i]);
                if submitted < total {
                    eng.schedule_at(now, Ev::Admit);
                }
            }
        });

        debug_assert_eq!(inflight, 0, "all IOs drained");
        let span = engine.now();
        tput.record(completed, completed * job.block_size as u64);
        tput.set_span(span);
        Ok(DeviceRun {
            completed,
            span,
            latency: hist,
            throughput: tput,
            cmt_hit_ratio: if self.ctl.placement == IndexPlacement::Dftl {
                self.cmt.hit_ratio()
            } else {
                1.0
            },
            events: engine.processed(),
        })
    }

    /// Mapped entries after a run (functional-path observability).
    pub fn mapped_pages(&self) -> usize {
        self.l2p.mapped_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;
    use crate::workload::fio::IoPattern;

    fn run(placement: IndexPlacement, pattern: IoPattern, ios: u64) -> DeviceRun {
        let mut job = FioJob::paper(pattern, GIB);
        job.total_ios = ios;
        let mut dev =
            SsdDevice::new(SsdSpec::gen5(), placement, Fabric::default(), job.span_pages());
        dev.run(&job).unwrap()
    }

    #[test]
    fn completes_all_ios_and_counts_events() {
        let r = run(IndexPlacement::Ideal, IoPattern::RandRead, 5_000);
        assert_eq!(r.completed, 5_000);
        // 1 admit + 3 stage events per IO
        assert_eq!(r.events, 4 * 5_000);
        assert!(r.span > SimTime::ZERO);
    }

    fn run_wide(placement: IndexPlacement, ios: u64) -> DeviceRun {
        // 64 GiB span so random reads genuinely thrash the 64-page CMT
        // (a 1 GiB span fits the CMT entirely and DFTL ≈ Ideal — the
        // locality effect, covered by dftl_cmt_sees_sequential_locality).
        let mut job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
        job.total_ios = ios;
        let mut dev =
            SsdDevice::new(SsdSpec::gen5(), placement, Fabric::default(), job.span_pages());
        dev.run(&job).unwrap()
    }

    #[test]
    fn scheme_ordering_matches_analytic_model() {
        let ideal = run_wide(IndexPlacement::Ideal, 20_000).kiops();
        let cxl = run_wide(IndexPlacement::LmbCxl, 20_000).kiops();
        let pcie = run_wide(IndexPlacement::LmbPcie, 20_000).kiops();
        let dftl = run_wide(IndexPlacement::Dftl, 20_000).kiops();
        assert!(ideal >= cxl * 0.99, "ideal {ideal} vs cxl {cxl}");
        assert!(cxl > pcie, "cxl {cxl} vs pcie {pcie}");
        assert!(pcie > dftl, "pcie {pcie} vs dftl {dftl}");
    }

    #[test]
    fn writes_functionally_update_l2p() {
        let mut job = FioJob::paper(IoPattern::RandWrite, GIB);
        job.total_ios = 3_000;
        let mut dev = SsdDevice::new(
            SsdSpec::gen4(),
            IndexPlacement::LmbCxl,
            Fabric::default(),
            job.span_pages(),
        );
        let r = dev.run(&job).unwrap();
        assert_eq!(r.completed, 3_000);
        assert!(dev.mapped_pages() > 2_000, "most writes hit distinct pages");
    }

    #[test]
    fn dftl_cmt_sees_sequential_locality() {
        let seq = run(IndexPlacement::Dftl, IoPattern::SeqRead, 20_000);
        let rand = run(IndexPlacement::Dftl, IoPattern::RandRead, 20_000);
        assert!(seq.cmt_hit_ratio > 0.95, "seq hit {}", seq.cmt_hit_ratio);
        assert!(
            rand.cmt_hit_ratio < seq.cmt_hit_ratio,
            "rand {} vs seq {}",
            rand.cmt_hit_ratio,
            seq.cmt_hit_ratio
        );
        assert!(seq.kiops() > rand.kiops());
    }

    #[test]
    fn latency_floor_is_base_service() {
        let r = run(IndexPlacement::LmbCxl, IoPattern::RandRead, 5_000);
        // min latency >= idx(430+4*190) + 0.9*tR + xfer
        let floor = 430 + 4 * 190 + (0.9 * 57_000.0) as u64;
        assert!(
            r.latency.min().as_ns() >= floor,
            "min {} < floor {floor}",
            r.latency.min()
        );
    }

    #[test]
    fn qd1_throughput_is_inverse_latency() {
        let mut job = FioJob::paper(IoPattern::RandRead, GIB);
        job.total_ios = 2_000;
        job.qd = 1;
        job.numjobs = 1;
        let mut dev = SsdDevice::new(
            SsdSpec::gen5(),
            IndexPlacement::Ideal,
            Fabric::default(),
            job.span_pages(),
        );
        let r = dev.run(&job).unwrap();
        let expect = 1.0 / r.latency.mean().as_secs_f64();
        let got = r.throughput.iops();
        assert!(
            (got - expect).abs() / expect < 0.02,
            "QD1: X {got} vs 1/R {expect}"
        );
    }
}
