//! SSD controller pipeline model.
//!
//! Every IO crosses three explicit stages, mirroring how the paper's
//! firmware modification works (§4: latency is injected into "the L2P
//! indexing module"):
//!
//! ```text
//!   host link ──► index stage (W FTL lookup slots) ──► media ──► done
//! ```
//!
//! * **Reads** perform a *synchronous* L2P lookup before media access:
//!   `k` dependent index-memory references at the placement's latency
//!   (derived from the fabric model) plus firmware time `f`. This is the
//!   stage the four schemes differ in, and where added CXL latency eats
//!   throughput on fast devices — the paper's central result.
//! * **Writes** buffer data and *post* their mapping updates (no
//!   round-trip), so Ideal/LMB writes are index-neutral — exactly the
//!   paper's observation that LMB write throughput matches Ideal.
//!   DFTL, by contrast, must synchronously fetch (and on eviction write
//!   back) translation pages from flash, which is why its writes crater.
//!
//! Throughput is the bottleneck-stage capacity capped by the closed-loop
//! limit (`outstanding / base_latency`); saturated mean latency follows
//! Little's law. Per-IO latency *distributions* come from the batched
//! max-plus pipeline scan executed by the AOT-compiled XLA model
//! ([`crate::runtime`]), with this module supplying per-IO service
//! parameters.

use crate::cxl::fabric::Fabric;
use crate::sim::time::SimTime;
use crate::ssd::ftl::dftl::DftlModel;
use crate::ssd::spec::SsdSpec;
use crate::ssd::IndexPlacement;
use crate::workload::fio::{FioJob, IoPattern};

/// Calibrated index-stage parameters (per device; see DESIGN.md
/// §Calibration).
#[derive(Debug, Clone, Copy)]
pub struct PipelineParams {
    /// Parallel FTL lookup slots (W).
    pub index_width: u32,
    /// Firmware processing per IO in the index stage, ns (f).
    pub firmware_ns: f64,
    /// Dependent index-memory references per read lookup (k).
    pub index_accesses: u32,
    /// Expected flash ops per DFTL read miss (translation fetch).
    pub dftl_flash_ops_read: f64,
    /// Expected flash ops per DFTL write miss (fetch + dirty evict).
    pub dftl_flash_ops_write: f64,
}

/// Capacities of each pipeline stage, in IOPS, for one (pattern, scheme).
#[derive(Debug, Clone, Copy)]
pub struct StageCaps {
    pub link_iops: f64,
    pub index_iops: f64,
    pub media_iops: f64,
    /// Small-block write-path commit cap (writes only).
    pub write_path_iops: Option<f64>,
}

impl StageCaps {
    /// The binding stage.
    pub fn bottleneck(&self) -> f64 {
        let mut x = self.link_iops.min(self.index_iops).min(self.media_iops);
        if let Some(w) = self.write_path_iops {
            x = x.min(w);
        }
        x
    }

    /// Name of the binding stage (reports/flamegraph-style attribution).
    pub fn bottleneck_name(&self) -> &'static str {
        let b = self.bottleneck();
        if let Some(w) = self.write_path_iops {
            if b == w {
                return "write-path";
            }
        }
        if b == self.index_iops {
            "index"
        } else if b == self.media_iops {
            "media"
        } else {
            "link"
        }
    }
}

/// The controller model for one device + index placement.
#[derive(Debug, Clone)]
pub struct Controller {
    pub spec: SsdSpec,
    pub placement: IndexPlacement,
    pub fabric: Fabric,
    /// DFTL CMT hit ratio used by the analytic model (the paper's own
    /// simulation corresponds to 0.0; measured CMT warm-up can override).
    pub dftl_hit_ratio: f64,
    /// Multiplier on index-memory access latency (shared-expander
    /// contention inflation, set by the coordinator; 1.0 = uncontended).
    pub index_access_inflation: f64,
}

impl Controller {
    pub fn new(spec: SsdSpec, placement: IndexPlacement, fabric: Fabric) -> Self {
        let dftl_hit_ratio = default_dftl_hit(spec.name);
        Controller { spec, placement, fabric, dftl_hit_ratio, index_access_inflation: 1.0 }
    }

    fn dftl_model(&self) -> DftlModel {
        DftlModel {
            hit_ratio: self.dftl_hit_ratio,
            flash_read: self.fabric.cfg.flash_read,
            flash_ops_read: self.spec.pipeline.dftl_flash_ops_read,
            flash_ops_write: self.spec.pipeline.dftl_flash_ops_write,
            dram_access: self.fabric.cfg.onboard_dram,
        }
    }

    /// One index-memory access at this placement (contention-inflated).
    pub fn index_access(&self) -> SimTime {
        let base = self.placement.index_access_latency(&self.fabric, self.spec.gen);
        SimTime::ns((base.as_ns() as f64 * self.index_access_inflation) as u64)
    }

    /// Index-stage service time for one IO.
    pub fn index_service(&self, is_write: bool) -> SimTime {
        let f = SimTime::ns(self.spec.pipeline.firmware_ns as u64);
        match self.placement {
            IndexPlacement::Dftl => f + self.dftl_model().expected_index_cost(is_write),
            _ if is_write => f, // posted mapping update: no round-trip
            _ => f + self.index_access() * self.spec.pipeline.index_accesses as u64,
        }
    }

    /// Stage capacities for a pattern at block size `bs`.
    pub fn stage_caps(&self, pattern: IoPattern, bs: u32) -> StageCaps {
        let bs_f = bs as f64;
        let link_iops = self.spec.link().bandwidth_bps() as f64 / bs_f;
        let idx_service = self.index_service(pattern.is_write()).as_secs_f64();
        let index_iops = self.spec.pipeline.index_width as f64 / idx_service;

        let nand = &self.spec.nand;
        let page = nand.page_bytes as f64;
        let (media_iops, write_path_iops) = if pattern.is_write() {
            let wa = if pattern.is_seq() {
                1.0
            } else {
                self.spec.write_amplification()
            };
            let media = nand.program_bw_bps() / (bs_f * wa);
            (media, Some(self.spec.write_path_kiops * 1e3))
        } else {
            let per_read_pages = (bs_f / page).max(1.0);
            let die_iops = nand.read_iops() / per_read_pages;
            let media = if pattern.is_seq() {
                // sequential reads coalesce: one page read serves
                // page/bs consecutive IOs, bounded by channel bandwidth
                let coalesced = die_iops * (page / bs_f).max(1.0);
                coalesced.min(nand.seq_read_bw_bps() / bs_f)
            } else {
                die_iops
            };
            (media, None)
        };
        StageCaps { link_iops, index_iops, media_iops, write_path_iops }
    }

    /// Unloaded per-IO latency (QD=1 service sum).
    pub fn base_latency(&self, pattern: IoPattern, bs: u32) -> SimTime {
        let xfer = self.spec.link().serialize(bs as u64);
        if pattern.is_write() {
            self.index_service(true) + self.spec.write_buffer_latency + xfer
        } else {
            self.index_service(false) + self.spec.nand.t_read + xfer
        }
    }

    /// Closed-loop steady-state throughput for a job, in IOPS.
    pub fn throughput_iops(&self, job: &FioJob) -> f64 {
        let caps = self.stage_caps(job.pattern, job.block_size);
        let r = self.base_latency(job.pattern, job.block_size).as_secs_f64();
        let closed_loop = job.outstanding() as f64 / r;
        caps.bottleneck().min(closed_loop)
    }

    /// Mean latency under the job's load (Little's law in saturation).
    pub fn mean_latency(&self, job: &FioJob) -> SimTime {
        let x = self.throughput_iops(job);
        let r = self.base_latency(job.pattern, job.block_size);
        let little = job.outstanding() as f64 / x;
        SimTime::ns((little.max(r.as_secs_f64()) * 1e9) as u64)
    }

    /// Bandwidth in GB/s for a job.
    pub fn throughput_gbps(&self, job: &FioJob) -> f64 {
        self.throughput_iops(job) * job.block_size as f64 / 1e9
    }
}

/// Default DFTL CMT hit ratio per device (calibrated; the Gen5 part's
/// hotter pipeline thrashes its relatively smaller CMT harder).
fn default_dftl_hit(name: &str) -> f64 {
    if name.contains("Gen5") {
        0.20
    } else {
        0.35
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::GIB;

    fn ctl(spec: SsdSpec, placement: IndexPlacement) -> Controller {
        Controller::new(spec, placement, Fabric::default())
    }

    fn job(pattern: IoPattern) -> FioJob {
        FioJob::paper(pattern, 64 * GIB)
    }

    fn kiops(c: &Controller, pattern: IoPattern) -> f64 {
        c.throughput_iops(&job(pattern)) / 1e3
    }

    // ---- Table 3 calibration: Ideal must land on spec ----

    #[test]
    fn gen4_ideal_matches_table3() {
        let c = ctl(SsdSpec::gen4(), IndexPlacement::Ideal);
        let rr = kiops(&c, IoPattern::RandRead);
        assert!((rr - 1750.0).abs() / 1750.0 < 0.05, "gen4 rand read {rr}");
        let rw = kiops(&c, IoPattern::RandWrite);
        assert!((rw - 340.0).abs() / 340.0 < 0.05, "gen4 rand write {rw}");
    }

    #[test]
    fn gen5_ideal_matches_table3() {
        let c = ctl(SsdSpec::gen5(), IndexPlacement::Ideal);
        let rr = kiops(&c, IoPattern::RandRead);
        assert!((rr - 2800.0).abs() / 2800.0 < 0.05, "gen5 rand read {rr}");
        let rw = kiops(&c, IoPattern::RandWrite);
        assert!((rw - 700.0).abs() / 700.0 < 0.05, "gen5 rand write {rw}");
    }

    // ---- Figure 6(a) shape: Gen4 ----

    #[test]
    fn gen4_writes_lmb_matches_ideal() {
        for pattern in [IoPattern::RandWrite, IoPattern::SeqWrite] {
            let ideal = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), pattern);
            for p in [IndexPlacement::LmbCxl, IndexPlacement::LmbPcie] {
                let x = kiops(&ctl(SsdSpec::gen4(), p), pattern);
                assert!(
                    (x - ideal).abs() / ideal < 0.01,
                    "{pattern:?} {p:?}: {x} vs ideal {ideal}"
                );
            }
        }
    }

    #[test]
    fn gen4_dftl_writes_roughly_7x_worse() {
        let ideal = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), IoPattern::RandWrite);
        let dftl = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Dftl), IoPattern::RandWrite);
        let ratio = ideal / dftl;
        assert!((4.0..10.0).contains(&ratio), "gen4 write ratio {ratio} (paper ~7x)");
    }

    #[test]
    fn gen4_lmb_cxl_read_matches_ideal() {
        for pattern in [IoPattern::RandRead, IoPattern::SeqRead] {
            let ideal = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), pattern);
            let cxl = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::LmbCxl), pattern);
            assert!((cxl - ideal).abs() / ideal < 0.02, "{pattern:?}: {cxl} vs {ideal}");
        }
    }

    #[test]
    fn gen4_lmb_pcie_read_drops_10_to_20_pct() {
        let ideal = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), IoPattern::RandRead);
        let pcie = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::LmbPcie), IoPattern::RandRead);
        let drop = 1.0 - pcie / ideal;
        assert!((0.08..0.20).contains(&drop), "gen4 rand-read drop {drop} (paper 13.3%)");
    }

    #[test]
    fn gen4_dftl_reads_roughly_14x_worse() {
        let ideal = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), IoPattern::RandRead);
        let dftl = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Dftl), IoPattern::RandRead);
        let ratio = ideal / dftl;
        assert!((10.0..20.0).contains(&ratio), "gen4 read ratio {ratio} (paper ~14x)");
    }

    // ---- Figure 6(b) shape: Gen5 ----

    #[test]
    fn gen5_writes_lmb_matches_ideal_even_pcie() {
        let ideal = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Ideal), IoPattern::RandWrite);
        let pcie = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbPcie), IoPattern::RandWrite);
        assert!((pcie - ideal).abs() / ideal < 0.01, "{pcie} vs {ideal}");
    }

    #[test]
    fn gen5_lmb_cxl_rand_read_drops_hard() {
        // paper: −56%. Same +190 ns that was free on Gen4 bites here.
        let ideal = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Ideal), IoPattern::RandRead);
        let cxl = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbCxl), IoPattern::RandRead);
        let drop = 1.0 - cxl / ideal;
        assert!((0.25..0.60).contains(&drop), "gen5 CXL rand-read drop {drop} (paper 56%)");
    }

    #[test]
    fn gen5_lmb_pcie_rand_read_drops_harder_than_cxl() {
        let ideal = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Ideal), IoPattern::RandRead);
        let cxl = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbCxl), IoPattern::RandRead);
        let pcie = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbPcie), IoPattern::RandRead);
        assert!(pcie < cxl, "PCIe path must be worse than P2P");
        let drop = 1.0 - pcie / ideal;
        assert!(drop > 0.55, "gen5 PCIe rand-read drop {drop} (paper 70%)");
    }

    #[test]
    fn gen5_dftl_still_far_worse_than_lmb_pcie() {
        // paper: "LMB-PCIe can outperform the DFTL scheme by 20×"
        let pcie = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbPcie), IoPattern::RandRead);
        let dftl = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Dftl), IoPattern::RandRead);
        assert!(pcie / dftl > 2.0, "LMB-PCIe {pcie} vs DFTL {dftl}");
        let ideal = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Ideal), IoPattern::RandRead);
        assert!((15.0..40.0).contains(&(ideal / dftl)), "gen5 DFTL ratio {}", ideal / dftl);
    }

    // ---- the paper's takeaway: faster SSDs are hurt more ----

    #[test]
    fn cxl_latency_bites_harder_on_faster_device() {
        let d4 = {
            let i = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::Ideal), IoPattern::RandRead);
            let c = kiops(&ctl(SsdSpec::gen4(), IndexPlacement::LmbCxl), IoPattern::RandRead);
            1.0 - c / i
        };
        let d5 = {
            let i = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::Ideal), IoPattern::RandRead);
            let c = kiops(&ctl(SsdSpec::gen5(), IndexPlacement::LmbCxl), IoPattern::RandRead);
            1.0 - c / i
        };
        assert!(d5 > d4 + 0.2, "gen5 drop {d5} must exceed gen4 drop {d4}");
    }

    // ---- mechanics ----

    #[test]
    fn locality_recovers_dftl_performance() {
        // §4.1 closing remark, and the ablation bench's backbone.
        let mut c = ctl(SsdSpec::gen4(), IndexPlacement::Dftl);
        c.dftl_hit_ratio = 0.0;
        let cold = kiops(&c, IoPattern::RandRead);
        c.dftl_hit_ratio = 0.99;
        let hot = kiops(&c, IoPattern::RandRead);
        assert!(hot > cold * 10.0, "hot {hot} vs cold {cold}");
    }

    #[test]
    fn contention_inflation_reduces_lmb_throughput() {
        let mut c = ctl(SsdSpec::gen5(), IndexPlacement::LmbCxl);
        let base = kiops(&c, IoPattern::RandRead);
        c.index_access_inflation = 3.0;
        let contended = kiops(&c, IoPattern::RandRead);
        assert!(contended < base * 0.75, "{contended} vs {base}");
    }

    #[test]
    fn base_latency_near_spec() {
        let c = ctl(SsdSpec::gen4(), IndexPlacement::Ideal);
        let r = c.base_latency(IoPattern::RandRead, 4096);
        // spec says 67 µs; tR=73 µs + overheads ⇒ within 20%
        assert!((60_000..85_000).contains(&r.as_ns()), "read base {r}");
        let w = c.base_latency(IoPattern::RandWrite, 4096);
        assert!((9_000..12_000).contains(&w.as_ns()), "write base {w}");
    }

    #[test]
    fn bottleneck_attribution() {
        let c = ctl(SsdSpec::gen5(), IndexPlacement::LmbPcie);
        let caps = c.stage_caps(IoPattern::RandRead, 4096);
        assert_eq!(caps.bottleneck_name(), "index");
        let c = ctl(SsdSpec::gen4(), IndexPlacement::Ideal);
        let caps = c.stage_caps(IoPattern::RandRead, 4096);
        assert_eq!(caps.bottleneck_name(), "media");
    }

    #[test]
    fn large_block_reads_are_bandwidth_bound() {
        let c = ctl(SsdSpec::gen5(), IndexPlacement::Ideal);
        let mut j = job(IoPattern::SeqRead);
        j.block_size = 128 * 1024;
        let gbps = c.throughput_gbps(&j);
        assert!((12.0..15.0).contains(&gbps), "gen5 128K seq read {gbps} GB/s (spec 14)");
    }
}
