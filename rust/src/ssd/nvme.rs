//! NVMe-style submission/completion queues.
//!
//! A functional ring-pair: the host driver posts commands to the SQ,
//! rings the doorbell, the controller consumes and posts completions to
//! the CQ with phase-bit semantics. The quickstart example drives the
//! simulated SSD through this interface, and the HMB comparison uses the
//! same command set (the NVMe 1.2 HMB feature is the paper's §2.1
//! host-memory predecessor to LMB).

use crate::error::{Error, Result};

/// NVMe opcode subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NvmeOpcode {
    Read,
    Write,
    Flush,
}

/// A submission-queue entry (stripped to what the model needs).
#[derive(Debug, Clone, Copy)]
pub struct NvmeCommand {
    pub cid: u16,
    pub opcode: NvmeOpcode,
    /// Starting LBA (512 B units, as NVMe counts).
    pub slba: u64,
    /// Number of logical blocks, 0-based as in the spec.
    pub nlb: u16,
}

/// A completion-queue entry.
#[derive(Debug, Clone, Copy)]
pub struct NvmeCompletion {
    pub cid: u16,
    pub status: u16,
    pub phase: bool,
    pub sq_head: u16,
}

pub const STATUS_SUCCESS: u16 = 0;
pub const STATUS_INVALID_FIELD: u16 = 0x2002;

/// A submission/completion queue pair with `depth` slots each.
#[derive(Debug)]
pub struct QueuePair {
    depth: u16,
    sq: Vec<Option<NvmeCommand>>,
    cq: Vec<Option<NvmeCompletion>>,
    sq_tail: u16,
    sq_head: u16,
    cq_tail: u16,
    cq_head: u16,
    phase: bool,
    pub submitted: u64,
    pub completed: u64,
}

impl QueuePair {
    pub fn new(depth: u16) -> Result<Self> {
        if depth < 2 || !depth.is_power_of_two() {
            return Err(Error::Device(format!("queue depth {depth} must be a power of two >= 2")));
        }
        Ok(QueuePair {
            depth,
            sq: vec![None; depth as usize],
            cq: vec![None; depth as usize],
            sq_tail: 0,
            sq_head: 0,
            cq_tail: 0,
            cq_head: 0,
            phase: true,
            submitted: 0,
            completed: 0,
        })
    }

    fn next(&self, v: u16) -> u16 {
        (v + 1) % self.depth
    }

    /// Slots available in the SQ (one slot is kept open to distinguish
    /// full from empty).
    pub fn sq_free(&self) -> u16 {
        (self.depth + self.sq_head - self.sq_tail - 1) % self.depth
    }

    /// Host: post a command; errors when the ring is full.
    pub fn submit(&mut self, cmd: NvmeCommand) -> Result<()> {
        if self.sq_free() == 0 {
            return Err(Error::Device("SQ full".into()));
        }
        self.sq[self.sq_tail as usize] = Some(cmd);
        self.sq_tail = self.next(self.sq_tail);
        self.submitted += 1;
        Ok(())
    }

    /// Controller: fetch the next command (doorbell consumption).
    pub fn fetch(&mut self) -> Option<NvmeCommand> {
        if self.sq_head == self.sq_tail {
            return None;
        }
        let cmd = self.sq[self.sq_head as usize].take();
        self.sq_head = self.next(self.sq_head);
        cmd
    }

    /// Controller: post a completion for `cid`.
    pub fn complete(&mut self, cid: u16, status: u16) -> Result<()> {
        let next_tail = self.next(self.cq_tail);
        if next_tail == self.cq_head {
            return Err(Error::Device("CQ full".into()));
        }
        self.cq[self.cq_tail as usize] = Some(NvmeCompletion {
            cid,
            status,
            phase: self.phase,
            sq_head: self.sq_head,
        });
        self.cq_tail = next_tail;
        if self.cq_tail == 0 {
            self.phase = !self.phase; // phase flips on wrap
        }
        self.completed += 1;
        Ok(())
    }

    /// Host: reap one completion if present.
    pub fn reap(&mut self) -> Option<NvmeCompletion> {
        if self.cq_head == self.cq_tail {
            return None;
        }
        let c = self.cq[self.cq_head as usize].take();
        self.cq_head = self.next(self.cq_head);
        c
    }

    /// Outstanding (submitted, not yet completed) commands.
    pub fn inflight(&self) -> u64 {
        self.submitted - self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd(cid: u16) -> NvmeCommand {
        NvmeCommand { cid, opcode: NvmeOpcode::Read, slba: cid as u64 * 8, nlb: 7 }
    }

    #[test]
    fn submit_fetch_complete_reap_cycle() {
        let mut q = QueuePair::new(8).unwrap();
        q.submit(cmd(1)).unwrap();
        q.submit(cmd(2)).unwrap();
        let c1 = q.fetch().unwrap();
        assert_eq!(c1.cid, 1);
        q.complete(c1.cid, STATUS_SUCCESS).unwrap();
        let done = q.reap().unwrap();
        assert_eq!(done.cid, 1);
        assert_eq!(done.status, STATUS_SUCCESS);
        assert_eq!(q.inflight(), 1);
    }

    #[test]
    fn sq_full_detected() {
        let mut q = QueuePair::new(4).unwrap();
        for i in 0..3 {
            q.submit(cmd(i)).unwrap();
        }
        assert!(q.submit(cmd(9)).is_err(), "ring keeps one open slot");
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = QueuePair::new(16).unwrap();
        for i in 0..10 {
            q.submit(cmd(i)).unwrap();
        }
        for i in 0..10 {
            assert_eq!(q.fetch().unwrap().cid, i);
        }
        assert!(q.fetch().is_none());
    }

    #[test]
    fn phase_bit_flips_on_wrap() {
        let mut q = QueuePair::new(4).unwrap();
        let mut phases = Vec::new();
        for round in 0..6 {
            q.submit(cmd(round)).unwrap();
            let c = q.fetch().unwrap();
            q.complete(c.cid, STATUS_SUCCESS).unwrap();
            phases.push(q.reap().unwrap().phase);
        }
        // depth 4 → phase flips after completions 4, 8, ...
        assert_eq!(phases, [true, true, true, true, false, false]);
    }

    #[test]
    fn invalid_depth_rejected() {
        assert!(QueuePair::new(3).is_err());
        assert!(QueuePair::new(0).is_err());
        assert!(QueuePair::new(64).is_ok());
    }
}
