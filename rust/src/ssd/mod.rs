//! SSD substrate: NAND flash, FTL (with pluggable L2P index placement),
//! garbage collection, the controller pipeline, and NVMe-style queues.
//!
//! This is the paper's evaluation vehicle (§4): two commercial SSDs
//! (PCIe Gen4/Gen5, Table 3) whose firmware was modified to place the
//! L2P mapping table in onboard DRAM (*Ideal*), in flash (*DFTL*), or in
//! the CXL expander reached either P2P (*LMB-CXL*) or via host bridging
//! (*LMB-PCIe*). We model the controller white-box so the same four
//! placements fall out of one mechanism: the latency of the index
//! stage's memory accesses.

pub mod controller;
pub mod device;
pub mod memsem;
pub mod ftl;
pub mod nand;
pub mod nvme;
pub mod spec;

pub use controller::{Controller, PipelineParams, StageCaps};
pub use device::{DeviceRun, SsdDevice};
pub use spec::SsdSpec;

use crate::cxl::fabric::{Fabric, PathKind};
use crate::pcie::link::PcieGen;
use crate::sim::time::SimTime;

/// Where the L2P index lives — the paper's four evaluation schemes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IndexPlacement {
    /// All mapping entries in onboard DRAM (*Ideal*).
    Ideal,
    /// Mapping entries in the CXL expander, device reaches it P2P
    /// (*LMB-CXL*): CXL-native SSD.
    LmbCxl,
    /// Mapping entries in the CXL expander, device reaches it through
    /// the host root complex (*LMB-PCIe*): plain PCIe SSD.
    LmbPcie,
    /// Demand-paged flash-resident mapping (DFTL, Gupta et al.).
    Dftl,
    /// NVMe 1.2 Host Memory Buffer (§2.1): index in *host* DRAM over
    /// PCIe. Not in the paper's Figure 6 (hence excluded from `ALL`);
    /// used by the HMB-vs-LMB ablation.
    Hmb,
}

impl IndexPlacement {
    pub const ALL: [IndexPlacement; 4] =
        [IndexPlacement::Ideal, IndexPlacement::LmbCxl, IndexPlacement::LmbPcie, IndexPlacement::Dftl];

    pub fn label(self) -> &'static str {
        match self {
            IndexPlacement::Ideal => "Ideal",
            IndexPlacement::LmbCxl => "LMB-CXL",
            IndexPlacement::LmbPcie => "LMB-PCIe",
            IndexPlacement::Dftl => "DFTL",
            IndexPlacement::Hmb => "HMB(host)",
        }
    }

    /// Latency of ONE index-memory access under this placement, for an
    /// SSD on the given PCIe generation — derived from the fabric model
    /// (Figure 2), not hard-coded.
    pub fn index_access_latency(self, fabric: &Fabric, gen: PcieGen) -> SimTime {
        match self {
            IndexPlacement::Ideal => fabric.path_latency(PathKind::OnboardDram),
            IndexPlacement::LmbCxl => fabric.path_latency(PathKind::CxlP2pToHdm),
            IndexPlacement::LmbPcie => fabric.path_latency(PathKind::PcieToHdm(gen)),
            // DFTL's hit path is onboard DRAM; the miss path (flash) is
            // charged separately via `DftlModel`.
            IndexPlacement::Dftl => fabric.path_latency(PathKind::OnboardDram),
            IndexPlacement::Hmb => fabric.path_latency(PathKind::PcieToHostMem(gen)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_latencies_derive_paper_constants() {
        let f = Fabric::default();
        assert_eq!(
            IndexPlacement::LmbCxl.index_access_latency(&f, PcieGen::Gen4),
            SimTime::ns(190)
        );
        assert_eq!(
            IndexPlacement::LmbPcie.index_access_latency(&f, PcieGen::Gen4),
            SimTime::ns(880)
        );
        assert_eq!(
            IndexPlacement::LmbPcie.index_access_latency(&f, PcieGen::Gen5),
            SimTime::ns(1190)
        );
        assert_eq!(
            IndexPlacement::Ideal.index_access_latency(&f, PcieGen::Gen5),
            SimTime::ns(70)
        );
    }

    #[test]
    fn labels_are_paper_scheme_names() {
        let labels: Vec<_> = IndexPlacement::ALL.iter().map(|p| p.label()).collect();
        assert_eq!(labels, ["Ideal", "LMB-CXL", "LMB-PCIe", "DFTL"]);
    }
}
