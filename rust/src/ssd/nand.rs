//! NAND flash array model.
//!
//! Geometry (channels × dies × planes × blocks × pages) plus timing
//! (tR / tProg / tErase). Two roles:
//!
//! 1. **capacity derivation** — aggregate read IOPS / program bandwidth
//!    bounds that calibrate the controller pipeline to Table 3;
//! 2. **functional array** — pages can be programmed/read/erased with
//!    write-before-read and erase-before-program invariants enforced,
//!    which the FTL/GC tests exercise.

use crate::error::{Error, Result};
use crate::sim::time::SimTime;
use std::collections::HashMap;

/// NAND cell technology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellType {
    Tlc,
    Qlc,
}

/// Geometry + timing of the flash array.
#[derive(Debug, Clone)]
pub struct NandConfig {
    pub cell: CellType,
    pub channels: u32,
    pub dies_per_channel: u32,
    pub planes_per_die: u32,
    /// Flash page size in bytes (16 KiB on the modeled parts).
    pub page_bytes: u32,
    pub pages_per_block: u32,
    pub blocks_per_plane: u32,
    /// Page read latency (tR).
    pub t_read: SimTime,
    /// Page program latency (tProg).
    pub t_prog: SimTime,
    /// Block erase latency (tBERS).
    pub t_erase: SimTime,
    /// Per-channel bus bandwidth, bytes/sec.
    pub channel_bw_bps: u64,
}

impl NandConfig {
    pub fn dies(&self) -> u32 {
        self.channels * self.dies_per_channel
    }

    /// Raw capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.dies() as u64
            * self.planes_per_die as u64
            * self.blocks_per_plane as u64
            * self.pages_per_block as u64
            * self.page_bytes as u64
    }

    /// Aggregate small-read capacity: every die can serve an independent
    /// page read every tR.
    pub fn read_iops(&self) -> f64 {
        self.dies() as f64 / self.t_read.as_secs_f64()
    }

    /// Aggregate program bandwidth with all-plane striping: each die
    /// programs planes_per_die pages per tProg.
    pub fn program_bw_bps(&self) -> f64 {
        let per_die =
            self.planes_per_die as f64 * self.page_bytes as f64 / self.t_prog.as_secs_f64();
        per_die * self.dies() as f64
    }

    /// Aggregate sequential read bandwidth (channel-bus bound).
    pub fn seq_read_bw_bps(&self) -> f64 {
        (self.channels as u64 * self.channel_bw_bps) as f64
    }
}

/// Physical page address within the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Ppa {
    pub die: u32,
    pub plane: u32,
    pub block: u32,
    pub page: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PageState {
    Erased,
    Programmed,
}

/// Functional flash array (sparse: only touched blocks are materialised).
#[derive(Debug)]
pub struct NandArray {
    cfg: NandConfig,
    /// (die, plane, block) → per-page state.
    blocks: HashMap<(u32, u32, u32), Vec<PageState>>,
    pub programs: u64,
    pub reads: u64,
    pub erases: u64,
}

impl NandArray {
    pub fn new(cfg: NandConfig) -> Self {
        NandArray { cfg, blocks: HashMap::new(), programs: 0, reads: 0, erases: 0 }
    }

    pub fn config(&self) -> &NandConfig {
        &self.cfg
    }

    fn validate(&self, ppa: Ppa) -> Result<()> {
        let c = &self.cfg;
        if ppa.die >= c.dies()
            || ppa.plane >= c.planes_per_die
            || ppa.block >= c.blocks_per_plane
            || ppa.page >= c.pages_per_block
        {
            return Err(Error::Device(format!("PPA out of range: {ppa:?}")));
        }
        Ok(())
    }

    fn block_mut(&mut self, ppa: Ppa) -> &mut Vec<PageState> {
        let pages = self.cfg.pages_per_block as usize;
        self.blocks
            .entry((ppa.die, ppa.plane, ppa.block))
            .or_insert_with(|| vec![PageState::Erased; pages])
    }

    /// Program a page. NAND constraint: pages within a block must be
    /// programmed in order, and only once between erases.
    pub fn program(&mut self, ppa: Ppa) -> Result<SimTime> {
        self.validate(ppa)?;
        let block = self.block_mut(ppa);
        if block[ppa.page as usize] == PageState::Programmed {
            return Err(Error::Device(format!("program to programmed page {ppa:?}")));
        }
        if ppa.page > 0 && block[ppa.page as usize - 1] != PageState::Programmed {
            return Err(Error::Device(format!("out-of-order program {ppa:?}")));
        }
        block[ppa.page as usize] = PageState::Programmed;
        self.programs += 1;
        Ok(self.cfg.t_prog)
    }

    /// Read a page (must be programmed).
    pub fn read(&mut self, ppa: Ppa) -> Result<SimTime> {
        self.validate(ppa)?;
        let programmed = self
            .blocks
            .get(&(ppa.die, ppa.plane, ppa.block))
            .map(|b| b[ppa.page as usize] == PageState::Programmed)
            .unwrap_or(false);
        if !programmed {
            return Err(Error::Device(format!("read of erased page {ppa:?}")));
        }
        self.reads += 1;
        Ok(self.cfg.t_read)
    }

    /// Erase a whole block.
    pub fn erase(&mut self, die: u32, plane: u32, block: u32) -> Result<SimTime> {
        self.validate(Ppa { die, plane, block, page: 0 })?;
        let pages = self.cfg.pages_per_block as usize;
        self.blocks.insert((die, plane, block), vec![PageState::Erased; pages]);
        self.erases += 1;
        Ok(self.cfg.t_erase)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ssd::spec::SsdSpec;

    fn tiny() -> NandConfig {
        NandConfig {
            cell: CellType::Tlc,
            channels: 2,
            dies_per_channel: 2,
            planes_per_die: 2,
            page_bytes: 16384,
            pages_per_block: 8,
            blocks_per_plane: 4,
            t_read: SimTime::us(73),
            t_prog: SimTime::us(1380),
            t_erase: SimTime::ms(3),
            channel_bw_bps: 450_000_000,
        }
    }

    #[test]
    fn capacity_math() {
        let c = tiny();
        assert_eq!(c.dies(), 4);
        assert_eq!(c.capacity(), 4 * 2 * 4 * 8 * 16384);
    }

    #[test]
    fn program_read_erase_lifecycle() {
        let mut a = NandArray::new(tiny());
        let p = Ppa { die: 0, plane: 0, block: 0, page: 0 };
        assert!(a.read(p).is_err(), "read-before-write rejected");
        a.program(p).unwrap();
        assert!(a.program(p).is_err(), "double program rejected");
        a.read(p).unwrap();
        a.erase(0, 0, 0).unwrap();
        assert!(a.read(p).is_err(), "erased page unreadable");
        a.program(p).unwrap();
        assert_eq!(a.programs, 2);
    }

    #[test]
    fn in_order_programming_enforced() {
        let mut a = NandArray::new(tiny());
        let p1 = Ppa { die: 0, plane: 0, block: 0, page: 1 };
        assert!(a.program(p1).is_err(), "page 1 before page 0");
        a.program(Ppa { die: 0, plane: 0, block: 0, page: 0 }).unwrap();
        a.program(p1).unwrap();
    }

    #[test]
    fn out_of_range_rejected() {
        let mut a = NandArray::new(tiny());
        assert!(a.program(Ppa { die: 99, plane: 0, block: 0, page: 0 }).is_err());
    }

    #[test]
    fn gen4_nand_derives_table3_read_iops() {
        let cfg = SsdSpec::gen4().nand;
        let kiops = cfg.read_iops() / 1e3;
        // Table 3: 1750 KIOPS 4K random read
        assert!((kiops - 1750.0).abs() / 1750.0 < 0.02, "gen4 read {kiops} KIOPS");
    }

    #[test]
    fn gen5_nand_derives_table3_read_iops() {
        let cfg = SsdSpec::gen5().nand;
        let kiops = cfg.read_iops() / 1e3;
        assert!((kiops - 2800.0).abs() / 2800.0 < 0.02, "gen5 read {kiops} KIOPS");
    }

    #[test]
    fn program_bandwidth_supports_table3_seq_write() {
        // NAND program BW must exceed the spec seq-write figure (host
        // link / controller become the binding constraint).
        let g4 = SsdSpec::gen4();
        assert!(g4.nand.program_bw_bps() >= 6.8e9, "gen4 {}", g4.nand.program_bw_bps());
        let g5 = SsdSpec::gen5();
        assert!(g5.nand.program_bw_bps() >= 10.0e9, "gen5 {}", g5.nand.program_bw_bps());
    }
}
