//! Logical-to-physical mapping table.
//!
//! Functional page-level L2P map (4 B PPA per 4 KiB LPA — Table 3 drives
//! carry a 7.68 GB table, the paper's headline problem). The map is
//! sparse in memory, and can be *serialised through the LMB data path*:
//! entries are written to / read from the expander backing store via the
//! allocation's DPA, which is how the integration tests prove the SSD's
//! index actually lives in CXL memory under the LMB schemes (Figure 5).

use std::collections::HashMap;

use crate::cxl::expander::Expander;
use crate::cxl::fm::FabricRef;
use crate::cxl::types::Dpa;
use crate::error::Result;

/// Sentinel for "never written".
pub const UNMAPPED: u32 = u32::MAX;

/// Page-level L2P table over `num_pages` logical pages.
#[derive(Debug)]
pub struct L2pTable {
    num_pages: u64,
    /// Sparse map; absent = UNMAPPED.
    entries: HashMap<u64, u32>,
    pub lookups: u64,
    pub updates: u64,
}

impl L2pTable {
    pub fn new(num_pages: u64) -> Self {
        L2pTable { num_pages, entries: HashMap::new(), lookups: 0, updates: 0 }
    }

    pub fn num_pages(&self) -> u64 {
        self.num_pages
    }

    /// Bytes the full table would occupy (4 B per entry).
    pub fn table_bytes(&self) -> u64 {
        self.num_pages * 4
    }

    pub fn lookup(&mut self, lpa: u64) -> u32 {
        debug_assert!(lpa < self.num_pages);
        self.lookups += 1;
        self.entries.get(&lpa).copied().unwrap_or(UNMAPPED)
    }

    pub fn update(&mut self, lpa: u64, ppa: u32) {
        debug_assert!(lpa < self.num_pages);
        self.updates += 1;
        if ppa == UNMAPPED {
            self.entries.remove(&lpa);
        } else {
            self.entries.insert(lpa, ppa);
        }
    }

    pub fn mapped_count(&self) -> usize {
        self.entries.len()
    }

    /// Flush entries `[first, first+count)` into LMB memory at `dpa`
    /// (4 B little-endian each) through the expander's functional store.
    pub fn flush_to_lmb(
        &self,
        expander: &mut Expander,
        dpa: Dpa,
        first: u64,
        count: u64,
    ) -> Result<()> {
        let mut buf = Vec::with_capacity((count * 4) as usize);
        for lpa in first..first + count {
            let ppa = self.entries.get(&lpa).copied().unwrap_or(UNMAPPED);
            buf.extend_from_slice(&ppa.to_le_bytes());
        }
        expander.write_dpa(dpa, &buf)
    }

    /// [`L2pTable::flush_to_lmb`] through a shared fabric handle — the
    /// multi-host route to the expander data plane (there is no public
    /// `&mut Expander` on [`FabricRef`], so firmware flushes go here).
    pub fn flush_to_fabric(
        &self,
        fabric: &FabricRef,
        dpa: Dpa,
        first: u64,
        count: u64,
    ) -> Result<()> {
        fabric.with_expander_mut(|e| self.flush_to_lmb(e, dpa, first, count))?
    }

    /// [`L2pTable::load_from_lmb`] through a shared fabric handle.
    pub fn load_from_fabric(
        &mut self,
        fabric: &FabricRef,
        dpa: Dpa,
        first: u64,
        count: u64,
    ) -> Result<()> {
        fabric.with_fm(|fm| self.load_from_lmb(&fm.expander(), dpa, first, count))?
    }

    /// Load entries `[first, first+count)` back from LMB memory.
    pub fn load_from_lmb(
        &mut self,
        expander: &Expander,
        dpa: Dpa,
        first: u64,
        count: u64,
    ) -> Result<()> {
        let mut buf = vec![0u8; (count * 4) as usize];
        expander.read_dpa(dpa, &mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            let ppa = u32::from_le_bytes(chunk.try_into().unwrap());
            let lpa = first + i as u64;
            if ppa == UNMAPPED {
                self.entries.remove(&lpa);
            } else {
                self.entries.insert(lpa, ppa);
            }
        }
        Ok(())
    }

    /// Dense snapshot (tests + the XLA gather-kernel parity check).
    pub fn snapshot(&self, first: u64, count: u64) -> Vec<u32> {
        (first..first + count)
            .map(|lpa| self.entries.get(&lpa).copied().unwrap_or(UNMAPPED))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::ExpanderConfig;
    use crate::cxl::types::GIB;

    #[test]
    fn lookup_update_roundtrip() {
        let mut t = L2pTable::new(1024);
        assert_eq!(t.lookup(5), UNMAPPED);
        t.update(5, 42);
        assert_eq!(t.lookup(5), 42);
        t.update(5, UNMAPPED); // trim
        assert_eq!(t.lookup(5), UNMAPPED);
        assert_eq!(t.lookups, 3);
        assert_eq!(t.updates, 2);
    }

    #[test]
    fn table_size_matches_paper_rule() {
        // 7.68 TB → 1.875 G pages → 7.5 GiB-ish table (0.1% of capacity)
        let t = L2pTable::new(7_680_000_000_000 / 4096);
        assert_eq!(t.table_bytes(), 7_500_000_000);
    }

    #[test]
    fn lmb_flush_load_roundtrip() {
        let mut ex = Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() });
        let mut t = L2pTable::new(4096);
        for lpa in 0..512 {
            t.update(lpa, (lpa * 7 + 1) as u32);
        }
        t.flush_to_lmb(&mut ex, Dpa(0x10000), 0, 1024).unwrap();
        let mut t2 = L2pTable::new(4096);
        t2.load_from_lmb(&ex, Dpa(0x10000), 0, 1024).unwrap();
        for lpa in 0..512 {
            assert_eq!(t2.snapshot(lpa, 1)[0], (lpa * 7 + 1) as u32);
        }
        assert_eq!(t2.lookup(700), UNMAPPED, "unwritten entries stay unmapped");
    }

    #[test]
    fn snapshot_dense_view() {
        let mut t = L2pTable::new(16);
        t.update(1, 10);
        t.update(3, 30);
        assert_eq!(t.snapshot(0, 4), vec![UNMAPPED, 10, UNMAPPED, 30]);
    }
}
