//! DFTL (Gupta et al., ASPLOS'09): demand-paged mapping with a Cached
//! Mapping Table — the paper's flash-resident baseline.
//!
//! Two faces, matching the simulator's hybrid design:
//!
//! * [`CmtCache`] — a functional CLOCK cache of *translation pages*
//!   (one flash page holds `entries_per_page` L2P entries), producing
//!   exact hit/miss decisions for a request stream;
//! * [`DftlModel`] — the analytic per-IO cost used by the batch data
//!   plane: expected index-stage service given a hit ratio (either
//!   measured from a [`CmtCache`] warm-up or supplied by config — the
//!   paper's own simulation charges a flat 25 µs miss on every IO,
//!   i.e. hit ratio 0).

use std::collections::HashMap;

use crate::sim::time::SimTime;

/// CLOCK cache over translation pages.
#[derive(Debug)]
pub struct CmtCache {
    /// translation-page id → clock reference bit
    resident: HashMap<u64, bool>,
    /// clock order (page ids; lazily rebuilt on eviction sweep)
    ring: Vec<u64>,
    hand: usize,
    capacity: usize,
    pub entries_per_page: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CmtCache {
    /// `capacity` = number of translation pages the CMT can hold;
    /// `entries_per_page` = L2P entries per translation page (flash page
    /// bytes / 4).
    pub fn new(capacity: usize, entries_per_page: u64) -> Self {
        assert!(capacity > 0 && entries_per_page > 0);
        CmtCache {
            resident: HashMap::new(),
            ring: Vec::new(),
            hand: 0,
            capacity,
            entries_per_page,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn tpage_of(&self, lpa: u64) -> u64 {
        lpa / self.entries_per_page
    }

    /// Access the translation entry for `lpa`; returns true on CMT hit.
    pub fn access(&mut self, lpa: u64) -> bool {
        let tp = self.tpage_of(lpa);
        if let Some(refbit) = self.resident.get_mut(&tp) {
            *refbit = true;
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if self.resident.len() >= self.capacity {
            self.evict();
        }
        self.resident.insert(tp, false);
        self.ring.push(tp);
        false
    }

    fn evict(&mut self) {
        loop {
            if self.ring.is_empty() {
                return;
            }
            self.hand %= self.ring.len();
            let tp = self.ring[self.hand];
            match self.resident.get_mut(&tp) {
                Some(refbit) if *refbit => {
                    *refbit = false;
                    self.hand += 1;
                }
                Some(_) => {
                    self.resident.remove(&tp);
                    self.ring.swap_remove(self.hand);
                    self.evictions += 1;
                    return;
                }
                None => {
                    // stale ring slot from a previous swap_remove
                    self.ring.swap_remove(self.hand);
                }
            }
        }
    }

    pub fn resident_pages(&self) -> usize {
        self.resident.len()
    }

    /// Observed hit ratio so far.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Analytic DFTL cost model for the batch data plane.
#[derive(Debug, Clone, Copy)]
pub struct DftlModel {
    /// Probability an index access hits the CMT (onboard DRAM).
    pub hit_ratio: f64,
    /// Flash read latency (translation-page fetch) — the paper's 25 µs.
    pub flash_read: SimTime,
    /// Expected flash operations per *read* miss (fetch).
    pub flash_ops_read: f64,
    /// Expected flash operations per *write* miss (fetch + dirty
    /// write-back of the evicted translation page).
    pub flash_ops_write: f64,
    /// CMT hit cost (onboard DRAM access).
    pub dram_access: SimTime,
}

impl DftlModel {
    /// Expected index service time for one IO.
    pub fn expected_index_cost(&self, is_write: bool) -> SimTime {
        let ops = if is_write { self.flash_ops_write } else { self.flash_ops_read };
        let miss_ns = (1.0 - self.hit_ratio) * ops * self.flash_read.as_ns() as f64;
        let hit_ns = self.dram_access.as_ns() as f64; // DRAM touched either way
        SimTime::ns((hit_ns + miss_ns) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Pcg64;

    #[test]
    fn sequential_stream_hits_after_first_touch() {
        let mut c = CmtCache::new(8, 1024);
        let mut misses = 0;
        for lpa in 0..4096u64 {
            if !c.access(lpa) {
                misses += 1;
            }
        }
        // one miss per translation page (4096/1024 = 4)
        assert_eq!(misses, 4);
        assert!(c.hit_ratio() > 0.99);
    }

    #[test]
    fn working_set_within_capacity_converges_to_hits() {
        let mut c = CmtCache::new(16, 1024);
        let mut rng = Pcg64::new(1);
        // 16 pages of working set exactly fits
        for _ in 0..50_000 {
            let lpa = rng.next_below(16 * 1024);
            c.access(lpa);
        }
        assert!(c.hit_ratio() > 0.99, "hit={}", c.hit_ratio());
        assert_eq!(c.resident_pages(), 16);
    }

    #[test]
    fn thrashing_working_set_misses() {
        let mut c = CmtCache::new(4, 1024);
        let mut rng = Pcg64::new(2);
        // working set 100× capacity → mostly misses
        for _ in 0..50_000 {
            let lpa = rng.next_below(400 * 1024);
            c.access(lpa);
        }
        assert!(c.hit_ratio() < 0.05, "hit={}", c.hit_ratio());
        assert!(c.evictions > 40_000);
    }

    #[test]
    fn clock_keeps_hot_page() {
        let mut c = CmtCache::new(2, 1024);
        // page 0 is hot; pages 1..100 stream through
        for i in 0..100u64 {
            c.access(0); // keep ref bit set
            c.access((1 + i) * 1024);
        }
        // hot page survived: final access is a hit
        let before = c.hits;
        assert!(c.access(0));
        assert_eq!(c.hits, before + 1);
    }

    #[test]
    fn expected_cost_matches_paper_injection_at_zero_hit() {
        let m = DftlModel {
            hit_ratio: 0.0,
            flash_read: SimTime::us(25),
            flash_ops_read: 1.0,
            flash_ops_write: 2.0,
            dram_access: SimTime::ns(70),
        };
        // read: 1 flash read + DRAM ≈ the paper's flat +25 µs
        assert_eq!(m.expected_index_cost(false), SimTime::ns(25_070));
        // write: fetch + write-back
        assert_eq!(m.expected_index_cost(true), SimTime::ns(50_070));
    }

    #[test]
    fn expected_cost_scales_with_hit_ratio() {
        let m = DftlModel {
            hit_ratio: 0.5,
            flash_read: SimTime::us(25),
            flash_ops_read: 1.0,
            flash_ops_write: 2.0,
            dram_access: SimTime::ns(70),
        };
        assert_eq!(m.expected_index_cost(false), SimTime::ns(12_570));
    }
}
