//! Garbage collection: analytic write-amplification model + a functional
//! greedy collector.
//!
//! The steady-state random-write WA of greedy GC over uniformly-random
//! writes follows the classic closed form WA ≈ (1 + OP) / (2 · OP)
//! (OP = over-provisioning fraction). Table 3's sustained random-write
//! figures pin each device's OP (see `spec.rs`). The functional
//! collector validates the closed form on a small array and powers the
//! DES mode's background-GC events.


/// Analytic model.
#[derive(Debug, Clone, Copy)]
pub struct GcModel {
    pub over_provisioning: f64,
}

impl GcModel {
    /// Steady-state write amplification for uniform random writes.
    pub fn random_write_wa(&self) -> f64 {
        (1.0 + self.over_provisioning) / (2.0 * self.over_provisioning)
    }

    /// Sequential writes invalidate whole blocks — no relocation.
    pub fn seq_write_wa(&self) -> f64 {
        1.0
    }
}

/// Functional greedy garbage collector over an abstract block pool.
///
/// Blocks hold `pages_per_block` page slots; user writes go to the open
/// block; when free blocks run short, the collector picks the block with
/// the fewest valid pages, relocates them, and erases it.
#[derive(Debug)]
pub struct GreedyGc {
    pages_per_block: u32,
    /// valid bitmap per block
    blocks: Vec<Vec<bool>>,
    /// write pointer within the open block
    open_block: usize,
    open_page: u32,
    free_blocks: Vec<usize>,
    /// lpa → (block, page)
    map: std::collections::HashMap<u64, (usize, u32)>,
    pub user_writes: u64,
    pub relocations: u64,
    pub erases: u64,
    gc_threshold: usize,
}

impl GreedyGc {
    pub fn new(num_blocks: usize, pages_per_block: u32) -> Self {
        assert!(num_blocks >= 4);
        let mut free_blocks: Vec<usize> = (1..num_blocks).collect();
        free_blocks.reverse();
        GreedyGc {
            pages_per_block,
            blocks: vec![vec![false; pages_per_block as usize]; num_blocks],
            open_block: 0,
            open_page: 0,
            free_blocks,
            map: std::collections::HashMap::new(),
            user_writes: 0,
            relocations: 0,
            erases: 0,
            gc_threshold: 2,
        }
    }

    /// Total physical pages.
    pub fn physical_pages(&self) -> u64 {
        self.blocks.len() as u64 * self.pages_per_block as u64
    }

    fn append(&mut self, lpa: u64) {
        // invalidate old location
        if let Some((b, p)) = self.map.get(&lpa).copied() {
            self.blocks[b][p as usize] = false;
        }
        self.blocks[self.open_block][self.open_page as usize] = true;
        self.map.insert(lpa, (self.open_block, self.open_page));
        self.open_page += 1;
        if self.open_page == self.pages_per_block {
            let next = self.free_blocks.pop().expect("GC must keep a free block");
            self.open_block = next;
            self.open_page = 0;
        }
    }

    /// Write one logical page, running GC as needed.
    pub fn write(&mut self, lpa: u64) {
        self.user_writes += 1;
        self.append(lpa);
        while self.free_blocks.len() < self.gc_threshold {
            self.collect();
        }
    }

    fn collect(&mut self) {
        // victim: fewest valid pages, excluding open + free blocks
        let victim = (0..self.blocks.len())
            .filter(|&b| b != self.open_block && !self.free_blocks.contains(&b))
            .min_by_key(|&b| self.blocks[b].iter().filter(|&&v| v).count())
            .expect("victim exists");
        let valid: Vec<u64> = self
            .map
            .iter()
            .filter(|(_, &(b, _))| b == victim)
            .map(|(&lpa, _)| lpa)
            .collect();
        for lpa in valid {
            self.relocations += 1;
            self.append(lpa);
        }
        self.blocks[victim].fill(false);
        self.free_blocks.push(victim);
        self.erases += 1;
    }

    /// Observed write amplification.
    pub fn wa(&self) -> f64 {
        if self.user_writes == 0 {
            1.0
        } else {
            (self.user_writes + self.relocations) as f64 / self.user_writes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_values() {
        assert!((GcModel { over_provisioning: 0.111 }.random_write_wa() - 5.0).abs() < 0.05);
        assert!((GcModel { over_provisioning: 0.159 }.random_write_wa() - 3.64).abs() < 0.05);
        assert_eq!(GcModel { over_provisioning: 0.25 }.seq_write_wa(), 1.0);
    }

    #[test]
    fn sequential_writes_have_wa_one() {
        let mut gc = GreedyGc::new(32, 64);
        let logical = (32 * 64) as u64 * 3 / 4;
        // three full sequential passes
        for _ in 0..3 {
            for lpa in 0..logical {
                gc.write(lpa);
            }
        }
        assert!(gc.wa() < 1.15, "seq WA = {}", gc.wa());
    }

    #[test]
    fn random_write_wa_tracks_closed_form() {
        use crate::sim::rng::Pcg64;
        let blocks = 64;
        let ppb = 64u32;
        let mut gc = GreedyGc::new(blocks, ppb);
        let op = 0.25f64; // logical = physical / (1+op)
        let logical = (gc.physical_pages() as f64 / (1.0 + op)) as u64;
        let mut rng = Pcg64::new(42);
        // fill once, then steady-state random overwrites
        for lpa in 0..logical {
            gc.write(lpa);
        }
        let (w0, r0) = (gc.user_writes, gc.relocations);
        for _ in 0..logical * 12 {
            gc.write(rng.next_below(logical));
        }
        let wa = 1.0 + (gc.relocations - r0) as f64 / (gc.user_writes - w0) as f64;
        let expected = GcModel { over_provisioning: op }.random_write_wa(); // 2.5
        // greedy beats the closed form slightly on small configs; accept a band
        assert!(
            (expected * 0.55..expected * 1.35).contains(&wa),
            "WA {wa:.2} vs closed form {expected:.2}"
        );
    }

    #[test]
    fn overwrite_invalidates_old_location() {
        let mut gc = GreedyGc::new(8, 16);
        for _ in 0..100 {
            gc.write(7);
        }
        // only one valid copy of lpa 7 exists
        let valid: usize =
            gc.blocks.iter().map(|b| b.iter().filter(|&&v| v).count()).sum();
        assert_eq!(valid, 1);
    }
}
