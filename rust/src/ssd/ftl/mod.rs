//! Flash Translation Layer: L2P mapping, DFTL demand caching, GC.

pub mod dftl;
pub mod gc;
pub mod l2p;

pub use dftl::{CmtCache, DftlModel};
pub use gc::{GcModel, GreedyGc};
pub use l2p::L2pTable;
