//! SSD specifications (paper Table 3) and calibrated controller
//! parameters.
//!
//! | Parameter                | Gen4 x4   | Gen5 x4   |
//! |--------------------------|-----------|-----------|
//! | Capacity (TB)            | 7.68      | 7.68      |
//! | 4K rand R/W KIOPS        | 1750/340  | 2800/700  |
//! | 128K seq R/W GB/s        | 7.2/6.8   | 14/10     |
//! | 4K rand R/W latency (µs) | 67/9      | 56/8      |
//!
//! The NAND geometry/timing is chosen so the *derived* capacities land
//! on Table 3 (see `nand.rs` tests), and the index-stage parameters
//! (`PipelineParams`) are calibrated so the four schemes reproduce the
//! Figure 6 shape (DESIGN.md §Calibration). The Gen5 part models a
//! deeper firmware lookup (k = 4 dependent memory references per IO —
//! two-level map walk + journal + stats on the higher-IOPS part), which
//! is what makes the same +190 ns CXL latency bite much harder on Gen5,
//! the paper's central observation.

use crate::pcie::link::{PcieGen, PcieLink};
use crate::sim::time::SimTime;
use crate::ssd::controller::PipelineParams;
use crate::ssd::nand::{CellType, NandConfig};

/// A full device specification: marketing numbers + modeled internals.
#[derive(Debug, Clone)]
pub struct SsdSpec {
    pub name: &'static str,
    pub gen: PcieGen,
    pub lanes: u8,
    /// User capacity in bytes (decimal TB as vendors quote).
    pub capacity: u64,
    /// Table 3 reference points (used by the calibration bench).
    pub spec_rand_read_kiops: f64,
    pub spec_rand_write_kiops: f64,
    pub spec_seq_read_gbps: f64,
    pub spec_seq_write_gbps: f64,
    pub spec_read_latency: SimTime,
    pub spec_write_latency: SimTime,
    /// Modeled internals.
    pub nand: NandConfig,
    pub pipeline: PipelineParams,
    /// Over-provisioning fraction (drives steady-state random-write WA).
    pub over_provisioning: f64,
    /// Write-buffer ack latency (4K random write, Table 3).
    pub write_buffer_latency: SimTime,
    /// Controller write-path commit cap in KIOPS (the small-block write
    /// pipeline: buffer slots, parity, commit bookkeeping). Binds 4 KiB
    /// sequential writes, which on real drives do not reach the 128 KiB
    /// sequential bandwidth divided by 4 KiB.
    pub write_path_kiops: f64,
}

impl SsdSpec {
    /// The paper's PCIe Gen4 x4 7.68 TB TLC drive.
    pub fn gen4() -> Self {
        SsdSpec {
            name: "Gen4x4-7.68T",
            gen: PcieGen::Gen4,
            lanes: 4,
            capacity: 7_680_000_000_000,
            spec_rand_read_kiops: 1750.0,
            spec_rand_write_kiops: 340.0,
            spec_seq_read_gbps: 7.2,
            spec_seq_write_gbps: 6.8,
            spec_read_latency: SimTime::us(67),
            spec_write_latency: SimTime::us(9),
            nand: NandConfig {
                cell: CellType::Tlc,
                channels: 16,
                dies_per_channel: 8, // 128 dies
                planes_per_die: 4,
                page_bytes: 16 * 1024,
                pages_per_block: 1152,
                blocks_per_plane: 800,
                t_read: SimTime::us(73),
                t_prog: SimTime::us(1200),
                t_erase: SimTime::ms(3),
                channel_bw_bps: 450_000_000,
            },
            pipeline: PipelineParams {
                index_width: 2,
                firmware_ns: 440.0,
                index_accesses: 1,
                dftl_flash_ops_read: 1.0,
                dftl_flash_ops_write: 2.0,
            },
            over_provisioning: 0.111,
            write_buffer_latency: SimTime::us(9),
            write_path_kiops: 450.0,
        }
    }

    /// The paper's PCIe Gen5 x4 7.68 TB TLC drive.
    pub fn gen5() -> Self {
        SsdSpec {
            name: "Gen5x4-7.68T",
            gen: PcieGen::Gen5,
            lanes: 4,
            capacity: 7_680_000_000_000,
            spec_rand_read_kiops: 2800.0,
            spec_rand_write_kiops: 700.0,
            spec_seq_read_gbps: 14.0,
            spec_seq_write_gbps: 10.0,
            spec_read_latency: SimTime::us(56),
            spec_write_latency: SimTime::us(8),
            nand: NandConfig {
                cell: CellType::Tlc,
                channels: 16,
                dies_per_channel: 10, // 160 dies
                planes_per_die: 4,
                page_bytes: 16 * 1024,
                pages_per_block: 1152,
                blocks_per_plane: 640,
                t_read: SimTime::us(57),
                t_prog: SimTime::us(1000),
                t_erase: SimTime::ms(3),
                channel_bw_bps: 900_000_000,
            },
            pipeline: PipelineParams {
                index_width: 2,
                firmware_ns: 430.0,
                index_accesses: 4,
                dftl_flash_ops_read: 1.0,
                dftl_flash_ops_write: 2.0,
            },
            over_provisioning: 0.159,
            write_buffer_latency: SimTime::us(8),
            write_path_kiops: 900.0,
        }
    }

    /// Spec for a generation.
    pub fn for_gen(gen: PcieGen) -> Self {
        match gen {
            PcieGen::Gen4 => Self::gen4(),
            PcieGen::Gen5 => Self::gen5(),
        }
    }

    /// Host link model for this device.
    pub fn link(&self) -> PcieLink {
        PcieLink::new(self.gen, self.lanes)
    }

    /// Steady-state random-write amplification from over-provisioning
    /// (greedy GC closed form: WA ≈ (1 + OP) / (2 · OP)).
    pub fn write_amplification(&self) -> f64 {
        (1.0 + self.over_provisioning) / (2.0 * self.over_provisioning)
    }

    /// Number of 4 KiB logical pages.
    pub fn logical_pages(&self) -> u64 {
        self.capacity / 4096
    }

    /// L2P table size in bytes (4 B PPA per 4 KiB page — the paper's
    /// "0.1% of capacity" rule).
    pub fn l2p_bytes(&self) -> u64 {
        self.logical_pages() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2p_is_point_one_percent_of_capacity() {
        // 4 B per 4 KiB page = 1/1024 ≈ the paper's "0.1% of capacity".
        let s = SsdSpec::gen4();
        let ratio = s.l2p_bytes() as f64 / s.capacity as f64;
        assert!((0.0009..0.0011).contains(&ratio), "ratio={ratio}");
        // 7.68 TB → 7.5 GB of mapping table: far beyond onboard DRAM
        // budgets, which is the paper's motivation.
        assert_eq!(s.l2p_bytes(), 7_500_000_000);
    }

    #[test]
    fn nand_capacity_close_to_spec() {
        for s in [SsdSpec::gen4(), SsdSpec::gen5()] {
            let raw = s.nand.capacity() as f64;
            let user = s.capacity as f64;
            // raw must exceed user (OP) but stay within ~15%
            assert!(raw > user, "{}: raw {raw} <= user {user}", s.name);
            assert!(raw < user * 1.15, "{}: raw {raw} too large", s.name);
        }
    }

    #[test]
    fn write_amplification_matches_calibration() {
        // Chosen so program_bw / (4K · WA) lands on Table 3 rand-write.
        let g4 = SsdSpec::gen4();
        let wa = g4.write_amplification();
        assert!((4.5..5.5).contains(&wa), "gen4 WA={wa}");
        let g5 = SsdSpec::gen5();
        let wa5 = g5.write_amplification();
        assert!((3.2..4.0).contains(&wa5), "gen5 WA={wa5}");
    }

    #[test]
    fn link_bandwidth_covers_seq_spec() {
        let g4 = SsdSpec::gen4();
        assert!(g4.link().bandwidth_bps() as f64 >= g4.spec_seq_read_gbps * 1e9 * 0.99);
        let g5 = SsdSpec::gen5();
        assert!(g5.link().bandwidth_bps() as f64 >= g5.spec_seq_read_gbps * 1e9 * 0.99);
    }
}
