//! Memory-semantic SSD (§2.1: Samsung CMM-H / CXL-SSD).
//!
//! A device that "blends DRAM accessibility and flash durability into a
//! single-tier memory": the CPU issues byte-granular loads/stores
//! against a flash-backed address space fronted by an onboard DRAM
//! cache. The paper's §2.1 critique: "they are reliant on DRAM size and
//! cache hit ratios, with misses leading to latency issues and the
//! spatial limitation persists due to the identical form factor".
//!
//! LMB's fix falls out of the same framework: extend the device's cache
//! with expander memory, creating a three-tier hierarchy
//! (onboard DRAM → LMB/HDM → flash). This module models both
//! configurations analytically and functionally (a CLOCK cache over
//! cachelines, reusing the CMT machinery).

use crate::cxl::fabric::{Fabric, PathKind};
use crate::sim::time::SimTime;
use crate::ssd::ftl::dftl::CmtCache;

/// Cacheline size of the memory-semantic frontend.
pub const MEMSEM_LINE: u64 = 64;

/// Configuration of a memory-semantic SSD.
#[derive(Debug, Clone)]
pub struct MemSemConfig {
    /// Onboard DRAM cache bytes (spatially limited — the paper's point).
    pub onboard_cache: u64,
    /// Optional LMB tier bytes (0 = plain CMM-H).
    pub lmb_tier: u64,
    /// Flash page fill cost on a miss that reaches flash.
    pub flash_fill: SimTime,
}

impl MemSemConfig {
    /// A CMM-H-like part: small onboard cache, no LMB.
    pub fn cmm_h(onboard_cache: u64) -> Self {
        MemSemConfig { onboard_cache, lmb_tier: 0, flash_fill: SimTime::us(25) }
    }

    /// The LMB-extended variant.
    pub fn with_lmb(onboard_cache: u64, lmb_tier: u64) -> Self {
        MemSemConfig { onboard_cache, lmb_tier, flash_fill: SimTime::us(25) }
    }
}

/// Expected load latency given tier hit probabilities.
///
/// `h1` = onboard hit, `h2` = LMB hit among onboard misses.
pub fn expected_load_latency(cfg: &MemSemConfig, fabric: &Fabric, h1: f64, h2: f64) -> SimTime {
    let dram = fabric.path_latency(PathKind::OnboardDram).as_ns() as f64;
    let hdm = fabric.path_latency(PathKind::CxlP2pToHdm).as_ns() as f64;
    let flash = cfg.flash_fill.as_ns() as f64;
    let h2 = if cfg.lmb_tier > 0 { h2 } else { 0.0 };
    let ns = h1 * dram + (1.0 - h1) * (h2 * hdm + (1.0 - h2) * flash);
    SimTime::ns(ns as u64)
}

/// Functional two-tier cache simulation over a load trace: returns
/// (onboard hit ratio, LMB hit ratio among onboard misses), measured on
/// the steady state — the first `warmup` accesses populate the tiers
/// but are excluded from the ratios (compulsory misses are a property
/// of the trace length, not the hierarchy).
///
/// Uses CLOCK at cacheline granularity for the onboard tier and a
/// larger CLOCK for the LMB tier (inclusive hierarchy).
pub fn simulate_tiers(cfg: &MemSemConfig, addrs: &[u64], warmup: usize) -> (f64, f64) {
    let l1_lines = (cfg.onboard_cache / MEMSEM_LINE).max(1) as usize;
    let mut l1 = CmtCache::new(l1_lines, MEMSEM_LINE);
    let mut l2 = (cfg.lmb_tier > 0)
        .then(|| CmtCache::new((cfg.lmb_tier / MEMSEM_LINE).max(1) as usize, MEMSEM_LINE));
    let (mut l1_hits, mut l2_hits, mut l2_lookups, mut measured) = (0u64, 0u64, 0u64, 0u64);
    for (i, &a) in addrs.iter().enumerate() {
        let count = i >= warmup;
        if count {
            measured += 1;
        }
        if l1.access(a) {
            if count {
                l1_hits += 1;
            }
            // inclusive: keep L2 warm
            if let Some(l2c) = l2.as_mut() {
                l2c.access(a);
            }
        } else if let Some(l2c) = l2.as_mut() {
            let hit = l2c.access(a);
            if count {
                l2_lookups += 1;
                if hit {
                    l2_hits += 1;
                }
            }
        }
    }
    let h1 = l1_hits as f64 / measured.max(1) as f64;
    let h2 = if l2_lookups > 0 { l2_hits as f64 / l2_lookups as f64 } else { 0.0 };
    (h1, h2)
}

/// End-to-end comparison a bench/example can print: mean load latency
/// for the plain device vs the LMB-extended one on the same trace.
pub fn compare_on_trace(
    onboard: u64,
    lmb_tier: u64,
    fabric: &Fabric,
    addrs: &[u64],
) -> (SimTime, SimTime) {
    let warmup = addrs.len() / 2;
    let plain = MemSemConfig::cmm_h(onboard);
    let (h1, _) = simulate_tiers(&plain, addrs, warmup);
    let lat_plain = expected_load_latency(&plain, fabric, h1, 0.0);

    let ext = MemSemConfig::with_lmb(onboard, lmb_tier);
    let (h1e, h2e) = simulate_tiers(&ext, addrs, warmup);
    let lat_ext = expected_load_latency(&ext, fabric, h1e, h2e);
    (lat_plain, lat_ext)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Pcg64;
    use crate::workload::zipf::Zipfian;

    fn zipf_trace(n: usize, span_lines: u64, theta: f64, seed: u64) -> Vec<u64> {
        let z = Zipfian::new(span_lines, theta);
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| z.sample(&mut rng) * MEMSEM_LINE).collect()
    }

    #[test]
    fn latency_model_tiers_ordered() {
        let fabric = Fabric::default();
        let cfg = MemSemConfig::with_lmb(1 << 20, 1 << 26);
        // all-onboard-hit < all-LMB-hit < all-flash
        let a = expected_load_latency(&cfg, &fabric, 1.0, 0.0);
        let b = expected_load_latency(&cfg, &fabric, 0.0, 1.0);
        let c = expected_load_latency(&cfg, &fabric, 0.0, 0.0);
        assert_eq!(a, SimTime::ns(70));
        assert_eq!(b, SimTime::ns(190));
        assert_eq!(c, SimTime::us(25));
    }

    #[test]
    fn plain_device_ignores_h2() {
        let fabric = Fabric::default();
        let cfg = MemSemConfig::cmm_h(1 << 20);
        let with = expected_load_latency(&cfg, &fabric, 0.5, 0.9);
        let without = expected_load_latency(&cfg, &fabric, 0.5, 0.0);
        assert_eq!(with, without, "no LMB tier -> h2 is meaningless");
    }

    #[test]
    fn lmb_tier_absorbs_onboard_misses() {
        // working set 4 MiB; onboard 1 MiB; LMB tier 64 MiB; enough
        // accesses (~4.6 per line) that steady state dominates
        let trace = zipf_trace(300_000, (4 << 20) / MEMSEM_LINE, 0.8, 42);
        let cfg = MemSemConfig::with_lmb(1 << 20, 64 << 20);
        let (h1, h2) = simulate_tiers(&cfg, &trace, trace.len() / 2);
        assert!(h1 > 0.2 && h1 < 0.95, "onboard partial hit: {h1}");
        assert!(h2 > 0.7, "LMB tier should absorb most misses: {h2}");
    }

    #[test]
    fn extension_cuts_mean_latency_by_an_order() {
        let fabric = Fabric::default();
        let trace = zipf_trace(300_000, (4 << 20) / MEMSEM_LINE, 0.8, 7);
        let (plain, ext) = compare_on_trace(1 << 20, 64 << 20, &fabric, &trace);
        assert!(
            plain.as_ns() > 3 * ext.as_ns(),
            "plain {plain} should dwarf LMB-extended {ext}"
        );
    }

    #[test]
    fn tiny_working_set_makes_tiers_equal() {
        let fabric = Fabric::default();
        // 256 KiB working set fits the 1 MiB onboard cache
        let trace = zipf_trace(60_000, (256 << 10) / MEMSEM_LINE, 0.2, 9);
        let (plain, ext) = compare_on_trace(1 << 20, 64 << 20, &fabric, &trace);
        let rel = (plain.as_ns() as f64 - ext.as_ns() as f64).abs() / plain.as_ns() as f64;
        assert!(rel < 0.25, "cache-resident workloads don't need LMB: {plain} vs {ext}");
    }
}
