//! Host physical address space.
//!
//! The LMB kernel module maps leased expander extents into host physical
//! address space (§3.2: "the obtained memory is mapped into the physical
//! address space of the host, waiting to be allocated to the local
//! device"). This module models that space: a low range of host DRAM
//! plus HDM windows that alias expander DPA ranges.

use crate::cxl::types::{Dpa, Hpa, Range};
use crate::error::{Error, Result};

/// What an HPA resolves to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Plain host DRAM at the given offset.
    HostDram { offset: u64 },
    /// An HDM window; the HPA maps to this expander DPA.
    Hdm { dpa: Dpa },
}

#[derive(Debug, Clone, Copy)]
struct HdmWindow {
    hpa: Range,
    dpa_base: Dpa,
}

/// The host physical address map.
#[derive(Debug)]
pub struct AddressSpace {
    dram: Range,
    windows: Vec<HdmWindow>,
    /// Bump pointer for placing new HDM windows above existing ranges.
    next_window_base: u64,
    /// Exclusive upper bound for auto-placed windows (multi-host: the
    /// end of this host's HPA region, so a window-hungry host errors
    /// instead of bleeding into a sibling's region in the shared
    /// decoder table). `None` = unbounded (single-host rigs).
    window_limit: Option<u64>,
}

impl AddressSpace {
    /// A host with `dram_bytes` of local DRAM at HPA 0.
    pub fn new(dram_bytes: u64) -> Self {
        Self::with_window_region(dram_bytes, 0, None)
    }

    /// Like [`AddressSpace::new`], but HDM windows are placed starting
    /// at `window_base` (raised above DRAM if needed). Multi-host
    /// sharding uses this to give each host a disjoint HPA region, so
    /// the expander's shared decoder table never sees two hosts claim
    /// the same window.
    pub fn with_window_base(dram_bytes: u64, window_base: u64) -> Self {
        Self::with_window_region(dram_bytes, window_base, None)
    }

    /// [`AddressSpace::with_window_base`] plus an exclusive end for the
    /// auto-placement region: [`AddressSpace::place_hdm_window`] fails
    /// cleanly once the budget is spent (the bump pointer never reuses
    /// freed window space).
    pub fn with_window_region(
        dram_bytes: u64,
        window_base: u64,
        window_limit: Option<u64>,
    ) -> Self {
        AddressSpace {
            dram: Range::new(0, dram_bytes),
            windows: Vec::new(),
            next_window_base: window_base.max(dram_bytes.next_power_of_two().max(1 << 32)),
            window_limit,
        }
    }

    /// Register an HDM window at a fixed HPA range.
    pub fn add_hdm_window(&mut self, hpa: Range, dpa_base: Dpa) -> Result<()> {
        if self.dram.overlaps(&hpa) || self.windows.iter().any(|w| w.hpa.overlaps(&hpa)) {
            return Err(Error::Config(format!(
                "HDM window {:#x}+{:#x} overlaps existing ranges",
                hpa.base, hpa.len
            )));
        }
        self.next_window_base = self.next_window_base.max(hpa.end());
        self.windows.push(HdmWindow { hpa, dpa_base });
        Ok(())
    }

    /// Place a new HDM window for `len` bytes at an automatically chosen
    /// HPA; returns the window's base HPA. Fails if the window would
    /// leave this host's region (see [`AddressSpace::with_window_region`])
    /// or wrap the HPA space.
    pub fn place_hdm_window(&mut self, len: u64, dpa_base: Dpa) -> Result<Hpa> {
        let base = self.next_window_base;
        let end = base
            .checked_add(len)
            .ok_or_else(|| Error::Config("HDM window wraps the HPA space".into()))?;
        if self.window_limit.is_some_and(|limit| end > limit) {
            return Err(Error::Config(format!(
                "HDM window budget exhausted: {base:#x}+{len:#x} crosses the host region end"
            )));
        }
        self.add_hdm_window(Range::new(base, len), dpa_base)?;
        Ok(Hpa(base))
    }

    /// Remove the HDM window whose base HPA is `base` (extent release).
    pub fn remove_hdm_window(&mut self, base: Hpa) -> Result<()> {
        let before = self.windows.len();
        self.windows.retain(|w| w.hpa.base != base.0);
        if self.windows.len() == before {
            return Err(Error::DecodeFault(format!("no HDM window at {base:?}")));
        }
        Ok(())
    }

    /// Resolve an HPA to its target.
    pub fn resolve(&self, hpa: Hpa) -> Result<Target> {
        if self.dram.contains(hpa.0) {
            return Ok(Target::HostDram { offset: hpa.0 - self.dram.base });
        }
        self.windows
            .iter()
            .find(|w| w.hpa.contains(hpa.0))
            .map(|w| Target::Hdm { dpa: Dpa(w.dpa_base.0 + (hpa.0 - w.hpa.base)) })
            .ok_or_else(|| Error::DecodeFault(format!("unmapped HPA {hpa:?}")))
    }

    /// Whether the span `[hpa, hpa+len)` stays within one mapped region.
    pub fn resolve_span(&self, hpa: Hpa, len: u64) -> Result<Target> {
        if self.dram.contains_span(hpa.0, len) {
            return Ok(Target::HostDram { offset: hpa.0 - self.dram.base });
        }
        self.windows
            .iter()
            .find(|w| w.hpa.contains_span(hpa.0, len))
            .map(|w| Target::Hdm { dpa: Dpa(w.dpa_base.0 + (hpa.0 - w.hpa.base)) })
            .ok_or_else(|| {
                Error::DecodeFault(format!("unmapped or straddling span {hpa:?}+{len:#x}"))
            })
    }

    pub fn window_count(&self) -> usize {
        self.windows.len()
    }

    pub fn dram_bytes(&self) -> u64 {
        self.dram.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::{GIB, PAGE_SIZE};

    #[test]
    fn dram_resolution() {
        let s = AddressSpace::new(GIB);
        assert_eq!(s.resolve(Hpa(0x1000)).unwrap(), Target::HostDram { offset: 0x1000 });
        assert!(s.resolve(Hpa(GIB)).is_err());
    }

    #[test]
    fn hdm_window_translation() {
        let mut s = AddressSpace::new(GIB);
        let base = s.place_hdm_window(GIB, Dpa(0x4000)).unwrap();
        match s.resolve(Hpa(base.0 + 0x42)).unwrap() {
            Target::Hdm { dpa } => assert_eq!(dpa, Dpa(0x4042)),
            t => panic!("expected HDM, got {t:?}"),
        }
    }

    #[test]
    fn windows_do_not_overlap_dram_or_each_other() {
        let mut s = AddressSpace::new(GIB);
        assert!(s.add_hdm_window(Range::new(0, GIB), Dpa(0)).is_err(), "overlaps DRAM");
        let a = s.place_hdm_window(GIB, Dpa(0)).unwrap();
        assert!(s.add_hdm_window(Range::new(a.0, 0x1000), Dpa(GIB)).is_err());
        let b = s.place_hdm_window(GIB, Dpa(GIB)).unwrap();
        assert!(b.0 >= a.0 + GIB);
        assert_eq!(s.window_count(), 2);
    }

    #[test]
    fn explicit_window_base_is_honoured_and_clamped() {
        let mut s = AddressSpace::with_window_base(GIB, 1 << 44);
        assert_eq!(s.place_hdm_window(GIB, Dpa(0)).unwrap(), Hpa(1 << 44));
        // a base below the DRAM floor is raised, never overlapped
        let mut low = AddressSpace::with_window_base(GIB, 0x1000);
        let placed = low.place_hdm_window(GIB, Dpa(0)).unwrap();
        assert!(placed.0 >= GIB, "window cannot land inside host DRAM");
    }

    #[test]
    fn window_region_limit_bounds_auto_placement() {
        let base = 1u64 << 44;
        let mut s = AddressSpace::with_window_region(GIB, base, Some(base + 4 * GIB));
        s.place_hdm_window(3 * GIB, Dpa(0)).unwrap();
        // 2 GiB more would cross the region end — clean error, no spill
        assert!(s.place_hdm_window(2 * GIB, Dpa(0)).is_err(), "budget exhausted");
        // exactly filling the region is allowed
        s.place_hdm_window(GIB, Dpa(0)).unwrap();
        assert!(s.place_hdm_window(PAGE_SIZE, Dpa(0)).is_err());
        assert_eq!(s.window_count(), 2);
    }

    #[test]
    fn straddling_span_rejected() {
        let mut s = AddressSpace::new(GIB);
        let base = s.place_hdm_window(0x10000, Dpa(0)).unwrap();
        assert!(s.resolve_span(Hpa(base.0 + 0x8000), 0x8000).is_ok());
        assert!(s.resolve_span(Hpa(base.0 + 0x8000), 0x8001).is_err());
    }
}
