//! `lmb` — command-line driver for the LMB reproduction.
//!
//! Commands:
//!   fig2                         print Figure 2 latency derivation
//!   table3                       Ideal-scheme calibration vs Table 3
//!   fig6 --gen=gen4|gen5         the paper's main result grid
//!   run --gen=.. --scheme=.. --pattern=.. [--bs= --qd= --numjobs= --zipf=]
//!   des --gen=.. --scheme=.. --pattern=.. [--ios=N]   event-driven device
//!   contention --gen=.. --devices=N [--scheme=..]
//!   locality --gen=..            DFTL/LMB hit-ratio sweep
//!   gpu [--working-set=64G]      GPU spill-tier comparison (§2.2)
//!   info                         modeled device specs
//!
//! `--native` forces the pure-Rust data plane; default auto-detects
//! `artifacts/` (built by `make artifacts`) and uses the XLA path.

use lmb::cli::Args;
use lmb::config;
use lmb::coordinator::{contention, Coordinator};
use lmb::cxl::fabric::Fabric;
use lmb::cxl::types::GIB;
use lmb::gpu;
use lmb::prelude::*;
use lmb::ssd::controller::Controller;
use lmb::ssd::spec::SsdSpec;
use lmb::workload::fio::IoPattern;

fn coordinator(args: &Args) -> Coordinator {
    if args.has("native") {
        Coordinator::native()
    } else {
        Coordinator::auto()
    }
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "fig2" => cmd_fig2(),
        "table3" => cmd_table3(&args),
        "fig6" => cmd_fig6(&args),
        "run" => cmd_run(&args),
        "des" => cmd_des(&args),
        "contention" => cmd_contention(&args),
        "locality" => cmd_locality(&args),
        "gpu" => cmd_gpu(&args),
        "info" => cmd_info(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "lmb — CXL-Linked Memory Buffer reproduction\n\n\
         usage: lmb <command> [flags]\n\n\
         commands:\n  \
         fig2                        Figure 2 latency derivation\n  \
         table3                      Table 3 calibration\n  \
         fig6 --gen=gen4|gen5        the paper's main result\n  \
         run --gen= --scheme= --pattern= [--bs= --qd= --numjobs= --zipf=]\n  \
         des --gen= --scheme= --pattern= [--ios=]  event-driven device\n  \
         contention --gen= --devices=N [--scheme=]\n  \
         locality --gen=             DFTL hit-ratio sweep\n  \
         gpu [--working-set=64G]     GPU spill-tier comparison\n  \
         info                        modeled device specs\n\n\
         global flags: --native (skip XLA artifacts)"
    );
}

fn cmd_fig2() -> Result<()> {
    let fabric = Fabric::default();
    println!("Figure 2 — estimated access latencies (derived from component model)\n");
    println!("{:<34} {:>12}", "path", "latency");
    println!("{}", "-".repeat(48));
    for (label, lat) in fabric.figure2_rows() {
        println!("{label:<34} {:>12}", format!("{lat}"));
    }
    println!(
        "\nderived per-scheme injection constants: LMB-CXL +{}, \
         LMB-PCIe(Gen4) +{}, LMB-PCIe(Gen5) +{}, DFTL miss +{}",
        fabric.path_latency(PathKind::CxlP2pToHdm),
        fabric.path_latency(PathKind::PcieToHdm(lmb::pcie::link::PcieGen::Gen4)),
        fabric.path_latency(PathKind::PcieToHdm(lmb::pcie::link::PcieGen::Gen5)),
        fabric.path_latency(PathKind::FlashRead),
    );
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let coord = coordinator(args);
    println!("Table 3 calibration — Ideal scheme vs spec sheet\n");
    println!("{:<44} {:>10} {:>10} {:>7}", "metric", "spec", "model", "delta");
    println!("{}", "-".repeat(75));
    for (label, spec, measured) in coord.table3()? {
        let delta = (measured - spec) / spec * 100.0;
        println!("{label:<44} {spec:>10.1} {measured:>10.1} {delta:>6.1}%");
    }
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let gen = config::parse_gen(args.flag_or("gen", "gen4"))?;
    let coord = coordinator(args);
    let report = coord.figure6(gen)?;
    println!("{}", report.to_markdown());
    // the paper's headline ratios
    for (pattern, label) in
        [(IoPattern::RandWrite, "write"), (IoPattern::RandRead, "read")]
    {
        if let Some(r) = report.ratio_vs_ideal(lmb::ssd::IndexPlacement::Dftl, pattern) {
            println!("Ideal vs DFTL ({label}): {r:.1}x");
        }
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let gen = config::parse_gen(args.flag_or("gen", "gen4"))?;
    let scheme = config::parse_scheme(args.flag_or("scheme", "lmb-cxl"))?;
    let pattern = config::parse_pattern(args.flag_or("pattern", "randread"))?;
    let spec = SsdSpec::for_gen(gen);
    let mut job = FioJob::paper(pattern, args.flag_u64("span", 64 * GIB)?);
    job.block_size = args.flag_u64("bs", 4096)? as u32;
    job.qd = args.flag_u64("qd", 64)? as u32;
    job.numjobs = args.flag_u64("numjobs", 4)? as u32;
    if let Some(theta) = args.flag("zipf") {
        job.zipf_theta = Some(
            theta
                .parse()
                .map_err(|_| lmb::Error::Config(format!("bad zipf theta '{theta}'")))?,
        );
    }
    job.validate()?;
    let coord = coordinator(args);
    let row = coord.run_scheme(&spec, scheme, &job)?;
    println!(
        "{} {} {}: {:.0} KIOPS ({:.2} GB/s) mean={} p50={} p99={} bottleneck={} [{}]",
        row.device,
        row.scheme.label(),
        row.pattern.label(),
        row.kiops,
        row.gbps,
        row.mean_latency,
        row.p50,
        row.p99,
        row.bottleneck,
        coord.backend_name(),
    );
    Ok(())
}

fn cmd_des(args: &Args) -> Result<()> {
    let gen = config::parse_gen(args.flag_or("gen", "gen5"))?;
    let scheme = config::parse_scheme(args.flag_or("scheme", "lmb-cxl"))?;
    let pattern = config::parse_pattern(args.flag_or("pattern", "randread"))?;
    let spec = SsdSpec::for_gen(gen);
    let mut job = FioJob::paper(pattern, args.flag_u64("span", 64 * GIB)?);
    job.total_ios = args.flag_u64("ios", 50_000)?;
    job.qd = args.flag_u64("qd", 64)? as u32;
    let mut dev = lmb::ssd::device::SsdDevice::new(
        spec.clone(),
        scheme,
        Fabric::default(),
        job.span_pages(),
    );
    let run = dev.run(&job)?;
    println!(
        "{} {} {} [event-driven]: {:.0} KIOPS over {} ({} IOs, {} events, CMT hit {:.1}%)",
        spec.name,
        scheme.label(),
        pattern.label(),
        run.kiops(),
        run.span,
        run.completed,
        run.events,
        run.cmt_hit_ratio * 100.0
    );
    println!("  latency: {}", run.latency.summary());
    Ok(())
}

fn cmd_contention(args: &Args) -> Result<()> {
    let gen = config::parse_gen(args.flag_or("gen", "gen5"))?;
    let scheme = config::parse_scheme(args.flag_or("scheme", "lmb-cxl"))?;
    let devices = args.flag_u64("devices", 8)? as u32;
    let spec = SsdSpec::for_gen(gen);
    let fabric = Fabric::default();
    let job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
    println!(
        "Shared-expander contention — {} × {} rand-read, scheme {}\n",
        devices,
        spec.name,
        scheme.label()
    );
    println!(
        "{:>8} {:>14} {:>14} {:>8} {:>10}",
        "devices", "KIOPS/dev", "aggregate", "util", "access"
    );
    for p in contention::sweep(&spec, scheme, &fabric, &job, devices, 80e9)? {
        println!(
            "{:>8} {:>14.0} {:>14.0} {:>7.1}% {:>9}ns",
            p.devices,
            p.per_device_kiops,
            p.aggregate_kiops,
            p.utilisation * 100.0,
            p.access_ns
        );
    }
    Ok(())
}

fn cmd_locality(args: &Args) -> Result<()> {
    let gen = config::parse_gen(args.flag_or("gen", "gen4"))?;
    let spec = SsdSpec::for_gen(gen);
    let fabric = Fabric::default();
    let job = FioJob::paper(IoPattern::RandRead, 64 * GIB);
    println!(
        "Locality ablation — DFTL CMT hit-ratio sweep on {} rand-read\n",
        spec.name
    );
    println!("{:>6} {:>12} {:>14}", "hit", "DFTL KIOPS", "vs Ideal");
    let ideal =
        Controller::new(spec.clone(), lmb::ssd::IndexPlacement::Ideal, fabric.clone())
            .throughput_iops(&job)
            / 1e3;
    for pct in (0..=100).step_by(10) {
        let mut ctl =
            Controller::new(spec.clone(), lmb::ssd::IndexPlacement::Dftl, fabric.clone());
        ctl.dftl_hit_ratio = pct as f64 / 100.0;
        let kiops = ctl.throughput_iops(&job) / 1e3;
        println!("{:>5}% {:>12.0} {:>13.1}x", pct, kiops, ideal / kiops);
    }
    Ok(())
}

fn cmd_gpu(args: &Args) -> Result<()> {
    let ws = args.flag_u64("working-set", 64 * GIB)?;
    let gpu_spec = gpu::GpuSpec::default();
    let ssd = SsdSpec::gen5();
    let fabric = Fabric::default();
    println!("GPU memory extension (§2.2) — working set {} GiB\n", ws / GIB);
    for (name, w) in [
        ("dense stream", gpu::TensorWorkload::dense_stream(ws)),
        ("sparse gather", gpu::TensorWorkload::sparse_gather(ws)),
    ] {
        println!("{name}:");
        for r in gpu::compare_tiers(&gpu_spec, &w, &ssd, &fabric) {
            println!(
                "  {:<10} spill {:>8.2} GB/s  effective {:>8.2} GB/s  latency {}",
                r.tier.label(),
                r.spill_bw_bps / 1e9,
                r.effective_bw_bps / 1e9,
                r.spill_latency
            );
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    for spec in [SsdSpec::gen4(), SsdSpec::gen5()] {
        println!(
            "{}: {} lanes x {:?}, {:.2} TB, L2P table {:.2} GB, \
             NAND {}ch x {}die, tR {}, tProg {}, WA {:.2}",
            spec.name,
            spec.lanes,
            spec.gen,
            spec.capacity as f64 / 1e12,
            spec.l2p_bytes() as f64 / 1e9,
            spec.nand.channels,
            spec.nand.dies_per_channel,
            spec.nand.t_read,
            spec.nand.t_prog,
            spec.write_amplification(),
        );
    }
    Ok(())
}
