//! The LMB kernel module (§3) — the paper's contribution.
//!
//! One instance runs per host. It presents the Table 2 API to device
//! drivers:
//!
//! | Operation | Interface |
//! |-----------|-----------|
//! | Allocate  | `pcie_alloc(dev, size)` / `cxl_alloc(spid, size)` |
//! | Free      | `pcie_free(dev, mmid)` / `cxl_free(spid, mmid)`   |
//! | Share     | `pcie_share(dev, mmid)` / `cxl_share(spid, mmid)` |
//!
//! Mechanics (§3.2–§3.3):
//! * capacity comes from the FM in 256 MB extents, each mapped into host
//!   physical space through an HDM decoder window;
//! * sub-allocation metadata lives host-side ([`allocator::SubAllocator`]);
//! * PCIe consumers get IOMMU mappings (bus address), CXL consumers get
//!   SAT grants (and the GFD's DPID for P2P);
//! * freeing tears down the access-control state, and a fully-drained
//!   extent is released back to the FM;
//! * sharing aliases one allocation into another device's view without
//!   copying — the zero-copy path of Figure 5's discussion.

pub mod allocator;
pub mod failure;

use std::collections::HashMap;

use crate::cxl::fm::{FabricManager, HostId};
use crate::cxl::sat::SatPerm;
use crate::cxl::types::{
    Bdf, BusAddr, Dpa, Dpid, Hpa, MmId, Range, Spid, EXTENT_SIZE,
};
use crate::error::{Error, Result};
use crate::host::AddressSpace;
use crate::pcie::iommu::{Iommu, IommuPerm};
use allocator::{Placement, SubAllocator};

/// Who owns / consumes an allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Consumer {
    Pcie(Bdf),
    Cxl(Spid),
}

/// The handle returned by the alloc APIs (paper Table 2 out-params).
#[derive(Debug, Clone, Copy)]
pub struct LmbAlloc {
    pub mmid: MmId,
    /// Host physical address of the region (always valid).
    pub hpa: Hpa,
    /// Device bus address (PCIe consumers; translated by the IOMMU).
    pub bus_addr: Option<BusAddr>,
    /// GFD port id for P2P (CXL consumers).
    pub dpid: Option<Dpid>,
    /// Expander DPA (CXL consumers address HDM by DPA after setup).
    pub dpa: Dpa,
    pub size: u64,
}

#[derive(Debug)]
struct ShareRecord {
    consumer: Consumer,
    bus_addr: Option<BusAddr>,
}

#[derive(Debug)]
struct AllocRecord {
    owner: Consumer,
    placement: Placement,
    bus_addr: Option<BusAddr>,
    shares: Vec<ShareRecord>,
}

/// Per-host LMB kernel module state.
#[derive(Debug)]
pub struct LmbModule {
    host: HostId,
    sub: SubAllocator,
    allocs: HashMap<MmId, AllocRecord>,
    next_mmid: u64,
    /// §3.1: "we promote the loading priority of the LMB module" — the
    /// module must be initialised before device drivers allocate.
    loaded: bool,
    /// The GFD's DPID handed to CXL consumers for P2P addressing.
    gfd_dpid: Dpid,
}

impl LmbModule {
    /// Initialise ("load") the module for a bound host.
    pub fn load(host: HostId) -> Self {
        LmbModule {
            host,
            sub: SubAllocator::new(),
            allocs: HashMap::new(),
            next_mmid: 1,
            loaded: true,
            gfd_dpid: Dpid(0xFFF),
        }
    }

    pub fn host(&self) -> HostId {
        self.host
    }

    pub fn is_loaded(&self) -> bool {
        self.loaded
    }

    /// Bytes currently leased from the FM / used by live allocations.
    pub fn leased(&self) -> u64 {
        self.sub.leased()
    }

    pub fn used(&self) -> u64 {
        self.sub.used()
    }

    pub fn live_allocs(&self) -> usize {
        self.allocs.len()
    }

    fn next_mmid(&mut self) -> MmId {
        let id = MmId(self.next_mmid);
        self.next_mmid += 1;
        id
    }

    /// Ensure capacity for `size`, leasing extents from the FM as needed
    /// (§3.2: one 256 MB block at a time; large requests lease several).
    fn ensure_capacity(
        &mut self,
        fm: &mut FabricManager,
        space: &mut AddressSpace,
        size: u64,
    ) -> Result<Placement> {
        // §1 failure challenge: during an expander outage no new memory
        // may be handed out, even from already-leased extents.
        if fm.expander().is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        if let Some(p) = self.sub.alloc(size) {
            return Ok(p);
        }
        // Lease enough fresh extents to cover the request even when it
        // exceeds one extent. Each extent gets an HDM window + decoder.
        let needed = size.div_ceil(EXTENT_SIZE).max(1);
        for _ in 0..needed {
            let ext = fm.allocate_extent(self.host)?;
            let hpa = match space.place_hdm_window(ext.len, ext.dpa) {
                Ok(h) => h,
                Err(e) => {
                    let _ = fm.release_extent(self.host, ext);
                    return Err(e);
                }
            };
            if let Err(e) = fm.expander_mut().add_decoder(Range::new(hpa.0, ext.len), ext.dpa) {
                let _ = space.remove_hdm_window(hpa);
                let _ = fm.release_extent(self.host, ext);
                return Err(e);
            }
            self.sub.adopt(ext, hpa);
        }
        self.sub.alloc(size).ok_or(Error::AllocFailed {
            requested: size,
            reason: "request exceeds contiguous extent capacity".into(),
        })
    }

    /// `lmb_PCIe_alloc(*dev, size, *hpa, *mmid)` — allocate LMB memory
    /// for a PCIe device; creates the IOMMU mapping (§3.3).
    pub fn pcie_alloc(
        &mut self,
        fm: &mut FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        dev: Bdf,
        size: u64,
    ) -> Result<LmbAlloc> {
        if !self.loaded {
            return Err(Error::Device("LMB module not loaded".into()));
        }
        if !iommu.is_attached(dev) {
            return Err(Error::Device(format!("device {dev} not attached to IOMMU")));
        }
        let placement = self.ensure_capacity(fm, space, size)?;
        let bus = match iommu.map(dev, placement.hpa, placement.len, IommuPerm::ReadWrite) {
            Ok(b) => b,
            Err(e) => {
                self.sub.free(placement);
                return Err(e);
            }
        };
        let mmid = self.next_mmid();
        self.allocs.insert(
            mmid,
            AllocRecord {
                owner: Consumer::Pcie(dev),
                placement,
                bus_addr: Some(bus),
                shares: Vec::new(),
            },
        );
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: Some(bus),
            dpid: None,
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    /// `lmb_CXL_alloc(*CXLd, size, *hpa, *DPID, *mmid)` — allocate for a
    /// CXL device; programs a SAT entry so the device can P2P (§3.3).
    pub fn cxl_alloc(
        &mut self,
        fm: &mut FabricManager,
        space: &mut AddressSpace,
        dev: Spid,
        size: u64,
    ) -> Result<LmbAlloc> {
        if !self.loaded {
            return Err(Error::Device("LMB module not loaded".into()));
        }
        let placement = self.ensure_capacity(fm, space, size)?;
        let range = Range::new(placement.dpa.0, placement.len);
        if let Err(e) = fm.sat_grant(dev, range, SatPerm::ReadWrite) {
            self.sub.free(placement);
            return Err(e);
        }
        let mmid = self.next_mmid();
        self.allocs.insert(
            mmid,
            AllocRecord {
                owner: Consumer::Cxl(dev),
                placement,
                bus_addr: None,
                shares: Vec::new(),
            },
        );
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: None,
            dpid: Some(self.gfd_dpid),
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    fn take_record(&mut self, caller: Consumer, mmid: MmId) -> Result<AllocRecord> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        if rec.owner != caller {
            return Err(Error::NotOwner { mmid });
        }
        Ok(self.allocs.remove(&mmid).unwrap())
    }

    /// Common free path: tear down all access-control state, free the
    /// sub-allocation, release a drained extent back to the FM.
    fn free_inner(
        &mut self,
        fm: &mut FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        rec: AllocRecord,
    ) -> Result<()> {
        // revoke shares first (§3.3: "When a release … is made, the
        // associated entries are also updated")
        for share in &rec.shares {
            match share.consumer {
                Consumer::Pcie(bdf) => {
                    if let Some(bus) = share.bus_addr {
                        let _ = iommu.unmap(bdf, bus);
                    }
                }
                Consumer::Cxl(spid) => {
                    let _ = fm
                        .sat_revoke(spid, Range::new(rec.placement.dpa.0, rec.placement.len));
                }
            }
        }
        match rec.owner {
            Consumer::Pcie(bdf) => {
                if let Some(bus) = rec.bus_addr {
                    iommu.unmap(bdf, bus)?;
                }
            }
            Consumer::Cxl(spid) => {
                fm.sat_revoke(spid, Range::new(rec.placement.dpa.0, rec.placement.len))?;
            }
        }
        if let Some(idx) = self.sub.free(rec.placement) {
            // extent fully drained — only release if no other live alloc
            // references it (they cannot, by definition of fully free).
            let st = self.sub.remove_extent(idx);
            // NB: removing shifts indices; fix up remaining placements.
            for r in self.allocs.values_mut() {
                if r.placement.extent_idx > idx {
                    r.placement.extent_idx -= 1;
                }
            }
            fm.expander_mut().remove_decoder(st.hpa_base.0)?;
            space.remove_hdm_window(st.hpa_base)?;
            fm.release_extent(self.host, st.extent)?;
        }
        Ok(())
    }

    /// `lmb_PCIe_free(*dev, mmid)`.
    pub fn pcie_free(
        &mut self,
        fm: &mut FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        dev: Bdf,
        mmid: MmId,
    ) -> Result<()> {
        let rec = self.take_record(Consumer::Pcie(dev), mmid)?;
        self.free_inner(fm, iommu, space, rec)
    }

    /// `lmb_CXL_free(*CXLd, mmid)`.
    pub fn cxl_free(
        &mut self,
        fm: &mut FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        dev: Spid,
        mmid: MmId,
    ) -> Result<()> {
        let rec = self.take_record(Consumer::Cxl(dev), mmid)?;
        self.free_inner(fm, iommu, space, rec)
    }

    /// `lmb_PCIe_share(*dev, mmid, *hpa)` — map an existing allocation
    /// into another PCIe device's IOMMU domain (zero-copy sharing).
    pub fn pcie_share(
        &mut self,
        iommu: &mut Iommu,
        target: Bdf,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        let placement = rec.placement;
        let bus = iommu.map(target, placement.hpa, placement.len, IommuPerm::ReadWrite)?;
        let rec = self.allocs.get_mut(&mmid).unwrap();
        rec.shares.push(ShareRecord { consumer: Consumer::Pcie(target), bus_addr: Some(bus) });
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: Some(bus),
            dpid: None,
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    /// `lmb_CXL_share(*CXLd, mmid, *hpa, *DPID)` — grant another CXL
    /// device P2P access to an existing allocation.
    pub fn cxl_share(
        &mut self,
        fm: &mut FabricManager,
        target: Spid,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        let placement = rec.placement;
        fm.sat_grant(target, Range::new(placement.dpa.0, placement.len), SatPerm::ReadWrite)?;
        let rec = self.allocs.get_mut(&mmid).unwrap();
        rec.shares.push(ShareRecord { consumer: Consumer::Cxl(target), bus_addr: None });
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: None,
            dpid: Some(self.gfd_dpid),
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    /// Look up a live allocation (tests / coordinator bookkeeping).
    pub fn get(&self, mmid: MmId) -> Option<LmbAlloc> {
        self.allocs.get(&mmid).map(|r| LmbAlloc {
            mmid,
            hpa: r.placement.hpa,
            bus_addr: r.bus_addr,
            dpid: match r.owner {
                Consumer::Cxl(_) => Some(self.gfd_dpid),
                Consumer::Pcie(_) => None,
            },
            dpa: r.placement.dpa,
            size: r.placement.len,
        })
    }

    /// All live mmids (failure handling sweeps these).
    pub fn mmids(&self) -> Vec<MmId> {
        self.allocs.keys().copied().collect()
    }

    /// Allocator invariants (property tests).
    pub fn check_invariants(&self) -> Result<()> {
        self.sub.check_invariants().map_err(Error::FabricManager)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{GIB, PAGE_SIZE};

    struct Rig {
        fm: FabricManager,
        iommu: Iommu,
        space: AddressSpace,
        module: LmbModule,
        dev: Bdf,
    }

    fn rig() -> Rig {
        let mut fm = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: 4 * GIB, ..Default::default() }),
        );
        fm.attach_gfd().unwrap();
        let (host, _) = fm.bind_host().unwrap();
        let mut iommu = Iommu::new();
        let dev = Bdf::new(1, 0, 0);
        iommu.attach(dev);
        Rig {
            fm,
            iommu,
            space: AddressSpace::new(GIB),
            module: LmbModule::load(host),
            dev,
        }
    }

    #[test]
    fn pcie_alloc_returns_bus_addr_and_leases_extent() {
        let mut r = rig();
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, 8 * PAGE_SIZE)
            .unwrap();
        assert!(a.bus_addr.is_some());
        assert!(a.dpid.is_none());
        assert_eq!(a.size, 8 * PAGE_SIZE);
        assert_eq!(r.module.leased(), EXTENT_SIZE, "one 256MB extent leased");
        // The IOMMU must translate the bus address back to the HPA.
        let hpa = r
            .iommu
            .translate(r.dev, a.bus_addr.unwrap(), 64, true)
            .unwrap();
        assert_eq!(hpa, a.hpa);
        // And the HPA must resolve to the expander DPA.
        match r.space.resolve(a.hpa).unwrap() {
            crate::host::Target::Hdm { dpa } => assert_eq!(dpa, a.dpa),
            t => panic!("expected HDM target, got {t:?}"),
        }
    }

    #[test]
    fn second_alloc_reuses_extent() {
        let mut r = rig();
        r.module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        r.module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        assert_eq!(r.module.leased(), EXTENT_SIZE, "no extra extent for small allocs");
    }

    #[test]
    fn large_alloc_leases_multiple_extents() {
        let mut r = rig();
        // > one extent: the sub-allocator cannot place it contiguously in
        // one 256MB extent, so the request must fail cleanly (the paper's
        // allocator hands out ≤extent-sized regions).
        let res = r.module.pcie_alloc(
            &mut r.fm,
            &mut r.iommu,
            &mut r.space,
            r.dev,
            EXTENT_SIZE + PAGE_SIZE,
        );
        assert!(res.is_err(), "cross-extent contiguous alloc not supported");
        // but exactly one extent works
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, EXTENT_SIZE)
            .unwrap();
        assert_eq!(a.size, EXTENT_SIZE);
    }

    #[test]
    fn free_releases_drained_extent_to_fm() {
        let mut r = rig();
        let before = r.fm.available();
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        assert_eq!(r.fm.available(), before - EXTENT_SIZE);
        r.module
            .pcie_free(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, a.mmid)
            .unwrap();
        assert_eq!(r.fm.available(), before, "extent returned to FM");
        assert_eq!(r.module.leased(), 0);
        assert_eq!(r.iommu.mapping_count(r.dev), 0);
        r.fm.check_invariants().unwrap();
    }

    #[test]
    fn free_requires_ownership() {
        let mut r = rig();
        let intruder = Bdf::new(9, 0, 0);
        r.iommu.attach(intruder);
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        assert!(matches!(
            r.module
                .pcie_free(&mut r.fm, &mut r.iommu, &mut r.space, intruder, a.mmid),
            Err(Error::NotOwner { .. })
        ));
    }

    #[test]
    fn unknown_mmid_rejected() {
        let mut r = rig();
        assert!(matches!(
            r.module
                .pcie_free(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, MmId(404)),
            Err(Error::UnknownMmId(_))
        ));
    }

    #[test]
    fn cxl_alloc_gets_dpid_and_sat_entry() {
        let mut r = rig();
        let spid = r.fm.bind_cxl_device().unwrap();
        let a = r.module.cxl_alloc(&mut r.fm, &mut r.space, spid, PAGE_SIZE).unwrap();
        assert!(a.dpid.is_some());
        assert!(a.bus_addr.is_none());
        assert!(r.fm.expander().sat().check(spid, a.dpa, 64, true));
        r.module
            .cxl_free(&mut r.fm, &mut r.iommu, &mut r.space, spid, a.mmid)
            .unwrap();
        assert!(!r.fm.expander().sat().check(spid, a.dpa, 64, false));
    }

    #[test]
    fn pcie_share_maps_into_target_domain() {
        let mut r = rig();
        let target = Bdf::new(2, 0, 0);
        r.iommu.attach(target);
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        let s = r.module.pcie_share(&mut r.iommu, target, a.mmid).unwrap();
        assert_eq!(s.hpa, a.hpa);
        let hpa = r.iommu.translate(target, s.bus_addr.unwrap(), 64, true).unwrap();
        assert_eq!(hpa, a.hpa);
        // freeing the owner tears down the share too
        r.module
            .pcie_free(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, a.mmid)
            .unwrap();
        assert!(r.iommu.translate(target, s.bus_addr.unwrap(), 64, false).is_err());
    }

    #[test]
    fn cxl_share_grants_sat_to_second_device() {
        let mut r = rig();
        let spid_a = r.fm.bind_cxl_device().unwrap();
        let spid_b = r.fm.bind_cxl_device().unwrap();
        let a = r.module.cxl_alloc(&mut r.fm, &mut r.space, spid_a, PAGE_SIZE).unwrap();
        assert!(!r.fm.expander().sat().check(spid_b, a.dpa, 64, false));
        let s = r.module.cxl_share(&mut r.fm, spid_b, a.mmid).unwrap();
        assert_eq!(s.dpa, a.dpa);
        assert!(r.fm.expander().sat().check(spid_b, a.dpa, 64, true));
    }

    #[test]
    fn mixed_share_pcie_alloc_to_cxl_consumer() {
        // Figure 5 scenario: SSD (PCIe) produces, accelerator (CXL)
        // consumes — zero-copy via shared LMB memory.
        let mut r = rig();
        let spid = r.fm.bind_cxl_device().unwrap();
        let a = r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .unwrap();
        let s = r.module.cxl_share(&mut r.fm, spid, a.mmid).unwrap();
        assert!(r.fm.expander().sat().check(spid, s.dpa, 64, true));
    }

    #[test]
    fn alloc_failure_after_capacity_exhaustion() {
        let mut r = rig();
        // 4 GiB expander = 16 extents
        let mut ids = Vec::new();
        for _ in 0..16 {
            ids.push(
                r.module
                    .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, EXTENT_SIZE)
                    .unwrap(),
            );
        }
        assert!(r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .is_err());
        // free one and retry
        let a = ids.pop().unwrap();
        r.module
            .pcie_free(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, a.mmid)
            .unwrap();
        assert!(r
            .module
            .pcie_alloc(&mut r.fm, &mut r.iommu, &mut r.space, r.dev, PAGE_SIZE)
            .is_ok());
        r.module.check_invariants().unwrap();
    }
}
