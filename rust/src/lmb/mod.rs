//! The LMB kernel module (§3) — the paper's contribution.
//!
//! One instance runs per host. Device drivers reach it through a single
//! consumer-generic API; the per-host [`LmbHost`] context carries the
//! truly per-host state (IOMMU, address space, module) plus a shared
//! [`FabricRef`](crate::cxl::fm::FabricRef) to the FM arbitrating the
//! expander, so callers never thread that plumbing by hand — and any
//! number of hosts bind to one fabric:
//!
//! | Operation | Unified interface            | Paper's Table 2 names (retired)                   |
//! |-----------|------------------------------|---------------------------------------------------|
//! | Allocate  | `alloc(consumer, size)`      | `lmb_PCIe_alloc` / `lmb_CXL_alloc`                |
//! | Free      | `free(consumer, mmid)`       | `lmb_PCIe_free` / `lmb_CXL_free`                  |
//! | Share     | `share(owner, target, mmid)` | `lmb_PCIe_share` / `lmb_CXL_share`                |
//!
//! A [`Consumer`] names the device class; dispatching on it replaces the
//! old duplicated `pcie_*`/`cxl_*` method pairs. The paper-named shims
//! completed their deprecation cycle and are gone from every layer
//! (`tests/api_surface.rs` pins their absence at the
//! [`System`](crate::system::System) facade); the table above keeps the
//! paper mapping for readers coming from the text.
//!
//! Mechanics (§3.2–§3.3):
//! * capacity comes from the FM in 256 MB extents, each mapped into host
//!   physical space through an HDM decoder window;
//! * sub-allocation metadata lives host-side ([`allocator::SubAllocator`]),
//!   keyed by stable [`allocator::ExtentId`]s;
//! * PCIe consumers get IOMMU mappings (bus address), CXL consumers get
//!   SAT grants (and the GFD's DPID for P2P, plumbed from
//!   [`FabricManager::attach_gfd`] at module load);
//! * freeing tears down the access-control state, and a fully-drained
//!   extent is released back to the FM;
//! * sharing aliases one allocation into another device's view without
//!   copying — the zero-copy path of Figure 5's discussion. Only the
//!   owner may share, and re-sharing to a consumer that already has
//!   access is idempotent (no duplicate IOMMU mappings / SAT entries).

pub mod allocator;
pub mod context;
pub mod failure;
pub mod fault;
pub mod queue;
pub mod service;

pub use context::{IoSession, LmbHost, LmbRegion};
pub use fault::{FaultPlan, FaultPoint, RetryPolicy};
pub use queue::{
    AllocQueue, Completion, Outcome, PlacementPolicy, QueueLimits, QueueStats, QueueStatus,
    Request, SubmitHandle, Ticket, NO_TICKET,
};
pub use service::FmService;

use std::collections::HashMap;

use crate::cxl::fm::{FabricManager, HostId};
use crate::cxl::sat::SatPerm;
use crate::cxl::types::{
    Bdf, BusAddr, Dpa, Dpid, Hpa, MmId, Range, Spid, EXTENT_SIZE,
};
use crate::error::{Error, Result};
use crate::host::AddressSpace;
use crate::pcie::iommu::{Iommu, IommuPerm};
use allocator::{Placement, SubAllocator};

/// Who owns / consumes an allocation. The unified API dispatches the
/// PCIe-vs-CXL access-control setup (IOMMU map vs SAT grant) on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Consumer {
    Pcie(Bdf),
    Cxl(Spid),
}

impl Consumer {
    pub fn is_pcie(&self) -> bool {
        matches!(self, Consumer::Pcie(_))
    }

    pub fn is_cxl(&self) -> bool {
        matches!(self, Consumer::Cxl(_))
    }
}

impl From<Bdf> for Consumer {
    fn from(dev: Bdf) -> Self {
        Consumer::Pcie(dev)
    }
}

impl From<Spid> for Consumer {
    fn from(dev: Spid) -> Self {
        Consumer::Cxl(dev)
    }
}

/// The handle returned by the alloc APIs (paper Table 2 out-params).
#[derive(Debug, Clone, Copy)]
pub struct LmbAlloc {
    pub mmid: MmId,
    /// Host physical address of the region (always valid).
    pub hpa: Hpa,
    /// Device bus address (PCIe consumers; translated by the IOMMU).
    pub bus_addr: Option<BusAddr>,
    /// GFD port id for P2P (CXL consumers).
    pub dpid: Option<Dpid>,
    /// Expander DPA (CXL consumers address HDM by DPA after setup).
    pub dpa: Dpa,
    pub size: u64,
}

#[derive(Debug)]
struct ShareRecord {
    consumer: Consumer,
    bus_addr: Option<BusAddr>,
}

#[derive(Debug)]
struct AllocRecord {
    owner: Consumer,
    placement: Placement,
    bus_addr: Option<BusAddr>,
    shares: Vec<ShareRecord>,
}

/// Per-host LMB kernel module state.
#[derive(Debug)]
pub struct LmbModule {
    host: HostId,
    sub: SubAllocator,
    /// Live allocations. Mmids come from the FM's fabric-global
    /// namespace ([`FabricManager::alloc_mmid`]), so a handle minted on
    /// one host can never alias another host's allocation.
    allocs: HashMap<MmId, AllocRecord>,
    /// §3.1: "we promote the loading priority of the LMB module" — the
    /// module must be initialised before device drivers allocate.
    loaded: bool,
    /// The GFD's DPID handed to CXL consumers for P2P addressing,
    /// plumbed from [`FabricManager::attach_gfd`] through host binding.
    gfd_dpid: Dpid,
    /// How the FM places this module's fresh extents (see
    /// [`PlacementPolicy`]); contention-aware by default, first-fit as
    /// the ablation baseline.
    policy: PlacementPolicy,
}

impl LmbModule {
    /// Initialise ("load") the module for a bound host. `gfd_dpid` is
    /// the real GFD port id returned by [`FabricManager::attach_gfd`]
    /// (see also [`FabricManager::gfd_dpid`]); P2P handles reference it.
    pub fn load(host: HostId, gfd_dpid: Dpid) -> Self {
        LmbModule {
            host,
            sub: SubAllocator::new(),
            allocs: HashMap::new(),
            loaded: true,
            gfd_dpid,
            policy: PlacementPolicy::ContentionAware,
        }
    }

    pub fn host(&self) -> HostId {
        self.host
    }

    /// The extent-placement policy this module asks the FM for.
    pub fn placement_policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Override the extent-placement policy (ablations / baselines).
    pub fn set_placement_policy(&mut self, policy: PlacementPolicy) {
        self.policy = policy;
    }

    pub fn is_loaded(&self) -> bool {
        self.loaded
    }

    /// The GFD DPID this module hands to CXL consumers.
    pub fn gfd_dpid(&self) -> Dpid {
        self.gfd_dpid
    }

    /// Bytes currently leased from the FM / used by live allocations.
    pub fn leased(&self) -> u64 {
        self.sub.leased()
    }

    pub fn used(&self) -> u64 {
        self.sub.used()
    }

    pub fn live_allocs(&self) -> usize {
        self.allocs.len()
    }

    /// The consumer owning `mmid`, if it is live.
    pub fn owner_of(&self, mmid: MmId) -> Option<Consumer> {
        self.allocs.get(&mmid).map(|r| r.owner)
    }

    /// Ensure capacity for `size`, leasing extents from the FM as needed
    /// (§3.2: one 256 MB block at a time; large requests lease several).
    fn ensure_capacity(
        &mut self,
        fm: &FabricManager,
        space: &mut AddressSpace,
        size: u64,
    ) -> Result<Placement> {
        // §1 failure challenge: during an expander outage no new memory
        // may be handed out, even from already-leased extents.
        if fm.expander().is_failed() {
            return Err(Error::ExpanderFailed("device offline".into()));
        }
        if let Some(p) = self.sub.alloc(size) {
            return Ok(p);
        }
        // Lease enough fresh extents to cover the request even when it
        // exceeds one extent. Each extent gets an HDM window + decoder.
        let needed = size.div_ceil(EXTENT_SIZE).max(1);
        for _ in 0..needed {
            let ext = fm.allocate_extent_placed(self.host, EXTENT_SIZE, self.policy)?;
            let hpa = match space.place_hdm_window(ext.len, ext.dpa) {
                Ok(h) => h,
                Err(e) => {
                    let _ = fm.release_extent(self.host, ext);
                    return Err(e);
                }
            };
            if let Err(e) = fm.expander_mut().add_decoder(Range::new(hpa.0, ext.len), ext.dpa) {
                let _ = space.remove_hdm_window(hpa);
                let _ = fm.release_extent(self.host, ext);
                return Err(e);
            }
            self.sub.adopt(ext, hpa);
        }
        self.sub.alloc(size).ok_or(Error::AllocFailed {
            requested: size,
            reason: "request exceeds contiguous extent capacity".into(),
        })
    }

    // ---- unified API ----

    /// Allocate LMB memory for any consumer. Dispatches the class-
    /// specific access-control setup: PCIe consumers get an IOMMU
    /// mapping, CXL consumers a SAT grant plus the GFD DPID.
    pub fn alloc(
        &mut self,
        fm: &FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        consumer: impl Into<Consumer>,
        size: u64,
    ) -> Result<LmbAlloc> {
        fm.seal_check()?;
        match consumer.into() {
            Consumer::Pcie(dev) => self.alloc_pcie(fm, iommu, space, dev, size),
            Consumer::Cxl(dev) => self.alloc_cxl(fm, space, dev, size),
        }
    }

    /// Free an allocation owned by `consumer`: tears down every IOMMU
    /// mapping / SAT entry (shares included) and releases a drained
    /// extent back to the FM.
    pub fn free(
        &mut self,
        fm: &FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        consumer: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<()> {
        fm.seal_check()?;
        let rec = self.take_record(consumer.into(), mmid)?;
        self.free_inner(fm, iommu, space, rec)
    }

    /// Zero-copy sharing: alias `mmid` into `target`'s view. Only the
    /// allocation's owner may share ([`Error::NotOwner`] otherwise), and
    /// re-sharing to a consumer that already has access returns the
    /// existing view instead of programming duplicate state.
    pub fn share(
        &mut self,
        fm: &FabricManager,
        iommu: &mut Iommu,
        owner: impl Into<Consumer>,
        target: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        fm.seal_check()?;
        let owner = owner.into();
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        if rec.owner != owner {
            return Err(Error::NotOwner { mmid });
        }
        match target.into() {
            Consumer::Pcie(dev) => self.share_to_pcie(iommu, dev, mmid),
            Consumer::Cxl(dev) => self.share_to_cxl(fm, dev, mmid),
        }
    }

    /// Data-path access marker: one owner-checked functional read of
    /// `mmid`'s first byte, heating its physical extent for the tiering
    /// engine's ledger. Models device DMA traffic against the buffer
    /// without moving payload through the control plane — the signal
    /// the [`TierDaemon`](crate::tier::TierDaemon) folds each epoch.
    pub fn touch(
        &self,
        fm: &FabricManager,
        consumer: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<()> {
        fm.seal_check()?;
        let consumer = consumer.into();
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        if rec.owner != consumer {
            return Err(Error::NotOwner { mmid });
        }
        // translate-then-read under the expander read lock — the same
        // atomicity argument as `FabricRef::read_dpa`: a migration
        // commit holds the expander write lock, so the resolved address
        // cannot go stale before the access lands
        let exp = fm.expander();
        let phys = fm.resolve_dpa(rec.placement.dpa);
        fm.note_media_access(phys);
        let mut probe = [0u8; 1];
        exp.read_dpa(phys, &mut probe)
    }

    // ---- class-specific internals ----

    fn alloc_pcie(
        &mut self,
        fm: &FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        dev: Bdf,
        size: u64,
    ) -> Result<LmbAlloc> {
        if !self.loaded {
            return Err(Error::Device("LMB module not loaded".into()));
        }
        if !iommu.is_attached(dev) {
            return Err(Error::Device(format!("device {dev} not attached to IOMMU")));
        }
        let placement = self.ensure_capacity(fm, space, size)?;
        let bus = match iommu.map(dev, placement.hpa, placement.len, IommuPerm::ReadWrite) {
            Ok(b) => b,
            Err(e) => {
                let _ = self.sub.free(placement);
                return Err(e);
            }
        };
        let mmid = fm.alloc_mmid();
        self.allocs.insert(
            mmid,
            AllocRecord {
                owner: Consumer::Pcie(dev),
                placement,
                bus_addr: Some(bus),
                shares: Vec::new(),
            },
        );
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: Some(bus),
            dpid: None,
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    fn alloc_cxl(
        &mut self,
        fm: &FabricManager,
        space: &mut AddressSpace,
        dev: Spid,
        size: u64,
    ) -> Result<LmbAlloc> {
        if !self.loaded {
            return Err(Error::Device("LMB module not loaded".into()));
        }
        let placement = self.ensure_capacity(fm, space, size)?;
        let range = Range::new(placement.dpa.0, placement.len);
        if let Err(e) = fm.sat_grant(dev, range, SatPerm::ReadWrite) {
            let _ = self.sub.free(placement);
            return Err(e);
        }
        let mmid = fm.alloc_mmid();
        self.allocs.insert(
            mmid,
            AllocRecord {
                owner: Consumer::Cxl(dev),
                placement,
                bus_addr: None,
                shares: Vec::new(),
            },
        );
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: None,
            dpid: Some(self.gfd_dpid),
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    fn take_record(&mut self, caller: Consumer, mmid: MmId) -> Result<AllocRecord> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        if rec.owner != caller {
            return Err(Error::NotOwner { mmid });
        }
        Ok(self.allocs.remove(&mmid).unwrap())
    }

    /// Common free path: tear down all access-control state, free the
    /// sub-allocation, release a drained extent back to the FM.
    fn free_inner(
        &mut self,
        fm: &FabricManager,
        iommu: &mut Iommu,
        space: &mut AddressSpace,
        rec: AllocRecord,
    ) -> Result<()> {
        // revoke shares first (§3.3: "When a release … is made, the
        // associated entries are also updated")
        for share in &rec.shares {
            match share.consumer {
                Consumer::Pcie(bdf) => {
                    if let Some(bus) = share.bus_addr {
                        let _ = iommu.unmap(bdf, bus);
                    }
                }
                Consumer::Cxl(spid) => {
                    let _ = fm
                        .sat_revoke(spid, Range::new(rec.placement.dpa.0, rec.placement.len));
                }
            }
        }
        match rec.owner {
            Consumer::Pcie(bdf) => {
                if let Some(bus) = rec.bus_addr {
                    iommu.unmap(bdf, bus)?;
                }
            }
            Consumer::Cxl(spid) => {
                fm.sat_revoke(spid, Range::new(rec.placement.dpa.0, rec.placement.len))?;
            }
        }
        // a stale placement (extent already released) surfaces as
        // Error::StalePlacement here instead of aborting the process
        if let Some(id) = self.sub.free(rec.placement)? {
            // Extent fully drained — release it to the FM. ExtentIds are
            // stable, so every other live placement stays valid with no
            // rebasing sweep.
            let st = self.sub.remove_extent(id).ok_or(Error::StalePlacement { extent: id.0 })?;
            fm.expander_mut().remove_decoder(st.hpa_base.0)?;
            space.remove_hdm_window(st.hpa_base)?;
            fm.release_extent(self.host, st.extent)?;
        }
        Ok(())
    }

    /// Share into a PCIe target's IOMMU domain (no owner check — the
    /// unified [`LmbModule::share`] performs it).
    fn share_to_pcie(&mut self, iommu: &mut Iommu, target: Bdf, mmid: MmId) -> Result<LmbAlloc> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        let placement = rec.placement;
        // idempotence: a consumer that already has access gets its
        // existing view back instead of a second IOMMU mapping
        if rec.owner == Consumer::Pcie(target) {
            return Ok(self.get(mmid).unwrap());
        }
        if let Some(s) = rec.shares.iter().find(|s| s.consumer == Consumer::Pcie(target)) {
            return Ok(LmbAlloc {
                mmid,
                hpa: placement.hpa,
                bus_addr: s.bus_addr,
                dpid: None,
                dpa: placement.dpa,
                size: placement.len,
            });
        }
        let bus = iommu.map(target, placement.hpa, placement.len, IommuPerm::ReadWrite)?;
        let rec = self.allocs.get_mut(&mmid).unwrap();
        rec.shares.push(ShareRecord { consumer: Consumer::Pcie(target), bus_addr: Some(bus) });
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: Some(bus),
            dpid: None,
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    /// Grant a CXL target P2P access (no owner check — the unified
    /// [`LmbModule::share`] performs it).
    fn share_to_cxl(&mut self, fm: &FabricManager, target: Spid, mmid: MmId) -> Result<LmbAlloc> {
        let rec = self.allocs.get(&mmid).ok_or(Error::UnknownMmId(mmid))?;
        let placement = rec.placement;
        // idempotence: an existing grant (owner or prior share) is
        // reused; double-programming the SAT would also be rejected by
        // the GFD as an overlapping grant
        if rec.owner == Consumer::Cxl(target)
            || rec.shares.iter().any(|s| s.consumer == Consumer::Cxl(target))
        {
            return Ok(LmbAlloc {
                mmid,
                hpa: placement.hpa,
                bus_addr: None,
                dpid: Some(self.gfd_dpid),
                dpa: placement.dpa,
                size: placement.len,
            });
        }
        fm.sat_grant(target, Range::new(placement.dpa.0, placement.len), SatPerm::ReadWrite)?;
        let rec = self.allocs.get_mut(&mmid).unwrap();
        rec.shares.push(ShareRecord { consumer: Consumer::Cxl(target), bus_addr: None });
        Ok(LmbAlloc {
            mmid,
            hpa: placement.hpa,
            bus_addr: None,
            dpid: Some(self.gfd_dpid),
            dpa: placement.dpa,
            size: placement.len,
        })
    }

    // ---- lookups / invariants ----

    /// Look up a live allocation (tests / coordinator bookkeeping).
    pub fn get(&self, mmid: MmId) -> Option<LmbAlloc> {
        self.allocs.get(&mmid).map(|r| LmbAlloc {
            mmid,
            hpa: r.placement.hpa,
            bus_addr: r.bus_addr,
            dpid: match r.owner {
                Consumer::Cxl(_) => Some(self.gfd_dpid),
                Consumer::Pcie(_) => None,
            },
            dpa: r.placement.dpa,
            size: r.placement.len,
        })
    }

    /// All live mmids (failure handling sweeps these).
    pub fn mmids(&self) -> Vec<MmId> {
        self.allocs.keys().copied().collect()
    }

    /// Allocator invariants (property tests).
    pub fn check_invariants(&self) -> Result<()> {
        self.sub.check_invariants().map_err(Error::FabricManager)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{GIB, PAGE_SIZE};

    struct Rig {
        fm: FabricManager,
        iommu: Iommu,
        space: AddressSpace,
        module: LmbModule,
        dev: Bdf,
    }

    fn rig() -> Rig {
        let fm = FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: 4 * GIB, ..Default::default() }),
        );
        let gfd_dpid = fm.attach_gfd().unwrap();
        let (host, _) = fm.bind_host().unwrap();
        let mut iommu = Iommu::new();
        let dev = Bdf::new(1, 0, 0);
        iommu.attach(dev);
        Rig {
            fm,
            iommu,
            space: AddressSpace::new(GIB),
            module: LmbModule::load(host, gfd_dpid),
            dev,
        }
    }

    impl Rig {
        fn alloc(&mut self, consumer: impl Into<Consumer>, size: u64) -> Result<LmbAlloc> {
            self.module.alloc(&self.fm, &mut self.iommu, &mut self.space, consumer, size)
        }

        fn free(&mut self, consumer: impl Into<Consumer>, mmid: MmId) -> Result<()> {
            self.module.free(&self.fm, &mut self.iommu, &mut self.space, consumer, mmid)
        }

        fn share(
            &mut self,
            owner: impl Into<Consumer>,
            target: impl Into<Consumer>,
            mmid: MmId,
        ) -> Result<LmbAlloc> {
            self.module.share(&self.fm, &mut self.iommu, owner, target, mmid)
        }
    }

    #[test]
    fn pcie_alloc_returns_bus_addr_and_leases_extent() {
        let mut r = rig();
        let dev = r.dev;
        let a = r.alloc(dev, 8 * PAGE_SIZE).unwrap();
        assert!(a.bus_addr.is_some());
        assert!(a.dpid.is_none());
        assert_eq!(a.size, 8 * PAGE_SIZE);
        assert_eq!(r.module.leased(), EXTENT_SIZE, "one 256MB extent leased");
        // The IOMMU must translate the bus address back to the HPA.
        let hpa = r
            .iommu
            .translate(dev, a.bus_addr.unwrap(), 64, true)
            .unwrap();
        assert_eq!(hpa, a.hpa);
        // And the HPA must resolve to the expander DPA.
        match r.space.resolve(a.hpa).unwrap() {
            crate::host::Target::Hdm { dpa } => assert_eq!(dpa, a.dpa),
            t => panic!("expected HDM target, got {t:?}"),
        }
    }

    #[test]
    fn second_alloc_reuses_extent() {
        let mut r = rig();
        let dev = r.dev;
        r.alloc(dev, PAGE_SIZE).unwrap();
        r.alloc(dev, PAGE_SIZE).unwrap();
        assert_eq!(r.module.leased(), EXTENT_SIZE, "no extra extent for small allocs");
    }

    #[test]
    fn large_alloc_leases_multiple_extents() {
        let mut r = rig();
        let dev = r.dev;
        // > one extent: the sub-allocator cannot place it contiguously in
        // one 256MB extent, so the request must fail cleanly (the paper's
        // allocator hands out ≤extent-sized regions).
        let res = r.alloc(dev, EXTENT_SIZE + PAGE_SIZE);
        assert!(res.is_err(), "cross-extent contiguous alloc not supported");
        // but exactly one extent works
        let a = r.alloc(dev, EXTENT_SIZE).unwrap();
        assert_eq!(a.size, EXTENT_SIZE);
    }

    #[test]
    fn free_releases_drained_extent_to_fm() {
        let mut r = rig();
        let dev = r.dev;
        let before = r.fm.available();
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        assert_eq!(r.fm.available(), before - EXTENT_SIZE);
        r.free(dev, a.mmid).unwrap();
        assert_eq!(r.fm.available(), before, "extent returned to FM");
        assert_eq!(r.module.leased(), 0);
        assert_eq!(r.iommu.mapping_count(dev), 0);
        r.fm.check_invariants().unwrap();
    }

    #[test]
    fn extent_release_leaves_other_placements_valid() {
        // Regression for the ExtentId refactor: freeing an allocation
        // that drains one extent must not disturb live placements in any
        // other extent (the old positional scheme rebased indices here).
        let mut r = rig();
        let dev = r.dev;
        let a = r.alloc(dev, EXTENT_SIZE).unwrap(); // fills extent 0
        let b = r.alloc(dev, PAGE_SIZE).unwrap(); // lives in extent 1
        assert_eq!(r.module.leased(), 2 * EXTENT_SIZE);
        r.free(dev, a.mmid).unwrap(); // drains + releases extent 0
        assert_eq!(r.module.leased(), EXTENT_SIZE);
        // b's handle still resolves to valid, translatable state
        let still = r.module.get(b.mmid).expect("b survives a's extent release");
        assert_eq!(still.hpa, b.hpa);
        assert_eq!(still.dpa, b.dpa);
        let hpa = r.iommu.translate(dev, still.bus_addr.unwrap(), 64, true).unwrap();
        assert_eq!(hpa, b.hpa);
        r.module.check_invariants().unwrap();
        // and b can still be freed cleanly, draining the second extent
        r.free(dev, b.mmid).unwrap();
        assert_eq!(r.module.leased(), 0);
        r.fm.check_invariants().unwrap();
    }

    #[test]
    fn free_requires_ownership() {
        let mut r = rig();
        let dev = r.dev;
        let intruder = Bdf::new(9, 0, 0);
        r.iommu.attach(intruder);
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        assert!(matches!(r.free(intruder, a.mmid), Err(Error::NotOwner { .. })));
    }

    #[test]
    fn unknown_mmid_rejected() {
        let mut r = rig();
        let dev = r.dev;
        assert!(matches!(r.free(dev, MmId(404)), Err(Error::UnknownMmId(_))));
    }

    #[test]
    fn cxl_alloc_gets_real_gfd_dpid_and_sat_entry() {
        let mut r = rig();
        let spid = r.fm.bind_cxl_device().unwrap();
        let a = r.alloc(spid, PAGE_SIZE).unwrap();
        assert_eq!(a.dpid, r.fm.gfd_dpid(), "DPID is the real GFD port, not a sentinel");
        assert_eq!(a.dpid, Some(r.module.gfd_dpid()));
        assert!(a.bus_addr.is_none());
        assert!(r.fm.expander().sat().check(spid, a.dpa, 64, true));
        r.free(spid, a.mmid).unwrap();
        assert!(!r.fm.expander().sat().check(spid, a.dpa, 64, false));
    }

    #[test]
    fn share_maps_into_pcie_target_domain() {
        let mut r = rig();
        let dev = r.dev;
        let target = Bdf::new(2, 0, 0);
        r.iommu.attach(target);
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        let s = r.share(dev, target, a.mmid).unwrap();
        assert_eq!(s.hpa, a.hpa);
        let hpa = r.iommu.translate(target, s.bus_addr.unwrap(), 64, true).unwrap();
        assert_eq!(hpa, a.hpa);
        // freeing the owner tears down the share too
        r.free(dev, a.mmid).unwrap();
        assert!(r.iommu.translate(target, s.bus_addr.unwrap(), 64, false).is_err());
    }

    #[test]
    fn share_requires_owner() {
        let mut r = rig();
        let dev = r.dev;
        let intruder = Bdf::new(9, 0, 0);
        let target = Bdf::new(2, 0, 0);
        r.iommu.attach(intruder);
        r.iommu.attach(target);
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        assert!(matches!(
            r.share(intruder, target, a.mmid),
            Err(Error::NotOwner { .. })
        ));
        assert_eq!(r.iommu.mapping_count(target), 0, "denied share programs nothing");
    }

    #[test]
    fn repeated_share_does_not_duplicate_mappings() {
        let mut r = rig();
        let dev = r.dev;
        let target = Bdf::new(2, 0, 0);
        r.iommu.attach(target);
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        let s1 = r.share(dev, target, a.mmid).unwrap();
        let s2 = r.share(dev, target, a.mmid).unwrap();
        assert_eq!(s1.bus_addr, s2.bus_addr, "same view handed back");
        assert_eq!(r.iommu.mapping_count(target), 1, "no duplicate IOMMU mapping");
        // sharing back to the owner is a no-op returning the owner view
        let own = r.share(dev, dev, a.mmid).unwrap();
        assert_eq!(own.bus_addr, a.bus_addr);
        assert_eq!(r.iommu.mapping_count(dev), 1);
    }

    #[test]
    fn repeated_cxl_share_does_not_duplicate_sat_entries() {
        let mut r = rig();
        let spid_a = r.fm.bind_cxl_device().unwrap();
        let spid_b = r.fm.bind_cxl_device().unwrap();
        let a = r.alloc(spid_a, PAGE_SIZE).unwrap();
        let sat_after_alloc = r.fm.expander().sat().len();
        let s1 = r.share(spid_a, spid_b, a.mmid).unwrap();
        let s2 = r.share(spid_a, spid_b, a.mmid).unwrap();
        assert_eq!(s1.dpa, s2.dpa);
        assert_eq!(r.fm.expander().sat().len(), sat_after_alloc + 1, "one grant for b");
        // re-sharing to the owner reuses its own alloc-time grant
        let own = r.share(spid_a, spid_a, a.mmid).unwrap();
        assert_eq!(own.dpa, a.dpa);
        assert_eq!(r.fm.expander().sat().len(), sat_after_alloc + 1);
    }

    #[test]
    fn cxl_share_grants_sat_to_second_device() {
        let mut r = rig();
        let spid_a = r.fm.bind_cxl_device().unwrap();
        let spid_b = r.fm.bind_cxl_device().unwrap();
        let a = r.alloc(spid_a, PAGE_SIZE).unwrap();
        assert!(!r.fm.expander().sat().check(spid_b, a.dpa, 64, false));
        let s = r.share(spid_a, spid_b, a.mmid).unwrap();
        assert_eq!(s.dpa, a.dpa);
        assert!(r.fm.expander().sat().check(spid_b, a.dpa, 64, true));
    }

    #[test]
    fn mixed_share_pcie_alloc_to_cxl_consumer() {
        // Figure 5 scenario: SSD (PCIe) produces, accelerator (CXL)
        // consumes — zero-copy via shared LMB memory.
        let mut r = rig();
        let dev = r.dev;
        let spid = r.fm.bind_cxl_device().unwrap();
        let a = r.alloc(dev, PAGE_SIZE).unwrap();
        let s = r.share(dev, spid, a.mmid).unwrap();
        assert!(r.fm.expander().sat().check(spid, s.dpa, 64, true));
        assert_eq!(s.dpid, r.fm.gfd_dpid());
    }

    #[test]
    fn alloc_failure_after_capacity_exhaustion() {
        let mut r = rig();
        let dev = r.dev;
        // 4 GiB expander = 16 extents
        let mut ids = Vec::new();
        for _ in 0..16 {
            ids.push(r.alloc(dev, EXTENT_SIZE).unwrap());
        }
        assert!(r.alloc(dev, PAGE_SIZE).is_err());
        // free one and retry
        let a = ids.pop().unwrap();
        r.free(dev, a.mmid).unwrap();
        assert!(r.alloc(dev, PAGE_SIZE).is_ok());
        r.module.check_invariants().unwrap();
    }
}
