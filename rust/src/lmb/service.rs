//! The FM service loop: the actor that owns the execute side of the
//! allocation queue.
//!
//! With the thread-safe fabric split, driver threads no longer tick the
//! queue themselves — they hold cloneable [`SubmitHandle`]s and the
//! *service* owns the hosts plus the consumer end of the MPSC intake.
//! What used to be a caller-driven `tick_queue` grows into
//! [`FmService::run`]: an actor loop that
//!
//! 1. drains submissions from every handle (the MPSC pump),
//! 2. schedules them with the rotating per-lane quota (fair across
//!    hosts, deterministic for a fixed arrival order),
//! 3. fans each host's scheduled group out to a **worker pool** — lane
//!    `i` is pinned to worker `i % W`, so one host's requests stay
//!    ordered while disjoint hosts execute concurrently against the
//!    sharded fabric ([`LmbHost::execute_requests`]) — and
//! 4. publishes [`Completion`]s through the completion table the
//!    handles read (`poll` / `take` / blocking `wait`) from any thread.
//!
//! The loop parks on the intake channel when idle and terminates when
//! every handle has been dropped and all accepted work is drained, then
//! hands the hosts back — so a test (or an orchestrator) can join the
//! service thread and audit final state:
//!
//! ```
//! use lmb::cxl::expander::{Expander, ExpanderConfig};
//! use lmb::cxl::fm::{FabricManager, FabricRef};
//! use lmb::cxl::switch::PbrSwitch;
//! use lmb::cxl::types::{Bdf, GIB, PAGE_SIZE};
//! use lmb::lmb::{FmService, LmbHost, Request};
//!
//! let fabric = FabricRef::new(FabricManager::new(
//!     PbrSwitch::new(8),
//!     Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() }),
//! ));
//! let dev = Bdf::new(1, 0, 0);
//! let hosts: Vec<LmbHost> = (0..2)
//!     .map(|_| {
//!         let mut h = LmbHost::bind(fabric.clone(), GIB).unwrap();
//!         h.attach_pcie(dev);
//!         h
//!     })
//!     .collect();
//!
//! let service = FmService::new(hosts);
//! let handles: Vec<_> = (0..2).map(|lane| service.handle(lane).unwrap()).collect();
//! let fm_thread = std::thread::spawn(move || service.run());
//!
//! // driver threads submit from their own contexts and block on results
//! let drivers: Vec<_> = handles
//!     .into_iter()
//!     .map(|h| {
//!         std::thread::spawn(move || {
//!             let t = h
//!                 .submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE })
//!                 .unwrap();
//!             h.wait(t).unwrap().into_alloc().unwrap()
//!         })
//!     })
//!     .collect();
//! for d in drivers {
//!     d.join().unwrap();
//! }
//! // all handles dropped → the service loop drains and returns the hosts
//! let hosts = fm_thread.join().unwrap();
//! assert_eq!(hosts.iter().map(|h| h.module().live_allocs()).sum::<usize>(), 2);
//! ```

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::error::{Error, Result};
use crate::lmb::fault::{FaultPlan, FaultPoint, RetryPolicy};
use crate::lmb::queue::{
    AllocQueue, Completion, CompletionPoster, QueueLimits, Scheduled, SubmitHandle,
    DEFAULT_LANE_QUOTA,
};
use crate::lmb::LmbHost;
use crate::observe::{Event, EventRing, EventSink, StatsSnapshot};
use crate::sim::SimTime;
use crate::tier::{TierConfig, TierDaemon};

/// Recover a fault-plan guard even if a worker panicked while holding
/// it — the plan's counters are always structurally sound.
fn locked_plan(plan: &Mutex<FaultPlan>) -> MutexGuard<'_, FaultPlan> {
    match plan.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The FM-side actor owning hosts and the execute half of an
/// [`AllocQueue`]. Lane `i` of the queue maps to the host in slot `i`.
///
/// `FmService` is `Send`: build it, mint [`SubmitHandle`]s, then move
/// it into its service thread and call [`FmService::run`]. Failure
/// injection runs through the service too — the scenario engine
/// ([`crate::scenario`]) crashes lanes mid-burst with
/// [`FmService::crash_host`] (cancel the lane, reclaim the leases) and
/// re-homes tenants onto lanes added at runtime with
/// [`FmService::join_host`] + [`SubmitHandle::retarget`].
#[derive(Debug)]
pub struct FmService {
    queue: AllocQueue,
    /// One slot per lane; `None` marks a crashed host whose lane is
    /// dead (new submissions are rejected eagerly at the handle; work
    /// that raced past the cancellation completes as cancelled at
    /// execute time, never against reclaimed leases).
    slots: Vec<Option<LmbHost>>,
    lane_quota: usize,
    /// Worker-pool width for [`FmService::run`]; `None` = size to the
    /// machine (`available_parallelism`, capped at the lane count).
    workers: Option<usize>,
    /// The service's deadline clock: [`FmService::tick_at`] advances it
    /// and expires queued work whose deadline it passed. Plain
    /// [`FmService::tick`] reuses the last value, so callers that never
    /// advance time never expire anything.
    now: SimTime,
    /// Bounded deterministic retry of transient execution failures.
    retry: RetryPolicy,
    /// Seeded fault-injection schedule, shared with pool workers.
    plan: Option<Arc<Mutex<FaultPlan>>>,
    /// Transient-failure re-executions performed (serial + workers).
    retries: Arc<AtomicU64>,
    /// Canonical event stream ([`FmService::set_event_ring`]); `None`
    /// means the instrumented paths skip emission entirely.
    events: Option<EventRing>,
    /// Hotness-driven tiering daemon ([`FmService::set_tiering`]);
    /// `None` leaves every extent where placement put it.
    tiering: Option<TierDaemon>,
}

impl FmService {
    /// Wrap `hosts` (all bound to one shared fabric) in a service. The
    /// hosts' own per-context queues are unused from here on; every
    /// submission flows through the service's queue.
    pub fn new(hosts: Vec<LmbHost>) -> Self {
        FmService {
            queue: AllocQueue::new(),
            slots: hosts.into_iter().map(Some).collect(),
            lane_quota: DEFAULT_LANE_QUOTA,
            workers: None,
            now: SimTime::default(),
            retry: RetryPolicy::default(),
            plan: None,
            retries: Arc::new(AtomicU64::new(0)),
            events: None,
            tiering: None,
        }
    }

    /// Per-lane requests serviced per scheduling tick (fairness
    /// quantum).
    pub fn with_lane_quota(mut self, quota: usize) -> Self {
        self.lane_quota = quota.max(1);
        self
    }

    /// Fix the [`FmService::run`] worker-pool width. `1` forces the
    /// serial actor loop (the pre-sharding behavior — the baseline the
    /// scaling bench compares against); the default sizes the pool to
    /// the machine, capped at the lane count. Manual [`FmService::tick`]
    /// driving is always serial regardless of this setting.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// Replace the per-lane intake bounds on the service's queue
    /// (backpressure: see [`QueueLimits`]).
    pub fn with_limits(mut self, limits: QueueLimits) -> Self {
        self.queue.set_limits(limits);
        self
    }

    /// Replace the transient-failure retry policy
    /// (`RetryPolicy { max_attempts: 1, .. }` disables retry).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Arm a seeded fault-injection plan (builder form of
    /// [`FmService::set_fault_plan`]).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.set_fault_plan(plan);
        self
    }

    /// Arm (or replace) the seeded fault-injection plan. On the serial
    /// tick path every strike decision is a pure function of the plan's
    /// seed and the submission history, so faulted runs replay
    /// bit-for-bit; pool workers share the same plan behind a mutex,
    /// where strike *placement* follows thread interleaving
    /// ([`FaultPoint::CrashBetween`] is serial-path-only for exactly
    /// that reason).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.plan = Some(Arc::new(Mutex::new(plan)));
    }

    /// Arm the tiering daemon (builder form of
    /// [`FmService::set_tiering`]).
    pub fn with_tiering(mut self, cfg: TierConfig) -> Self {
        self.set_tiering(cfg);
        self
    }

    /// Arm (or replace) the hotness-driven tiering daemon. From here on
    /// every [`FmService::tick_at`] epoch boundary folds the data-path
    /// heat counters into the daemon's EWMA ledger and executes a
    /// budget-bounded batch of live promotions/demotions against the
    /// shared fabric (emitting `Migrate`/`Promote`/`Demote` events into
    /// the armed ring). If a [`FaultPlan`] is armed, each migration
    /// draws a [`FaultPoint::MigrateAbort`] strike decision before the
    /// copy, so mid-copy aborts replay deterministically with the rest
    /// of the schedule.
    pub fn set_tiering(&mut self, cfg: TierConfig) {
        self.tiering = Some(TierDaemon::new(cfg));
    }

    /// The armed tiering daemon, if any — its migration counters and
    /// EWMA ledger are the observable face of the placement engine.
    pub fn tiering(&self) -> Option<&TierDaemon> {
        self.tiering.as_ref()
    }

    /// Arm the canonical event stream (builder form of
    /// [`FmService::set_event_ring`]).
    pub fn with_event_ring(mut self, ring: EventRing) -> Self {
        self.set_event_ring(ring);
        self
    }

    /// Arm (or share) the canonical event stream: the queue's
    /// submit/schedule/complete path, the fabric's alloc/free/
    /// quarantine/failover path, and the service's own tick/execute/
    /// retry/fault/crash/join transitions all emit into `ring` from
    /// here on. The queue and fabric sinks are set-once per their
    /// lifetimes, so the first ring armed on a given fabric wins.
    pub fn set_event_ring(&mut self, ring: EventRing) {
        self.queue.set_event_sink(ring.sink());
        for (_, host) in self.hosts() {
            host.fabric_ref().set_event_sink(ring.sink());
        }
        self.events = Some(ring);
    }

    /// The armed event ring, if any.
    pub fn events(&self) -> Option<&EventRing> {
        self.events.as_ref()
    }

    /// Dump the armed event ring's retained stream as JSONL to `path`
    /// (see also the `LMB_EVENT_LOG` hook on the scenario harness).
    pub fn dump_events(&self, path: &Path) -> Result<()> {
        let ring = self.events.as_ref().ok_or_else(|| {
            Error::FabricManager("no event ring armed — call set_event_ring first".into())
        })?;
        ring.dump_jsonl(path).map_err(|e| {
            Error::FabricManager(format!("event dump to {} failed: {e}", path.display()))
        })
    }

    /// One snapshot of every diagnostic the service stack exposes:
    /// queue counters, retry and fault-strike totals, fabric lock and
    /// expander-TLB counters (zero if every host has crashed), and the
    /// event-stream watermarks. The single replacement for the removed
    /// 0.3-era `stats`/`retries_performed`/`fault_strikes*` accessors
    /// (their absence is pinned by `tests/api_surface.rs`).
    pub fn telemetry(&self) -> StatsSnapshot {
        let mut snap = StatsSnapshot {
            queue: self.queue.stats(),
            retries: self.retries.load(Ordering::Relaxed),
            ..Default::default()
        };
        if let Some(plan) = &self.plan {
            let p = locked_plan(plan);
            snap.fault_strikes = p.strikes();
            for (slot, point) in snap.fault_strikes_by_point.iter_mut().zip(FaultPoint::ALL) {
                *slot = p.strikes_at(point);
            }
        }
        if let Some((_, host)) = self.hosts().next() {
            let (lock, tlb_hits, tlb_misses) = host.fabric_ref().telemetry_counters();
            snap.lock = lock;
            snap.tlb_hits = tlb_hits;
            snap.tlb_misses = tlb_misses;
        }
        if let Some(ring) = &self.events {
            snap.events = ring.counts();
        }
        snap
    }

    fn sink(&self) -> Option<EventSink> {
        self.events.as_ref().map(EventRing::sink)
    }

    /// A cloneable submission endpoint for `lane`'s host. Mint every
    /// handle **before** calling [`FmService::run`] — the run loop
    /// closes the intake so it can observe disconnection. (Under
    /// manual [`FmService::tick`] driving the intake stays open, so
    /// handles for lanes added by [`FmService::join_host`] can be
    /// minted at any time.)
    pub fn handle(&self, lane: usize) -> Result<SubmitHandle> {
        match self.slots.get(lane) {
            Some(Some(_)) => self.queue.handle(lane),
            Some(None) => {
                Err(Error::FabricManager(format!("host behind lane {lane} has crashed")))
            }
            None => Err(Error::FabricManager(format!(
                "no host behind lane {lane} ({} lanes)",
                self.slots.len()
            ))),
        }
    }

    /// The live hosts the service arbitrates, as `(lane, host)` pairs
    /// in lane order (crashed lanes are skipped).
    pub fn hosts(&self) -> impl Iterator<Item = (usize, &LmbHost)> {
        self.slots.iter().enumerate().filter_map(|(lane, s)| s.as_ref().map(|h| (lane, h)))
    }

    /// The host behind `lane`, if it is alive.
    pub fn host(&self, lane: usize) -> Result<&LmbHost> {
        self.slots
            .get(lane)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| Error::FabricManager(format!("no live host behind lane {lane}")))
    }

    /// Number of lanes ever created (live + crashed).
    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// Number of live hosts.
    pub fn alive(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Crash the host behind `lane` mid-flight: its
    /// queued-but-unscheduled submissions complete with
    /// [`Error::Cancelled`], its leases/SAT grants/decoders are
    /// reclaimed through the fabric, and the lane goes **dead** — later
    /// submissions and retargets aimed at it are rejected eagerly at
    /// the [`SubmitHandle`] (no doomed tickets), and any submission
    /// that raced past the cancellation is cancelled at execute time
    /// instead of touching reclaimed memory.
    pub fn crash_host(&mut self, lane: usize) -> Result<()> {
        let host = self
            .slots
            .get_mut(lane)
            .ok_or_else(|| Error::FabricManager(format!("no lane {lane}")))?
            .take()
            .ok_or_else(|| Error::FabricManager(format!("host behind lane {lane} already gone")))?;
        self.queue.cancel_lane(lane);
        host.fabric_ref().release_host(host.host());
        if let Some(sink) = self.sink() {
            sink.emit(Event::Crash { tick: self.now, lane });
        }
        Ok(())
    }

    /// Add a host (bound to the same shared fabric) behind a fresh
    /// lane; returns the lane id. Mint an endpoint for it with
    /// [`FmService::handle`] (manual ticking) or by retargeting an
    /// existing handle ([`SubmitHandle::retarget`]).
    pub fn join_host(&mut self, host: LmbHost) -> usize {
        self.slots.push(Some(host));
        let lane = self.slots.len() - 1;
        if let Some(sink) = self.sink() {
            sink.emit(Event::Join { tick: self.now, lane });
        }
        lane
    }

    /// Invariant sweep over every live host (module bookkeeping, IOMMU
    /// mappings, fabric lease accounting). Deliberately works through
    /// the hosts' own poison-bypassing checks so post-crash state can
    /// be audited.
    pub fn check_invariants(&self) -> Result<()> {
        for (_, host) in self.hosts() {
            host.check_invariants()?;
        }
        Ok(())
    }

    /// One scheduling tick: pump the intake, pop up to the per-lane
    /// quota from every lane (rotating order), execute each lane's
    /// group against its host, and post completions. Always serial —
    /// the deterministic replay path the scenario engine and the
    /// queued≡sync equivalence driver build on. Returns how many
    /// requests were serviced. Equivalent to
    /// [`FmService::tick_at`] at the clock's last value.
    pub fn tick(&mut self) -> usize {
        self.tick_at(self.now)
    }

    /// [`FmService::tick`] with the deadline clock advanced to `now`:
    /// queued submissions whose deadline is at or before `now` complete
    /// with [`Error::TimedOut`] *before* scheduling, then the survivors
    /// are scheduled and executed. If a [`FaultPlan`] is armed, its
    /// strike decisions land here: scheduled items may be dropped
    /// ([`FaultPoint::IntakeDrop`]), whole groups crashed between
    /// schedule and execute ([`FaultPoint::CrashBetween`] — the host is
    /// [`FmService::crash_host`]ed), and execution faulted per
    /// [`run_group`]'s catalog. Returns expired + serviced requests.
    pub fn tick_at(&mut self, now: SimTime) -> usize {
        self.now = now;
        // publish the tick to the queue/fabric emitters before anything
        // can fire, so every event this tick carries the right stamp
        if let Some(sink) = self.sink() {
            sink.set_now(now);
        }
        // tiering epochs run before scheduling: migrated placements are
        // visible to every request executed this tick. Field-disjoint
        // borrows (daemon is &mut tiering, the fabric comes off a live
        // slot, the abort strikes come off the shared plan) keep the
        // daemon re-entrant with the rest of the tick.
        if let Some(daemon) = self.tiering.as_mut() {
            if let Some(host) = self.slots.iter().flatten().next() {
                let fabric = host.fabric_ref();
                let plan = self.plan.clone();
                // a poisoned fabric ends the epoch early; the daemon
                // retries at the next boundary, so the Err is not fatal
                let _ = daemon.on_tick(now, fabric, || {
                    plan.as_ref()
                        .is_some_and(|p| locked_plan(p).strike(FaultPoint::MigrateAbort))
                });
            }
        }
        let expired = self.queue.expire_due(now);
        let mut rest = self.queue.schedule(self.lane_quota);
        // intake-drop strikes: scheduled, then lost before dispatch
        if let Some(plan) = &self.plan {
            let mut dropped = Vec::new();
            {
                let mut p = locked_plan(plan);
                rest.retain(|s| {
                    if p.strike(FaultPoint::IntakeDrop) {
                        dropped.push((s.ticket, s.lane, s.tenant));
                        false
                    } else {
                        true
                    }
                });
            }
            for (ticket, lane, tenant) in dropped {
                if let Some(sink) = self.sink() {
                    sink.emit(Event::Fault { tick: now, lane, point: FaultPoint::IntakeDrop });
                }
                self.queue.complete(Completion {
                    ticket,
                    lane,
                    tenant,
                    result: Err(Error::Cancelled { ticket: ticket.0 }),
                });
            }
        }
        let total = expired + rest.len();
        while !rest.is_empty() {
            let lane = rest[0].lane;
            let cut = rest.iter().position(|s| s.lane != lane).unwrap_or(rest.len());
            let tail = rest.split_off(cut);
            let group = std::mem::replace(&mut rest, tail);
            // crash-between-schedule-and-execute: the race the scenario
            // ROADMAP item asks for, landed as a declarative knob. Only
            // meaningful for a live lane, and serial-path-only so the
            // crash decision replays deterministically.
            let crash = match &self.plan {
                Some(plan) if matches!(self.slots.get(lane), Some(Some(_))) => {
                    locked_plan(plan).strike(FaultPoint::CrashBetween)
                }
                _ => false,
            };
            if crash {
                if let Some(sink) = self.sink() {
                    sink.emit(Event::Fault { tick: now, lane, point: FaultPoint::CrashBetween });
                }
                for s in &group {
                    self.queue.complete(Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                    });
                }
                self.crash_host(lane).expect("lane verified live before the crash strike");
                continue;
            }
            self.execute_group(lane, group);
        }
        total
    }

    fn execute_group(&mut self, lane: usize, group: Vec<Scheduled>) {
        let sink = self.sink();
        match self.slots.get_mut(lane) {
            Some(Some(host)) => {
                if let Some(sink) = &sink {
                    sink.emit(Event::Execute { tick: self.now, lane, group: group.len() });
                }
                let plan = self.plan.as_deref();
                for c in run_group(host, group, self.retry, plan, &self.retries, sink.as_ref()) {
                    self.queue.complete(c);
                }
            }
            Some(None) => {
                // the host crashed after these submissions were sent:
                // cancel them (terminal) rather than execute against
                // reclaimed leases — mirrors AllocQueue::cancel_lane
                // for work that raced past the cancellation
                for s in group {
                    self.queue.complete(crate::lmb::queue::Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                    });
                }
            }
            None => {
                // a handle minted for a lane this service never had —
                // impossible through FmService::handle, but a forged
                // Submission must not strand its waiter
                for s in group {
                    self.queue.complete(crate::lmb::queue::Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::FabricManager(format!("no host behind lane {lane}"))),
                    });
                }
            }
        }
    }

    /// The service loop. Closes the intake (no new handles), then
    /// alternates draining ticks with parking on the channel; exits
    /// when every [`SubmitHandle`] has been dropped and all accepted
    /// submissions have completed, returning the hosts (in lane order)
    /// for final inspection.
    ///
    /// With more than one worker (see [`FmService::with_workers`]) the
    /// loop becomes a scheduler thread fanning lane groups out to a
    /// pool: lane `i` is pinned to worker `i % W`, so per-lane FIFO
    /// order is preserved while disjoint hosts' groups execute
    /// concurrently against the sharded fabric. Scheduling (which
    /// requests run, in which per-lane order) stays deterministic for
    /// a fixed arrival order; only cross-lane completion interleaving
    /// varies, exactly as it already does for threaded submitters.
    pub fn run(mut self) -> Vec<LmbHost> {
        self.queue.close_intake();
        let workers = self
            .workers
            .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, usize::from))
            .min(self.slots.len())
            .max(1);
        if workers <= 1 {
            loop {
                // drain everything currently visible
                while self.tick() > 0 {}
                // park until new work arrives or the last handle drops
                if !self.queue.pump_blocking() {
                    break;
                }
            }
            // the disconnect may have raced a final burst into the buffer
            while self.tick() > 0 {}
            return self.slots.into_iter().flatten().collect();
        }
        self.run_pool(workers)
    }

    /// Schedule one batch and route each lane group to its pinned
    /// worker; returns how many requests were dispatched. A closed
    /// worker channel means that worker panicked — its groups' waiters
    /// are woken by the queue teardown, so the send error is dropped.
    fn dispatch(
        queue: &mut AllocQueue,
        lane_quota: usize,
        txs: &[Sender<(usize, Vec<Scheduled>)>],
    ) -> usize {
        let mut rest = queue.schedule(lane_quota);
        let total = rest.len();
        while !rest.is_empty() {
            let lane = rest[0].lane;
            let cut = rest.iter().position(|s| s.lane != lane).unwrap_or(rest.len());
            let tail = rest.split_off(cut);
            let group = std::mem::replace(&mut rest, tail);
            let _ = txs[lane % txs.len()].send((lane, group));
        }
        total
    }

    fn run_pool(self, workers: usize) -> Vec<LmbHost> {
        let FmService { mut queue, slots, lane_quota, retry, plan, retries, events, .. } = self;
        let poster = queue.poster();
        // static lane→worker partition: worker w owns lanes ≡ w (mod W)
        let mut shards: Vec<Vec<(usize, Option<LmbHost>)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (lane, slot) in slots.into_iter().enumerate() {
            shards[lane % workers].push((lane, slot));
        }
        let mut returned: Vec<(usize, Option<LmbHost>)> = Vec::new();
        std::thread::scope(|scope| {
            let mut txs: Vec<Sender<(usize, Vec<Scheduled>)>> = Vec::with_capacity(workers);
            let mut joins = Vec::with_capacity(workers);
            for shard in shards {
                let (tx, rx) = channel();
                let poster = poster.clone();
                let plan = plan.clone();
                let retries = Arc::clone(&retries);
                let sink = events.as_ref().map(EventRing::sink);
                joins.push(
                    scope.spawn(move || worker_loop(shard, rx, poster, retry, plan, retries, sink)),
                );
                txs.push(tx);
            }
            loop {
                while Self::dispatch(&mut queue, lane_quota, &txs) > 0 {}
                if !queue.pump_blocking() {
                    break;
                }
            }
            // the disconnect may have raced a final burst into the buffer
            while Self::dispatch(&mut queue, lane_quota, &txs) > 0 {}
            // closing the channels drains the workers and hands the
            // host slots back
            drop(txs);
            for j in joins {
                match j.join() {
                    Ok(shard) => returned.extend(shard),
                    Err(panic) => std::panic::resume_unwind(panic),
                }
            }
        });
        returned.sort_by_key(|&(lane, _)| lane);
        returned.into_iter().filter_map(|(_, slot)| slot).collect()
    }
}

/// One pool worker: executes lane groups against the hosts it owns and
/// posts completions from its own thread. Mirrors the three
/// [`FmService::tick`] execute branches (live host / crashed lane /
/// forged lane) so pooled and serial runs complete identically — the
/// live branch goes through the same [`run_group`] fault/retry pipeline
/// ([`FaultPoint::CrashBetween`] excepted: crashing a host requires the
/// scheduler's ownership of the slot, so it stays serial-path-only).
fn worker_loop(
    mut shard: Vec<(usize, Option<LmbHost>)>,
    rx: Receiver<(usize, Vec<Scheduled>)>,
    poster: CompletionPoster,
    retry: RetryPolicy,
    plan: Option<Arc<Mutex<FaultPlan>>>,
    retries: Arc<AtomicU64>,
    sink: Option<EventSink>,
) -> Vec<(usize, Option<LmbHost>)> {
    while let Ok((lane, group)) = rx.recv() {
        match shard.iter_mut().find(|&&mut (l, _)| l == lane) {
            Some((_, Some(host))) => {
                if let Some(sink) = &sink {
                    sink.emit(Event::Execute { tick: sink.now(), lane, group: group.len() });
                }
                for c in run_group(host, group, retry, plan.as_deref(), &retries, sink.as_ref()) {
                    poster.post(c);
                }
            }
            Some((_, None)) => {
                for s in group {
                    poster.post(Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                    });
                }
            }
            None => {
                for s in group {
                    poster.post(Completion {
                        ticket: s.ticket,
                        lane,
                        tenant: s.tenant,
                        result: Err(Error::FabricManager(format!("no host behind lane {lane}"))),
                    });
                }
            }
        }
    }
    shard
}

/// Execute one live lane group through the fault-injection window and
/// the bounded retry loop. The shared pipeline of the serial tick and
/// every pool worker:
///
/// 1. **Fault window** (plan armed): a [`FaultPoint::SlowRegion`]
///    strike arms a brief stall on the fabric's next allocation; a
///    [`FaultPoint::MidGroupPanic`] strike fails the back half of the
///    group with [`Error::FabricPoisoned`] *finally* (a panicked
///    worker's batch is not transparently retried — the caller decides
///    whether to resubmit); a [`FaultPoint::ExpanderNak`] strike makes
///    the whole group's **first attempt** fail with a transient
///    [`Error::ExpanderFailed`], which the retry loop then heals.
/// 2. **First attempt**: the group executes against the host (or is
///    NAK'd wholesale).
/// 3. **Bounded retry**: completions that failed with a *transient*
///    error ([`Error::is_transient`]) are re-executed individually, up
///    to `retry.max_attempts` total attempts, with jitter-free
///    exponential backoff (`retry.backoff_yields` scheduler yields
///    between rounds). Quarantined-region reroute happens inside the
///    re-execution (placement skips poisoned shards), so a retry can
///    succeed even while part of the fabric stays down. Permanent
///    errors surface immediately.
fn run_group(
    host: &mut LmbHost,
    mut group: Vec<Scheduled>,
    retry: RetryPolicy,
    plan: Option<&Mutex<FaultPlan>>,
    retries: &AtomicU64,
    sink: Option<&EventSink>,
) -> Vec<Completion> {
    let lane = group.first().map(|s| s.lane).unwrap_or(0);
    let mut out = Vec::with_capacity(group.len());
    let mut nak_first = false;
    if let Some(plan) = plan {
        let mut p = locked_plan(plan);
        if p.strike(FaultPoint::SlowRegion) {
            host.fabric_ref().inject_slow_region(1);
            if let Some(sink) = sink {
                sink.emit(Event::Fault { tick: sink.now(), lane, point: FaultPoint::SlowRegion });
            }
        }
        if p.strike(FaultPoint::MidGroupPanic) && !group.is_empty() {
            if let Some(sink) = sink {
                sink.emit(Event::Fault {
                    tick: sink.now(),
                    lane,
                    point: FaultPoint::MidGroupPanic,
                });
            }
            let tail = group.split_off(group.len() / 2);
            for s in tail {
                out.push(Completion {
                    ticket: s.ticket,
                    lane: s.lane,
                    tenant: s.tenant,
                    result: Err(Error::FabricPoisoned),
                });
            }
        }
        nak_first = p.strike(FaultPoint::ExpanderNak);
        if nak_first {
            if let Some(sink) = sink {
                sink.emit(Event::Fault { tick: sink.now(), lane, point: FaultPoint::ExpanderNak });
            }
        }
    }
    // keep the requests around: a transient failure re-executes them
    let originals: Vec<Scheduled> = group.clone();
    let mut completions: Vec<Completion> = if nak_first {
        group
            .iter()
            .map(|s| Completion {
                ticket: s.ticket,
                lane: s.lane,
                tenant: s.tenant,
                result: Err(Error::ExpanderFailed("injected NAK".into())),
            })
            .collect()
    } else {
        host.execute_requests(group)
    };
    for attempt in 1..retry.max_attempts {
        let transient: Vec<usize> = completions
            .iter()
            .enumerate()
            .filter(|(_, c)| matches!(&c.result, Err(e) if e.is_transient()))
            .map(|(i, _)| i)
            .collect();
        if transient.is_empty() {
            break;
        }
        for _ in 0..retry.backoff_yields(attempt - 1) {
            std::thread::yield_now();
        }
        for i in transient {
            let ticket = completions[i].ticket;
            let orig = originals
                .iter()
                .find(|s| s.ticket == ticket)
                .expect("every retried completion came from this group")
                .clone();
            retries.fetch_add(1, Ordering::Relaxed);
            if let Some(sink) = sink {
                sink.emit(Event::Retry {
                    tick: sink.now(),
                    lane: orig.lane,
                    ticket,
                    attempt: attempt + 1,
                });
            }
            let redo = host.execute_requests(vec![orig]);
            completions[i] = redo.into_iter().next().expect("one request yields one completion");
        }
    }
    out.extend(completions);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::fm::{FabricManager, FabricRef};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{Bdf, EXTENT_SIZE, GIB, PAGE_SIZE};
    use crate::lmb::queue::{QueueStatus, Request};

    fn fabric_with(bytes: u64) -> FabricRef {
        FabricRef::new(FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: bytes, ..Default::default() }),
        ))
    }

    fn service(hosts: usize, expander_bytes: u64) -> (FmService, FabricRef, Bdf) {
        let fabric = fabric_with(expander_bytes);
        let dev = Bdf::new(1, 0, 0);
        let hosts: Vec<LmbHost> = (0..hosts)
            .map(|_| {
                let mut h = LmbHost::bind(fabric.clone(), GIB).unwrap();
                h.attach_pcie(dev);
                h
            })
            .collect();
        (FmService::new(hosts), fabric, dev)
    }

    #[test]
    fn manual_ticks_execute_handle_submissions() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        let t0 = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        let t1 = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 2);
        let a0 = h0.take(t0).unwrap().into_alloc().unwrap();
        let a1 = h1.take(t1).unwrap().into_alloc().unwrap();
        assert_ne!(a0.mmid, a1.mmid, "fabric-global mmids across service lanes");
        assert_eq!(fabric.lease_count(), 2);
        // frees flow back the same way
        let f0 = h0.submit(Request::Free { consumer: dev.into(), mmid: a0.mmid }).unwrap();
        assert_eq!(svc.tick(), 1);
        assert_eq!(h0.poll(f0), QueueStatus::Ready);
        h0.take(f0).unwrap().result.unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn unknown_lane_is_rejected_at_handle_time() {
        let (svc, _fabric, _dev) = service(1, GIB);
        assert!(svc.handle(0).is_ok());
        assert!(svc.handle(1).is_err());
    }

    #[test]
    fn run_terminates_when_handles_drop_and_returns_hosts() {
        let (svc, fabric, dev) = service(2, GIB);
        let handles: Vec<SubmitHandle> = (0..2).map(|l| svc.handle(l).unwrap()).collect();
        let fm_thread = std::thread::spawn(move || svc.run());
        let drivers: Vec<_> = handles
            .into_iter()
            .map(|h| {
                std::thread::spawn(move || {
                    let t = h
                        .submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE })
                        .unwrap();
                    h.wait(t).unwrap().into_alloc().unwrap().mmid
                })
            })
            .collect();
        let mmids: Vec<_> = drivers.into_iter().map(|d| d.join().unwrap()).collect();
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 2);
        assert_eq!(mmids.len(), 2);
        let live: usize = hosts.iter().map(|h| h.module().live_allocs()).sum();
        assert_eq!(live, 2);
        assert_eq!(fabric.available(), GIB - 2 * EXTENT_SIZE);
        for host in &hosts {
            host.check_invariants().unwrap();
        }
    }

    #[test]
    fn pooled_run_executes_across_workers_and_returns_hosts_in_lane_order() {
        let (svc, fabric, dev) = service(4, 4 * GIB);
        let svc = svc.with_workers(4);
        let handles: Vec<SubmitHandle> = (0..4).map(|l| svc.handle(l).unwrap()).collect();
        let fm_thread = std::thread::spawn(move || svc.run());
        let drivers: Vec<_> = handles
            .into_iter()
            .map(|h| {
                std::thread::spawn(move || {
                    let mut live = Vec::new();
                    for _ in 0..8 {
                        let t = h
                            .submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE })
                            .unwrap();
                        live.push(h.wait(t).unwrap().into_alloc().unwrap());
                    }
                    for a in live.drain(..4) {
                        let t = h
                            .submit(Request::Free { consumer: dev.into(), mmid: a.mmid })
                            .unwrap();
                        h.wait(t).unwrap().result.unwrap();
                    }
                    live.len()
                })
            })
            .collect();
        for d in drivers {
            assert_eq!(d.join().unwrap(), 4, "every driver kept 4 of its 8 allocs");
        }
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 4);
        assert!(
            hosts.windows(2).all(|w| w[0].host() < w[1].host()),
            "hosts hand back in lane order even though workers finish out of order"
        );
        let live: usize = hosts.iter().map(|h| h.module().live_allocs()).sum();
        assert_eq!(live, 16);
        for host in &hosts {
            host.check_invariants().unwrap();
        }
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn pooled_run_rejects_dead_lane_submissions_eagerly() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        svc.crash_host(0).unwrap();
        let svc = svc.with_workers(2);
        let fm_thread = std::thread::spawn(move || svc.run());
        // satellite bugfix: a submit at the dead lane is rejected at the
        // handle — no doomed ticket is minted, nothing is enqueued
        let err = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap_err();
        assert!(
            matches!(err, Error::Cancelled { ticket: crate::lmb::queue::NO_TICKET }),
            "got {err:?}"
        );
        let ok = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        h1.wait(ok).unwrap().into_alloc().unwrap();
        drop((h0, h1));
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 1, "the crashed slot is not handed back");
        assert_eq!(hosts[0].module().live_allocs(), 1);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn crash_host_cancels_lane_and_reclaims_leases() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        let a = h0.submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h0.take(a).unwrap().result.unwrap();
        assert_eq!(fabric.available(), GIB - EXTENT_SIZE);
        // one queued-but-unscheduled request dies with the host
        let doomed = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        svc.crash_host(0).unwrap();
        assert!(h0.take(doomed).unwrap().is_cancelled());
        assert_eq!(fabric.available(), GIB, "crash reclaims the victim's extents");
        assert_eq!((svc.alive(), svc.lanes()), (1, 2));
        assert!(svc.handle(0).is_err(), "dead lane mints no new endpoints");
        assert!(svc.crash_host(0).is_err(), "double crash is rejected");
        // a late submission at the dead lane is rejected eagerly — no
        // doomed ticket, nothing for the scheduler to cancel later
        let err = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap_err();
        assert!(matches!(err, Error::Cancelled { .. }), "got {err:?}");
        assert_eq!(svc.tick(), 0, "the rejected submit enqueued nothing");
        // the surviving lane still executes
        let ok = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h1.take(ok).unwrap().into_alloc().unwrap();
        svc.check_invariants().unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn join_host_adds_a_lane_behind_a_retargeted_handle() {
        let (mut svc, fabric, dev) = service(1, GIB);
        let h0 = svc.handle(0).unwrap();
        let mut joined = crate::lmb::LmbHost::bind(fabric.clone(), GIB).unwrap();
        joined.attach_pcie(dev);
        let lane = svc.join_host(joined);
        assert_eq!(lane, 1);
        assert_eq!((svc.alive(), svc.lanes()), (2, 2));
        let h1 = h0.retarget(lane).unwrap();
        let t = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h1.take(t).unwrap().into_alloc().unwrap();
        assert_eq!(svc.host(lane).unwrap().module().live_allocs(), 1);
        assert_eq!(svc.hosts().count(), 2);
        svc.check_invariants().unwrap();
    }

    #[test]
    fn tick_at_expires_overdue_work_before_scheduling() {
        use crate::sim::SimTime;
        let (mut svc, _fabric, dev) = service(1, GIB);
        let h = svc.handle(0).unwrap();
        let stale = h
            .submit_with_deadline(
                Request::Alloc { consumer: dev.into(), size: PAGE_SIZE },
                SimTime(1_000),
            )
            .unwrap();
        let fresh = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        // the clock jumps past the deadline before the service runs
        assert_eq!(svc.tick_at(SimTime(2_000)), 2, "one expired + one executed");
        let c = h.take(stale).unwrap();
        assert!(c.is_timed_out(), "got {:?}", c.result);
        assert_eq!(h.poll(stale), QueueStatus::TimedOut, "terminal status");
        h.take(fresh).unwrap().into_alloc().unwrap();
        assert_eq!(svc.telemetry().queue.timed_out, 1);
        svc.check_invariants().unwrap();
    }

    #[test]
    fn expander_nak_strike_is_healed_by_retry() {
        use crate::lmb::fault::{FaultPlan, FaultPoint};
        let (svc, _fabric, dev) = service(1, GIB);
        let mut svc =
            svc.with_fault_plan(FaultPlan::new(0xfa17).enable(FaultPoint::ExpanderNak, 1_000_000));
        let h = svc.handle(0).unwrap();
        let t = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        // every group's first attempt NAKs, but the transient retry
        // re-executes it against the healthy fabric and succeeds
        h.take(t).unwrap().into_alloc().unwrap();
        assert!(svc.telemetry().fault_strikes_by_point[FaultPoint::ExpanderNak.index()] >= 1);
        assert!(svc.telemetry().retries >= 1, "the NAK was healed by a retry");
        svc.check_invariants().unwrap();
    }

    #[test]
    fn retry_surfaces_permanent_outage_after_bounded_attempts() {
        use crate::lmb::fault::RetryPolicy;
        let (svc, fabric, dev) = service(1, GIB);
        let mut svc = svc.with_retry(RetryPolicy { max_attempts: 3, backoff_base: 1 });
        let h = svc.handle(0).unwrap();
        fabric.set_expander_failed(true);
        let t = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        let c = h.take(t).unwrap();
        assert!(
            matches!(c.result, Err(Error::ExpanderFailed(_))),
            "a dead expander still surfaces after retries: {:?}",
            c.result
        );
        assert_eq!(svc.telemetry().retries, 2, "exactly max_attempts - 1 retries");
        fabric.set_expander_failed(false);
    }

    #[test]
    fn intake_drop_strikes_cancel_scheduled_work() {
        use crate::lmb::fault::{FaultPlan, FaultPoint};
        let (svc, _fabric, dev) = service(1, GIB);
        let mut svc =
            svc.with_fault_plan(FaultPlan::new(7).enable(FaultPoint::IntakeDrop, 1_000_000));
        let h = svc.handle(0).unwrap();
        let t = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1, "the dropped item still counts as scheduled");
        assert!(h.take(t).unwrap().is_cancelled(), "dropped on the floor, not executed");
        assert_eq!(svc.telemetry().queue.cancelled, 1);
        assert_eq!(svc.host(0).unwrap().module().live_allocs(), 0);
    }

    #[test]
    fn crash_between_strike_kills_the_host_and_cancels_the_group() {
        use crate::lmb::fault::{FaultPlan, FaultPoint};
        let (svc, fabric, dev) = service(2, GIB);
        let mut svc = svc.with_fault_plan(
            FaultPlan::new(11).enable(FaultPoint::CrashBetween, 1_000_000).with_crash_budget(1),
        );
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        let t0 = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        let t1 = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        svc.tick();
        // the first lane group drew the crash; the budget (1) protects
        // the second group, which executes normally
        assert!(h0.take(t0).unwrap().is_cancelled(), "group cancelled by the crash race");
        h1.take(t1).unwrap().into_alloc().unwrap();
        assert_eq!((svc.alive(), svc.lanes()), (1, 2));
        // the crashed lane is dead for new work
        assert!(h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).is_err());
        svc.check_invariants().unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn slow_region_strike_stalls_but_completes() {
        use crate::lmb::fault::{FaultPlan, FaultPoint};
        let (svc, _fabric, dev) = service(1, GIB);
        let mut svc =
            svc.with_fault_plan(FaultPlan::new(13).enable(FaultPoint::SlowRegion, 1_000_000));
        let h = svc.handle(0).unwrap();
        let t = h.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h.take(t).unwrap().into_alloc().unwrap();
        assert!(svc.telemetry().fault_strikes_by_point[FaultPoint::SlowRegion.index()] >= 1, "latency fault fired");
        svc.check_invariants().unwrap();
    }

    #[test]
    fn tiering_daemon_promotes_hot_pm_extent_at_epoch() {
        use crate::cxl::expander::MediaTier;
        use crate::lmb::queue::Outcome;
        use crate::observe::{EventKind, EventRing};
        use crate::tier::TierConfig;

        let fabric = FabricRef::new(FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig {
                dram_capacity: EXTENT_SIZE,
                pm_capacity: 8 * EXTENT_SIZE,
                ..Default::default()
            }),
        ));
        let dev = Bdf::new(1, 0, 0);
        let mut host = LmbHost::bind(fabric.clone(), GIB).unwrap();
        host.attach_pcie(dev);
        let mut svc = FmService::new(vec![host]).with_tiering(TierConfig::default());
        svc.set_event_ring(EventRing::new(256));
        let h = svc.handle(0).unwrap();

        // two extent-sized allocs: at most one fits the single-extent
        // DRAM tier, so at least one lands in PM
        let mut allocs = Vec::new();
        for _ in 0..2 {
            let t = h.submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE }).unwrap();
            assert_eq!(svc.tick(), 1);
            allocs.push(h.take(t).unwrap().into_alloc().unwrap());
        }
        let hot = allocs
            .iter()
            .find(|a| fabric.tier_of(a.dpa).unwrap() == MediaTier::Pm)
            .expect("one extent must spill past the single DRAM slot");
        let (hot_mmid, hot_dpa) = (hot.mmid, hot.dpa);

        // heat the PM extent through the queued data path; the clock
        // stays inside the first epoch so nothing migrates yet
        for _ in 0..8 {
            let t = h.submit(Request::Touch { consumer: dev.into(), mmid: hot_mmid }).unwrap();
            assert_eq!(svc.tick(), 1);
            assert!(matches!(h.take(t).unwrap().result.unwrap(), Outcome::Touched));
        }
        assert_eq!(fabric.tier_of(hot_dpa).unwrap(), MediaTier::Pm, "no migration before the epoch");

        // crossing the epoch boundary folds the heat and promotes
        svc.tick_at(SimTime::us(150));
        assert_eq!(
            fabric.tier_of(hot_dpa).unwrap(),
            MediaTier::Dram,
            "hot PM extent promoted at the epoch boundary"
        );
        let c = svc.tiering().unwrap().counters();
        assert_eq!(c.promotes, 1, "exactly the hot extent moved up");
        assert_eq!(c.aborts, 0);
        let ev = svc.events().unwrap().counts();
        assert_eq!(ev.of(EventKind::Promote), 1);
        assert_eq!(ev.of(EventKind::Demote), c.demotes);
        assert_eq!(
            ev.of(EventKind::Migrate),
            ev.of(EventKind::Promote) + ev.of(EventKind::Demote),
            "every Migrate pairs with a terminal Promote/Demote"
        );
        svc.check_invariants().unwrap();
        fabric.check_invariants().unwrap();
    }
}
