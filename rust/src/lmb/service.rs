//! The FM service loop: the actor that owns the execute side of the
//! allocation queue.
//!
//! With the thread-safe fabric split, driver threads no longer tick the
//! queue themselves — they hold cloneable [`SubmitHandle`]s and the
//! *service* owns the hosts plus the consumer end of the MPSC intake.
//! What used to be a caller-driven `tick_queue` grows into
//! [`FmService::run`]: an actor loop that
//!
//! 1. drains submissions from every handle (the MPSC pump),
//! 2. schedules them with the rotating per-lane quota (fair across
//!    hosts, deterministic for a fixed arrival order),
//! 3. fans each host's scheduled group out to a **worker pool** — lane
//!    `i` is pinned to worker `i % W`, so one host's requests stay
//!    ordered while disjoint hosts execute concurrently against the
//!    sharded fabric ([`LmbHost::execute_requests`]) — and
//! 4. publishes [`Completion`]s through the completion table the
//!    handles read (`poll` / `take` / blocking `wait`) from any thread.
//!
//! The loop parks on the intake channel when idle and terminates when
//! every handle has been dropped and all accepted work is drained, then
//! hands the hosts back — so a test (or an orchestrator) can join the
//! service thread and audit final state:
//!
//! ```
//! use lmb::cxl::expander::{Expander, ExpanderConfig};
//! use lmb::cxl::fm::{FabricManager, FabricRef};
//! use lmb::cxl::switch::PbrSwitch;
//! use lmb::cxl::types::{Bdf, GIB, PAGE_SIZE};
//! use lmb::lmb::{FmService, LmbHost, Request};
//!
//! let fabric = FabricRef::new(FabricManager::new(
//!     PbrSwitch::new(8),
//!     Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() }),
//! ));
//! let dev = Bdf::new(1, 0, 0);
//! let hosts: Vec<LmbHost> = (0..2)
//!     .map(|_| {
//!         let mut h = LmbHost::bind(fabric.clone(), GIB).unwrap();
//!         h.attach_pcie(dev);
//!         h
//!     })
//!     .collect();
//!
//! let service = FmService::new(hosts);
//! let handles: Vec<_> = (0..2).map(|lane| service.handle(lane).unwrap()).collect();
//! let fm_thread = std::thread::spawn(move || service.run());
//!
//! // driver threads submit from their own contexts and block on results
//! let drivers: Vec<_> = handles
//!     .into_iter()
//!     .map(|h| {
//!         std::thread::spawn(move || {
//!             let t = h
//!                 .submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE })
//!                 .unwrap();
//!             h.wait(t).unwrap().into_alloc().unwrap()
//!         })
//!     })
//!     .collect();
//! for d in drivers {
//!     d.join().unwrap();
//! }
//! // all handles dropped → the service loop drains and returns the hosts
//! let hosts = fm_thread.join().unwrap();
//! assert_eq!(hosts.iter().map(|h| h.module().live_allocs()).sum::<usize>(), 2);
//! ```

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::error::{Error, Result};
use crate::lmb::queue::{
    AllocQueue, Completion, CompletionPoster, QueueStats, Scheduled, SubmitHandle,
    DEFAULT_LANE_QUOTA,
};
use crate::lmb::LmbHost;

/// The FM-side actor owning hosts and the execute half of an
/// [`AllocQueue`]. Lane `i` of the queue maps to the host in slot `i`.
///
/// `FmService` is `Send`: build it, mint [`SubmitHandle`]s, then move
/// it into its service thread and call [`FmService::run`]. Failure
/// injection runs through the service too — the scenario engine
/// ([`crate::scenario`]) crashes lanes mid-burst with
/// [`FmService::crash_host`] (cancel the lane, reclaim the leases) and
/// re-homes tenants onto lanes added at runtime with
/// [`FmService::join_host`] + [`SubmitHandle::retarget`].
#[derive(Debug)]
pub struct FmService {
    queue: AllocQueue,
    /// One slot per lane; `None` marks a crashed host whose lane stays
    /// allocated (late submissions complete as cancelled, they never
    /// execute against reclaimed leases).
    slots: Vec<Option<LmbHost>>,
    lane_quota: usize,
    /// Worker-pool width for [`FmService::run`]; `None` = size to the
    /// machine (`available_parallelism`, capped at the lane count).
    workers: Option<usize>,
}

impl FmService {
    /// Wrap `hosts` (all bound to one shared fabric) in a service. The
    /// hosts' own per-context queues are unused from here on; every
    /// submission flows through the service's queue.
    pub fn new(hosts: Vec<LmbHost>) -> Self {
        FmService {
            queue: AllocQueue::new(),
            slots: hosts.into_iter().map(Some).collect(),
            lane_quota: DEFAULT_LANE_QUOTA,
            workers: None,
        }
    }

    /// Per-lane requests serviced per scheduling tick (fairness
    /// quantum).
    pub fn with_lane_quota(mut self, quota: usize) -> Self {
        self.lane_quota = quota.max(1);
        self
    }

    /// Fix the [`FmService::run`] worker-pool width. `1` forces the
    /// serial actor loop (the pre-sharding behavior — the baseline the
    /// scaling bench compares against); the default sizes the pool to
    /// the machine, capped at the lane count. Manual [`FmService::tick`]
    /// driving is always serial regardless of this setting.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// A cloneable submission endpoint for `lane`'s host. Mint every
    /// handle **before** calling [`FmService::run`] — the run loop
    /// closes the intake so it can observe disconnection. (Under
    /// manual [`FmService::tick`] driving the intake stays open, so
    /// handles for lanes added by [`FmService::join_host`] can be
    /// minted at any time.)
    pub fn handle(&self, lane: usize) -> Result<SubmitHandle> {
        match self.slots.get(lane) {
            Some(Some(_)) => self.queue.handle(lane),
            Some(None) => {
                Err(Error::FabricManager(format!("host behind lane {lane} has crashed")))
            }
            None => Err(Error::FabricManager(format!(
                "no host behind lane {lane} ({} lanes)",
                self.slots.len()
            ))),
        }
    }

    /// The live hosts the service arbitrates, as `(lane, host)` pairs
    /// in lane order (crashed lanes are skipped).
    pub fn hosts(&self) -> impl Iterator<Item = (usize, &LmbHost)> {
        self.slots.iter().enumerate().filter_map(|(lane, s)| s.as_ref().map(|h| (lane, h)))
    }

    /// The host behind `lane`, if it is alive.
    pub fn host(&self, lane: usize) -> Result<&LmbHost> {
        self.slots
            .get(lane)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| Error::FabricManager(format!("no live host behind lane {lane}")))
    }

    /// Number of lanes ever created (live + crashed).
    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// Number of live hosts.
    pub fn alive(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Crash the host behind `lane` mid-flight: its
    /// queued-but-unscheduled submissions complete with
    /// [`Error::Cancelled`], its leases/SAT grants/decoders are
    /// reclaimed through the fabric, and the lane goes dead — later
    /// submissions aimed at it are cancelled at execute time instead
    /// of touching reclaimed memory.
    pub fn crash_host(&mut self, lane: usize) -> Result<()> {
        let host = self
            .slots
            .get_mut(lane)
            .ok_or_else(|| Error::FabricManager(format!("no lane {lane}")))?
            .take()
            .ok_or_else(|| Error::FabricManager(format!("host behind lane {lane} already gone")))?;
        self.queue.cancel_lane(lane);
        host.fabric_ref().release_host(host.host());
        Ok(())
    }

    /// Add a host (bound to the same shared fabric) behind a fresh
    /// lane; returns the lane id. Mint an endpoint for it with
    /// [`FmService::handle`] (manual ticking) or by retargeting an
    /// existing handle ([`SubmitHandle::retarget`]).
    pub fn join_host(&mut self, host: LmbHost) -> usize {
        self.slots.push(Some(host));
        self.slots.len() - 1
    }

    /// Invariant sweep over every live host (module bookkeeping, IOMMU
    /// mappings, fabric lease accounting). Deliberately works through
    /// the hosts' own poison-bypassing checks so post-crash state can
    /// be audited.
    pub fn check_invariants(&self) -> Result<()> {
        for (_, host) in self.hosts() {
            host.check_invariants()?;
        }
        Ok(())
    }

    /// Queue counters (submitted / completed / cancelled / ticks).
    pub fn stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// One scheduling tick: pump the intake, pop up to the per-lane
    /// quota from every lane (rotating order), execute each lane's
    /// group against its host, and post completions. Always serial —
    /// the deterministic replay path the scenario engine and the
    /// queued≡sync equivalence driver build on. Returns how many
    /// requests were serviced.
    pub fn tick(&mut self) -> usize {
        let mut rest = self.queue.schedule(self.lane_quota);
        let total = rest.len();
        while !rest.is_empty() {
            let lane = rest[0].lane;
            let cut = rest.iter().position(|s| s.lane != lane).unwrap_or(rest.len());
            let tail = rest.split_off(cut);
            let group = std::mem::replace(&mut rest, tail);
            self.execute_group(lane, group);
        }
        total
    }

    fn execute_group(&mut self, lane: usize, group: Vec<Scheduled>) {
        match self.slots.get_mut(lane) {
            Some(Some(host)) => {
                for c in host.execute_requests(group) {
                    self.queue.complete(c);
                }
            }
            Some(None) => {
                // the host crashed after these submissions were sent:
                // cancel them (terminal) rather than execute against
                // reclaimed leases — mirrors AllocQueue::cancel_lane
                // for work that raced past the cancellation
                for s in group {
                    self.queue.complete(crate::lmb::queue::Completion {
                        ticket: s.ticket,
                        lane,
                        result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                    });
                }
            }
            None => {
                // a handle minted for a lane this service never had —
                // impossible through FmService::handle, but a forged
                // Submission must not strand its waiter
                for s in group {
                    self.queue.complete(crate::lmb::queue::Completion {
                        ticket: s.ticket,
                        lane,
                        result: Err(Error::FabricManager(format!("no host behind lane {lane}"))),
                    });
                }
            }
        }
    }

    /// The service loop. Closes the intake (no new handles), then
    /// alternates draining ticks with parking on the channel; exits
    /// when every [`SubmitHandle`] has been dropped and all accepted
    /// submissions have completed, returning the hosts (in lane order)
    /// for final inspection.
    ///
    /// With more than one worker (see [`FmService::with_workers`]) the
    /// loop becomes a scheduler thread fanning lane groups out to a
    /// pool: lane `i` is pinned to worker `i % W`, so per-lane FIFO
    /// order is preserved while disjoint hosts' groups execute
    /// concurrently against the sharded fabric. Scheduling (which
    /// requests run, in which per-lane order) stays deterministic for
    /// a fixed arrival order; only cross-lane completion interleaving
    /// varies, exactly as it already does for threaded submitters.
    pub fn run(mut self) -> Vec<LmbHost> {
        self.queue.close_intake();
        let workers = self
            .workers
            .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, usize::from))
            .min(self.slots.len())
            .max(1);
        if workers <= 1 {
            loop {
                // drain everything currently visible
                while self.tick() > 0 {}
                // park until new work arrives or the last handle drops
                if !self.queue.pump_blocking() {
                    break;
                }
            }
            // the disconnect may have raced a final burst into the buffer
            while self.tick() > 0 {}
            return self.slots.into_iter().flatten().collect();
        }
        self.run_pool(workers)
    }

    /// Schedule one batch and route each lane group to its pinned
    /// worker; returns how many requests were dispatched. A closed
    /// worker channel means that worker panicked — its groups' waiters
    /// are woken by the queue teardown, so the send error is dropped.
    fn dispatch(
        queue: &mut AllocQueue,
        lane_quota: usize,
        txs: &[Sender<(usize, Vec<Scheduled>)>],
    ) -> usize {
        let mut rest = queue.schedule(lane_quota);
        let total = rest.len();
        while !rest.is_empty() {
            let lane = rest[0].lane;
            let cut = rest.iter().position(|s| s.lane != lane).unwrap_or(rest.len());
            let tail = rest.split_off(cut);
            let group = std::mem::replace(&mut rest, tail);
            let _ = txs[lane % txs.len()].send((lane, group));
        }
        total
    }

    fn run_pool(self, workers: usize) -> Vec<LmbHost> {
        let FmService { mut queue, slots, lane_quota, .. } = self;
        let poster = queue.poster();
        // static lane→worker partition: worker w owns lanes ≡ w (mod W)
        let mut shards: Vec<Vec<(usize, Option<LmbHost>)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (lane, slot) in slots.into_iter().enumerate() {
            shards[lane % workers].push((lane, slot));
        }
        let mut returned: Vec<(usize, Option<LmbHost>)> = Vec::new();
        std::thread::scope(|scope| {
            let mut txs: Vec<Sender<(usize, Vec<Scheduled>)>> = Vec::with_capacity(workers);
            let mut joins = Vec::with_capacity(workers);
            for shard in shards {
                let (tx, rx) = channel();
                let poster = poster.clone();
                joins.push(scope.spawn(move || worker_loop(shard, rx, poster)));
                txs.push(tx);
            }
            loop {
                while Self::dispatch(&mut queue, lane_quota, &txs) > 0 {}
                if !queue.pump_blocking() {
                    break;
                }
            }
            // the disconnect may have raced a final burst into the buffer
            while Self::dispatch(&mut queue, lane_quota, &txs) > 0 {}
            // closing the channels drains the workers and hands the
            // host slots back
            drop(txs);
            for j in joins {
                match j.join() {
                    Ok(shard) => returned.extend(shard),
                    Err(panic) => std::panic::resume_unwind(panic),
                }
            }
        });
        returned.sort_by_key(|&(lane, _)| lane);
        returned.into_iter().filter_map(|(_, slot)| slot).collect()
    }
}

/// One pool worker: executes lane groups against the hosts it owns and
/// posts completions from its own thread. Mirrors the three
/// [`FmService::tick`] execute branches (live host / crashed lane /
/// forged lane) so pooled and serial runs complete identically.
fn worker_loop(
    mut shard: Vec<(usize, Option<LmbHost>)>,
    rx: Receiver<(usize, Vec<Scheduled>)>,
    poster: CompletionPoster,
) -> Vec<(usize, Option<LmbHost>)> {
    while let Ok((lane, group)) = rx.recv() {
        match shard.iter_mut().find(|&&mut (l, _)| l == lane) {
            Some((_, Some(host))) => {
                for c in host.execute_requests(group) {
                    poster.post(c);
                }
            }
            Some((_, None)) => {
                for s in group {
                    poster.post(Completion {
                        ticket: s.ticket,
                        lane,
                        result: Err(Error::Cancelled { ticket: s.ticket.0 }),
                    });
                }
            }
            None => {
                for s in group {
                    poster.post(Completion {
                        ticket: s.ticket,
                        lane,
                        result: Err(Error::FabricManager(format!("no host behind lane {lane}"))),
                    });
                }
            }
        }
    }
    shard
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::fm::{FabricManager, FabricRef};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{Bdf, EXTENT_SIZE, GIB, PAGE_SIZE};
    use crate::lmb::queue::{QueueStatus, Request};

    fn fabric_with(bytes: u64) -> FabricRef {
        FabricRef::new(FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: bytes, ..Default::default() }),
        ))
    }

    fn service(hosts: usize, expander_bytes: u64) -> (FmService, FabricRef, Bdf) {
        let fabric = fabric_with(expander_bytes);
        let dev = Bdf::new(1, 0, 0);
        let hosts: Vec<LmbHost> = (0..hosts)
            .map(|_| {
                let mut h = LmbHost::bind(fabric.clone(), GIB).unwrap();
                h.attach_pcie(dev);
                h
            })
            .collect();
        (FmService::new(hosts), fabric, dev)
    }

    #[test]
    fn manual_ticks_execute_handle_submissions() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        let t0 = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        let t1 = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 2);
        let a0 = h0.take(t0).unwrap().into_alloc().unwrap();
        let a1 = h1.take(t1).unwrap().into_alloc().unwrap();
        assert_ne!(a0.mmid, a1.mmid, "fabric-global mmids across service lanes");
        assert_eq!(fabric.lease_count(), 2);
        // frees flow back the same way
        let f0 = h0.submit(Request::Free { consumer: dev.into(), mmid: a0.mmid }).unwrap();
        assert_eq!(svc.tick(), 1);
        assert_eq!(h0.poll(f0), QueueStatus::Ready);
        h0.take(f0).unwrap().result.unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn unknown_lane_is_rejected_at_handle_time() {
        let (svc, _fabric, _dev) = service(1, GIB);
        assert!(svc.handle(0).is_ok());
        assert!(svc.handle(1).is_err());
    }

    #[test]
    fn run_terminates_when_handles_drop_and_returns_hosts() {
        let (svc, fabric, dev) = service(2, GIB);
        let handles: Vec<SubmitHandle> = (0..2).map(|l| svc.handle(l).unwrap()).collect();
        let fm_thread = std::thread::spawn(move || svc.run());
        let drivers: Vec<_> = handles
            .into_iter()
            .map(|h| {
                std::thread::spawn(move || {
                    let t = h
                        .submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE })
                        .unwrap();
                    h.wait(t).unwrap().into_alloc().unwrap().mmid
                })
            })
            .collect();
        let mmids: Vec<_> = drivers.into_iter().map(|d| d.join().unwrap()).collect();
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 2);
        assert_eq!(mmids.len(), 2);
        let live: usize = hosts.iter().map(|h| h.module().live_allocs()).sum();
        assert_eq!(live, 2);
        assert_eq!(fabric.available(), GIB - 2 * EXTENT_SIZE);
        for host in &hosts {
            host.check_invariants().unwrap();
        }
    }

    #[test]
    fn pooled_run_executes_across_workers_and_returns_hosts_in_lane_order() {
        let (svc, fabric, dev) = service(4, 4 * GIB);
        let svc = svc.with_workers(4);
        let handles: Vec<SubmitHandle> = (0..4).map(|l| svc.handle(l).unwrap()).collect();
        let fm_thread = std::thread::spawn(move || svc.run());
        let drivers: Vec<_> = handles
            .into_iter()
            .map(|h| {
                std::thread::spawn(move || {
                    let mut live = Vec::new();
                    for _ in 0..8 {
                        let t = h
                            .submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE })
                            .unwrap();
                        live.push(h.wait(t).unwrap().into_alloc().unwrap());
                    }
                    for a in live.drain(..4) {
                        let t = h
                            .submit(Request::Free { consumer: dev.into(), mmid: a.mmid })
                            .unwrap();
                        h.wait(t).unwrap().result.unwrap();
                    }
                    live.len()
                })
            })
            .collect();
        for d in drivers {
            assert_eq!(d.join().unwrap(), 4, "every driver kept 4 of its 8 allocs");
        }
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 4);
        assert!(
            hosts.windows(2).all(|w| w[0].host() < w[1].host()),
            "hosts hand back in lane order even though workers finish out of order"
        );
        let live: usize = hosts.iter().map(|h| h.module().live_allocs()).sum();
        assert_eq!(live, 16);
        for host in &hosts {
            host.check_invariants().unwrap();
        }
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn pooled_run_cancels_dead_lane_groups() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        svc.crash_host(0).unwrap();
        let svc = svc.with_workers(2);
        let fm_thread = std::thread::spawn(move || svc.run());
        let doomed = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert!(h0.wait(doomed).unwrap().is_cancelled(), "dead lane cancels at execute time");
        let ok = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        h1.wait(ok).unwrap().into_alloc().unwrap();
        drop((h0, h1));
        let hosts = fm_thread.join().unwrap();
        assert_eq!(hosts.len(), 1, "the crashed slot is not handed back");
        assert_eq!(hosts[0].module().live_allocs(), 1);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn crash_host_cancels_lane_and_reclaims_leases() {
        let (mut svc, fabric, dev) = service(2, GIB);
        let h0 = svc.handle(0).unwrap();
        let h1 = svc.handle(1).unwrap();
        let a = h0.submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h0.take(a).unwrap().result.unwrap();
        assert_eq!(fabric.available(), GIB - EXTENT_SIZE);
        // one queued-but-unscheduled request dies with the host
        let doomed = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        svc.crash_host(0).unwrap();
        assert!(h0.take(doomed).unwrap().is_cancelled());
        assert_eq!(fabric.available(), GIB, "crash reclaims the victim's extents");
        assert_eq!((svc.alive(), svc.lanes()), (1, 2));
        assert!(svc.handle(0).is_err(), "dead lane mints no new endpoints");
        assert!(svc.crash_host(0).is_err(), "double crash is rejected");
        // a submission that raced past the cancellation cancels at
        // execute time instead of touching reclaimed memory
        let late = h0.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        assert!(h0.take(late).unwrap().is_cancelled());
        // the surviving lane still executes
        let ok = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h1.take(ok).unwrap().into_alloc().unwrap();
        svc.check_invariants().unwrap();
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn join_host_adds_a_lane_behind_a_retargeted_handle() {
        let (mut svc, fabric, dev) = service(1, GIB);
        let h0 = svc.handle(0).unwrap();
        let mut joined = crate::lmb::LmbHost::bind(fabric.clone(), GIB).unwrap();
        joined.attach_pcie(dev);
        let lane = svc.join_host(joined);
        assert_eq!(lane, 1);
        assert_eq!((svc.alive(), svc.lanes()), (2, 2));
        let h1 = h0.retarget(lane);
        let t = h1.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE }).unwrap();
        assert_eq!(svc.tick(), 1);
        h1.take(t).unwrap().into_alloc().unwrap();
        assert_eq!(svc.host(lane).unwrap().module().live_allocs(), 1);
        assert_eq!(svc.hosts().count(), 2);
        svc.check_invariants().unwrap();
    }
}
