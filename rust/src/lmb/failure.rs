//! Expander failure handling (§1 "LMB challenges": "A single failure in
//! the memory expander can render all devices unavailable").
//!
//! The paper raises the problem without solving it; we implement the
//! obvious mitigation space so the failover example and bench can
//! explore it:
//!
//! * **FailStop** — surface errors to consumers; devices fall back to
//!   their degraded native mode (e.g. the SSD reverts to DFTL-style
//!   flash-resident indexing until the expander returns).
//! * **WriteThroughShadow** — the module keeps a host-DRAM shadow of
//!   designated *critical* allocations (e.g. L2P tables); on expander
//!   failure consumers are re-pointed at the shadow, trading host DRAM
//!   for availability.
//!
//! Recovery re-validates leases and rebuilds access-control state.
//!
//! Since the shared-fabric split the domain is cluster-wide: one
//! expander backs every bound host, so a failure hits them all at once.
//! [`FailureDomain::fail_cluster`] / [`FailureDomain::recover_cluster`]
//! sweep every host's allocations — each host's critical allocations
//! spill to *its own* host-DRAM shadow. A host *crash* is the other
//! cluster failure mode and is handled by
//! [`Cluster::crash_host`](crate::cluster::Cluster::crash_host) /
//! [`FabricManager::release_host`](crate::cxl::fm::FabricManager::release_host),
//! which reclaims the leases without perturbing sibling hosts.

use std::collections::HashMap;

use crate::cluster::Cluster;
use crate::cxl::fm::FabricRef;
use crate::cxl::types::MmId;
use crate::error::{Error, Result};
use crate::lmb::{LmbHost, LmbModule};

/// Failure-handling policy for LMB allocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Errors propagate; consumers degrade themselves.
    FailStop,
    /// Critical allocations are shadowed in host DRAM and served from
    /// there while the expander is down.
    WriteThroughShadow,
}

/// Where a consumer should direct accesses for one allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingState {
    /// Normal: served by the expander.
    Expander,
    /// Failed over: served by the host-DRAM shadow (slower for P2P
    /// consumers, but available).
    HostShadow,
    /// Unavailable (FailStop policy during an outage).
    Unavailable,
}

/// Tracks failure state and per-allocation serving decisions.
#[derive(Debug)]
pub struct FailureDomain {
    policy: FailurePolicy,
    /// mmids registered as critical (shadowed under WriteThroughShadow).
    critical: HashMap<MmId, bool>,
    expander_up: bool,
    /// Counters for the failover bench.
    pub failovers: u64,
    pub recoveries: u64,
}

impl FailureDomain {
    pub fn new(policy: FailurePolicy) -> Self {
        FailureDomain {
            policy,
            critical: HashMap::new(),
            expander_up: true,
            failovers: 0,
            recoveries: 0,
        }
    }

    pub fn policy(&self) -> FailurePolicy {
        self.policy
    }

    /// Mark an allocation as critical (shadow-eligible). Under
    /// `WriteThroughShadow`, writes are mirrored host-side; the mirror
    /// costs host DRAM equal to the allocation size.
    pub fn register_critical(&mut self, mmid: MmId) {
        self.critical.insert(mmid, true);
    }

    pub fn is_critical(&self, mmid: MmId) -> bool {
        self.critical.get(&mmid).copied().unwrap_or(false)
    }

    /// Inject an expander failure through a host context; returns the
    /// serving state for each live allocation.
    pub fn fail(&mut self, lmb: &LmbHost) -> HashMap<MmId, ServingState> {
        self.fail_expander(lmb.fabric_ref(), lmb.module())
    }

    /// Recover the expander through a host context (see
    /// [`FailureDomain::recover_expander`] for the copy-back contract).
    pub fn recover<F>(&mut self, lmb: &LmbHost, copy_back: F) -> Result<u64>
    where
        F: FnMut(MmId) -> Result<u64>,
    {
        self.recover_expander(lmb.fabric_ref(), lmb.module(), copy_back)
    }

    /// Inject an expander failure; returns the serving state for each
    /// live allocation in `module`.
    pub fn fail_expander(
        &mut self,
        fabric: &FabricRef,
        module: &LmbModule,
    ) -> HashMap<MmId, ServingState> {
        self.fail_with(fabric, module.mmids())
    }

    /// Recover the expander. Shadowed allocations must be copied back
    /// before serving switches; the caller provides the copy-back hook
    /// (returning bytes restored) so the bench can account for it.
    pub fn recover_expander<F>(
        &mut self,
        fabric: &FabricRef,
        module: &LmbModule,
        copy_back: F,
    ) -> Result<u64>
    where
        F: FnMut(MmId) -> Result<u64>,
    {
        self.recover_with(fabric, module.mmids(), copy_back)
    }

    /// Cluster-wide failure: the shared expander goes down once and the
    /// outage hits every bound host. Returns the serving state of every
    /// live allocation across the cluster — under `WriteThroughShadow`
    /// each host's critical allocations are served from *that host's*
    /// own DRAM shadow (mmids are fabric-global, so one map covers all
    /// hosts without collisions).
    pub fn fail_cluster(&mut self, cluster: &Cluster) -> HashMap<MmId, ServingState> {
        let mmids: Vec<MmId> =
            cluster.hosts().flat_map(|(_, host)| host.module().mmids()).collect();
        self.fail_with(cluster.fabric_ref(), mmids)
    }

    /// Cluster-wide recovery: every host's shadowed critical
    /// allocations are copied back (the hook receives each mmid and
    /// returns bytes restored) before serving switches back to the
    /// expander.
    pub fn recover_cluster<F>(&mut self, cluster: &Cluster, copy_back: F) -> Result<u64>
    where
        F: FnMut(MmId) -> Result<u64>,
    {
        let mmids: Vec<MmId> =
            cluster.hosts().flat_map(|(_, host)| host.module().mmids()).collect();
        self.recover_with(cluster.fabric_ref(), mmids, copy_back)
    }

    /// Shared failure core: down the expander once, sweep `mmids`.
    fn fail_with(
        &mut self,
        fabric: &FabricRef,
        mmids: impl IntoIterator<Item = MmId>,
    ) -> HashMap<MmId, ServingState> {
        fabric.set_expander_failed(true);
        self.expander_up = false;
        self.failovers += 1;
        mmids.into_iter().map(|mmid| (mmid, self.serving_state(mmid))).collect()
    }

    /// Shared recovery core: bring the expander back, copy shadowed
    /// criticals among `mmids` back before serving switches.
    fn recover_with<F>(
        &mut self,
        fabric: &FabricRef,
        mmids: impl IntoIterator<Item = MmId>,
        mut copy_back: F,
    ) -> Result<u64>
    where
        F: FnMut(MmId) -> Result<u64>,
    {
        if self.expander_up {
            return Err(Error::FabricManager("expander is not failed".into()));
        }
        fabric.set_expander_failed(false);
        let mut restored = 0;
        if self.policy == FailurePolicy::WriteThroughShadow {
            for mmid in mmids {
                if self.is_critical(mmid) {
                    restored += copy_back(mmid)?;
                }
            }
        }
        self.expander_up = true;
        self.recoveries += 1;
        Ok(restored)
    }

    /// Current serving state for an allocation.
    pub fn serving_state(&self, mmid: MmId) -> ServingState {
        if self.expander_up {
            return ServingState::Expander;
        }
        match self.policy {
            FailurePolicy::WriteThroughShadow if self.is_critical(mmid) => {
                ServingState::HostShadow
            }
            _ => ServingState::Unavailable,
        }
    }

    pub fn expander_up(&self) -> bool {
        self.expander_up
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{Bdf, GIB, PAGE_SIZE};

    fn rig() -> (LmbHost, Bdf) {
        let fabric = FabricRef::new(crate::cxl::fm::FabricManager::new(
            PbrSwitch::new(8),
            Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() }),
        ));
        let mut lmb = LmbHost::bind(fabric, GIB).unwrap();
        let dev = Bdf::new(1, 0, 0);
        lmb.attach_pcie(dev);
        (lmb, dev)
    }

    #[test]
    fn failstop_makes_allocations_unavailable() {
        let (mut lmb, dev) = rig();
        let a = lmb.alloc(dev, PAGE_SIZE).unwrap();
        let mut fd = FailureDomain::new(FailurePolicy::FailStop);
        let states = fd.fail(&lmb);
        assert_eq!(states[&a.mmid], ServingState::Unavailable);
        // new allocations fail during the outage
        assert!(lmb.alloc(dev, PAGE_SIZE).is_err());
        fd.recover(&lmb, |_| Ok(0)).unwrap();
        assert_eq!(fd.serving_state(a.mmid), ServingState::Expander);
        assert!(lmb.alloc(dev, PAGE_SIZE).is_ok());
    }

    #[test]
    fn shadow_policy_keeps_critical_allocs_available() {
        let (mut lmb, dev) = rig();
        let crit = lmb.alloc(dev, PAGE_SIZE).unwrap();
        let plain = lmb.alloc(dev, PAGE_SIZE).unwrap();
        let mut fd = FailureDomain::new(FailurePolicy::WriteThroughShadow);
        fd.register_critical(crit.mmid);
        let states = fd.fail(&lmb);
        assert_eq!(states[&crit.mmid], ServingState::HostShadow);
        assert_eq!(states[&plain.mmid], ServingState::Unavailable);
    }

    #[test]
    fn recovery_copies_back_shadowed_bytes() {
        let (mut lmb, dev) = rig();
        let a = lmb.alloc(dev, 4 * PAGE_SIZE).unwrap();
        let mut fd = FailureDomain::new(FailurePolicy::WriteThroughShadow);
        fd.register_critical(a.mmid);
        fd.fail(&lmb);
        let restored = fd
            .recover(&lmb, |mmid| {
                assert_eq!(mmid, a.mmid);
                Ok(a.size)
            })
            .unwrap();
        assert_eq!(restored, 4 * PAGE_SIZE);
        assert_eq!(fd.failovers, 1);
        assert_eq!(fd.recoveries, 1);
    }

    #[test]
    fn double_recovery_rejected() {
        let (lmb, _dev) = rig();
        let mut fd = FailureDomain::new(FailurePolicy::FailStop);
        assert!(fd.recover(&lmb, |_| Ok(0)).is_err());
    }

    #[test]
    fn cluster_failover_spills_each_hosts_criticals_to_its_own_shadow() {
        let mut cluster = Cluster::builder()
            .hosts(3)
            .expander_gib(2)
            .host_dram_gib(1)
            .build()
            .unwrap();
        let mut criticals = Vec::new();
        let mut plains = Vec::new();
        for i in 0..3 {
            let dev = Bdf::new(1, 0, 0);
            cluster.host_mut(i).unwrap().attach_pcie(dev);
            criticals.push(cluster.alloc(i, dev, PAGE_SIZE).unwrap().mmid);
            plains.push(cluster.alloc(i, dev, PAGE_SIZE).unwrap().mmid);
        }
        let mut fd = FailureDomain::new(FailurePolicy::WriteThroughShadow);
        for &mmid in &criticals {
            fd.register_critical(mmid);
        }

        let states = fd.fail_cluster(&cluster);
        assert_eq!(states.len(), 6, "one entry per live allocation, cluster-wide");
        for &mmid in &criticals {
            assert_eq!(states[&mmid], ServingState::HostShadow);
        }
        for &mmid in &plains {
            assert_eq!(states[&mmid], ServingState::Unavailable);
        }
        // the single shared expander being down blocks *every* host
        for i in 0..3 {
            let dev = Bdf::new(1, 0, 0);
            assert!(cluster.alloc(i, dev, PAGE_SIZE).is_err());
        }

        let restored = fd.recover_cluster(&cluster, |_| Ok(PAGE_SIZE)).unwrap();
        assert_eq!(restored, 3 * PAGE_SIZE, "one copy-back per host's critical");
        let dev = Bdf::new(1, 0, 0);
        assert!(cluster.alloc(0, dev, PAGE_SIZE).is_ok());
        cluster.check_invariants().unwrap();
    }
}
