//! [`LmbHost`] — the per-host LMB context behind the unified Table 2 API.
//!
//! The original surface forced every caller to thread
//! `(&mut FabricManager, &mut Iommu, &mut AddressSpace)` through six
//! near-duplicate `pcie_*`/`cxl_*` methods. The context carries the
//! per-host pieces of that triple (plus the loaded [`LmbModule`]) and a
//! shared [`FabricRef`], and exposes the consumer-generic, handle-based
//! API everything else in the crate builds on: `System`, the failure
//! domain, the examples, and the benches. One `LmbHost` per bound host;
//! sharding across hosts means binding several contexts to clones of
//! one `FabricRef` (see [`crate::cluster::Cluster`]).

use crate::cxl::fm::{FabricManager, FabricRef, HostId};
use crate::cxl::types::{Bdf, Dpa, Dpid, MmId, Spid};
use crate::error::{Error, Result};
use crate::host::AddressSpace;
use crate::lmb::queue::{
    AllocQueue, Completion, Outcome, PlacementPolicy, QueueStatus, Request, Scheduled,
    SubmitHandle, Ticket, DEFAULT_LANE_QUOTA,
};
use crate::lmb::{Consumer, LmbAlloc, LmbModule};
use crate::pcie::iommu::Iommu;

/// Spacing between the HDM-window regions of successive hosts. Every
/// host maps leased extents into its own physical address space; giving
/// each host a disjoint 256 TiB region keeps the expander's (shared)
/// decoder table free of cross-host HPA collisions.
const HOST_WINDOW_STRIDE: u64 = 1 << 48;

/// Per-host LMB context: holds this host's IOMMU, address space and
/// loaded module plus a shared handle to the fabric manager, and
/// dispatches the class-specific access-control setup on [`Consumer`].
///
/// ```
/// use lmb::cxl::expander::{Expander, ExpanderConfig};
/// use lmb::cxl::fm::{FabricManager, FabricRef};
/// use lmb::cxl::switch::PbrSwitch;
/// use lmb::cxl::types::{Bdf, GIB, PAGE_SIZE};
/// use lmb::lmb::LmbHost;
///
/// let fabric = FabricRef::new(FabricManager::new(
///     PbrSwitch::new(8),
///     Expander::new(ExpanderConfig { dram_capacity: GIB, ..Default::default() }),
/// ));
/// let mut host = LmbHost::bind(fabric.clone(), GIB).unwrap();
/// // any number of hosts bind to the same expander through clones
/// let sibling = LmbHost::bind(fabric.clone(), GIB).unwrap();
///
/// // a PCIe SSD allocates buffer memory; a CXL accelerator shares it
/// let ssd = Bdf::new(1, 0, 0);
/// host.attach_pcie(ssd);
/// let accel = host.attach_cxl_device().unwrap();
/// let a = host.alloc(ssd, 8 * PAGE_SIZE).unwrap();
/// assert!(a.bus_addr.is_some(), "PCIe consumers get an IOMMU mapping");
/// let shared = host.share(ssd, accel, a.mmid).unwrap();
/// assert_eq!(shared.dpid, fabric.gfd_dpid(), "CXL consumers get the GFD DPID");
///
/// // leases are arbitrated per host by the shared FM
/// assert!(fabric.leased_to(host.host()) > 0);
/// assert_eq!(fabric.leased_to(sibling.host()), 0);
///
/// host.free(ssd, a.mmid).unwrap();
/// assert_eq!(host.module().live_allocs(), 0);
/// ```
#[derive(Debug)]
pub struct LmbHost {
    fabric: FabricRef,
    iommu: Iommu,
    space: AddressSpace,
    module: LmbModule,
    host: HostId,
    host_spid: Spid,
    /// This host's own allocation queue (single lane). The synchronous
    /// `alloc`/`free`/`share` are one-shot submit + drain over it, so
    /// queued and synchronous callers share one allocation code path.
    queue: AllocQueue,
}

impl LmbHost {
    /// Bind a host root port to the shared fabric and load its LMB
    /// module (§3.1: the module loads before any device driver
    /// initialises). Attaches the GFD first if bring-up has not
    /// happened yet, so the module always learns the real GFD DPID.
    pub fn bind(fabric: FabricRef, host_dram: u64) -> Result<Self> {
        // DRAM larger than the stride would push this host's HDM windows
        // into the next host's HPA region and collide in the shared
        // decoder table — reject up front rather than fail on first alloc
        if host_dram > HOST_WINDOW_STRIDE {
            return Err(Error::Config(format!(
                "host DRAM of {host_dram} B exceeds the per-host HDM window stride (2^48 B)"
            )));
        }
        let (host, host_spid, gfd_dpid, window_base) =
            fabric.with_fm(|fm| -> Result<(HostId, Spid, Dpid, u64)> {
                let gfd_dpid = match fm.gfd_dpid() {
                    Some(d) => d,
                    None => fm.attach_gfd()?,
                };
                let (host, host_spid) = fm.bind_host()?;
                // host ids are never reused, so pathological bind/crash
                // churn could run the window space dry — fail loudly,
                // not wrap
                let window_base = match HOST_WINDOW_STRIDE.checked_mul(u64::from(host.0) + 1) {
                    Some(base) => base,
                    None => {
                        fm.release_host(host);
                        return Err(Error::FabricManager(format!(
                            "host id {} exhausts the per-host HPA window space",
                            host.0
                        )));
                    }
                };
                Ok((host, host_spid, gfd_dpid, window_base))
            })??;
        let module = LmbModule::load(host, gfd_dpid);
        // bound the window region so a window-hungry host errors cleanly
        // instead of spilling into the next host's HPA region
        let window_end = window_base.saturating_add(HOST_WINDOW_STRIDE);
        let space = AddressSpace::with_window_region(host_dram, window_base, Some(window_end));
        Ok(LmbHost {
            fabric,
            iommu: Iommu::new(),
            space,
            module,
            host,
            host_spid,
            queue: AllocQueue::new(),
        })
    }

    pub fn host(&self) -> HostId {
        self.host
    }

    /// SPID of this host's root port on the switch.
    pub fn host_spid(&self) -> Spid {
        self.host_spid
    }

    /// Attach a PCIe device: creates its IOMMU domain.
    pub fn attach_pcie(&mut self, dev: Bdf) {
        self.iommu.attach(dev);
    }

    /// Bind a CXL device (accelerator, CXL-SSD) to the fabric.
    pub fn attach_cxl_device(&mut self) -> Result<Spid> {
        self.fabric.bind_cxl_device()
    }

    // ---- the unified Table 2 surface ----
    //
    // Since the queued-allocation refactor these are one-shot
    // submit + drain over this host's [`AllocQueue`]: synchronous and
    // queued callers exercise the identical scheduling and execution
    // path ([`LmbHost::execute_requests`]).

    /// Allocate `size` bytes of LMB memory for `consumer`.
    pub fn alloc(&mut self, consumer: impl Into<Consumer>, size: u64) -> Result<LmbAlloc> {
        let consumer = consumer.into();
        let outcome = self.submit_and_wait(Request::Alloc { consumer, size })?;
        outcome.into_alloc()
    }

    /// Batch allocation, all-or-nothing: the whole batch is submitted to
    /// the queue and drained in one go; if any request fails, every
    /// allocation made by this call is rolled back (freed) and the
    /// first error is returned.
    pub fn alloc_many(
        &mut self,
        consumer: impl Into<Consumer>,
        sizes: &[u64],
    ) -> Result<Vec<LmbAlloc>> {
        let consumer = consumer.into();
        let tickets: Vec<Ticket> = sizes
            .iter()
            .map(|&size| self.queue.submit(0, Request::Alloc { consumer, size }))
            .collect();
        self.drain_queue();
        let mut done: Vec<LmbAlloc> = Vec::with_capacity(tickets.len());
        let mut first_err = None;
        for t in tickets {
            let result = match self.queue.take(t) {
                Some(c) => c.result,
                None => Err(Error::FabricManager("queue lost a completion".into())),
            };
            match result {
                Ok(Outcome::Alloc(a)) => done.push(a),
                Ok(_) => unreachable!("alloc submission yielded a non-alloc outcome"),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(done),
            Some(e) => {
                // roll back newest first; the sharded FM takes its own
                // per-region locks per free
                let LmbHost { fabric, iommu, space, module, .. } = self;
                let fm = fabric.manager();
                for a in done.into_iter().rev() {
                    let _ = module.free(fm, iommu, space, consumer, a.mmid);
                }
                Err(e)
            }
        }
    }

    /// Free `mmid`, which must be owned by `consumer`.
    pub fn free(&mut self, consumer: impl Into<Consumer>, mmid: MmId) -> Result<()> {
        let consumer = consumer.into();
        match self.submit_and_wait(Request::Free { consumer, mmid })? {
            Outcome::Freed => Ok(()),
            other => unreachable!("free submission yielded {other:?}"),
        }
    }

    /// Zero-copy share of `mmid` (owned by `owner`) into `target`'s
    /// view. Ownership is enforced; repeat shares are idempotent.
    pub fn share(
        &mut self,
        owner: impl Into<Consumer>,
        target: impl Into<Consumer>,
        mmid: MmId,
    ) -> Result<LmbAlloc> {
        let owner = owner.into();
        let target = target.into();
        let outcome = self.submit_and_wait(Request::Share { owner, target, mmid })?;
        outcome.into_alloc()
    }

    /// Record one data-path access to `mmid` (owned by `consumer`) for
    /// the tiering engine's heat ledger — the synchronous face of
    /// [`Request::Touch`].
    pub fn touch(&mut self, consumer: impl Into<Consumer>, mmid: MmId) -> Result<()> {
        let consumer = consumer.into();
        match self.submit_and_wait(Request::Touch { consumer, mmid })? {
            Outcome::Touched => Ok(()),
            other => unreachable!("touch submission yielded {other:?}"),
        }
    }

    // ---- queued allocation (submission / completion model) ----

    /// Enqueue a control-plane request on this host's queue; returns a
    /// completion handle. Nothing executes until [`LmbHost::tick_queue`]
    /// or [`LmbHost::drain_queue`] (or any synchronous call, which
    /// drains the queue as its one-shot path).
    pub fn submit(&mut self, request: Request) -> Ticket {
        self.queue.submit(0, request)
    }

    /// [`LmbHost::submit`] with a completion deadline: if the request
    /// is still queued when the service clock passes `deadline`
    /// (see [`FmService::tick_at`](crate::lmb::FmService::tick_at) /
    /// `AllocQueue::expire_due`), it completes with
    /// [`Error::TimedOut`](crate::error::Error::TimedOut) instead of
    /// executing.
    pub fn submit_with_deadline(
        &mut self,
        request: Request,
        deadline: crate::sim::SimTime,
    ) -> Ticket {
        self.queue.submit_with_deadline(0, request, deadline)
    }

    /// Where a submission is in its lifecycle.
    pub fn poll_submission(&self, ticket: Ticket) -> QueueStatus {
        self.queue.poll(ticket)
    }

    /// Claim a serviced submission's completion (tickets are
    /// single-use).
    pub fn take_completion(&mut self, ticket: Ticket) -> Option<Completion> {
        self.queue.take(ticket)
    }

    /// A cloneable, `Send` submission endpoint onto this host's queue:
    /// device driver threads submit (and `poll`/`take`/`wait`) from
    /// their own contexts while this host's owner keeps ticking the
    /// queue — or hand the whole host to an
    /// [`FmService`](crate::lmb::FmService) and let the service loop
    /// drive execution.
    pub fn submit_handle(&self) -> Result<SubmitHandle> {
        self.queue.handle(0)
    }

    /// Run one deterministic scheduling tick: pump the intake channel,
    /// pop up to the lane quota of queued requests and execute them.
    /// Returns how many were serviced.
    pub fn tick_queue(&mut self) -> usize {
        let batch = self.queue.schedule(DEFAULT_LANE_QUOTA);
        let completions = self.execute_requests(batch);
        let n = completions.len();
        for c in completions {
            self.queue.complete(c);
        }
        n
    }

    /// Tick until the queue is idle; returns how many submissions were
    /// serviced.
    pub fn drain_queue(&mut self) -> usize {
        let mut total = 0;
        loop {
            let n = self.tick_queue();
            if n == 0 {
                return total;
            }
            total += n;
        }
    }

    /// This host's allocation queue (stats / pending inspection).
    pub fn queue(&self) -> &AllocQueue {
        &self.queue
    }

    /// The extent-placement policy this host's module requests.
    pub fn placement_policy(&self) -> PlacementPolicy {
        self.module.placement_policy()
    }

    /// Override the extent-placement policy (ablation baselines).
    pub fn set_placement_policy(&mut self, policy: PlacementPolicy) {
        self.module.set_placement_policy(policy);
    }

    /// Execute scheduled requests against this host — the single
    /// allocation code path beneath the synchronous surface and every
    /// queue (this host's own, the cluster-wide one, and the
    /// [`FmService`](crate::lmb::FmService) loop, all of which route
    /// each slot's scheduled group here). The sharded FM takes its own
    /// per-region locks per request, so disjoint-region groups on
    /// sibling hosts execute concurrently. One completion per request;
    /// a failure completes its own ticket and does not stop the rest of
    /// the group. A sealed (panic-poisoned) fabric completes every
    /// ticket with [`Error::FabricPoisoned`] via the module's per-call
    /// seal check instead of stranding its waiter.
    pub fn execute_requests(&mut self, batch: Vec<Scheduled>) -> Vec<Completion> {
        if batch.is_empty() {
            return Vec::new();
        }
        let LmbHost { fabric, iommu, space, module, .. } = self;
        let fm = fabric.manager();
        let mut completions = Vec::with_capacity(batch.len());
        for s in &batch {
            let result = match s.request {
                Request::Alloc { consumer, size } => {
                    module.alloc(fm, iommu, space, consumer, size).map(Outcome::Alloc)
                }
                Request::Free { consumer, mmid } => {
                    module.free(fm, iommu, space, consumer, mmid).map(|()| Outcome::Freed)
                }
                Request::Share { owner, target, mmid } => {
                    module.share(fm, iommu, owner, target, mmid).map(Outcome::Shared)
                }
                Request::Touch { consumer, mmid } => {
                    module.touch(fm, consumer, mmid).map(|()| Outcome::Touched)
                }
            };
            completions.push(Completion {
                ticket: s.ticket,
                lane: s.lane,
                tenant: s.tenant,
                result,
            });
        }
        completions
    }

    /// One-shot path for the synchronous surface: submit, drain, claim.
    fn submit_and_wait(&mut self, request: Request) -> Result<Outcome> {
        let ticket = self.submit(request);
        self.drain_queue();
        match self.queue.take(ticket) {
            Some(c) => c.result,
            None => Err(Error::FabricManager("queue lost a completion".into())),
        }
    }

    /// Allocate with RAII semantics: the returned [`LmbRegion`] frees the
    /// allocation when dropped (unless explicitly leaked).
    pub fn alloc_scoped(
        &mut self,
        consumer: impl Into<Consumer>,
        size: u64,
    ) -> Result<LmbRegion<'_>> {
        let consumer = consumer.into();
        let alloc = self.alloc(consumer, size)?;
        Ok(LmbRegion { host: self, consumer, alloc, armed: true })
    }

    // ---- data path (host-mediated) ----

    /// Functional write into an LMB allocation.
    pub fn write(&mut self, mmid: MmId, offset: u64, data: &[u8]) -> Result<()> {
        let a = self.module.get(mmid).ok_or(Error::UnknownMmId(mmid))?;
        // checked: a wrapping sum would sneak a huge offset past the
        // bounds guard and corrupt a neighbouring allocation's bytes
        match offset.checked_add(data.len() as u64) {
            Some(end) if end <= a.size => {}
            _ => return Err(Error::Config("write beyond allocation".into())),
        }
        self.fabric.write_dpa(Dpa(a.dpa.0 + offset), data)
    }

    /// Functional read from an LMB allocation.
    pub fn read(&self, mmid: MmId, offset: u64, out: &mut [u8]) -> Result<()> {
        let a = self.module.get(mmid).ok_or(Error::UnknownMmId(mmid))?;
        match offset.checked_add(out.len() as u64) {
            Some(end) if end <= a.size => {}
            _ => return Err(Error::Config("read beyond allocation".into())),
        }
        self.fabric.read_dpa(Dpa(a.dpa.0 + offset), out)
    }

    /// Batched data path: resolve `mmid`'s placement once and stream
    /// any number of reads/writes, scoped to the closure.
    ///
    /// [`LmbHost::write`]/[`LmbHost::read`] re-check the fabric seal
    /// and re-resolve the mmid on every call — fine for one-off control
    /// traffic, linear overhead on the data path. The closure receives
    /// an [`IoSession`] whose ops reuse the resolved placement under
    /// the seal scope held for the closure's duration; each op takes
    /// only the expander's device lock, so allocation on sibling hosts
    /// proceeds concurrently. Do not call back into sealed fabric APIs
    /// ([`FabricRef::with_fm`] etc.) from inside the closure — the seal
    /// is not reentrant.
    pub fn with_io_session<R>(
        &mut self,
        mmid: MmId,
        f: impl FnOnce(&mut IoSession<'_>) -> Result<R>,
    ) -> Result<R> {
        let a = self.module.get(mmid).ok_or(Error::UnknownMmId(mmid))?;
        self.fabric.with_fm(|fm| {
            // resolve the module-virtual placement to physical once: a
            // live migration also runs under the seal this scope holds,
            // so the physical base cannot move while the session streams
            let dpa = fm.resolve_dpa(a.dpa);
            let mut io = IoSession { fm, mmid, dpa, size: a.size };
            f(&mut io)
        })?
    }

    // ---- lookups / component access ----

    /// Look up a live allocation by handle.
    pub fn get(&self, mmid: MmId) -> Option<LmbAlloc> {
        self.module.get(mmid)
    }

    /// All live mmids.
    pub fn mmids(&self) -> Vec<MmId> {
        self.module.mmids()
    }

    /// The shared fabric handle this host is bound through. Clone it to
    /// bind further hosts to the same switch + expander.
    pub fn fabric_ref(&self) -> &FabricRef {
        &self.fabric
    }

    /// Scoped read-only view of the shared FM: the closure runs with
    /// the fabric locked and nothing escapes the scope (see
    /// [`FabricRef::with_fm`]). There is deliberately no mutable
    /// counterpart: mutations go through FM methods keyed by [`HostId`]
    /// so lease ownership checks cannot be bypassed.
    pub fn with_fm<R>(&self, f: impl FnOnce(&FabricManager) -> R) -> Result<R> {
        self.fabric.with_fm(f)
    }

    pub fn iommu(&self) -> &Iommu {
        &self.iommu
    }

    pub fn iommu_mut(&mut self) -> &mut Iommu {
        &mut self.iommu
    }

    pub fn space(&self) -> &AddressSpace {
        &self.space
    }

    pub fn module(&self) -> &LmbModule {
        &self.module
    }

    /// Module + FM invariants in one sweep (property tests).
    pub fn check_invariants(&self) -> Result<()> {
        self.module.check_invariants()?;
        self.fabric.check_invariants()
    }
}

/// A batched I/O session over one LMB allocation: the placement is
/// resolved once (module-virtual → current physical, under the seal —
/// the same fence live extent migration runs behind) at
/// [`LmbHost::with_io_session`] time and every op reuses it under the
/// seal scope the enclosing closure holds. Each op also heats the
/// physical extent's tiering counter (one relaxed `fetch_add`) — the
/// signal the [`TierDaemon`](crate::tier::TierDaemon) folds into its
/// promotion/demotion decisions.
///
/// The session is only ever lent to the caller's closure — it borrows
/// the sealed `FabricManager`, so it cannot outlive the scope and no
/// guard ever escapes. Bounds are still checked per op against the
/// allocation's size; what the session removes is the per-op mmid
/// lookup and seal-check of the unbatched
/// [`LmbHost::write`]/[`LmbHost::read`]. Ops contend only on the
/// expander's device lock, never on region or control-plane locks.
#[derive(Debug)]
pub struct IoSession<'h> {
    fm: &'h FabricManager,
    mmid: MmId,
    dpa: Dpa,
    size: u64,
}

impl IoSession<'_> {
    /// The allocation this session streams to.
    pub fn mmid(&self) -> MmId {
        self.mmid
    }

    /// Allocation size in bytes (ops are bounds-checked against it).
    pub fn size(&self) -> u64 {
        self.size
    }

    fn check_bounds(&self, offset: u64, len: u64, what: &str) -> Result<()> {
        match offset.checked_add(len) {
            Some(end) if end <= self.size => Ok(()),
            _ => Err(Error::Config(format!("{what} beyond allocation"))),
        }
    }

    /// Functional write at `offset` within the allocation.
    pub fn write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.check_bounds(offset, data.len() as u64, "write")?;
        self.fm.note_media_access(Dpa(self.dpa.0 + offset));
        self.fm.expander_mut().write_dpa(Dpa(self.dpa.0 + offset), data)
    }

    /// Functional read at `offset` within the allocation.
    pub fn read(&self, offset: u64, out: &mut [u8]) -> Result<()> {
        self.check_bounds(offset, out.len() as u64, "read")?;
        self.fm.note_media_access(Dpa(self.dpa.0 + offset));
        self.fm.expander().read_dpa(Dpa(self.dpa.0 + offset), out)
    }
}

/// RAII guard over one LMB allocation: freed on drop unless released.
///
/// Holds the [`LmbHost`] mutably for its lifetime, so the guard suits
/// scoped staging buffers; long-lived allocations should hold the plain
/// [`LmbAlloc`] handle (see [`LmbRegion::into_raw`]).
#[derive(Debug)]
pub struct LmbRegion<'h> {
    host: &'h mut LmbHost,
    consumer: Consumer,
    alloc: LmbAlloc,
    armed: bool,
}

impl LmbRegion<'_> {
    /// The underlying allocation handle (Table 2 out-params).
    pub fn handle(&self) -> LmbAlloc {
        self.alloc
    }

    pub fn mmid(&self) -> MmId {
        self.alloc.mmid
    }

    pub fn consumer(&self) -> Consumer {
        self.consumer
    }

    /// Write into the region through the host data path.
    pub fn write(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.host.write(self.alloc.mmid, offset, data)
    }

    /// Read from the region through the host data path.
    pub fn read(&self, offset: u64, out: &mut [u8]) -> Result<()> {
        self.host.read(self.alloc.mmid, offset, out)
    }

    /// Free now, surfacing any teardown error (drop would swallow it).
    pub fn free(mut self) -> Result<()> {
        self.armed = false;
        let consumer = self.consumer;
        let mmid = self.alloc.mmid;
        self.host.free(consumer, mmid)
    }

    /// Defuse the guard, returning the raw handle; the caller becomes
    /// responsible for freeing via [`LmbHost::free`].
    pub fn into_raw(mut self) -> LmbAlloc {
        self.armed = false;
        self.alloc
    }
}

impl Drop for LmbRegion<'_> {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.host.free(self.consumer, self.alloc.mmid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::expander::{Expander, ExpanderConfig};
    use crate::cxl::switch::PbrSwitch;
    use crate::cxl::types::{EXTENT_SIZE, GIB, PAGE_SIZE};

    fn fabric_with(expander_bytes: u64) -> FabricRef {
        FabricRef::new(FabricManager::new(
            PbrSwitch::new(16),
            Expander::new(ExpanderConfig { dram_capacity: expander_bytes, ..Default::default() }),
        ))
    }

    fn host_with(expander_bytes: u64) -> LmbHost {
        LmbHost::bind(fabric_with(expander_bytes), GIB).unwrap()
    }

    #[test]
    fn bind_attaches_gfd_and_loads_module() {
        let host = host_with(GIB);
        assert!(host.module().is_loaded());
        let fabric_dpid = host.with_fm(|fm| fm.gfd_dpid()).unwrap();
        assert_eq!(Some(host.module().gfd_dpid()), fabric_dpid);
    }

    #[test]
    fn bind_reuses_existing_gfd() {
        let fabric = fabric_with(GIB);
        let dpid = fabric.with_fm(|fm| fm.attach_gfd()).unwrap().unwrap();
        let host = LmbHost::bind(fabric, GIB).unwrap();
        assert_eq!(host.module().gfd_dpid(), dpid);
    }

    #[test]
    fn multiple_hosts_share_one_fabric() {
        let fabric = fabric_with(GIB); // 4 extents
        let mut h1 = LmbHost::bind(fabric.clone(), GIB).unwrap();
        let mut h2 = LmbHost::bind(fabric.clone(), GIB).unwrap();
        assert_ne!(h1.host(), h2.host());
        assert_ne!(h1.host_spid(), h2.host_spid());

        let d1 = Bdf::new(1, 0, 0);
        let d2 = Bdf::new(1, 0, 0); // same BDF, different host — fine
        h1.attach_pcie(d1);
        h2.attach_pcie(d2);
        let a1 = h1.alloc(d1, PAGE_SIZE).unwrap();
        let a2 = h2.alloc(d2, PAGE_SIZE).unwrap();

        // leases draw from one pool, accounted per host
        assert_eq!(fabric.available(), GIB - 2 * EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h1.host()), EXTENT_SIZE);
        assert_eq!(fabric.leased_to(h2.host()), EXTENT_SIZE);

        // mmids are fabric-global: no collision across hosts, and a
        // foreign handle is unknown to the other module
        assert_ne!(a1.mmid, a2.mmid);
        assert!(matches!(h2.free(d2, a1.mmid), Err(Error::UnknownMmId(_))));
        assert!(matches!(h1.share(d1, d1, a2.mmid), Err(Error::UnknownMmId(_))));

        // placements land in disjoint DPA extents
        assert_ne!(a1.dpa.align_down(EXTENT_SIZE), a2.dpa.align_down(EXTENT_SIZE));

        h1.free(d1, a1.mmid).unwrap();
        h2.free(d2, a2.mmid).unwrap();
        assert_eq!(fabric.available(), GIB);
        fabric.check_invariants().unwrap();
    }

    #[test]
    fn scoped_region_frees_on_drop() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        {
            let mut region = host.alloc_scoped(dev, 4 * PAGE_SIZE).unwrap();
            region.write(0, b"scratch").unwrap();
            let mut buf = [0u8; 7];
            region.read(0, &mut buf).unwrap();
            assert_eq!(&buf, b"scratch");
        }
        assert_eq!(host.module().live_allocs(), 0, "drop freed the region");
        assert_eq!(host.module().leased(), 0, "extent back at the FM");
    }

    #[test]
    fn scoped_region_into_raw_survives() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let a = host.alloc_scoped(dev, PAGE_SIZE).unwrap().into_raw();
        assert_eq!(host.module().live_allocs(), 1, "into_raw defused the guard");
        host.free(dev, a.mmid).unwrap();
        assert_eq!(host.module().live_allocs(), 0);
    }

    #[test]
    fn scoped_region_explicit_free_reports_errors() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let region = host.alloc_scoped(dev, PAGE_SIZE).unwrap();
        region.free().unwrap();
        assert_eq!(host.module().live_allocs(), 0);
    }

    #[test]
    fn io_session_streams_under_one_scoped_lock() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let a = host.alloc(dev, 4 * PAGE_SIZE).unwrap();
        host.with_io_session(a.mmid, |io| {
            assert_eq!(io.mmid(), a.mmid);
            assert_eq!(io.size(), 4 * PAGE_SIZE);
            // stream many ops without re-locking / re-resolving
            for i in 0..64u64 {
                io.write(i * 8, &i.to_le_bytes())?;
            }
            let mut buf = [0u8; 8];
            io.read(63 * 8, &mut buf)?;
            assert_eq!(u64::from_le_bytes(buf), 63);
            // per-op bounds checks still apply
            assert!(io.write(4 * PAGE_SIZE - 2, b"xxxx").is_err());
            assert!(io.read(4 * PAGE_SIZE, &mut buf).is_err());
            assert!(io.write(u64::MAX, b"x").is_err(), "offset overflow caught");
            Ok(())
        })
        .unwrap();
        // scope over: the lock is free and the unbatched path sees the
        // same bytes
        let mut buf = [0u8; 8];
        host.read(a.mmid, 0, &mut buf).unwrap();
        assert_eq!(u64::from_le_bytes(buf), 0);
        host.free(dev, a.mmid).unwrap();
    }

    #[test]
    fn io_session_returns_closure_value() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let a = host.alloc(dev, PAGE_SIZE).unwrap();
        let sum = host
            .with_io_session(a.mmid, |io| {
                io.write(0, &[3, 4])?;
                let mut buf = [0u8; 2];
                io.read(0, &mut buf)?;
                Ok(u64::from(buf[0]) + u64::from(buf[1]))
            })
            .unwrap();
        assert_eq!(sum, 7, "value-returning scoped API");
        host.free(dev, a.mmid).unwrap();
    }

    #[test]
    fn io_session_unknown_mmid_rejected() {
        let mut host = host_with(GIB);
        let res = host.with_io_session(MmId(404), |_io| Ok(()));
        assert!(matches!(res, Err(Error::UnknownMmId(_))));
    }

    #[test]
    fn queued_submissions_complete_on_drain() {
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let t_alloc = host.submit(Request::Alloc { consumer: dev.into(), size: 4 * PAGE_SIZE });
        assert_eq!(host.poll_submission(t_alloc), QueueStatus::Queued);
        assert_eq!(host.module().live_allocs(), 0, "nothing executes before a tick");
        assert_eq!(host.drain_queue(), 1);
        assert_eq!(host.poll_submission(t_alloc), QueueStatus::Ready);
        let a = host.take_completion(t_alloc).unwrap().into_alloc().unwrap();
        assert_eq!(a.size, 4 * PAGE_SIZE);
        assert_eq!(host.poll_submission(t_alloc), QueueStatus::Unknown, "ticket retired");

        // a queued free completes with Outcome::Freed
        let t_free = host.submit(Request::Free { consumer: dev.into(), mmid: a.mmid });
        assert_eq!(host.drain_queue(), 1);
        let c = host.take_completion(t_free).unwrap();
        assert!(matches!(c.result, Ok(Outcome::Freed)));
        assert_eq!(host.module().live_allocs(), 0);
        host.check_invariants().unwrap();
    }

    #[test]
    fn sync_calls_drain_previously_queued_submissions() {
        // the sync surface is submit+drain over the same queue, so a
        // pending queued alloc is serviced (FIFO, before the sync op)
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let t = host.submit(Request::Alloc { consumer: dev.into(), size: PAGE_SIZE });
        let b = host.alloc(dev, PAGE_SIZE).unwrap();
        let a = host.take_completion(t).unwrap().into_alloc().unwrap();
        assert!(a.mmid < b.mmid, "queued submission serviced first");
        assert_eq!(host.module().live_allocs(), 2);
        let stats = host.queue().stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn queued_failure_completes_with_error_not_panic() {
        let mut host = host_with(GIB); // 4 extents
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let ok: Vec<_> = (0..4)
            .map(|_| host.submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE }))
            .collect();
        let doomed = host.submit(Request::Alloc { consumer: dev.into(), size: EXTENT_SIZE });
        host.drain_queue();
        for t in ok {
            assert!(host.take_completion(t).unwrap().result.is_ok());
        }
        let c = host.take_completion(doomed).unwrap();
        assert!(matches!(c.result, Err(Error::OutOfCapacity { .. })), "got {:?}", c.result);
        assert_eq!(host.module().leased(), GIB, "failure did not disturb the group");
        host.check_invariants().unwrap();
    }

    #[test]
    fn placement_policy_is_configurable_per_host() {
        let mut host = host_with(4 * GIB);
        assert_eq!(host.placement_policy(), PlacementPolicy::ContentionAware);
        host.set_placement_policy(PlacementPolicy::FirstFit);
        assert_eq!(host.placement_policy(), PlacementPolicy::FirstFit);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        // first-fit packs from DPA 0 upward
        let a = host.alloc(dev, EXTENT_SIZE).unwrap();
        let b = host.alloc(dev, EXTENT_SIZE).unwrap();
        assert_eq!(a.dpa, Dpa(0));
        assert_eq!(b.dpa, Dpa(EXTENT_SIZE));
    }

    #[test]
    fn alloc_many_rolls_back_on_partial_failure() {
        // 1 GiB expander = 4 extents; a batch of 6 extent-sized requests
        // must fail and leave no residue.
        let mut host = host_with(GIB);
        let dev = Bdf::new(1, 0, 0);
        host.attach_pcie(dev);
        let before = host.with_fm(|fm| fm.available()).unwrap();
        let err = host.alloc_many(dev, &[EXTENT_SIZE; 6]).unwrap_err();
        assert!(matches!(err, Error::OutOfCapacity { .. }), "got {err:?}");
        assert_eq!(host.module().live_allocs(), 0, "partial allocs rolled back");
        assert_eq!(host.module().leased(), 0);
        let after = host.with_fm(|fm| fm.available()).unwrap();
        assert_eq!(after, before, "every extent back at the FM");
        assert_eq!(host.iommu().mapping_count(dev), 0);
        host.check_invariants().unwrap();
        // a batch that fits succeeds afterwards
        let got = host.alloc_many(dev, &[EXTENT_SIZE; 4]).unwrap();
        assert_eq!(got.len(), 4);
    }
}
