//! Deterministic fault injection for the submission plane.
//!
//! A [`FaultPlan`] is a seeded schedule of failures threaded through
//! [`FmService`](crate::lmb::service::FmService): each *fault point*
//! names a place in the schedule→execute pipeline where the plan may
//! strike, and whether it strikes on a given opportunity is a pure
//! function of `(seed, point, opportunity index)` — no clocks, no OS
//! randomness — so a red run replays bit-for-bit from its seed. The
//! scenario engine exposes the same knobs declaratively
//! (`[fault_plan]` in a descriptor) and the CI fault matrix forces one
//! point at a time via `LMB_FAULT_POINT`/`LMB_FAULT_RATE_PPM`.
//!
//! The catalog (see the "Robustness model" section in the crate docs):
//!
//! | point | strikes where | observable outcome |
//! |---|---|---|
//! | `intake_drop` | after scheduling, before dispatch | ticket completes `Err(Cancelled)` |
//! | `mid_group_panic` | halfway through a lane group | tail of the group completes `Err(FabricPoisoned)` |
//! | `expander_nak` | first execution attempt | `Err(ExpanderFailed)`, retried as transient |
//! | `slow_region` | before a group executes | next fabric allocation stalls briefly |
//! | `crash_between` | between schedule and execute | whole group cancelled, host crashed |
//! | `migrate_abort` | mid-copy during live extent migration | migration rolls back to the source placement |

use crate::error::Error;

/// A place in the submission pipeline where a [`FaultPlan`] may strike.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultPoint {
    /// Drop a scheduled submission on the floor (completes cancelled).
    IntakeDrop,
    /// Fail the back half of a lane group as if a worker panicked
    /// mid-batch while holding fabric state (poisoned-then-recovered).
    MidGroupPanic,
    /// NAK the first execution attempt with a transient expander error
    /// (exercises the retry/backoff path end to end).
    ExpanderNak,
    /// Make the next fabric allocation stall briefly (a slow region,
    /// not a failed one — latency fault, not an error).
    SlowRegion,
    /// Crash the group's host between schedule and execute — the
    /// crash-reclaim *race* the scenario ROADMAP item asks for.
    CrashBetween,
    /// Abort a live extent migration mid-copy: the destination carve is
    /// rolled back and the source placement stays authoritative.
    MigrateAbort,
}

impl FaultPoint {
    /// Every declared point, in catalog order. The CI fault matrix
    /// iterates this list; keep it in sync with the enum.
    pub const ALL: [FaultPoint; 6] = [
        FaultPoint::IntakeDrop,
        FaultPoint::MidGroupPanic,
        FaultPoint::ExpanderNak,
        FaultPoint::SlowRegion,
        FaultPoint::CrashBetween,
        FaultPoint::MigrateAbort,
    ];

    /// This point's position in [`FaultPoint::ALL`] — the index of its
    /// slot in the unified telemetry snapshot's
    /// [`fault_strikes_by_point`](crate::observe::StatsSnapshot::fault_strikes_by_point)
    /// array.
    pub fn index(self) -> usize {
        FaultPoint::ALL.iter().position(|p| *p == self).expect("ALL lists every variant")
    }

    /// Stable wire name (descriptors, `LMB_FAULT_POINT`).
    pub fn name(&self) -> &'static str {
        match self {
            FaultPoint::IntakeDrop => "intake_drop",
            FaultPoint::MidGroupPanic => "mid_group_panic",
            FaultPoint::ExpanderNak => "expander_nak",
            FaultPoint::SlowRegion => "slow_region",
            FaultPoint::CrashBetween => "crash_between",
            FaultPoint::MigrateAbort => "migrate_abort",
        }
    }

    /// Parse a wire name back to a point.
    pub fn from_name(s: &str) -> Result<FaultPoint, Error> {
        FaultPoint::ALL
            .iter()
            .copied()
            .find(|p| p.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = FaultPoint::ALL.iter().map(|p| p.name()).collect();
                Error::Config(format!(
                    "unknown fault point '{s}' (expected one of {})",
                    names.join(", ")
                ))
            })
    }

    fn id(&self) -> u64 {
        match self {
            FaultPoint::IntakeDrop => 1,
            FaultPoint::MidGroupPanic => 2,
            FaultPoint::ExpanderNak => 3,
            FaultPoint::SlowRegion => 4,
            FaultPoint::CrashBetween => 5,
            FaultPoint::MigrateAbort => 6,
        }
    }
}

/// Per-point state: enabled rate plus deterministic progress counters.
#[derive(Debug, Clone, Copy, Default)]
struct PointState {
    /// Strike probability in parts-per-million (0 = disabled).
    rate_ppm: u32,
    /// Opportunities seen so far (the deterministic "time" axis).
    seq: u64,
    /// Opportunities that struck.
    strikes: u64,
}

/// A seeded, deterministic schedule of injected faults.
///
/// `strike(point)` advances that point's opportunity counter and
/// returns whether this opportunity fails; the decision hashes
/// `(seed, point id, seq)` through SplitMix64 and compares against the
/// enabled rate, so two plans built with the same seed and rates make
/// identical decisions in the same call order.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    points: [PointState; FaultPoint::ALL.len()],
    /// Remaining host crashes `CrashBetween` may perform. Crashing is
    /// irreversible inside one service, so it is budgeted (default 1)
    /// rather than rate-unbounded — otherwise a high rate kills every
    /// lane and the plan stops observing anything.
    crash_budget: u32,
}

impl FaultPlan {
    /// A plan with every point disabled. Enable points with
    /// [`enable`](Self::enable).
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, points: [PointState::default(); FaultPoint::ALL.len()], crash_budget: 1 }
    }

    /// Enable `point` at `rate_ppm` parts-per-million per opportunity
    /// (1_000_000 = every opportunity strikes).
    pub fn enable(mut self, point: FaultPoint, rate_ppm: u32) -> Self {
        self.points[Self::slot(point)].rate_ppm = rate_ppm.min(1_000_000);
        self
    }

    /// Cap how many hosts [`FaultPoint::CrashBetween`] may crash.
    pub fn with_crash_budget(mut self, budget: u32) -> Self {
        self.crash_budget = budget;
        self
    }

    fn slot(point: FaultPoint) -> usize {
        FaultPoint::ALL.iter().position(|p| *p == point).expect("point is in ALL")
    }

    /// SplitMix64 finalizer — the same zero-dependency mixer the DES
    /// core uses for stream splitting.
    fn mix(mut z: u64) -> u64 {
        z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Present one opportunity at `point`; returns true if it strikes.
    /// Deterministic in `(seed, point, call index)`; a disabled point
    /// still advances its counter so enabling it later in a re-run
    /// does not shift other points' decisions.
    pub fn strike(&mut self, point: FaultPoint) -> bool {
        let slot = Self::slot(point);
        let seq = self.points[slot].seq;
        self.points[slot].seq += 1;
        let rate = self.points[slot].rate_ppm;
        if rate == 0 {
            return false;
        }
        if point == FaultPoint::CrashBetween && self.crash_budget == 0 {
            return false;
        }
        let h = Self::mix(self.seed ^ point.id().wrapping_mul(0xa076_1d64_78bd_642f) ^ seq);
        let hit = (h % 1_000_000) < rate as u64;
        if hit {
            self.points[slot].strikes += 1;
            if point == FaultPoint::CrashBetween {
                self.crash_budget -= 1;
            }
        }
        hit
    }

    /// Total strikes across all points (for "fault actually fired"
    /// asserts in the matrix tests).
    pub fn strikes(&self) -> u64 {
        self.points.iter().map(|p| p.strikes).sum()
    }

    /// Strikes for one point.
    pub fn strikes_at(&self, point: FaultPoint) -> u64 {
        self.points[Self::slot(point)].strikes
    }

    /// Opportunities presented to one point (struck or not).
    pub fn opportunities_at(&self, point: FaultPoint) -> u64 {
        self.points[Self::slot(point)].seq
    }
}

/// Bounded deterministic retry policy for transient failures inside
/// `FmService` (see [`Error::is_transient`]).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total execution attempts per submission (1 = no retry).
    pub max_attempts: u32,
    /// Backoff between attempts, in scheduler yields: attempt `k`
    /// (0-based retry index) backs off `base << k` yields, capped.
    /// Jitter-free by design — backoff is part of the deterministic
    /// replay, not an entropy source.
    pub backoff_base: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_base: 4 }
    }
}

impl RetryPolicy {
    /// Backoff (in yields) before 0-based retry `k`, capped at 4096.
    pub fn backoff_yields(&self, k: u32) -> u32 {
        // Widen before shifting: `u32 << 30` silently drops bits, which
        // would wrap a large backoff back to zero instead of capping.
        let shifted = (self.backoff_base as u64) << k.min(32);
        shifted.min(4096) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_reject_unknowns() {
        for p in FaultPoint::ALL {
            assert_eq!(FaultPoint::from_name(p.name()).unwrap(), p);
        }
        let err = FaultPoint::from_name("warp_core_breach").unwrap_err();
        assert!(err.to_string().contains("unknown fault point"));
        assert!(err.to_string().contains("intake_drop"));
    }

    #[test]
    fn same_seed_same_decisions() {
        let mut a = FaultPlan::new(0xfa17).enable(FaultPoint::ExpanderNak, 250_000);
        let mut b = FaultPlan::new(0xfa17).enable(FaultPoint::ExpanderNak, 250_000);
        let da: Vec<bool> = (0..256).map(|_| a.strike(FaultPoint::ExpanderNak)).collect();
        let db: Vec<bool> = (0..256).map(|_| b.strike(FaultPoint::ExpanderNak)).collect();
        assert_eq!(da, db);
        assert!(a.strikes() > 0, "a 25% rate over 256 opportunities must strike");
        assert!(a.strikes() < 256, "and must not strike every time");
        assert_eq!(a.strikes_at(FaultPoint::ExpanderNak), a.strikes());
        assert_eq!(a.opportunities_at(FaultPoint::ExpanderNak), 256);
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = FaultPlan::new(1).enable(FaultPoint::IntakeDrop, 500_000);
        let mut b = FaultPlan::new(2).enable(FaultPoint::IntakeDrop, 500_000);
        let da: Vec<bool> = (0..128).map(|_| a.strike(FaultPoint::IntakeDrop)).collect();
        let db: Vec<bool> = (0..128).map(|_| b.strike(FaultPoint::IntakeDrop)).collect();
        assert_ne!(da, db, "distinct seeds should disagree somewhere in 128 draws");
    }

    #[test]
    fn disabled_points_never_strike_but_still_count() {
        let mut plan = FaultPlan::new(7);
        for _ in 0..64 {
            assert!(!plan.strike(FaultPoint::SlowRegion));
        }
        assert_eq!(plan.opportunities_at(FaultPoint::SlowRegion), 64);
        assert_eq!(plan.strikes(), 0);
    }

    #[test]
    fn crash_budget_caps_crash_between() {
        let mut plan =
            FaultPlan::new(3).enable(FaultPoint::CrashBetween, 1_000_000).with_crash_budget(2);
        let strikes: usize =
            (0..32).map(|_| plan.strike(FaultPoint::CrashBetween) as usize).sum();
        assert_eq!(strikes, 2, "budget of 2 at a certain rate strikes exactly twice");
        // Other points are not budgeted.
        let mut plan = FaultPlan::new(3).enable(FaultPoint::IntakeDrop, 1_000_000);
        let strikes: usize = (0..32).map(|_| plan.strike(FaultPoint::IntakeDrop) as usize).sum();
        assert_eq!(strikes, 32);
    }

    #[test]
    fn rate_extremes_behave() {
        let mut always = FaultPlan::new(9).enable(FaultPoint::MidGroupPanic, 1_000_000);
        assert!((0..64).all(|_| always.strike(FaultPoint::MidGroupPanic)));
        let mut never = FaultPlan::new(9).enable(FaultPoint::MidGroupPanic, 0);
        assert!((0..64).all(|_| !never.strike(FaultPoint::MidGroupPanic)));
    }

    #[test]
    fn backoff_is_bounded_and_monotone() {
        let p = RetryPolicy::default();
        assert_eq!(p.backoff_yields(0), 4);
        assert_eq!(p.backoff_yields(1), 8);
        assert_eq!(p.backoff_yields(10), 4096, "cap holds");
        assert_eq!(p.backoff_yields(31), 4096, "shift overflow saturates to the cap");
        let widths: Vec<u32> = (0..12).map(|k| p.backoff_yields(k)).collect();
        assert!(widths.windows(2).all(|w| w[0] <= w[1]));
    }
}
