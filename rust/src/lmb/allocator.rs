//! LMB sub-allocator (§3.2 "Memory allocator").
//!
//! The kernel module leases 256 MB extents from the FM and sub-allocates
//! them to devices at 4 KiB granularity. All allocator metadata lives on
//! the host ("we keep the memory allocator metadata in the host … avoid
//! triggering multiple CXL memory accesses") — in this model, plain Rust
//! structures, never the expander backing store.
//!
//! Policy: first-fit over per-extent free lists with coalescing on free.
//! Each extent caches its largest free run, so placement skips extents
//! that cannot fit a request in O(1) instead of probing their free
//! lists (the old probe-every-extent scan survives as a bench/test
//! oracle in [`crate::testing::oracle`]). When an extent drains to
//! fully-free it is reported so the module can release it to the FM
//! ("When all device memory in a memory block has been freed, the
//! kernel module releases the area to FM").
//!
//! Extents are identified by stable [`ExtentId`]s: releasing one extent
//! never invalidates placements held in any other extent, so callers keep
//! their [`Placement`] handles across arbitrary free patterns (the old
//! positional `extent_idx` scheme forced a rebasing sweep over every live
//! allocation on each extent release).

use std::collections::BTreeMap;

use crate::cxl::fm::Extent;
use crate::cxl::types::{align_up, Dpa, Hpa, Range, PAGE_SIZE};
use crate::error::{Error, Result};

/// Stable identity of a leased extent within one allocator.
///
/// Ids are never reused and survive the release of other extents, unlike
/// a positional index into the extent list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ExtentId(pub u64);

/// A leased extent plus its host mapping and free list.
#[derive(Debug)]
pub struct ExtentState {
    pub extent: Extent,
    /// HPA where this extent's HDM window was placed.
    pub hpa_base: Hpa,
    /// Free offsets within the extent (sorted, coalesced).
    free: Vec<Range>,
    pub used: u64,
    /// Cached length of the largest free run. Lets
    /// [`SubAllocator::alloc`] reject an extent that cannot fit a
    /// request in O(1) instead of probing its whole free list.
    largest_free: u64,
}

impl ExtentState {
    pub fn new(extent: Extent, hpa_base: Hpa) -> Self {
        let free = vec![Range::new(0, extent.len)];
        ExtentState { extent, hpa_base, free, used: 0, largest_free: extent.len }
    }

    fn alloc(&mut self, len: u64) -> Option<u64> {
        let pos = self.free.iter().position(|r| r.len >= len)?;
        let r = self.free[pos];
        if r.len == len {
            self.free.remove(pos);
        } else {
            self.free[pos] = Range::new(r.base + len, r.len - len);
        }
        self.used += len;
        // only carving the (unique-length or not) largest run can lower
        // the cached maximum; smaller runs leave it untouched
        if r.len == self.largest_free {
            self.largest_free = self.free.iter().map(|f| f.len).max().unwrap_or(0);
        }
        Some(r.base)
    }

    fn free(&mut self, offset: u64, len: u64) {
        let mut r = Range::new(offset, len);
        let idx = self.free.partition_point(|f| f.base < r.base);
        if idx < self.free.len() && r.end() == self.free[idx].base {
            r = Range::new(r.base, r.len + self.free[idx].len);
            self.free.remove(idx);
        }
        if idx > 0 && self.free[idx - 1].end() == r.base {
            let prev = self.free[idx - 1];
            let merged = Range::new(prev.base, prev.len + r.len);
            self.free[idx - 1] = merged;
            r = merged;
        } else {
            self.free.insert(idx, r);
        }
        self.used -= len;
        // freeing only ever grows or merges runs, so the new run is the
        // sole candidate for a larger maximum — O(1) maintenance
        self.largest_free = self.largest_free.max(r.len);
    }

    pub fn is_empty(&self) -> bool {
        self.used == 0
    }

    /// Largest free run (fragmentation observability; cached, O(1)).
    pub fn largest_free(&self) -> u64 {
        self.largest_free
    }
}

/// A placed sub-allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Stable id of the extent holding this placement.
    pub extent: ExtentId,
    /// Byte offset within the extent.
    pub offset: u64,
    /// Rounded-up length.
    pub len: u64,
    pub dpa: Dpa,
    pub hpa: Hpa,
}

/// The module-level allocator over all leased extents.
#[derive(Debug, Default)]
pub struct SubAllocator {
    /// Keyed by stable id; iteration order == adoption order, so
    /// first-fit behaviour matches the old positional scheme.
    extents: BTreeMap<ExtentId, ExtentState>,
    next_id: u64,
}

impl SubAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt a freshly leased extent (already HDM-mapped at `hpa_base`).
    pub fn adopt(&mut self, extent: Extent, hpa_base: Hpa) -> ExtentId {
        let id = ExtentId(self.next_id);
        self.next_id += 1;
        self.extents.insert(id, ExtentState::new(extent, hpa_base));
        id
    }

    /// Try to place `size` bytes (rounded to pages) in any leased extent.
    /// First-fit in adoption order, but extents whose cached
    /// `largest_free` cannot fit the request are skipped in O(1) —
    /// fragmented or full extents no longer cost a free-list probe each.
    pub fn alloc(&mut self, size: u64) -> Option<Placement> {
        let len = align_up(size.max(1), PAGE_SIZE);
        for (&id, st) in self.extents.iter_mut() {
            if st.largest_free < len {
                continue;
            }
            if let Some(off) = st.alloc(len) {
                return Some(Placement {
                    extent: id,
                    offset: off,
                    len,
                    dpa: Dpa(st.extent.dpa.0 + off),
                    hpa: Hpa(st.hpa_base.0 + off),
                });
            }
        }
        None
    }

    /// Free a placement; returns `Ok(Some(id))` when that extent is now
    /// fully free (caller should release it to the FM), and
    /// [`Error::StalePlacement`] when the placement references an extent
    /// this allocator no longer tracks — a stale handle is a reportable
    /// error, not an abort.
    pub fn free(&mut self, p: Placement) -> Result<Option<ExtentId>> {
        let st = self
            .extents
            .get_mut(&p.extent)
            .ok_or(Error::StalePlacement { extent: p.extent.0 })?;
        st.free(p.offset, p.len);
        Ok(st.is_empty().then_some(p.extent))
    }

    /// Drop a (fully free) extent from tracking, returning it — `None`
    /// if `id` is not (or no longer) tracked. Every other extent keeps
    /// its id, so live placements stay valid.
    pub fn remove_extent(&mut self, id: ExtentId) -> Option<ExtentState> {
        self.extents.remove(&id)
    }

    /// Look up one extent's state.
    pub fn extent(&self, id: ExtentId) -> Option<&ExtentState> {
        self.extents.get(&id)
    }

    /// All leased extents in adoption order.
    pub fn extents(&self) -> impl Iterator<Item = (ExtentId, &ExtentState)> {
        self.extents.iter().map(|(&id, st)| (id, st))
    }

    /// Number of leased extents.
    pub fn extent_count(&self) -> usize {
        self.extents.len()
    }

    /// Total leased / used bytes.
    pub fn leased(&self) -> u64 {
        self.extents.values().map(|e| e.extent.len).sum()
    }

    pub fn used(&self) -> u64 {
        self.extents.values().map(|e| e.used).sum()
    }

    /// Invariant check for property tests: free lists sorted, coalesced,
    /// within bounds, used+free == extent length, and the cached
    /// `largest_free` agreeing with the actual free list.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, st) in self.extents.iter() {
            let i = id.0;
            let mut prev_end: Option<u64> = None;
            let mut free_total = 0;
            let mut largest = 0;
            for r in &st.free {
                if r.end() > st.extent.len {
                    return Err(format!("extent {i}: free range beyond extent"));
                }
                if let Some(pe) = prev_end {
                    if r.base < pe {
                        return Err(format!("extent {i}: free list overlap"));
                    }
                    if r.base == pe {
                        return Err(format!("extent {i}: free list not coalesced"));
                    }
                }
                prev_end = Some(r.end());
                free_total += r.len;
                largest = largest.max(r.len);
            }
            if free_total + st.used != st.extent.len {
                return Err(format!(
                    "extent {i}: leak (free {free_total} + used {} != {})",
                    st.used, st.extent.len
                ));
            }
            if largest != st.largest_free {
                return Err(format!(
                    "extent {i}: largest_free drift (cached {}, actual {largest})",
                    st.largest_free
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::fm::HostId;
    use crate::cxl::types::{EXTENT_SIZE, GIB};

    fn extent(base: u64) -> Extent {
        Extent { dpa: Dpa(base), len: EXTENT_SIZE, owner: HostId(0) }
    }

    #[test]
    fn alloc_rounds_to_pages_and_translates() {
        let mut a = SubAllocator::new();
        a.adopt(extent(0), Hpa(4 * GIB));
        let p = a.alloc(100).unwrap();
        assert_eq!(p.len, PAGE_SIZE);
        assert_eq!(p.dpa, Dpa(0));
        assert_eq!(p.hpa, Hpa(4 * GIB));
        let q = a.alloc(PAGE_SIZE + 1).unwrap();
        assert_eq!(q.len, 2 * PAGE_SIZE);
        assert_eq!(q.offset, PAGE_SIZE);
        a.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = SubAllocator::new();
        a.adopt(extent(0), Hpa(4 * GIB));
        assert!(a.alloc(EXTENT_SIZE).is_some());
        assert!(a.alloc(PAGE_SIZE).is_none());
    }

    #[test]
    fn free_coalesces_and_reports_empty() {
        let mut a = SubAllocator::new();
        let id = a.adopt(extent(0), Hpa(4 * GIB));
        let p1 = a.alloc(PAGE_SIZE).unwrap();
        let p2 = a.alloc(PAGE_SIZE).unwrap();
        let p3 = a.alloc(PAGE_SIZE).unwrap();
        assert_eq!(a.free(p1).unwrap(), None);
        assert_eq!(a.free(p3).unwrap(), None);
        assert_eq!(a.free(p2).unwrap(), Some(id), "middle free drains the extent");
        a.check_invariants().unwrap();
        assert_eq!(a.extent(id).unwrap().largest_free(), EXTENT_SIZE);
        // after coalescing, a full-extent allocation fits again
        assert!(a.alloc(EXTENT_SIZE).is_some());
    }

    #[test]
    fn spans_multiple_extents() {
        let mut a = SubAllocator::new();
        a.adopt(extent(0), Hpa(4 * GIB));
        a.adopt(extent(EXTENT_SIZE), Hpa(5 * GIB));
        let p1 = a.alloc(EXTENT_SIZE).unwrap();
        let p2 = a.alloc(EXTENT_SIZE).unwrap();
        assert_ne!(p1.extent, p2.extent);
        assert_eq!(p2.hpa, Hpa(5 * GIB));
        assert_eq!(a.used(), 2 * EXTENT_SIZE);
    }

    #[test]
    fn extent_ids_stable_across_removal() {
        // The regression the ExtentId refactor fixes for good: releasing
        // one extent must leave placements in every other extent valid
        // without any index rebasing.
        let mut a = SubAllocator::new();
        let id0 = a.adopt(extent(0), Hpa(4 * GIB));
        let id1 = a.adopt(extent(EXTENT_SIZE), Hpa(5 * GIB));
        let p0 = a.alloc(EXTENT_SIZE).unwrap();
        let p1 = a.alloc(PAGE_SIZE).unwrap();
        assert_eq!(p0.extent, id0);
        assert_eq!(p1.extent, id1);
        // drain and drop the first extent
        assert_eq!(a.free(p0).unwrap(), Some(id0));
        let st = a.remove_extent(id0).unwrap();
        assert_eq!(st.hpa_base, Hpa(4 * GIB));
        // p1's id still resolves, and freeing through it still works
        assert!(a.extent(p1.extent).is_some());
        assert_eq!(a.free(p1).unwrap(), Some(id1));
        a.check_invariants().unwrap();
        // a newly adopted extent gets a fresh id, never a recycled one
        let id2 = a.adopt(extent(2 * EXTENT_SIZE), Hpa(6 * GIB));
        assert!(id2 > id1);
    }

    #[test]
    fn property_random_alloc_free_preserves_invariants() {
        use crate::sim::rng::Pcg64;
        let mut rng = Pcg64::new(0xa110c);
        let mut a = SubAllocator::new();
        a.adopt(extent(0), Hpa(4 * GIB));
        a.adopt(extent(EXTENT_SIZE), Hpa(5 * GIB));
        let mut live: Vec<Placement> = Vec::new();
        for _ in 0..2000 {
            if rng.chance(0.6) || live.is_empty() {
                let sz = (rng.next_below(64) + 1) * PAGE_SIZE;
                if let Some(p) = a.alloc(sz) {
                    // no overlap with any live placement
                    for q in &live {
                        let pr = Range::new(p.dpa.0, p.len);
                        let qr = Range::new(q.dpa.0, q.len);
                        assert!(!pr.overlaps(&qr), "overlapping placements");
                    }
                    live.push(p);
                }
            } else {
                let i = rng.next_below(live.len() as u64) as usize;
                let p = live.swap_remove(i);
                a.free(p).unwrap();
            }
            a.check_invariants().unwrap();
        }
    }

    #[test]
    fn stale_placement_is_an_error_not_an_abort() {
        let mut a = SubAllocator::new();
        let id = a.adopt(extent(0), Hpa(4 * GIB));
        let p = a.alloc(PAGE_SIZE).unwrap();
        assert_eq!(a.free(p).unwrap(), Some(id), "extent drained");
        let st = a.remove_extent(id).unwrap();
        assert_eq!(st.extent.dpa, Dpa(0));
        // the extent is gone: freeing through the stale handle reports
        assert!(matches!(a.free(p), Err(Error::StalePlacement { extent }) if extent == id.0));
        // and a double remove is a None, not a panic
        assert!(a.remove_extent(id).is_none());
        a.check_invariants().unwrap();
    }

    #[test]
    fn largest_free_cache_tracks_churn_and_skips_full_extents() {
        let mut a = SubAllocator::new();
        let id0 = a.adopt(extent(0), Hpa(4 * GIB));
        a.adopt(extent(EXTENT_SIZE), Hpa(5 * GIB));
        // fill extent 0 completely; its cached largest_free must be 0
        let big = a.alloc(EXTENT_SIZE).unwrap();
        assert_eq!(big.extent, id0);
        assert_eq!(a.extent(id0).unwrap().largest_free(), 0);
        // small allocations skip the full extent and land in extent 1
        let small = a.alloc(PAGE_SIZE).unwrap();
        assert_ne!(small.extent, id0);
        a.check_invariants().unwrap();
        // carving and returning runs keeps the cache exact (checked
        // against the real free list by check_invariants)
        let q = a.alloc(3 * PAGE_SIZE).unwrap();
        a.free(small).unwrap();
        a.check_invariants().unwrap();
        a.free(q).unwrap();
        a.free(big).unwrap();
        a.check_invariants().unwrap();
        assert_eq!(a.extent(id0).unwrap().largest_free(), EXTENT_SIZE);
    }
}
