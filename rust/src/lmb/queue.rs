//! Queued allocation (§3.2 at fleet scale): submissions, completions,
//! and deterministic tick-driven scheduling over the shared FM.
//!
//! The paper's allocator API is synchronous per host, but its
//! scalability story has many devices' allocation traffic contending on
//! one Fabric Manager. [`AllocQueue`] turns that contention point into
//! a scheduling point:
//!
//! * **Submission** — [`AllocQueue::submit`] enqueues a [`Request`]
//!   (alloc / free / share) on a *lane* (one lane per host slot) and
//!   returns a [`Ticket`] immediately; nothing touches the fabric yet.
//! * **Scheduling** — [`AllocQueue::schedule`] pops up to a per-lane
//!   quota of requests per tick, visiting lanes in rotating order so
//!   every host makes progress (no lane can starve a sibling). The
//!   schedule is a pure function of the submission history — no clock,
//!   no RNG — so queued tests replay deterministically from a seeded
//!   request stream.
//! * **Execution** — the queue owner (an
//!   [`LmbHost`](crate::lmb::LmbHost) for its own lane, the
//!   [`Cluster`](crate::cluster::Cluster) across slots) executes each
//!   scheduled group under a **single fabric lock** via
//!   [`LmbHost::execute_requests`](crate::lmb::LmbHost::execute_requests)
//!   — the same single-lock batch entry `alloc_many` established — and
//!   posts a [`Completion`] per ticket back with
//!   [`AllocQueue::complete`].
//! * **Completion** — callers observe progress with
//!   [`AllocQueue::poll`] and claim results with [`AllocQueue::take`]
//!   (tickets are single-use: once taken, a ticket is gone).
//!
//! Placement is where the contention model bites: each executing host
//! carries a [`PlacementPolicy`], and under
//! [`PlacementPolicy::ContentionAware`] the FM prices every candidate
//! carve point with the coordinator's queueing cost model and spreads
//! extents across placement regions (falling back to first-fit on
//! ties). The synchronous `alloc`/`free`/`share` surfaces are one-shot
//! submit + drain over this queue, so there is exactly one allocation
//! code path whether callers are synchronous or queued.
//!
//! When a host crashes, its lane is cancelled
//! ([`AllocQueue::cancel_lane`]): queued-but-unscheduled submissions
//! complete with [`Error::Cancelled`] instead of leaking tickets or
//! executing against reclaimed leases.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::cxl::types::MmId;
use crate::error::{Error, Result};
use crate::lmb::{Consumer, LmbAlloc};

pub use crate::cxl::fm::PlacementPolicy;

/// Default per-lane quota a drain tick schedules (see
/// [`AllocQueue::schedule`]).
pub const DEFAULT_LANE_QUOTA: usize = 16;

/// Completion handle returned by [`AllocQueue::submit`]. Single-use:
/// taking the completion retires the ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(pub u64);

/// One queued control-plane operation.
#[derive(Debug, Clone)]
pub enum Request {
    /// Allocate `size` bytes for `consumer` (→ [`Outcome::Alloc`]).
    Alloc { consumer: Consumer, size: u64 },
    /// Free `mmid`, which must be owned by `consumer` (→ [`Outcome::Freed`]).
    Free { consumer: Consumer, mmid: MmId },
    /// Owner-authorised zero-copy share (→ [`Outcome::Shared`]).
    Share { owner: Consumer, target: Consumer, mmid: MmId },
}

impl Request {
    /// The mmid an already-live allocation this request operates on, if
    /// any — the cluster router checks its home host before dispatch.
    pub fn target_mmid(&self) -> Option<MmId> {
        match self {
            Request::Alloc { .. } => None,
            Request::Free { mmid, .. } | Request::Share { mmid, .. } => Some(*mmid),
        }
    }
}

/// Successful result of a serviced [`Request`].
#[derive(Debug, Clone, Copy)]
pub enum Outcome {
    Alloc(LmbAlloc),
    Freed,
    Shared(LmbAlloc),
}

impl Outcome {
    /// Unwrap the allocation handle an alloc/share outcome carries (the
    /// common case for synchronous callers).
    pub fn into_alloc(self) -> Result<LmbAlloc> {
        match self {
            Outcome::Alloc(a) | Outcome::Shared(a) => Ok(a),
            Outcome::Freed => Err(Error::FabricManager(
                "completion carried a free outcome, not an allocation".into(),
            )),
        }
    }
}

/// A serviced (or cancelled) submission, claimed via
/// [`AllocQueue::take`].
#[derive(Debug)]
pub struct Completion {
    pub ticket: Ticket,
    /// Lane (host slot) the submission was routed on.
    pub lane: usize,
    pub result: Result<Outcome>,
}

impl Completion {
    /// Whether this submission was cancelled (lane drained on host
    /// crash) rather than executed.
    pub fn is_cancelled(&self) -> bool {
        matches!(self.result, Err(Error::Cancelled { .. }))
    }

    /// Unwrap an allocation outcome (the common case for sync callers).
    pub fn into_alloc(self) -> Result<LmbAlloc> {
        self.result?.into_alloc()
    }
}

/// Where a ticket currently is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueStatus {
    /// Submitted, not yet scheduled.
    Queued,
    /// Popped by [`AllocQueue::schedule`], completion not yet posted
    /// (only observable between a manual `schedule` and `complete`).
    InFlight,
    /// Completion ready to [`AllocQueue::take`].
    Ready,
    /// Cancelled by [`AllocQueue::cancel_lane`]; `take` yields the
    /// [`Error::Cancelled`] completion.
    Cancelled,
    /// Never submitted, or already taken.
    Unknown,
}

/// Lifetime counters (observability; also what the ablation reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub submitted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub ticks: u64,
}

/// A scheduled request handed to the executor for one tick.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub ticket: Ticket,
    pub lane: usize,
    pub request: Request,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EntryState {
    Queued,
    InFlight,
}

/// The queued-allocation scheduler. See the module docs for the
/// submission → schedule → execute → complete lifecycle.
#[derive(Debug, Default)]
pub struct AllocQueue {
    /// Per-lane FIFOs, keyed by lane id (sorted, so rotation order is
    /// deterministic). Empty lanes are removed eagerly.
    lanes: BTreeMap<usize, VecDeque<(Ticket, Request)>>,
    /// Lifecycle of every ticket not yet completed.
    states: HashMap<u64, EntryState>,
    /// Posted completions awaiting [`AllocQueue::take`].
    completions: HashMap<u64, Completion>,
    next_ticket: u64,
    /// First lane the next tick serves (rotates for fairness).
    rr_start: usize,
    stats: QueueStats,
}

impl AllocQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue `request` on `lane`; returns its completion handle.
    pub fn submit(&mut self, lane: usize, request: Request) -> Ticket {
        let ticket = Ticket(self.next_ticket);
        self.next_ticket += 1;
        self.lanes.entry(lane).or_default().push_back((ticket, request));
        self.states.insert(ticket.0, EntryState::Queued);
        self.stats.submitted += 1;
        ticket
    }

    /// Pop one tick's worth of work: up to `quota` requests per lane,
    /// lanes visited in ascending order starting from the rotation
    /// cursor. Each lane's pops stay contiguous in the returned batch so
    /// the executor can service a whole lane group under one fabric
    /// lock. Deterministic: identical submission histories produce
    /// identical schedules.
    pub fn schedule(&mut self, quota: usize) -> Vec<Scheduled> {
        if self.lanes.is_empty() || quota == 0 {
            return Vec::new();
        }
        // rotation: lanes >= cursor first, then wrap around
        let order: Vec<usize> = {
            let after: Vec<usize> = self.lanes.range(self.rr_start..).map(|(&l, _)| l).collect();
            let before: Vec<usize> = self.lanes.range(..self.rr_start).map(|(&l, _)| l).collect();
            after.into_iter().chain(before).collect()
        };
        let mut batch = Vec::new();
        for lane in &order {
            let queue = self.lanes.get_mut(lane).expect("lane listed but missing");
            for _ in 0..quota {
                match queue.pop_front() {
                    Some((ticket, request)) => {
                        self.states.insert(ticket.0, EntryState::InFlight);
                        batch.push(Scheduled { ticket, lane: *lane, request });
                    }
                    None => break,
                }
            }
            if queue.is_empty() {
                self.lanes.remove(lane);
            }
        }
        // next tick starts after the lane served first this tick
        if let Some(&first) = order.first() {
            self.rr_start = first + 1;
        }
        self.stats.ticks += 1;
        batch
    }

    /// Post the result of a scheduled request.
    pub fn complete(&mut self, completion: Completion) {
        let ticket = completion.ticket;
        if completion.is_cancelled() {
            self.stats.cancelled += 1;
        } else {
            self.stats.completed += 1;
        }
        self.states.remove(&ticket.0);
        self.completions.insert(ticket.0, completion);
    }

    /// Drop every queued-but-unscheduled submission on `lane`, posting
    /// an [`Error::Cancelled`] completion for each so no ticket is left
    /// dangling. Returns how many were cancelled. The cluster's host
    /// crash path calls this before releasing the host's leases.
    pub fn cancel_lane(&mut self, lane: usize) -> usize {
        let Some(queue) = self.lanes.remove(&lane) else {
            return 0;
        };
        let n = queue.len();
        for (ticket, _) in queue {
            self.states.remove(&ticket.0);
            self.completions.insert(
                ticket.0,
                Completion { ticket, lane, result: Err(Error::Cancelled { ticket: ticket.0 }) },
            );
            self.stats.cancelled += 1;
        }
        n
    }

    /// Where `ticket` is in its lifecycle.
    pub fn poll(&self, ticket: Ticket) -> QueueStatus {
        if let Some(c) = self.completions.get(&ticket.0) {
            if c.is_cancelled() {
                return QueueStatus::Cancelled;
            }
            return QueueStatus::Ready;
        }
        match self.states.get(&ticket.0) {
            Some(EntryState::Queued) => QueueStatus::Queued,
            Some(EntryState::InFlight) => QueueStatus::InFlight,
            None => QueueStatus::Unknown,
        }
    }

    /// Claim a completion; the ticket is retired. `None` while still
    /// queued/in-flight (poll first) or if the ticket is unknown.
    pub fn take(&mut self, ticket: Ticket) -> Option<Completion> {
        self.completions.remove(&ticket.0)
    }

    /// Submissions not yet scheduled (across all lanes).
    pub fn pending(&self) -> usize {
        self.lanes.values().map(VecDeque::len).sum()
    }

    /// Submissions not yet scheduled on one lane.
    pub fn pending_on(&self, lane: usize) -> usize {
        self.lanes.get(&lane).map_or(0, VecDeque::len)
    }

    /// Completions posted but not yet taken.
    pub fn ready(&self) -> usize {
        self.completions.len()
    }

    pub fn stats(&self) -> QueueStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::types::{Bdf, PAGE_SIZE};

    fn alloc_req(pages: u64) -> Request {
        Request::Alloc { consumer: Consumer::Pcie(Bdf::new(1, 0, 0)), size: pages * PAGE_SIZE }
    }

    #[test]
    fn submit_poll_take_lifecycle() {
        let mut q = AllocQueue::new();
        let t = q.submit(0, alloc_req(1));
        assert_eq!(q.poll(t), QueueStatus::Queued);
        assert_eq!(q.pending(), 1);
        let batch = q.schedule(8);
        assert_eq!(batch.len(), 1);
        assert_eq!(q.poll(t), QueueStatus::InFlight);
        q.complete(Completion { ticket: t, lane: 0, result: Ok(Outcome::Freed) });
        assert_eq!(q.poll(t), QueueStatus::Ready);
        let c = q.take(t).unwrap();
        assert_eq!(c.ticket, t);
        assert_eq!(q.poll(t), QueueStatus::Unknown, "tickets are single-use");
        assert!(q.take(t).is_none());
        let s = q.stats();
        assert_eq!((s.submitted, s.completed, s.cancelled, s.ticks), (1, 1, 0, 1));
    }

    #[test]
    fn schedule_is_fair_across_lanes_and_rotates() {
        let mut q = AllocQueue::new();
        // lane 0 floods; lane 1 submits two
        let heavy: Vec<Ticket> = (0..6).map(|_| q.submit(0, alloc_req(1))).collect();
        let light: Vec<Ticket> = (0..2).map(|_| q.submit(1, alloc_req(1))).collect();
        // quota 2: both lanes progress every tick — the flood cannot
        // starve the light lane
        let b1 = q.schedule(2);
        let lanes1: Vec<usize> = b1.iter().map(|s| s.lane).collect();
        assert_eq!(lanes1, [0, 0, 1, 1], "lane groups contiguous, both served");
        assert!(b1.iter().any(|s| s.ticket == light[0]));
        // rotation: the next tick starts at lane 1 (empty now) → lane 0
        let b2 = q.schedule(2);
        assert_eq!(b2.len(), 2);
        assert!(b2.iter().all(|s| s.lane == 0));
        let b3 = q.schedule(2);
        assert_eq!(b3.len(), 2);
        assert_eq!(q.pending(), 0);
        assert!(q.schedule(2).is_empty());
        let _ = heavy;
    }

    #[test]
    fn rotation_starts_later_lanes_first_on_the_next_tick() {
        let mut q = AllocQueue::new();
        for lane in 0..3 {
            q.submit(lane, alloc_req(1));
            q.submit(lane, alloc_req(1));
        }
        let b1 = q.schedule(1);
        assert_eq!(b1.iter().map(|s| s.lane).collect::<Vec<_>>(), [0, 1, 2]);
        // cursor moved past lane 0: the wrap order is now 1, 2, 0
        let b2 = q.schedule(1);
        assert_eq!(b2.iter().map(|s| s.lane).collect::<Vec<_>>(), [1, 2, 0]);
    }

    #[test]
    fn deterministic_schedules_for_identical_histories() {
        let drive = || {
            let mut q = AllocQueue::new();
            for i in 0..12u64 {
                q.submit((i % 3) as usize, alloc_req(i + 1));
            }
            let mut order = Vec::new();
            loop {
                let batch = q.schedule(2);
                if batch.is_empty() {
                    break;
                }
                order.extend(batch.into_iter().map(|s| (s.lane, s.ticket.0)));
            }
            order
        };
        assert_eq!(drive(), drive());
    }

    #[test]
    fn cancel_lane_completes_queued_submissions_as_cancelled() {
        let mut q = AllocQueue::new();
        let doomed: Vec<Ticket> = (0..3).map(|_| q.submit(4, alloc_req(1))).collect();
        let survivor = q.submit(5, alloc_req(1));
        assert_eq!(q.cancel_lane(4), 3);
        assert_eq!(q.cancel_lane(4), 0, "idempotent");
        for t in doomed {
            assert_eq!(q.poll(t), QueueStatus::Cancelled);
            let c = q.take(t).unwrap();
            assert!(c.is_cancelled());
            assert!(matches!(c.result, Err(Error::Cancelled { ticket }) if ticket == t.0));
        }
        assert_eq!(q.poll(survivor), QueueStatus::Queued, "sibling lane untouched");
        assert_eq!(q.stats().cancelled, 3);
        assert_eq!(q.pending(), 1);
    }

    #[test]
    fn zero_quota_schedules_nothing() {
        let mut q = AllocQueue::new();
        let t = q.submit(0, alloc_req(1));
        assert!(q.schedule(0).is_empty());
        assert_eq!(q.poll(t), QueueStatus::Queued);
    }
}
